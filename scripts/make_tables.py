#!/usr/bin/env python3
"""Format a `sclap report` JSON document as paper-style result tables.

Usage:
    make_tables.py [--require-preset NAME]... [REPORT.json]

Reads the document `sclap report` emits (stdout or ``--out FILE``),
schema-checks it, and prints two tables in the style of the evaluation
section of arXiv 1402.3281 ("Partitioning Complex Networks via
Size-constrained Clustering"): the per-preset geometric means across
the instance family (the paper's headline aggregation), and the full
preset x instance cell matrix behind them.

Schema (producer: `cmd_report` in `rust/src/main.rs`):

  * top level: integer ``k`` (>= 2), ``reps`` (>= 1) and ``seed``,
    non-empty string arrays ``presets`` and ``instances``, and arrays
    ``cells`` and ``geomeans``;
  * ``cells`` holds exactly one entry per (preset, instance) pair, each
    with non-negative ``avg_cut``/``seconds``, an integer ``best_cut``
    <= ``avg_cut``, ``infeasible`` in [0, reps] and ``reps`` matching
    the top level;
  * ``geomeans`` holds exactly one entry per preset (same order as
    ``presets``) with non-negative ``avg_cut``/``best_cut``/``seconds``
    and zero-cell markers in [0, #instances].

``--require-preset NAME`` (repeatable) additionally requires that
preset's column to be present — CI uses it so a silently shrunken
matrix cannot pass.

The paper reports *relative* quality/speed against kMetis and hMetis
on its benchmark family; those instances are far outside CI, so the
reference numbers printed at the end are labelled context, never
asserted.  Schema violations exit 1; the tables are the artifact.

Standard library only.
"""

import json
import sys

# Paper-reported headline numbers (arXiv 1402.3281, abstract + Sec. 5),
# keyed by the configuration family our presets mirror.  Context only.
PAPER_REFERENCE = [
    ("UFast", "fastest config: ~10 min for 3.3G edges, < 0.5x kMetis cut"),
    ("CFast", "fast clustering config: ~hMetis quality, ~10x faster"),
    ("CEco", "eco config: quality between Fast and Strong at medium cost"),
    ("CStrong", "strong config: outperforms all competitors on quality"),
]


def fail(errors):
    for line in errors:
        print(f"FAIL: {line}")
    print(f"{len(errors)} report validation error(s)")
    return 1


def check_schema(doc, require_presets):
    errors = []
    if not isinstance(doc, dict):
        return ["top level is not a JSON object"]
    for key in ("k", "reps", "seed"):
        if not isinstance(doc.get(key), int):
            errors.append(f"{key} missing or not an integer")
    if isinstance(doc.get("k"), int) and doc["k"] < 2:
        errors.append(f"k {doc['k']} < 2")
    if isinstance(doc.get("reps"), int) and doc["reps"] < 1:
        errors.append(f"reps {doc['reps']} < 1")
    presets, instances = doc.get("presets"), doc.get("instances")
    for key, val in (("presets", presets), ("instances", instances)):
        if (
            not isinstance(val, list)
            or not val
            or not all(isinstance(s, str) and s for s in val)
        ):
            errors.append(f"{key} missing, empty, or not all non-empty strings")
    if errors:
        return errors

    cells, reps = doc.get("cells"), doc["reps"]
    if not isinstance(cells, list):
        return errors + ["cells missing or not an array"]
    seen = set()
    for i, cell in enumerate(cells):
        where = f"cell {i}"
        if not isinstance(cell, dict):
            errors.append(f"{where}: not an object")
            continue
        preset, instance = cell.get("preset"), cell.get("instance")
        if preset not in presets or instance not in instances:
            errors.append(f"{where}: ({preset!r}, {instance!r}) not declared")
            continue
        if (preset, instance) in seen:
            errors.append(f"{where}: duplicate ({preset}, {instance})")
        seen.add((preset, instance))
        for key in ("avg_cut", "seconds"):
            v = cell.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"{where}: {key} {v!r} not a non-negative number")
        best = cell.get("best_cut")
        if not isinstance(best, int) or best < 0:
            errors.append(f"{where}: best_cut {best!r} not a non-negative integer")
        elif isinstance(cell.get("avg_cut"), (int, float)):
            if best > cell["avg_cut"] + 1e-9:
                errors.append(
                    f"{where}: best_cut {best} above avg_cut {cell['avg_cut']}"
                )
        infeasible = cell.get("infeasible")
        if not isinstance(infeasible, int) or not 0 <= infeasible <= reps:
            errors.append(f"{where}: infeasible {infeasible!r} not in [0, {reps}]")
        if cell.get("reps") != reps:
            errors.append(f"{where}: reps {cell.get('reps')!r} != {reps}")
    missing = [
        (p, i) for p in presets for i in instances if (p, i) not in seen
    ]
    for p, i in missing:
        errors.append(f"cell ({p}, {i}) missing from the matrix")

    geomeans = doc.get("geomeans")
    if not isinstance(geomeans, list):
        return errors + ["geomeans missing or not an array"]
    geo_presets = [g.get("preset") for g in geomeans if isinstance(g, dict)]
    if geo_presets != presets:
        errors.append(f"geomeans presets {geo_presets} != declared {presets}")
    for g in geomeans:
        if not isinstance(g, dict):
            continue
        where = f"geomean {g.get('preset')!r}"
        for key in ("avg_cut", "best_cut", "seconds"):
            v = g.get(key)
            if not isinstance(v, (int, float)) or v < 0:
                errors.append(f"{where}: {key} {v!r} not a non-negative number")
        for key in ("zero_cut_cells", "zero_time_cells"):
            v = g.get(key)
            if not isinstance(v, int) or not 0 <= v <= len(instances):
                errors.append(f"{where}: {key} {v!r} not in [0, {len(instances)}]")

    for name in require_presets:
        if name not in presets:
            errors.append(f"required preset {name!r} not in the report")
    return errors


def print_tables(doc):
    presets, instances = doc["presets"], doc["instances"]
    cells = {(c["preset"], c["instance"]): c for c in doc["cells"]}
    print(
        f"sclap result tables: k={doc['k']} reps={doc['reps']} "
        f"seed={doc['seed']} — geomean over {len(instances)} instance(s)"
    )
    print()
    header = f"{'preset':<12} {'geo avg cut':>12} {'geo best cut':>13} {'geo time [s]':>13}"
    print(header)
    print("-" * len(header))
    starred = False
    for g in doc["geomeans"]:
        star = "*" if g["zero_cut_cells"] or g["zero_time_cells"] else " "
        starred = starred or star == "*"
        print(
            f"{g['preset']:<12} {g['avg_cut']:>12.1f} {g['best_cut']:>13.1f} "
            f"{g['seconds']:>12.4f}{star}"
        )
    if starred:
        print("* geomean excludes zero-valued cells (see zero_*_cells)")
    print()
    header = f"{'instance':<12}" + "".join(f" {p:>16}" for p in presets)
    print(header)
    print("-" * len(header))
    for instance in instances:
        row = [f"{instance:<12}"]
        for p in presets:
            c = cells[(p, instance)]
            note = f"!{c['infeasible']}" if c["infeasible"] else ""
            row.append(f" {c['best_cut']:>10}/{c['avg_cut']:>3.0f}{note:<2}")
        print("".join(row))
    print("cell format: best cut / avg cut (!n = n infeasible runs)")
    print()
    print("paper-reported reference (arXiv 1402.3281; relative, not asserted):")
    known = set(presets)
    for name, claim in PAPER_REFERENCE:
        marker = "->" if name in known else "  "
        print(f"  {marker} {name:<8} {claim}")


def main(argv):
    args = list(argv[1:])
    require_presets = []
    while "--require-preset" in args:
        i = args.index("--require-preset")
        require_presets.append(args[i + 1])
        del args[i : i + 2]
    if len(args) > 1:
        raise SystemExit(__doc__)
    if args:
        with open(args[0]) as f:
            doc = json.load(f)
    else:
        doc = json.load(sys.stdin)
    errors = check_schema(doc, require_presets)
    if errors:
        return fail(errors)
    print_tables(doc)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
