#!/usr/bin/env python3
"""Validate a sclap `!metrics` Prometheus text-format exposition.

Usage:
    prom_validate.py [--expect-metric NAME]... [--min-samples N] [FILE]

Reads the exposition from FILE (or stdin), tolerating the wire framing
`sclap client` prints around it (a leading ``# sclap metrics`` line and
a trailing ``# EOF`` line are stripped; JSON response lines from the
same client stream are ignored).

Checks (renderer documented in `rust/src/obs/metrics.rs`):

  * every line is a comment (``# TYPE``/``# HELP``), blank, or a sample
    ``name{labels} value`` with a legal metric name
    (``[a-zA-Z_:][a-zA-Z0-9_:]*``), legal label names, properly escaped
    label values (only ``\\\\``, ``\\"`` and ``\\n`` escapes) and a
    parseable value (floats, ``+Inf``/``-Inf``/``NaN`` accepted);
  * a ``# TYPE`` line precedes the first sample of its family, each
    family is declared once, and no (name, labels) sample repeats;
  * counter families end in ``_total`` and carry finite, non-negative
    values;
  * every histogram family has cumulative, monotone non-decreasing
    ``_bucket`` samples ending in ``le="+Inf"``, plus ``_sum`` and
    ``_count`` samples with ``_count`` equal to the ``+Inf`` bucket.

``--expect-metric NAME`` (repeatable) requires a sample of that exact
name; ``--min-samples N`` requires at least N samples in total.  CI
(`obs-smoke`) scrapes a live server and pipes the block through here.

Standard library only; exit 0 on success, 1 with a report otherwise.
"""

import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
SAMPLE_RE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
TYPES = {"counter", "gauge", "histogram", "summary", "untyped"}


def fail(errors):
    for line in errors:
        print(f"FAIL: {line}")
    print(f"{len(errors)} metrics validation error(s)")
    return 1


def parse_value(text):
    if text in ("+Inf", "-Inf", "NaN"):
        return float(text.replace("Inf", "inf").replace("NaN", "nan"))
    try:
        return float(text)
    except ValueError:
        return None


def unescape_ok(value):
    """True iff every backslash starts a legal \\\\, \\" or \\n escape."""
    i = 0
    while i < len(value):
        if value[i] == "\\":
            if i + 1 >= len(value) or value[i + 1] not in ('\\', '"', "n"):
                return False
            i += 2
        else:
            i += 1
    return True


def family_of(name):
    """Map a sample name to its TYPE-declared family name."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def validate(lines, expect_metrics, min_samples):
    errors = []
    types = {}  # family -> declared type
    seen_samples = set()  # (name, labels) uniqueness
    sample_names = set()
    samples = []  # (line_no, name, labels dict, value)
    total = 0

    for n, raw in enumerate(lines, start=1):
        line = raw.rstrip("\n")
        where = f"line {n}"
        if not line.strip():
            continue
        if line in ("# sclap metrics", "# EOF"):
            continue  # client wire framing
        if line.startswith('{"'):
            continue  # a JSON response line from the same client stream
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 2 and parts[1] == "TYPE":
                if len(parts) != 4 or parts[3] not in TYPES:
                    errors.append(f"{where}: malformed TYPE line: {line!r}")
                    continue
                family = parts[2]
                if not NAME_RE.match(family):
                    errors.append(f"{where}: bad family name {family!r}")
                elif family in types:
                    errors.append(f"{where}: family {family!r} declared twice")
                else:
                    types[family] = parts[3]
            # HELP and other comments pass through unchecked
            continue
        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"{where}: not a comment or sample: {line!r}")
            continue
        name, labels_text, value_text = m.group(1), m.group(2), m.group(3)
        labels = {}
        if labels_text:
            body = labels_text[1:-1]
            matched = LABEL_RE.findall(body)
            rebuilt = ",".join(f'{k}="{v}"' for k, v in matched)
            if rebuilt != body:
                errors.append(f"{where}: malformed labels {labels_text!r}")
                continue
            for key, val in matched:
                if not LABEL_NAME_RE.match(key):
                    errors.append(f"{where}: bad label name {key!r}")
                if not unescape_ok(val):
                    errors.append(f"{where}: bad escape in label value {val!r}")
                labels[key] = val
        value = parse_value(value_text)
        if value is None:
            errors.append(f"{where}: unparseable value {value_text!r}")
            continue
        family = family_of(name)
        if family not in types and name not in types:
            errors.append(f"{where}: sample {name!r} precedes its TYPE line")
        key = (name, tuple(sorted(labels.items())))
        if key in seen_samples:
            errors.append(f"{where}: duplicate sample {name}{labels_text or ''}")
        seen_samples.add(key)
        sample_names.add(name)
        samples.append((n, name, labels, value))
        total += 1
        declared = types.get(name, types.get(family))
        if declared == "counter":
            if not name.endswith("_total"):
                errors.append(f"{where}: counter {name!r} does not end in _total")
            if not (value >= 0 and value != float("inf")):
                errors.append(f"{where}: counter {name!r} value {value} invalid")

    # Histogram structure: cumulative buckets ending in +Inf == _count.
    for family, kind in sorted(types.items()):
        if kind != "histogram":
            continue
        buckets = [
            (n, labels.get("le"), value)
            for n, name, labels, value in samples
            if name == f"{family}_bucket"
        ]
        if not buckets:
            errors.append(f"histogram {family!r} has no _bucket samples")
            continue
        prev = 0.0
        for n, le, value in buckets:
            if le is None:
                errors.append(f"line {n}: {family}_bucket without le label")
            if value < prev:
                errors.append(
                    f"line {n}: {family}_bucket le={le!r} count {value} "
                    f"below previous bucket {prev}"
                )
            prev = value
        if buckets[-1][1] != "+Inf":
            errors.append(f"histogram {family!r} does not end in le=\"+Inf\"")
        counts = [v for _, name, _, v in samples if name == f"{family}_count"]
        sums = [v for _, name, _, v in samples if name == f"{family}_sum"]
        if len(counts) != 1 or len(sums) != 1:
            errors.append(f"histogram {family!r} needs exactly one _count and _sum")
        elif counts[0] != buckets[-1][2]:
            errors.append(
                f"histogram {family!r}: _count {counts[0]} != "
                f"+Inf bucket {buckets[-1][2]}"
            )

    for name in expect_metrics:
        if name not in sample_names:
            errors.append(f"expected metric {name!r} has no samples")
    if total < min_samples:
        errors.append(f"only {total} sample(s), expected at least {min_samples}")

    if not errors:
        histograms = sum(1 for t in types.values() if t == "histogram")
        print(
            f"ok: {total} samples across {len(types)} families "
            f"({histograms} histogram(s))"
        )
    return errors


def main(argv):
    args = list(argv[1:])
    expect_metrics, min_samples = [], 0
    while "--expect-metric" in args:
        i = args.index("--expect-metric")
        expect_metrics.append(args[i + 1])
        del args[i : i + 2]
    if "--min-samples" in args:
        i = args.index("--min-samples")
        min_samples = int(args[i + 1])
        del args[i : i + 2]
    if len(args) > 1:
        raise SystemExit(__doc__)
    if args:
        with open(args[0]) as f:
            lines = f.readlines()
    else:
        lines = sys.stdin.readlines()
    errors = validate(lines, expect_metrics, min_samples)
    return fail(errors) if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
