#!/usr/bin/env python3
"""Validate a sclap `--trace FILE` Chrome trace_event export.

Usage:
    trace_validate.py [--expect-span NAME]... [--min-spans N] TRACE.json

Checks (schema documented in `rust/src/obs/trace.rs`):

  * the document is a JSON object with a ``traceEvents`` array,
    ``displayTimeUnit`` and an ``otherData`` object;
  * the first event is the ``process_name`` metadata record (ph "M");
  * every other event has ph "B", "E" or "C", a string ``name``,
    integer ``ts``/``pid``/``tid``, and (for counters) an ``args``
    object with numeric values;
  * per ``tid`` (one lane per logical track instance) timestamps are
    monotone non-decreasing and "B"/"E" events balance like
    parentheses — never more Ends than Begins, zero depth at the end;
  * ``otherData.events`` equals the non-metadata event count and
    ``otherData.dropped`` is 0 (a dropped event means the fixed
    per-worker buffers overflowed — a real trace should never drop).

``--expect-span NAME`` (repeatable) requires at least one "B" event
with that name; ``--min-spans N`` requires at least N "B" events in
total.  CI (`obs-smoke`) uses both to assert that a partition run
traced at least one span per V-cycle level.

Standard library only; exit 0 on success, 1 with a report otherwise.
"""

import json
import sys

REQUIRED_PHASES = {"B", "E", "C"}


def fail(errors):
    for line in errors:
        print(f"FAIL: {line}")
    print(f"{len(errors)} trace validation error(s)")
    return 1


def validate(doc, expect_spans, min_spans):
    errors = []
    if not isinstance(doc, dict):
        return ["top level is not a JSON object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing, not an array, or empty"]
    if doc.get("displayTimeUnit") != "ms":
        errors.append("displayTimeUnit is not 'ms'")
    other = doc.get("otherData")
    if not isinstance(other, dict):
        errors.append("otherData missing or not an object")
        other = {}

    meta = events[0]
    if meta.get("ph") != "M" or meta.get("name") != "process_name":
        errors.append(f"first event is not the process_name metadata: {meta}")

    last_ts = {}  # tid -> last seen ts
    depth = {}  # tid -> open span depth
    span_names = {}  # name -> count of "B" events
    begins = ends = 0
    for i, e in enumerate(events[1:], start=1):
        where = f"event {i}"
        ph = e.get("ph")
        if ph not in REQUIRED_PHASES:
            errors.append(f"{where}: unexpected ph {ph!r}")
            continue
        name = e.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing name")
        for field in ("ts", "pid", "tid"):
            if not isinstance(e.get(field), int):
                errors.append(f"{where}: {field} missing or not an integer")
        tid, ts = e.get("tid"), e.get("ts")
        if isinstance(tid, int) and isinstance(ts, int):
            if ts < last_ts.get(tid, 0):
                errors.append(
                    f"{where}: ts {ts} goes backwards on tid {tid} "
                    f"(last {last_ts[tid]})"
                )
            last_ts[tid] = ts
        if ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args:
                errors.append(f"{where}: counter without args")
            elif not all(isinstance(v, (int, float)) for v in args.values()):
                errors.append(f"{where}: counter args are not all numeric")
        elif ph == "B":
            begins += 1
            depth[tid] = depth.get(tid, 0) + 1
            if isinstance(name, str):
                span_names[name] = span_names.get(name, 0) + 1
        else:  # "E"
            ends += 1
            depth[tid] = depth.get(tid, 0) - 1
            if depth[tid] < 0:
                errors.append(f"{where}: E without matching B on tid {tid}")

    for tid, d in sorted(depth.items()):
        if d > 0:
            errors.append(f"tid {tid}: {d} span(s) never ended")
    if begins != ends:
        errors.append(f"unbalanced spans: {begins} B vs {ends} E")

    declared = other.get("events")
    if declared != len(events) - 1:
        errors.append(
            f"otherData.events {declared!r} != {len(events) - 1} actual events"
        )
    if other.get("dropped") != 0:
        errors.append(f"otherData.dropped {other.get('dropped')!r} != 0")

    for name in expect_spans:
        if span_names.get(name, 0) == 0:
            errors.append(f"expected span {name!r} never begins")
    if begins < min_spans:
        errors.append(f"only {begins} span(s), expected at least {min_spans}")

    if not errors:
        lanes = len(last_ts)
        print(
            f"ok: {len(events) - 1} events ({begins} spans, "
            f"{len(span_names)} distinct names) across {lanes} lane(s), "
            "0 dropped"
        )
    return errors


def main(argv):
    args = list(argv[1:])
    expect_spans, min_spans = [], 0
    while "--expect-span" in args:
        i = args.index("--expect-span")
        expect_spans.append(args[i + 1])
        del args[i : i + 2]
    if "--min-spans" in args:
        i = args.index("--min-spans")
        min_spans = int(args[i + 1])
        del args[i : i + 2]
    if len(args) != 1:
        raise SystemExit(__doc__)
    with open(args[0]) as f:
        doc = json.load(f)
    errors = validate(doc, expect_spans, min_spans)
    return fail(errors) if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
