#!/usr/bin/env python3
"""Compare a fresh BENCH_*.json against a committed baseline.

Usage:
    bench_compare.py [--gate-external-io] BASELINE.json FRESH.json

Records (flat ``{"section": ..., key: scalar, ...}`` maps, see
``bench::harness::JsonReport``) are matched by section plus whatever
identity keys they carry (shards, format, threads, engine, label, kind,
k).  For every matched pair, higher-is-better throughput fields
(``medges_per_s``, ``mb_per_s``, ``speedup``, ``level0_speedup``,
``streaming_speedup``, and the service-layer ``cold_req_per_s``,
``warm_req_per_s``, ``warm_speedup``) are compared:

  * FAIL  if fresh < 0.75 x baseline (>25% regression)
  * WARN  if fresh < 0.90 x baseline (>10% regression)

Lower-is-better ``size_ratio`` fails when fresh > baseline / 0.75.

The committed baselines come from a quiet dedicated machine; CI runners
are slower and noisier, which is why ratios — not absolute times — are
compared, and why the fail threshold is generous.  Fresh-only or
baseline-only records are reported but never fail the run (benches grow
new sections over time).

With ``--gate-external-io`` the FRESH report must additionally clear the
SCLAPS2 acceptance gates natively (no baseline involved): every
``v2_vs_v1`` record at shards >= 2 needs ``size_ratio <= 0.6`` and
``level0_speedup >= 1.2`` (warn below 1.5 — the committed-baseline
target — to absorb CI noise without letting a real regression through).
"""

import json
import sys

IDENTITY_KEYS = ("shards", "format", "threads", "engine", "label", "kind", "k")
HIGHER_IS_BETTER = (
    "medges_per_s",
    "mb_per_s",
    "speedup",
    "level0_speedup",
    "streaming_speedup",
    "cold_req_per_s",
    "warm_req_per_s",
    "warm_speedup",
)
FAIL_RATIO = 0.75
WARN_RATIO = 0.90


def load_records(path):
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for rec in doc.get("records", []):
        key = (rec.get("section"),) + tuple(
            (k, rec[k]) for k in IDENTITY_KEYS if k in rec
        )
        if key in out:
            raise SystemExit(f"{path}: duplicate record identity {key}")
        out[key] = rec
    return out


def fmt_key(key):
    section = key[0]
    rest = " ".join(f"{k}={v}" for k, v in key[1:])
    return f"{section}[{rest}]" if rest else section


def compare(baseline_path, fresh_path):
    baseline = load_records(baseline_path)
    fresh = load_records(fresh_path)
    failures, warnings = [], []

    for key in sorted(set(baseline) - set(fresh), key=fmt_key):
        print(f"note: baseline-only record {fmt_key(key)} (not in fresh run)")
    for key in sorted(set(fresh) - set(baseline), key=fmt_key):
        print(f"note: fresh-only record {fmt_key(key)} (no baseline yet)")

    for key in sorted(set(baseline) & set(fresh), key=fmt_key):
        base_rec, fresh_rec = baseline[key], fresh[key]
        for field in HIGHER_IS_BETTER:
            b, f = base_rec.get(field), fresh_rec.get(field)
            if not isinstance(b, (int, float)) or not isinstance(f, (int, float)):
                continue
            if b <= 0:
                continue
            ratio = f / b
            line = (
                f"{fmt_key(key)} {field}: fresh {f:.3f} vs baseline {b:.3f} "
                f"({ratio:.2f}x)"
            )
            if ratio < FAIL_RATIO:
                failures.append(line)
            elif ratio < WARN_RATIO:
                warnings.append(line)
            else:
                print(f"ok:   {line}")
        # size_ratio: lower is better (v2 bytes / v1 bytes).
        b, f = base_rec.get("size_ratio"), fresh_rec.get("size_ratio")
        if isinstance(b, (int, float)) and isinstance(f, (int, float)) and b > 0:
            line = f"{fmt_key(key)} size_ratio: fresh {f:.3f} vs baseline {b:.3f}"
            if f > b / FAIL_RATIO:
                failures.append(line)
            elif f > b / WARN_RATIO:
                warnings.append(line)
            else:
                print(f"ok:   {line}")

    return failures, warnings


def gate_external_io(fresh_path):
    """SCLAPS2 acceptance gates on the fresh report alone."""
    failures, warnings = [], []
    for key, rec in load_records(fresh_path).items():
        if key[0] != "v2_vs_v1" or rec.get("shards", 0) < 2:
            continue
        name = fmt_key(key)
        size = rec.get("size_ratio")
        speed = rec.get("level0_speedup")
        if not isinstance(size, (int, float)) or size > 0.6:
            failures.append(f"{name}: size_ratio {size} exceeds the 0.6 gate")
        else:
            print(f"ok:   {name} size_ratio {size:.3f} <= 0.6")
        if not isinstance(speed, (int, float)) or speed < 1.2:
            failures.append(f"{name}: level0_speedup {speed} below the 1.2 gate")
        elif speed < 1.5:
            warnings.append(f"{name}: level0_speedup {speed:.2f} below the 1.5 target")
        else:
            print(f"ok:   {name} level0_speedup {speed:.2f} >= 1.5")
    return failures, warnings


def main(argv):
    args = list(argv[1:])
    gate = "--gate-external-io" in args
    if gate:
        args.remove("--gate-external-io")
    if len(args) != 2:
        raise SystemExit(__doc__)
    baseline_path, fresh_path = args

    failures, warnings = compare(baseline_path, fresh_path)
    if gate:
        gf, gw = gate_external_io(fresh_path)
        failures += gf
        warnings += gw

    for line in warnings:
        print(f"WARN: {line}")
    for line in failures:
        print(f"FAIL: {line}")
    if failures:
        print(f"{len(failures)} bench regression(s) beyond the 25% budget")
        return 1
    print(f"bench comparison clean ({len(warnings)} warning(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
