#!/usr/bin/env python3
"""Replay a sclap `serve --journal FILE` event journal and reconcile it.

Usage:
    journal_replay.py [--stats STATS.json] [--expect-shutdown] JOURNAL

Reads JOURNAL (and ``JOURNAL.1``, the rotation predecessor, first if it
exists) and replays the request lifecycle it records.  With ``--stats``
pointing at a captured one-line ``!stats`` response from the same
server run, the replayed event counts are reconciled against the live
counters.

Checks (writer documented in `rust/src/obs/journal.rs`, emission sites
in `rust/src/coordinator/net/server.rs`):

  * every line is a JSON object with integer ``seq``/``ts_ms`` and a
    known ``event`` (admitted / started / completed / cancelled / busy /
    cache_hit / error / shutdown), carrying that event's documented
    fields (``id`` everywhere but shutdown; ``connection`` on listen-
    mode admissions; ``seconds``+``cut`` on completions; ``reason`` on
    cancellations);
  * ``seq`` is strictly monotonic across the rotation boundary;
  * lifecycle order per id: started / completed / cancelled / cache_hit
    never precede an admission of that id (busy and error may — they
    also cover refusals and parse failures that were never admitted);
  * ``shutdown``, when present, is the final event, and every admitted
    id has reached a terminal outcome (completed / cancelled / busy /
    error) by then — the server journals terminals before its
    drain-then-close shutdown line;
  * with ``--stats``: ``started`` count == ``requests_activated``,
    non-cached ``completed`` count == ``requests_completed``,
    ``cancelled`` count == ``requests_cancelled``, ``cache_hit`` count
    == ``cache_hits + cache_joined``, and ``busy`` count >=
    ``queue_busy_rejections`` (joiners inherit their leader's refusal
    without taking a queue slot of their own).

Standard library only; exit 0 on success, 1 with a report otherwise.
"""

import json
import os
import sys

EVENTS = {
    "admitted",
    "started",
    "completed",
    "cancelled",
    "busy",
    "cache_hit",
    "error",
    "shutdown",
}
TERMINAL = {"completed", "cancelled", "busy", "error"}
NEEDS_ADMISSION = {"started", "completed", "cancelled", "cache_hit"}


def fail(errors):
    for line in errors:
        print(f"FAIL: {line}")
    print(f"{len(errors)} journal validation error(s)")
    return 1


def load_events(path):
    """All journal lines, rotation predecessor first, parse errors noted."""
    errors, events = [], []
    files = [p for p in (path + ".1", path) if os.path.exists(p)]
    if not files:
        return [f"journal {path!r} does not exist"], []
    for file in files:
        with open(file) as f:
            for n, raw in enumerate(f, start=1):
                where = f"{os.path.basename(file)}:{n}"
                line = raw.rstrip("\n")
                try:
                    record = json.loads(line)
                except ValueError as e:
                    errors.append(f"{where}: not JSON ({e}): {line!r}")
                    continue
                if not isinstance(record, dict):
                    errors.append(f"{where}: not a JSON object")
                    continue
                events.append((where, record))
    return errors, events


def validate(events, stats, expect_shutdown):
    errors = []
    counts = {name: 0 for name in EVENTS}
    completed_fresh = 0  # completions not served from the cache
    admitted = {}  # id -> admissions seen
    terminals = {}  # id -> terminal outcomes seen
    last_seq = None
    shutdown_at = None

    for where, e in events:
        seq, ts_ms, event = e.get("seq"), e.get("ts_ms"), e.get("event")
        if not isinstance(seq, int):
            errors.append(f"{where}: seq missing or not an integer")
        elif last_seq is not None and seq <= last_seq:
            errors.append(f"{where}: seq {seq} not above predecessor {last_seq}")
        if isinstance(seq, int):
            last_seq = seq
        if not isinstance(ts_ms, int) or ts_ms <= 0:
            errors.append(f"{where}: ts_ms missing or not a positive integer")
        if event not in EVENTS:
            errors.append(f"{where}: unknown event {event!r}")
            continue
        counts[event] += 1
        if shutdown_at is not None:
            errors.append(f"{where}: {event!r} after the shutdown event")
        if event == "shutdown":
            shutdown_at = where
            continue
        rid = e.get("id")
        if not isinstance(rid, str) or not rid:
            errors.append(f"{where}: {event} without an id")
            continue
        if event == "admitted":
            admitted[rid] = admitted.get(rid, 0) + 1
        elif event in NEEDS_ADMISSION and rid not in admitted:
            errors.append(f"{where}: {event} for {rid!r} before any admission")
        if event == "completed":
            if not isinstance(e.get("seconds"), (int, float)):
                errors.append(f"{where}: completed without numeric seconds")
            if not isinstance(e.get("cut"), int):
                errors.append(f"{where}: completed without an integer cut")
            if e.get("cached") is not True:
                completed_fresh += 1
        if event == "cancelled" and not e.get("reason"):
            errors.append(f"{where}: cancelled without a reason")
        if event in TERMINAL:
            terminals[rid] = terminals.get(rid, 0) + 1

    if expect_shutdown and shutdown_at is None:
        errors.append("no shutdown event (journal truncated?)")
    if shutdown_at is not None:
        for rid, n in sorted(admitted.items()):
            if terminals.get(rid, 0) < n:
                errors.append(
                    f"id {rid!r}: {n} admission(s) but only "
                    f"{terminals.get(rid, 0)} terminal outcome(s) at shutdown"
                )

    if stats is not None:
        counters = stats.get("counters", {})

        def reconcile(label, got, counter_names, exact=True):
            want = sum(counters.get(c, 0) for c in counter_names)
            if (got != want) if exact else (got < want):
                op = "!=" if exact else "<"
                errors.append(
                    f"journal {label} count {got} {op} "
                    f"{'+'.join(counter_names)} {want}"
                )

        reconcile("started", counts["started"], ["requests_activated"])
        reconcile("completed (fresh)", completed_fresh, ["requests_completed"])
        reconcile("cancelled", counts["cancelled"], ["requests_cancelled"])
        reconcile("cache_hit", counts["cache_hit"], ["cache_hits", "cache_joined"])
        reconcile("busy", counts["busy"], ["queue_busy_rejections"], exact=False)

    if not errors:
        summary = " ".join(
            f"{name}={counts[name]}" for name in sorted(EVENTS) if counts[name]
        )
        against = " (reconciled against !stats)" if stats is not None else ""
        print(f"ok: {len(events)} events, {len(admitted)} id(s){against}: {summary}")
    return errors


def main(argv):
    args = list(argv[1:])
    stats, expect_shutdown = None, False
    if "--stats" in args:
        i = args.index("--stats")
        with open(args[i + 1]) as f:
            stats = json.load(f)
        if stats.get("status") != "stats":
            raise SystemExit(f"--stats file is not a !stats response: {stats}")
        del args[i : i + 2]
    if "--expect-shutdown" in args:
        expect_shutdown = True
        args.remove("--expect-shutdown")
    if len(args) != 1:
        raise SystemExit(__doc__)
    errors, events = load_events(args[0])
    errors += validate(events, stats, expect_shutdown)
    return fail(errors) if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
