//! End-to-end driver: proves all three layers compose on a real
//! workload (the EXPERIMENTS.md §E2E record).
//!
//!     cargo run --release --example e2e_pipeline
//!
//! Pipeline exercised:
//!   1. L3 substrate — generate a web-like instance, compute stats.
//!   2. L3 coarsening — one SCLaP contraction shrinks it to coarse scale.
//!   3. L1/L2 via PJRT — the *coarse* graph is clustered by the
//!      AOT-compiled Pallas/JAX `lpa_round` artifact (the request path
//!      never touches python), reconciled on the host.
//!   4. L3 coordinator — the coarse clustering is contracted again and
//!      the full multilevel partitioner finishes the job; the service
//!      runs the 10-repetition protocol and reports the paper metrics.
//!
//! In the default offline build the PJRT backend is a stub (no `xla`
//! crate — see `runtime::pjrt`), so layer 3 falls back to the
//! pool-parallel synchronous engine, which implements the *same*
//! snapshot-score + reconcile semantics on CPU threads. In an image
//! with a vendored `xla` crate (enable the `pjrt` feature per
//! Cargo.toml, then `make artifacts`) the offload path runs for real.

use sclap::clustering::label_propagation::{size_constrained_lpa, LpaConfig};
use sclap::clustering::parallel_lpa::parallel_sclap;
use sclap::coarsening::contract::contract;
use sclap::coarsening::hierarchy::l_max;
use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::runtime::dense_lpa::offload_sclap;
use sclap::runtime::pjrt::Runtime;
use sclap::util::error::Result;
use sclap::util::exec::ExecutionCtx;
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;
use std::sync::Arc;

fn main() -> Result<()> {
    let total = Timer::start();
    println!("=== sclap end-to-end pipeline ===\n");

    // ---- 1. substrate: a web-like instance ----
    let mut rng = Rng::new(99);
    // LFR-style web-crawl stand-in: power-law degrees + strong locality
    // (mu = 0.08) — see rust/src/generators/lfr.rs for why pure R-MAT
    // would not exercise the paper's claims.
    let g = sclap::graph::subgraph::largest_component(
        &sclap::generators::lfr::lfr_like(60_000, 14.0, 0.08, &mut rng).0,
    );
    let stats = sclap::graph::stats::compute_stats(&g, &mut rng);
    println!("[1] instance: n={} m={} gini={:.2} diam≈{}",
        stats.n, stats.m, stats.degree_gini, stats.approx_diameter);

    // ---- 2. L3 coarsening: one cluster contraction ----
    let k = 16;
    let lmax = l_max(g.total_node_weight(), k, 0.03, g.max_node_weight());
    let u_coarse = ((lmax as f64) / (18.0 * k as f64)).max(1.0) as i64;
    let t = Timer::start();
    let (clustering, _) = size_constrained_lpa(
        &g,
        u_coarse.max(g.max_node_weight()),
        &LpaConfig::default(),
        None,
        None,
        &mut rng,
    );
    let level1 = contract(&g, &clustering);
    println!(
        "[2] cluster contraction: {} -> {} nodes ({:.0}x) in {:.2}s",
        g.n(),
        level1.coarse.n(),
        g.n() as f64 / level1.coarse.n() as f64,
        t.elapsed_s()
    );

    // Keep contracting with the sequential path until the graph fits the
    // largest AOT artifact (1024 nodes).
    let mut coarse = level1.coarse.clone();
    let mut rounds = 0;
    while coarse.n() > 1024 && rounds < 20 {
        rounds += 1;
        let u = (coarse.total_node_weight() / 256).max(coarse.max_node_weight());
        let (c, _) = size_constrained_lpa(&coarse, u, &LpaConfig::default(), None, None, &mut rng);
        if c.num_clusters as f64 > 0.98 * coarse.n() as f64 {
            // stalled: loosen the bound
            let u2 = u * 4;
            let (c2, _) =
                size_constrained_lpa(&coarse, u2, &LpaConfig::default(), None, None, &mut rng);
            coarse = contract(&coarse, &c2).coarse;
        } else {
            coarse = contract(&coarse, &c).coarse;
        }
    }
    println!("    further contracted to n={} m={}", coarse.n(), coarse.m());

    // ---- 3. the dense synchronous layer on the coarse graph ----
    // PJRT offload when the backend + artifacts exist; otherwise the
    // pool-parallel engine executes the identical synchronous-round
    // semantics on CPU threads (see module docs above).
    let u_dev = (coarse.total_node_weight() / 64).max(coarse.max_node_weight());
    let t = Timer::start();
    let offloaded = match Runtime::from_env() {
        Ok(mut runtime) => {
            println!(
                "[3] PJRT runtime up: platform={}, artifacts to N={}",
                runtime.platform(),
                runtime.max_n()
            );
            match offload_sclap(&coarse, u_dev, 10, &mut runtime)? {
                Some((c, stats)) => {
                    println!(
                        "    offloaded SCLaP: {} rounds, {} moves, artifact N{}",
                        stats.rounds, stats.applied, stats.artifact_n
                    );
                    Some(c)
                }
                None => {
                    println!("    coarse graph larger than artifact capacity");
                    None
                }
            }
        }
        Err(e) => {
            println!("[3] PJRT unavailable ({e})");
            None
        }
    };
    let dev_clustering = offloaded.unwrap_or_else(|| {
        println!("    falling back to the pool-parallel synchronous engine");
        let ctx = ExecutionCtx::new(0);
        parallel_sclap(&coarse, u_dev, 10, &ctx, &mut rng)
    });
    println!(
        "    synchronous clustering: {} clusters, cut {}, bound ok: {} ({:.2}s)",
        dev_clustering.num_clusters,
        dev_clustering.cut(&coarse),
        dev_clustering.respects_bound(u_dev),
        t.elapsed_s()
    );
    assert!(dev_clustering.respects_bound(u_dev), "invariant 7 violated");

    // ---- 4. full system through the coordinator service ----
    let coordinator = Coordinator::new(0);
    println!("[4] coordinator: {} workers, 10-repetition protocol", coordinator.worker_count());
    let shared = Arc::new(g);
    let t = Timer::start();
    let agg = coordinator.partition_repeated(
        shared.clone(),
        &PartitionConfig::preset(Preset::UFast, k),
        &default_seeds(10),
    );
    let kmetis = coordinator.partition_repeated(
        shared.clone(),
        &PartitionConfig::preset(Preset::KMetisLike, k),
        &default_seeds(10),
    );
    println!(
        "    UFast       : avg cut {:>10.0}  best {:>9}  avg t {:.2}s",
        agg.avg_cut, agg.best_cut, agg.avg_seconds
    );
    println!(
        "    kMetis-like : avg cut {:>10.0}  best {:>9}  avg t {:.2}s",
        kmetis.avg_cut, kmetis.best_cut, kmetis.avg_seconds
    );
    println!(
        "    headline    : {:.2}x fewer edges cut (paper uk-2007: 2.6x), wall {:.1}s",
        kmetis.avg_cut / agg.avg_cut,
        t.elapsed_s()
    );
    assert!(agg.avg_cut < kmetis.avg_cut, "cluster coarsening must win on web graphs");

    println!("\nALL LAYERS COMPOSED OK in {:.1}s", total.elapsed_s());
    Ok(())
}
