//! Social-network partitioning scenario (the paper's §1 motivation:
//! distribute a social graph over k processing elements with few
//! cross-PE friendships).
//!
//!     cargo run --release --example social_network [-- --full]
//!
//! Builds BA/WS social-network stand-ins, partitions them for a PE grid,
//! and reports per-block communication volume — including the
//! comparison the paper draws: cluster coarsening vs matching coarsening
//! on exactly this graph class.

use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::graph::csr::Graph;
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::metrics::evaluate;
use sclap::partitioning::partition::Partition;
use sclap::util::rng::Rng;
use std::sync::Arc;

fn communication_volume(g: &Graph, p: &Partition) -> Vec<i64> {
    // per-block: total weight of edges leaving the block
    let mut vol = vec![0i64; p.k];
    for (u, v, w) in g.edges() {
        let (bu, bv) = (p.block_of(u), p.block_of(v));
        if bu != bv {
            vol[bu as usize] += w;
            vol[bv as usize] += w;
        }
    }
    vol
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let mut rng = Rng::new(2024);
    let n = if full { 200_000 } else { 20_000 };

    println!("=== scenario: friendship graph (Barabási–Albert, n={n}) ===");
    let friends = sclap::generators::barabasi_albert(n, 5, &mut rng);
    println!("n={} m={}", friends.n(), friends.m());

    let coordinator = Coordinator::new(0);
    let g = Arc::new(friends);
    let k = 16;

    for preset in [Preset::UFast, Preset::UEcoVB, Preset::KMetisLike, Preset::KaffpaEco] {
        let agg = coordinator.partition_repeated(
            g.clone(),
            &PartitionConfig::preset(preset, k),
            &default_seeds(3),
        );
        let p = Partition::from_blocks(&g, k, agg.best_blocks.clone());
        let m = evaluate(&g, &p, 0.03);
        let vol = communication_volume(&g, &p);
        println!(
            "{:<12} avg cut {:>9.0}  best {:>8}  time {:>6.2}s  max-PE-traffic {:>7}  imbalance {:.3}",
            preset.name(),
            agg.avg_cut,
            agg.best_cut,
            agg.avg_seconds,
            vol.iter().max().unwrap(),
            m.imbalance,
        );
    }

    println!();
    println!("=== scenario: community structure recovery (planted partition) ===");
    let (sbm, truth) = sclap::generators::planted_partition(8, if full { 400 } else { 120 }, 0.2, 0.002, &mut rng);
    println!("n={} m={} (8 planted communities)", sbm.n(), sbm.m());
    let g = Arc::new(sbm);
    let agg = coordinator.partition_repeated(
        g.clone(),
        &PartitionConfig::preset(Preset::UEcoVB, 8),
        &default_seeds(3),
    );
    // agreement: fraction of node pairs the partition classifies like the truth
    let p = &agg.best_blocks;
    let mut rng2 = Rng::new(7);
    let mut agree = 0usize;
    let samples = 20_000;
    for _ in 0..samples {
        let a = rng2.below(g.n());
        let b = rng2.below(g.n());
        if (truth[a] == truth[b]) == (p[a] == p[b]) {
            agree += 1;
        }
    }
    println!(
        "best cut {} | pairwise agreement with planted communities: {:.1}%",
        agg.best_cut,
        100.0 * agree as f64 / samples as f64
    );
}
