//! Quickstart: partition a graph with the public API in ~20 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Loads a named benchmark instance (or any METIS/edge-list file via
//! `sclap::graph::io`), picks a preset, partitions, prints metrics.

use sclap::prelude::*;

fn main() {
    // 1. Get a graph: a named instance here; `graph::io::load_path` for
    //    your own files; `GraphBuilder` for programmatic construction.
    let graph = sclap::generators::instances::by_name("tiny-rmat")
        .expect("bundled instance")
        .build();
    println!("graph: n={} m={}", graph.n(), graph.m());

    // 2. Pick a configuration. Presets mirror the paper's §5.1 ladder:
    //    UFast = fastest, UEcoV/B ≈ hMetis quality at 10x speed,
    //    UStrong = best quality.
    let config = PartitionConfig::preset(Preset::UFast, 8);

    // 3. Partition (seed ⇒ deterministic).
    let result = MultilevelPartitioner::new(config).partition(&graph, 42);

    println!("cut          : {}", result.metrics.cut);
    println!("imbalance    : {:.3}", result.metrics.imbalance);
    println!("feasible     : {}", result.metrics.feasible);
    println!("levels       : {}", result.levels);
    println!("coarsest n   : {}", result.coarsest_n);
    println!("time         : {:.3}s", result.seconds);

    // 4. The partition itself: block id per node.
    let blocks = &result.partition.blocks;
    println!("node 0 -> block {}", blocks[0]);

    // 5. Ten-repetition protocol (paper §5) via the coordinator service.
    let coordinator = sclap::coordinator::Coordinator::new(0);
    let agg = coordinator.partition_repeated(
        std::sync::Arc::new(graph),
        &PartitionConfig::preset(Preset::UFast, 8),
        &sclap::coordinator::default_seeds(10),
    );
    println!(
        "10 reps: avg cut {:.1}, best cut {}, avg time {:.3}s",
        agg.avg_cut, agg.best_cut, agg.avg_seconds
    );
}
