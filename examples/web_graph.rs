//! Huge-web-graph scenario — the paper's §5.2 headline experiment at
//! container scale (the uk-2007 protocol: k = 16, three LP iterations
//! during coarsening, UFast vs the kMetis-like baseline).
//!
//!     cargo run --release --example web_graph [-- --full]
//!
//! `--full` uses the biggest webgraph-sim instance (~10⁷ edges); default
//! is a 1-minute-scale run. Reports the paper's §5.2 observables: cut
//! vs kMetis, shrink factor of the first contraction, and whether the
//! initial partition alone already beats the baseline's final result.

use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::multilevel::MultilevelPartitioner;
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, deg) = if full { (1_000_000, 14.0) } else { (150_000, 12.0) };

    println!("generating webgraph-sim (LFR-style, n={n}, avg deg {deg}, mu=0.06)...");
    let t = Timer::start();
    let mut rng = Rng::new(301);
    let g = sclap::graph::subgraph::largest_component(
        &sclap::generators::lfr::lfr_like(n, deg, 0.06, &mut rng).0,
    );
    println!("n={} m={} (generated in {:.1}s)", g.n(), g.m(), t.elapsed_s());

    let k = 16;
    // §5.2 protocol: only 3 LP iterations during coarsening on huge graphs.
    let mut ufast = PartitionConfig::preset(Preset::UFast, k);
    ufast.lpa_iterations = 3;
    let mut ufast_v = PartitionConfig::preset(Preset::UFastV, k);
    ufast_v.lpa_iterations = 3;
    let kmetis = PartitionConfig::preset(Preset::KMetisLike, k);

    println!("\n{:<12} {:>12} {:>10} {:>8} {:>10} {:>12}", "algorithm", "cut", "t[s]", "levels", "shrink1", "initial cut");
    let mut rows = Vec::new();
    for (name, config) in [("UFast", ufast), ("UFastV", ufast_v), ("kMetis-like", kmetis)] {
        let r = MultilevelPartitioner::new(config).partition(&g, 1);
        println!(
            "{name:<12} {:>12} {:>10.2} {:>8} {:>10.1} {:>12}",
            r.metrics.cut, r.seconds, r.levels, r.first_shrink, r.initial_cut
        );
        rows.push((name, r));
    }

    let ufast_cut = rows[0].1.metrics.cut as f64;
    let kmetis_cut = rows[2].1.metrics.cut as f64;
    println!("\npaper §5.2 observables:");
    println!(
        "  UFast/kMetis cut ratio : {:.2}x fewer edges cut (paper: ~2.4x on uk-2007)",
        kmetis_cut / ufast_cut
    );
    println!(
        "  first contraction      : {:.0}x fewer nodes (paper: ~100x)",
        rows[0].1.first_shrink
    );
    println!(
        "  initial partition already beats kMetis final: {} ({} vs {})",
        rows[0].1.initial_cut < rows[2].1.metrics.cut,
        rows[0].1.initial_cut,
        rows[2].1.metrics.cut
    );
}
