//! Microbenchmarks of the L3 hot path: SCLaP round throughput (edges/s),
//! orderings, active nodes, contraction, and the parallel variant.
//! These feed EXPERIMENTS.md §Perf (target: ≥50M edges/s traversal).
//!
//!     cargo bench --bench lpa_micro [-- --full]

use sclap::clustering::label_propagation::{
    size_constrained_lpa, LpaConfig, NodeOrdering,
};
use sclap::clustering::parallel_lpa::parallel_sclap;
use sclap::coarsening::contract::contract;
use sclap::graph::csr::Graph;
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;

fn bench<F: FnMut() -> u64>(label: &str, edges: usize, iters: usize, mut f: F) {
    // warmup
    let mut sink = f();
    let t = Timer::start();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    let secs = t.elapsed_s() / iters as f64;
    println!(
        "{label:<44} {:>8.1} ms   {:>7.1} M edges/s   (sink {sink})",
        secs * 1e3,
        edges as f64 / secs / 1e6,
    );
}

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let (scale, m) = if quick { (15, 500_000) } else { (18, 4_000_000) };
    let iters = if quick { 3 } else { 5 };

    let mut rng = Rng::new(1);
    println!("building R-MAT scale {scale}, {m} edges...");
    let g: Graph = sclap::graph::subgraph::largest_component(&sclap::generators::rmat(
        scale, m, 0.57, 0.19, 0.19, &mut rng,
    ));
    println!("n={} m={}\n", g.n(), g.m());
    let upper = (g.total_node_weight() / 64).max(g.max_node_weight());

    // one full SCLaP invocation (ℓ=3 rounds max) per measurement
    for (label, ordering, active) in [
        ("sclap l=3 random order", NodeOrdering::Random, false),
        ("sclap l=3 degree order", NodeOrdering::Degree, false),
        ("sclap l=3 degree order + active nodes", NodeOrdering::Degree, true),
    ] {
        let mut cfg = LpaConfig::clustering(3, ordering);
        cfg.active_nodes = active;
        let mut seed = 0u64;
        bench(label, 3 * g.m(), iters, || {
            seed += 1;
            let mut r = Rng::new(seed);
            let (c, rounds) = size_constrained_lpa(&g, upper, &cfg, None, None, &mut r);
            c.num_clusters as u64 + rounds as u64
        });
    }

    // parallel rounds (paper §6 future work)
    for threads in [1usize, 2, 4, 8] {
        let mut seed = 100u64;
        bench(
            &format!("parallel sclap l=3 ({threads} threads)"),
            3 * g.m(),
            iters,
            || {
                seed += 1;
                let mut r = Rng::new(seed);
                let c = parallel_sclap(&g, upper, 3, threads, &mut r);
                c.num_clusters as u64
            },
        );
    }

    // contraction throughput
    {
        let mut r = Rng::new(7);
        let (clustering, _) = size_constrained_lpa(
            &g,
            upper,
            &LpaConfig::clustering(3, NodeOrdering::Degree),
            None,
            None,
            &mut r,
        );
        bench("cluster contraction", g.m(), iters, || {
            contract(&g, &clustering).coarse.n() as u64
        });
    }

    // matching baseline for contrast
    {
        let mut seed = 200u64;
        bench("heavy-edge matching (+2hop)", g.m(), iters, || {
            seed += 1;
            let mut r = Rng::new(seed);
            let c = sclap::coarsening::matching::heavy_edge_matching(&g, upper, true, &mut r);
            c.num_clusters as u64
        });
    }

    println!("\ntarget (EXPERIMENTS.md §Perf): >=50M edges/s for the sequential");
    println!("sclap round on this class of hardware (paper-era machine ~25M).");
}
