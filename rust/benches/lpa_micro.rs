//! Microbenchmarks of the L3 hot path: SCLaP round throughput (edges/s),
//! orderings, active nodes, contraction, and the parallel variant.
//! These feed EXPERIMENTS.md §Perf (target: ≥50M edges/s traversal).
//!
//!     cargo bench --bench lpa_micro [-- --full]

use sclap::clustering::label_propagation::{
    size_constrained_lpa, LpaConfig, NodeOrdering,
};
use sclap::clustering::parallel_lpa::parallel_sclap;
use sclap::coarsening::contract::{contract, contract_parallel};
use sclap::graph::csr::Graph;
use sclap::util::pool::ThreadPool;
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;

/// Run `f` `iters` times (after one warmup), print throughput, and
/// return the mean seconds per iteration (for speedup summaries).
fn bench<F: FnMut() -> u64>(label: &str, edges: usize, iters: usize, mut f: F) -> f64 {
    // warmup
    let mut sink = f();
    let t = Timer::start();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    let secs = t.elapsed_s() / iters as f64;
    println!(
        "{label:<44} {:>8.1} ms   {:>7.1} M edges/s   (sink {sink})",
        secs * 1e3,
        edges as f64 / secs / 1e6,
    );
    secs
}

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let (scale, m) = if quick { (15, 500_000) } else { (18, 4_000_000) };
    let iters = if quick { 3 } else { 5 };

    let mut rng = Rng::new(1);
    println!("building R-MAT scale {scale}, {m} edges...");
    let g: Graph = sclap::graph::subgraph::largest_component(&sclap::generators::rmat(
        scale, m, 0.57, 0.19, 0.19, &mut rng,
    ));
    println!("n={} m={}\n", g.n(), g.m());
    let upper = (g.total_node_weight() / 64).max(g.max_node_weight());

    // one full SCLaP invocation (ℓ=3 rounds max) per measurement
    for (label, ordering, active) in [
        ("sclap l=3 random order", NodeOrdering::Random, false),
        ("sclap l=3 degree order", NodeOrdering::Degree, false),
        ("sclap l=3 degree order + active nodes", NodeOrdering::Degree, true),
    ] {
        let mut cfg = LpaConfig::clustering(3, ordering);
        cfg.active_nodes = active;
        let mut seed = 0u64;
        bench(label, 3 * g.m(), iters, || {
            seed += 1;
            let mut r = Rng::new(seed);
            let (c, rounds) = size_constrained_lpa(&g, upper, &cfg, None, None, &mut r);
            c.num_clusters as u64 + rounds as u64
        });
    }

    // Pool-parallel synchronous rounds (paper §6 future work), now on
    // the shared deterministic thread pool. Same seed ⇒ same clustering
    // for every pool size; only wall-clock changes.
    let mut secs_by_threads: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        let mut seed = 100u64;
        let secs = bench(
            &format!("parallel sclap l=3 ({threads} threads, pool)"),
            3 * g.m(),
            iters,
            || {
                seed += 1;
                let mut r = Rng::new(seed);
                let c = parallel_sclap(&g, upper, 3, &pool, &mut r);
                c.num_clusters as u64
            },
        );
        secs_by_threads.push((threads, secs));
    }
    let t1 = secs_by_threads[0].1;
    for &(threads, secs) in &secs_by_threads[1..] {
        println!(
            "    -> speedup {threads} threads vs 1: {:.2}x (target at 4: >= 1.5x)",
            t1 / secs
        );
    }

    // contraction throughput: sequential vs pool-parallel
    {
        let mut r = Rng::new(7);
        let (clustering, _) = size_constrained_lpa(
            &g,
            upper,
            &LpaConfig::clustering(3, NodeOrdering::Degree),
            None,
            None,
            &mut r,
        );
        let seq = bench("cluster contraction (sequential)", g.m(), iters, || {
            contract(&g, &clustering).coarse.n() as u64
        });
        let pool = ThreadPool::new(4);
        let par = bench("cluster contraction (pool, 4 threads)", g.m(), iters, || {
            contract_parallel(&g, &clustering, &pool).coarse.n() as u64
        });
        println!("    -> contraction speedup 4 threads: {:.2}x", seq / par);
    }

    // matching baseline for contrast
    {
        let mut seed = 200u64;
        bench("heavy-edge matching (+2hop)", g.m(), iters, || {
            seed += 1;
            let mut r = Rng::new(seed);
            let c = sclap::coarsening::matching::heavy_edge_matching(&g, upper, true, &mut r);
            c.num_clusters as u64
        });
    }

    println!("\ntarget (EXPERIMENTS.md §Perf): >=50M edges/s for the sequential");
    println!("sclap round on this class of hardware (paper-era machine ~25M).");
}
