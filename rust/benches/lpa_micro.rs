//! Microbenchmarks of the L3 hot path: SCLaP round throughput (edges/s),
//! orderings, active nodes, contraction, and the parallel variants —
//! including the coloring-based parallel *asynchronous* LPA
//! (arXiv 1404.4797 engine, `clustering::async_lpa`).
//! These feed EXPERIMENTS.md §Perf (target: ≥50M edges/s traversal) and
//! emit machine-readable results to `BENCH_lpa_micro.json`
//! (`bench::harness::JsonReport`).
//!
//!     cargo bench --bench lpa_micro [-- --full]

use sclap::bench::harness::JsonReport;
use sclap::clustering::async_lpa::parallel_async_sclap;
use sclap::clustering::label_propagation::{
    size_constrained_lpa, LpaConfig, NodeOrdering,
};
use sclap::clustering::parallel_lpa::parallel_sclap;
use sclap::coarsening::contract::{contract, contract_parallel};
use sclap::graph::csr::Graph;
use sclap::util::exec::ExecutionCtx;
use sclap::util::pool::ThreadPool;
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;

/// Run `f` `iters` times (after one warmup), print throughput, and
/// return the mean seconds per iteration (for speedup summaries).
fn bench<F: FnMut() -> u64>(label: &str, edges: usize, iters: usize, mut f: F) -> f64 {
    // warmup
    let mut sink = f();
    let t = Timer::start();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    let secs = t.elapsed_s() / iters as f64;
    println!(
        "{label:<44} {:>8.1} ms   {:>7.1} M edges/s   (sink {sink})",
        secs * 1e3,
        edges as f64 / secs / 1e6,
    );
    secs
}

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let (scale, m) = if quick { (15, 500_000) } else { (18, 4_000_000) };
    let iters = if quick { 3 } else { 5 };
    let mut report = JsonReport::new("lpa_micro");

    let mut rng = Rng::new(1);
    println!("building R-MAT scale {scale}, {m} edges...");
    let g: Graph = sclap::graph::subgraph::largest_component(&sclap::generators::rmat(
        scale, m, 0.57, 0.19, 0.19, &mut rng,
    ));
    println!("n={} m={}\n", g.n(), g.m());
    report.record(
        "instance",
        &[
            ("kind", "rmat".into()),
            ("scale", (scale as usize).into()),
            ("n", g.n().into()),
            ("m", g.m().into()),
            ("quick", quick.into()),
        ],
    );
    let upper = (g.total_node_weight() / 64).max(g.max_node_weight());

    // one full SCLaP invocation (ℓ=3 rounds max) per measurement
    for (label, ordering, active) in [
        ("sclap l=3 random order", NodeOrdering::Random, false),
        ("sclap l=3 degree order", NodeOrdering::Degree, false),
        ("sclap l=3 degree order + active nodes", NodeOrdering::Degree, true),
    ] {
        let mut cfg = LpaConfig::clustering(3, ordering);
        cfg.active_nodes = active;
        let mut seed = 0u64;
        let secs = bench(label, 3 * g.m(), iters, || {
            seed += 1;
            let mut r = Rng::new(seed);
            let (c, rounds) = size_constrained_lpa(&g, upper, &cfg, None, None, &mut r);
            c.num_clusters as u64 + rounds as u64
        });
        report.record(
            "sequential_sclap",
            &[
                ("label", label.into()),
                ("secs", secs.into()),
                ("medges_per_s", (3.0 * g.m() as f64 / secs / 1e6).into()),
            ],
        );
    }

    // Pool-parallel synchronous rounds (paper §6 future work) on the
    // shared deterministic context. Same seed ⇒ same clustering for
    // every pool size; only wall-clock changes.
    let mut secs_by_threads: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let ctx = ExecutionCtx::new(threads);
        let mut seed = 100u64;
        let secs = bench(
            &format!("parallel sclap l=3 ({threads} threads, pool)"),
            3 * g.m(),
            iters,
            || {
                seed += 1;
                let mut r = Rng::new(seed);
                let c = parallel_sclap(&g, upper, 3, &ctx, &mut r);
                c.num_clusters as u64
            },
        );
        secs_by_threads.push((threads, secs));
        report.record(
            "sync_parallel_sclap",
            &[("threads", threads.into()), ("secs", secs.into())],
        );
    }
    let t1 = secs_by_threads[0].1;
    for &(threads, secs) in &secs_by_threads[1..] {
        println!(
            "    -> speedup {threads} threads vs 1: {:.2}x (target at 4: >= 1.5x)",
            t1 / secs
        );
        report.record(
            "sync_parallel_sclap_speedup",
            &[("threads", threads.into()), ("speedup", (t1 / secs).into())],
        );
    }

    // The coloring-based parallel *asynchronous* coarsening round
    // (arXiv 1404.4797): same move rule as the sequential engine,
    // independent sets processed in parallel. This is the acceptance
    // metric of ISSUE 2: >= 1.3x at 4 threads on the largest micro
    // instance, recorded in BENCH_lpa_micro.json.
    let mut async_secs: Vec<(usize, f64)> = Vec::new();
    {
        let cfg = LpaConfig::clustering(3, NodeOrdering::Degree);
        // Quality of the engine — identical for every pool size (the
        // determinism contract), so it is computed once, untimed, and
        // kept out of the throughput measurements below.
        {
            let ctx = ExecutionCtx::new(1);
            let (c, _) =
                parallel_async_sclap(&g, upper, &cfg, None, &ctx, &mut Rng::new(301));
            report.record(
                "async_lpa_quality",
                &[
                    ("num_clusters", c.num_clusters.into()),
                    ("cut", c.cut(&g).into()),
                ],
            );
        }
        for threads in [1usize, 2, 4, 8] {
            let ctx = ExecutionCtx::new(threads);
            let mut seed = 300u64;
            let secs = bench(
                &format!("async-lpa coarsening l=3 ({threads} threads)"),
                3 * g.m(),
                iters,
                || {
                    seed += 1;
                    let mut r = Rng::new(seed);
                    let (c, _) =
                        parallel_async_sclap(&g, upper, &cfg, None, &ctx, &mut r);
                    c.num_clusters as u64
                },
            );
            async_secs.push((threads, secs));
            report.record(
                "async_lpa",
                &[
                    ("threads", threads.into()),
                    ("secs", secs.into()),
                    ("medges_per_s", (3.0 * g.m() as f64 / secs / 1e6).into()),
                ],
            );
        }
        let a1 = async_secs[0].1;
        let mut speedup4 = 0.0f64;
        for &(threads, secs) in &async_secs[1..] {
            let speedup = a1 / secs;
            if threads == 4 {
                speedup4 = speedup;
            }
            println!(
                "    -> async-lpa speedup {threads} threads vs 1: {speedup:.2}x (target at 4: >= 1.3x)"
            );
            report.record(
                "async_lpa_speedup",
                &[("threads", threads.into()), ("speedup", speedup.into())],
            );
        }
        report.record(
            "async_lpa_summary",
            &[
                ("speedup_4_threads", speedup4.into()),
                ("target", 1.3.into()),
                ("meets_target", (speedup4 >= 1.3).into()),
            ],
        );
    }

    // contraction throughput: sequential vs pool-parallel
    {
        let mut r = Rng::new(7);
        let (clustering, _) = size_constrained_lpa(
            &g,
            upper,
            &LpaConfig::clustering(3, NodeOrdering::Degree),
            None,
            None,
            &mut r,
        );
        let seq = bench("cluster contraction (sequential)", g.m(), iters, || {
            contract(&g, &clustering).coarse.n() as u64
        });
        let pool = ThreadPool::new(4);
        let par = bench("cluster contraction (pool, 4 threads)", g.m(), iters, || {
            contract_parallel(&g, &clustering, &pool).coarse.n() as u64
        });
        println!("    -> contraction speedup 4 threads: {:.2}x", seq / par);
        report.record(
            "contraction",
            &[
                ("secs_sequential", seq.into()),
                ("secs_parallel_4", par.into()),
                ("speedup", (seq / par).into()),
            ],
        );
    }

    // matching baseline for contrast
    {
        let mut seed = 200u64;
        let secs = bench("heavy-edge matching (+2hop)", g.m(), iters, || {
            seed += 1;
            let mut r = Rng::new(seed);
            let c = sclap::coarsening::matching::heavy_edge_matching(&g, upper, true, &mut r);
            c.num_clusters as u64
        });
        report.record("matching_baseline", &[("secs", secs.into())]);
    }

    match report.write() {
        Ok(path) => println!("\nwrote machine-readable results to {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench JSON: {e}"),
    }
    println!("\ntarget (EXPERIMENTS.md §Perf): >=50M edges/s for the sequential");
    println!("sclap round on this class of hardware (paper-era machine ~25M).");
}
