//! Table 3/4 reproduction: huge web graphs, k = 16, UFast / UFastV vs
//! the kMetis-like baseline, 3 LP iterations during coarsening (§5.2).
//!
//!     cargo bench --bench table3              # quick default (smaller instances)
//!     cargo bench --bench table3 -- --full    # full webgraph-sims
//!
//! Also reports the §5.2 in-text observables: the shrink factor of the
//! first contraction (paper: "two orders of magnitude less nodes") and
//! whether the initial partition alone beats the baseline's final cut.

use sclap::bench::harness::{fmt, BenchOpts, TableWriter};
use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::generators::instances::huge_suite;
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::util::timer::Timer;
use std::sync::Arc;

fn main() {
    let opts = BenchOpts::from_env();
    let k = 16;
    let reps = if opts.quick { 2 } else { opts.reps.min(3) };

    println!("== Table 3/4: huge web graphs, k = {k} ==\n");

    let specs = huge_suite();
    let specs: Vec<_> = if opts.quick {
        specs.into_iter().take(2).collect()
    } else {
        specs
    };

    let coordinator = Coordinator::new(0);
    let table = TableWriter::new(&[
        ("graph", 12),
        ("algorithm", 12),
        ("avg cut", 10),
        ("best cut", 10),
        ("t [s]", 8),
        ("shrinkTot", 9),
        ("IP cut", 10),
    ]);
    table.header();

    for spec in &specs {
        let t = Timer::start();
        let g = if opts.quick {
            // Quick mode: same structural class (LFR web-like, low mixing)
            // at 1/10 the size so the bench finishes in CI time.
            let mut rng = sclap::util::rng::Rng::new(spec.seed);
            sclap::graph::subgraph::largest_component(
                &sclap::generators::lfr::lfr_like(120_000, 14.0, 0.07, &mut rng).0,
            )
        } else {
            spec.build()
        };
        eprintln!(
            "[{}] built n={} m={} in {:.1}s",
            spec.name,
            g.n(),
            g.m(),
            t.elapsed_s()
        );
        let g = Arc::new(g);

        // §5.2: ℓ = 3 during coarsening for the huge graphs.
        let mut ufast = PartitionConfig::preset(Preset::UFast, k);
        ufast.lpa_iterations = 3;
        let mut ufastv = PartitionConfig::preset(Preset::UFastV, k);
        ufastv.lpa_iterations = 3;
        let kmetis = PartitionConfig::preset(Preset::KMetisLike, k);

        let mut baseline_avg = f64::NAN;
        for (name, config) in [
            ("UFast", ufast),
            ("UFastV", ufastv),
            ("kMetis-like", kmetis),
        ] {
            let agg =
                coordinator.partition_repeated(g.clone(), &config, &default_seeds(reps));
            // shrink + IP stats from one representative run
            let probe = &agg.runs[0];
            table.row(&[
                spec.name.into(),
                name.into(),
                fmt(agg.avg_cut),
                fmt(agg.best_cut as f64),
                format!("{:.1}", agg.avg_seconds),
                // total shrink input -> coarsest (hierarchy product)
                format!("{:.0}x", g.n() as f64 / probe.coarsest_n.max(1) as f64),
                fmt(agg.avg_initial_cut),
            ]);
            if name == "kMetis-like" {
                baseline_avg = agg.avg_cut;
            } else if name == "UFast" {
                baseline_avg = agg.avg_cut; // temp store; ratio printed below
            }
        }
        let _ = baseline_avg;
    }

    println!("\npaper reference (Table 4, real crawls on a 1TB machine):");
    println!("  uk-2002 : UFast 1.47M/71.7s  UFastV 1.43M/215.9s  kMetis 2.46M/63.7s");
    println!("  uk-2007 : UFast 4.34M/626.5s UFastV 4.19M/1756.4s kMetis 11.44M/827.6s");
    println!("  (expected shape: UFast cuts ~1.7-2.6x fewer edges at comparable time;");
    println!("   UFastV improves cut further at ~3x the time; on one instance,");
    println!("   sk-2005, kMetis wins on avg cut — a faithful reproduction need");
    println!("   not sweep all four instances.)");
}
