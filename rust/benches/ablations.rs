//! Ablation benches for the paper's §5.1 in-text claims — each row
//! isolates one algorithmic component of §4:
//!
//!  A1 CEcoR vs KaFFPaEco   — cluster vs matching coarsening
//!                            (paper: 3.5x faster, ~20% better)
//!  A2 CEcoR vs CEco        — degree ordering (paper: +8% quality, +20% speed)
//!  A3 CEco vs CEcoV        — V-cycles improve quality, cost time
//!  A4 CEcoV vs CEcoV/B     — coarse-level imbalance helps Eco
//!  A5 CFastV vs CFastV/B   — ...but HURTS the Fast family (LPA can't rebalance)
//!  A6 CFastV/B vs +E       — ensembles can help
//!  A7 +E vs +E/A           — active nodes trade quality for speed
//!  A8 CFast vs UFast       — cluster-based IP is faster
//!
//!     cargo bench --bench ablations [-- --full for the full protocol] [--reps N]

use sclap::bench::harness::{fmt, geomean_row, BenchOpts, TableWriter};
use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::generators::instances::{large_suite, tiny_suite};
use sclap::partitioning::config::{PartitionConfig, Preset};
use std::sync::Arc;

fn main() {
    let opts = BenchOpts::from_env();
    let suite = if opts.quick {
        tiny_suite()
    } else {
        // use the complex-network subset (drop the mesh contrast) — the
        // §4 techniques target irregular graphs
        large_suite()
            .into_iter()
            .filter(|s| s.name != "mesh-contrast")
            .collect()
    };
    let ks = if opts.quick { vec![4] } else { vec![4, 16] };
    let reps = opts.reps.min(5);

    println!("== Ablations (paper §4 components, §5.1 in-text claims) ==");
    println!("instances={} k={ks:?} reps={reps}\n", suite.len());

    let graphs: Vec<Arc<sclap::graph::csr::Graph>> =
        suite.iter().map(|s| Arc::new(s.build())).collect();
    let coordinator = Coordinator::new(0);

    let mut results: Vec<(Preset, f64, f64)> = Vec::new();
    let measured: Vec<Preset> = vec![
        Preset::KaffpaEco,
        Preset::CEcoR,
        Preset::CEco,
        Preset::CEcoV,
        Preset::CEcoVB,
        Preset::CEcoVBE,
        Preset::CEcoVBEA,
        Preset::CFast,
        Preset::CFastV,
        Preset::CFastVB,
        Preset::CFastVBE,
        Preset::CFastVBEA,
        Preset::UFast,
    ];
    for preset in &measured {
        let mut cells = Vec::new();
        for g in &graphs {
            for &k in &ks {
                if k >= g.n() {
                    continue;
                }
                let agg = coordinator.partition_repeated(
                    g.clone(),
                    &PartitionConfig::preset(*preset, k),
                    &default_seeds(reps),
                );
                cells.push((agg.avg_cut, agg.best_cut as f64, agg.avg_seconds));
            }
        }
        let g = geomean_row(&cells);
        if g.zero_cut_cells > 0 || g.zero_time_cells > 0 {
            println!(
                "note: {} excluded {} zero-cut / {} zero-time cell(s) from its geomeans",
                preset.name(),
                g.zero_cut_cells,
                g.zero_time_cells
            );
        }
        results.push((*preset, g.avg_cut, g.seconds));
    }

    let get = |p: Preset| results.iter().find(|(x, _, _)| *x == p).unwrap();
    let table = TableWriter::new(&[
        ("ablation", 34),
        ("cut ratio", 10),
        ("time ratio", 10),
        ("paper says", 26),
    ]);
    table.header();
    let row = |label: &str, a: Preset, b: Preset, paper: &str| {
        let (_, ca, ta) = get(a);
        let (_, cb, tb) = get(b);
        table.row(&[
            label.into(),
            format!("{:.3}", cb / ca),
            format!("{:.2}", tb / ta),
            paper.into(),
        ]);
    };
    row("A1 matching->cluster (KaFFPaEco->CEcoR)", Preset::KaffpaEco, Preset::CEcoR, "cut 0.84, time 0.29");
    row("A2 random->degree order (CEcoR->CEco)", Preset::CEcoR, Preset::CEco, "cut 0.94, time 0.84");
    row("A3 +V-cycles (CEco->CEcoV)", Preset::CEco, Preset::CEcoV, "cut 0.98, time 1.66");
    row("A4 +coarse imbalance (CEcoV->CEcoV/B)", Preset::CEcoV, Preset::CEcoVB, "cut 0.98, time 1.08");
    row("A5 +coarse imbalance (CFastV->CFastV/B)", Preset::CFastV, Preset::CFastVB, "cut 1.04 (WORSENS)");
    row("A6 +ensembles (CFastV/B->+E)", Preset::CFastVB, Preset::CFastVBE, "cut 0.98, time 4.9");
    row("A7 +active nodes (+E->+E/A)", Preset::CFastVBE, Preset::CFastVBEA, "cut 1.00, time 0.86");
    row("A8 cluster IP (CFast->UFast)", Preset::CFast, Preset::UFast, "time 0.38 (2.7x speedup)");

    println!("\nraw geomeans:");
    let t2 = TableWriter::new(&[("config", 14), ("avg cut", 10), ("t [s]", 8)]);
    t2.header();
    for (p, c, t) in &results {
        t2.row(&[p.name().into(), fmt(*c), format!("{t:.2}")]);
    }
}
