//! Table 1 reproduction: basic properties of the benchmark instance
//! suite (our generated stand-ins for the paper's collection — each row
//! names the paper instance it models; see DESIGN.md §3). Emits
//! machine-readable rows to `BENCH_table1.json`.
//!
//!     cargo bench --bench table1 [-- --full for the full protocol]

use sclap::bench::harness::{BenchOpts, JsonReport, TableWriter};
use sclap::generators::instances::{huge_suite, large_suite, tiny_suite};
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;

fn main() {
    let opts = BenchOpts::from_env();
    let mut report = JsonReport::new("table1");
    println!("== Table 1: instance suite properties ==");
    println!("(stand-ins for the paper's SNAP/LAW/DIMACS graphs; `models` = original)\n");

    let table = TableWriter::new(&[
        ("instance", 16),
        ("models", 26),
        ("n", 10),
        ("m", 11),
        ("maxdeg", 7),
        ("gini", 6),
        ("diam≈", 6),
        ("cc", 6),
    ]);
    table.header();

    let suite = if opts.quick { tiny_suite() } else { large_suite() };
    for spec in suite {
        let t = Timer::start();
        let g = spec.build();
        let mut rng = Rng::new(1);
        let s = sclap::graph::stats::compute_stats(&g, &mut rng);
        table.row(&[
            spec.name.into(),
            spec.models.chars().take(26).collect(),
            s.n.to_string(),
            s.m.to_string(),
            s.max_degree.to_string(),
            format!("{:.2}", s.degree_gini),
            s.approx_diameter.to_string(),
            format!("{:.2}", s.clustering_coeff),
        ]);
        report.record(
            "instance",
            &[
                ("instance", spec.name.into()),
                ("models", spec.models.into()),
                ("n", s.n.into()),
                ("m", s.m.into()),
                ("max_degree", s.max_degree.into()),
                ("degree_gini", s.degree_gini.into()),
                ("approx_diameter", s.approx_diameter.into()),
                ("clustering_coeff", s.clustering_coeff.into()),
                ("build_and_stats_secs", t.elapsed_s().into()),
            ],
        );
    }

    if !opts.quick {
        println!("\n-- huge suite (Table 3/4 stand-ins; built lazily by table3) --");
        let table = TableWriter::new(&[("instance", 16), ("models", 26), ("gen", 30)]);
        table.header();
        for spec in huge_suite() {
            table.row(&[
                spec.name.into(),
                spec.models.into(),
                format!("seed {}", spec.seed),
            ]);
        }
    }
    match report.write() {
        Ok(path) => println!("\nwrote machine-readable results to {}", path.display()),
        Err(e) => eprintln!("\nfailed to write bench JSON: {e}"),
    }
    println!("\nexpectation (paper): web/social instances show high degree gini");
    println!("(scale-free) and small diameter (small-world); the mesh contrast");
    println!("instance shows gini≈0 and large diameter.");
}
