//! L1/L2 offload bench: PJRT round latency per artifact shape, offload
//! vs sequential SCLaP on coarse graphs, plus the structural VMEM/MXU
//! estimates for the §Perf record.
//!
//! NOTE (DESIGN.md §Hardware-Adaptation): interpret-mode CPU wallclock
//! is NOT a TPU proxy. The numbers here measure the *plumbing* (PJRT
//! dispatch, literal marshaling, host reconciliation); the TPU story is
//! the VMEM/MXU table at the end.
//!
//!     cargo bench --bench kernel_offload [-- --full]

use sclap::clustering::label_propagation::{size_constrained_lpa, LpaConfig};
use sclap::runtime::dense_lpa::{offload_sclap, pack_dense};
use sclap::runtime::pjrt::Runtime;
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let mut runtime = match Runtime::from_env() {
        Ok(r) => r,
        Err(e) => {
            // Default offline build: the PJRT backend is stubbed out —
            // nothing to measure, and that is not a bench failure.
            eprintln!("skipping kernel_offload bench: {e}");
            return;
        }
    };
    println!("platform: {}\n", runtime.platform());

    println!("-- PJRT round latency per artifact shape --");
    let sizes: &[usize] = if quick { &[128, 256] } else { &[128, 256, 512, 1024] };
    for &n in sizes {
        let round = runtime.round_for(n).unwrap().expect("artifact");
        let mut rng = Rng::new(n as u64);
        let g = sclap::generators::erdos_renyi(n, 4 * n, &mut rng);
        let adj = pack_dense(&g, n);
        let labels: Vec<i32> = (0..n as i32).collect();
        let node_w = vec![1f32; n];
        let mut sizes_v = vec![1f32; n];
        sizes_v.truncate(n);
        // warmup + measure
        let _ = round.execute(&adj, &labels, &sizes_v, &node_w, 16.0).unwrap();
        let iters = if quick { 5 } else { 20 };
        let t = Timer::start();
        for _ in 0..iters {
            let _ = round.execute(&adj, &labels, &sizes_v, &node_w, 16.0).unwrap();
        }
        let per = t.elapsed_s() / iters as f64;
        let flops = 2.0 * (n as f64).powi(3);
        println!(
            "  N={n:<5} round {:>8.2} ms  ({:.2} GFLOP/s interpret-mode plumbing)",
            per * 1e3,
            flops / per / 1e9
        );
    }

    println!("\n-- offloaded vs sequential SCLaP on a coarse graph --");
    let mut rng = Rng::new(9);
    let n = if quick { 400 } else { 1000 };
    let g = sclap::graph::subgraph::largest_component(
        &sclap::generators::barabasi_albert(n, 4, &mut rng),
    );
    let upper = (g.total_node_weight() / 32).max(g.max_node_weight());
    let t = Timer::start();
    let (c_off, stats) = offload_sclap(&g, upper, 10, &mut runtime).unwrap().unwrap();
    let t_off = t.elapsed_s();
    let t = Timer::start();
    let (c_seq, _) =
        size_constrained_lpa(&g, upper, &LpaConfig::default(), None, None, &mut rng);
    let t_seq = t.elapsed_s();
    println!(
        "  offload  : cut {:>7}  clusters {:>5}  {:>8.2} ms  ({} rounds, N{} artifact)",
        c_off.cut(&g),
        c_off.num_clusters,
        t_off * 1e3,
        stats.rounds,
        stats.artifact_n
    );
    println!(
        "  sequential: cut {:>7}  clusters {:>5}  {:>8.2} ms",
        c_seq.cut(&g),
        c_seq.num_clusters,
        t_seq * 1e3
    );

    println!("\n-- TPU structural estimates (the real §Perf story) --");
    println!("  blocking 128x128x128 f32:");
    println!("    VMEM/step          : 192 KiB (3 tiles) << 16 MiB/core");
    println!("    double-buffered    : 320 KiB (A+B tiles x2 + O tile)");
    for &n in &[256usize, 512, 1024] {
        let flops = 2.0 * (n as f64).powi(3);
        // MXU: 128x128x8 MACs/cycle @ ~940 MHz (v4 order of magnitude)
        let mxu_flops = 2.0 * 128.0 * 128.0 * 8.0 * 0.94e9;
        println!(
            "    N={n:<5}: {:.1} MFLOP/round, ideal MXU round time {:.1} us, util 1.00 (shapes are 128-multiples)",
            flops / 1e6,
            flops / mxu_flops * 1e6
        );
    }
    println!("  => the scoring matmul is MXU-bound with full tile utilization;");
    println!("     HBM traffic per round = (N^2 + 2NC) * 4B, streamed once (BlockSpec k-major).");
}
