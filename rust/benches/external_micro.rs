//! Microbenchmarks of the out-of-core path: external vs in-memory
//! level-0 coarsening wall time at shard counts {1, 2, 4, 8} **per
//! shard format** (`v1` raw u64 CSR vs `v2` SCLAPS2 delta+varint),
//! plus the IO report — raw shard streaming throughput (MB/s),
//! semi-external LPA round time, and a `v2_vs_v1` summary (level-0
//! speedup, streaming speedup, on-disk size ratio) per shard count —
//! emitted as `BENCH_external_micro.json` and `BENCH_external_io.json`
//! (`bench::harness::JsonReport`).
//!
//!     cargo bench --bench external_micro [-- --full]

use sclap::bench::harness::JsonReport;
use sclap::clustering::external_lpa::{dense_from_labels, external_sclap};
use sclap::clustering::label_propagation::{size_constrained_lpa, LpaConfig, NodeOrdering};
use sclap::coarsening::contract::{contract, contract_store};
use sclap::graph::csr::Graph;
use sclap::graph::store::{write_sharded_as, GraphStore, ShardFormat, ShardedStore};
use sclap::util::exec::ExecutionCtx;
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;
use std::path::PathBuf;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn temp_dir(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sclap-extbench-{}-{label}", std::process::id()))
}

/// Mean seconds per iteration of `f` (one warmup).
fn time<F: FnMut() -> u64>(iters: usize, mut f: F) -> (f64, u64) {
    let mut sink = f();
    let t = Timer::start();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    (t.elapsed_s() / iters as f64, sink)
}

fn level0_upper(g: &Graph) -> i64 {
    (g.total_node_weight() / 64).max(g.max_node_weight()).max(1)
}

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let (n, avg_degree) = if quick { (30_000, 8.0) } else { (250_000, 10.0) };
    let iters = if quick { 3 } else { 5 };
    let lpa_rounds = 3usize;

    let mut rng = Rng::new(1);
    println!("building LFR-like instance: n={n}, avg degree {avg_degree}...");
    let (g, _) = sclap::generators::lfr::lfr_like(n, avg_degree, 0.15, &mut rng);
    println!("n={} m={}\n", g.n(), g.m());

    let mut report = JsonReport::new("external_micro");
    let mut io_report = JsonReport::new("external_io");
    for r in [&mut report, &mut io_report] {
        r.record(
            "instance",
            &[
                ("kind", "lfr".into()),
                ("n", g.n().into()),
                ("m", g.m().into()),
                ("quick", quick.into()),
            ],
        );
    }

    let upper = level0_upper(&g);
    let cfg = LpaConfig::clustering(lpa_rounds, NodeOrdering::Degree);
    let ctx = ExecutionCtx::sequential();

    // ---- in-memory level-0 reference: sequential SCLaP + contract ----
    let (secs, sink) = time(iters, || {
        let mut r = Rng::new(7);
        let (c, _) = size_constrained_lpa(&g, upper, &cfg, None, None, &mut r);
        let contraction = contract(&g, &c);
        contraction.coarse.n() as u64
    });
    println!(
        "in-memory level-0 (sequential SCLaP + contract)   {:>8.1} ms (coarse n {sink})",
        secs * 1e3
    );
    report.record(
        "in_memory_level0",
        &[
            ("engine", "sequential_sclap".into()),
            ("secs", secs.into()),
            ("medges_per_s", (g.m() as f64 * lpa_rounds as f64 / secs / 1e6).into()),
        ],
    );

    // ---- external level-0 at shard counts {1, 2, 4, 8} × {v1, v2} ----
    for shards in SHARD_COUNTS {
        // Per-format numbers this shard count, indexed like ALL
        // ([v1, v2]), feeding the `v2_vs_v1` summary record.
        let mut level0_secs = [0.0f64; 2];
        let mut streaming_secs = [0.0f64; 2];
        let mut size_bytes = [0u64; 2];
        for (fi, format) in ShardFormat::ALL.into_iter().enumerate() {
            let fmt = format.name();
            let dir = temp_dir(&format!("{fmt}-s{shards}"));
            let _ = std::fs::remove_dir_all(&dir);
            let store: ShardedStore = write_sharded_as(&g, &dir, shards, format).unwrap();
            let disk_bytes = store.disk_bytes().unwrap();
            size_bytes[fi] = disk_bytes;

            // level-0 coarsening: semi-external SCLaP + streaming contract
            let (secs, sink) = time(iters, || {
                let (labels, _) =
                    external_sclap(&store, upper, &cfg, None, &ctx, &mut Rng::new(7)).unwrap();
                let clustering = dense_from_labels(store.node_weights(), labels);
                let contraction = contract_store(&store, &clustering).unwrap();
                contraction.coarse.n() as u64
            });
            level0_secs[fi] = secs;
            println!(
                "external level-0, {fmt}, {shards} shard(s)             {:>8.1} ms (coarse n {sink})",
                secs * 1e3
            );
            let level0_fields = [
                ("format", fmt.into()),
                ("shards", shards.into()),
                ("secs", secs.into()),
                ("medges_per_s", (g.m() as f64 * lpa_rounds as f64 / secs / 1e6).into()),
            ];
            report.record("external_level0", &level0_fields);
            let mut io_fields = level0_fields.to_vec();
            io_fields.push(("disk_bytes", (disk_bytes as usize).into()));
            io_report.record("external_level0", &io_fields);

            // raw shard streaming throughput: one full pass over the shards
            let (secs, arcs) = time(iters, || {
                let mut cursor = store.cursor();
                let mut total = 0u64;
                for s in 0..store.num_shards() {
                    let view = cursor.load(s).unwrap();
                    total += view.arc_count() as u64;
                }
                total
            });
            streaming_secs[fi] = secs;
            let mb_per_s = disk_bytes as f64 / secs / (1 << 20) as f64;
            println!(
                "shard streaming, {fmt}, {shards} shard(s)              {:>8.1} ms   {:>7.1} MB/s ({arcs} arcs)",
                secs * 1e3,
                mb_per_s
            );
            io_report.record(
                "shard_streaming",
                &[
                    ("format", fmt.into()),
                    ("shards", shards.into()),
                    ("secs", secs.into()),
                    ("disk_bytes", (disk_bytes as usize).into()),
                    ("mb_per_s", mb_per_s.into()),
                ],
            );

            // one semi-external LPA round
            let round_cfg = LpaConfig::clustering(1, NodeOrdering::Degree);
            let (secs, _) = time(iters, || {
                external_sclap(&store, upper, &round_cfg, None, &ctx, &mut Rng::new(7))
                    .unwrap()
                    .1 as u64
            });
            println!(
                "external LPA round, {fmt}, {shards} shard(s)           {:>8.1} ms",
                secs * 1e3
            );
            io_report.record(
                "external_lpa_round",
                &[
                    ("format", fmt.into()),
                    ("shards", shards.into()),
                    ("round_secs", secs.into()),
                    ("medges_per_s", (g.m() as f64 / secs / 1e6).into()),
                ],
            );

            let _ = std::fs::remove_dir_all(&dir);
        }

        // v2-vs-v1 summary: the ratios the CI regression gate checks
        // (ISSUE acceptance: level0_speedup ≥ 1.5 and size_ratio ≤ 0.6
        // at shards {2, 4, 8}).
        let level0_speedup = level0_secs[0] / level0_secs[1];
        let streaming_speedup = streaming_secs[0] / streaming_secs[1];
        let size_ratio = size_bytes[1] as f64 / size_bytes[0] as f64;
        println!(
            "v2 vs v1, {shards} shard(s): level-0 {level0_speedup:.2}x, streaming \
             {streaming_speedup:.2}x, size {size_ratio:.3}x\n"
        );
        io_report.record(
            "v2_vs_v1",
            &[
                ("shards", shards.into()),
                ("level0_speedup", level0_speedup.into()),
                ("streaming_speedup", streaming_speedup.into()),
                ("size_ratio", size_ratio.into()),
            ],
        );
    }

    let path = report.write().expect("write BENCH_external_micro.json");
    println!("\nwrote {}", path.display());
    let path = io_report.write().expect("write BENCH_external_io.json");
    println!("wrote {}", path.display());
}
