//! Table 2 reproduction: avg cut / best cut / time for every
//! configuration of §5.1, geometric-mean aggregated across the instance
//! suite and the paper's k sweep {2,4,8,16,32,64}, ε = 3%.
//!
//!     cargo bench --bench table2           # quick (default)
//!     cargo bench --bench table2 -- --full       # full protocol (hours)
//!     cargo bench --bench table2 -- --reps 5 --k 4,16
//!
//! Expected shape (paper Table 2): CStrong/UStrong best quality;
//! UEcoV/B ≈ hMetis-like quality at ~10x less time; Fast family fastest
//! among ours; Scotch-like worst quality; kMetis-like fastest overall
//! but cutting more than the Fast family.

use sclap::bench::harness::{fmt, geomean_row, BenchOpts, TableWriter};
use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::generators::instances::{large_suite, tiny_suite};
use sclap::partitioning::config::{PartitionConfig, Preset};
use std::sync::Arc;

fn main() {
    let opts = BenchOpts::from_env();
    let suite = if opts.quick { tiny_suite() } else { large_suite() };
    let ks = opts.k_sweep();
    let reps = opts.reps;

    println!("== Table 2: configuration comparison ==");
    println!(
        "instances={} k={ks:?} reps={reps} (geomean across instance x k cells)\n",
        suite.len()
    );

    // Build instances once.
    let graphs: Vec<(String, Arc<sclap::graph::csr::Graph>)> = suite
        .iter()
        .map(|s| (s.name.to_string(), Arc::new(s.build())))
        .collect();

    let coordinator = Coordinator::new(0);
    let table = TableWriter::new(&[
        ("Algorithm", 14),
        ("avg cut", 10),
        ("best cut", 10),
        ("t [s]", 8),
    ]);
    table.header();

    // In quick mode skip the slowest configurations so the bench stays
    // CI-sized; the full run covers all 22 (paper order).
    let presets: Vec<Preset> = Preset::ALL
        .into_iter()
        .filter(|p| {
            !opts.quick
                || !matches!(
                    p,
                    Preset::CStrong
                        | Preset::UStrong
                        | Preset::KaffpaStrong
                        | Preset::HMetisLike
                )
        })
        .collect();

    for preset in presets {
        let mut cells = Vec::new();
        for (_, g) in &graphs {
            for &k in &ks {
                if k >= g.n() {
                    continue;
                }
                let agg = coordinator.partition_repeated(
                    g.clone(),
                    &PartitionConfig::preset(preset, k),
                    &default_seeds(reps),
                );
                cells.push((agg.avg_cut, agg.best_cut as f64, agg.avg_seconds));
            }
        }
        let g = geomean_row(&cells);
        // Zero cells (disconnected draws, sub-resolution timings) are
        // excluded from the geomeans, not epsilon-clamped; mark the
        // affected cells so the row is never compared against a
        // full-cell row unawares.
        table.row(&[
            format!("{}{}", preset.name(), g.zero_marker()),
            fmt(g.avg_cut),
            fmt(g.best_cut),
            format!("{:.2}{}", g.seconds, g.time_marker()),
        ]);
    }

    println!("\npaper reference rows (Table 2, absolute values on the real");
    println!("instance set — compare *ordering and ratios*, not magnitudes):");
    println!("  CEcoR 71814/10.2s  CEco 67222/8.6s  CEcoV/B 64585/15.5s");
    println!("  CFast 68839/3.9s   UFast 69170/1.5s UEcoV/B 65212/11.5s");
    println!("  CStrong 60179/422s UStrong 59936/296s");
    println!("  KaFFPaEco 85920/36.2s  Scotch 104955/10.6s");
    println!("  kMetis 71978/0.4s  hMetis 65410/107.4s");
}
