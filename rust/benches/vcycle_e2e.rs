//! End-to-end V-cycle benchmark for the zero-steady-state-allocation
//! workspace (`partitioning::workspace`): wall-clock throughput of warm
//! V-cycled partitioning — in-memory at thread counts {1, 4} and
//! out-of-core through the sharded store — on one shared
//! [`ExecutionCtx`], after a cold run has stocked the arena. Alongside
//! the timings, the workspace's own counters are reported as a
//! peak-scratch-RSS proxy: `peak_lease_bytes` (high-water mark of
//! simultaneously leased scratch) and `leases_created` vs
//! `fresh_allocations` (steady-state reuse ratio). Emitted as
//! `BENCH_vcycle_e2e.json` (`bench::harness::JsonReport`); the
//! committed baseline is deliberately conservative so the CI
//! regression gate (scripts/bench_compare.py) only trips on real
//! slowdowns.
//!
//!     cargo bench --bench vcycle_e2e [-- --full]

use sclap::bench::harness::JsonReport;
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::external::partition_store_with_ctx;
use sclap::partitioning::multilevel::MultilevelPartitioner;
use sclap::util::exec::ExecutionCtx;
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;
use std::path::PathBuf;
use std::sync::Arc;

const K: usize = 32;

fn temp_dir(label: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sclap-vcycle-{}-{label}", std::process::id()))
}

/// Mean seconds per iteration of `f` (the caller does the warmup).
fn time<F: FnMut() -> u64>(iters: usize, mut f: F) -> (f64, u64) {
    let mut sink = 0u64;
    let t = Timer::start();
    for _ in 0..iters {
        sink = sink.wrapping_add(f());
    }
    (t.elapsed_s() / iters as f64, sink)
}

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let (n, avg_degree) = if quick { (30_000, 8.0) } else { (200_000, 10.0) };
    let iters = if quick { 3 } else { 5 };

    let mut rng = Rng::new(1);
    println!("building LFR-like instance: n={n}, avg degree {avg_degree}...");
    let (g, _) = sclap::generators::lfr::lfr_like(n, avg_degree, 0.15, &mut rng);
    println!("n={} m={}\n", g.n(), g.m());

    let mut report = JsonReport::new("vcycle_e2e");
    report.record(
        "instance",
        &[
            ("kind", "lfr".into()),
            ("n", g.n().into()),
            ("m", g.m().into()),
            ("quick", quick.into()),
        ],
    );

    // ---- in-memory V-cycles (CFastV: 3 cycles) at threads {1, 4} ----
    for threads in [1usize, 4] {
        let ctx = Arc::new(ExecutionCtx::new(threads));
        let mut config = PartitionConfig::preset(Preset::CFastV, K);
        if threads > 1 {
            // Exercise the per-worker arena shards, not just the
            // caller's: parallel engines lease scratch lock-free from
            // their own shard.
            config.parallel_coarsening = true;
            config.parallel_refinement = true;
        }
        let partitioner = MultilevelPartitioner::with_ctx(config, ctx.clone());

        // Cold run: stocks the arena (and is itself worth a record —
        // the cold/warm delta is what the workspace buys).
        let t = Timer::start();
        let cold_cut = partitioner.partition(&g, 42).metrics.cut;
        let cold_secs = t.elapsed_s();
        let cold_stats = ctx.workspace().stats();

        let (secs, sink) = time(iters, || partitioner.partition(&g, 42).metrics.cut as u64);
        let warm_stats = ctx.workspace().stats();
        assert_eq!(
            sink,
            cold_cut as u64 * iters as u64,
            "warm runs must reproduce the cold partition bit for bit"
        );
        if threads == 1 {
            // Sequential pipeline: lease traffic is deterministic, so
            // steady state is exact — warm runs fresh-allocate nothing.
            assert_eq!(
                warm_stats.fresh_allocations, cold_stats.fresh_allocations,
                "warm V-cycle runs fresh-allocated scratch"
            );
        }
        let medges = g.m() as f64 / secs / 1e6;
        println!(
            "in-memory CFastV k={K}, {threads} thread(s)   cold {:>8.1} ms, warm {:>8.1} ms \
             ({medges:.2} Medges/s, peak lease {} KiB, {} leases / {} fresh)",
            cold_secs * 1e3,
            secs * 1e3,
            warm_stats.peak_lease_bytes / 1024,
            warm_stats.leases_created,
            warm_stats.fresh_allocations,
        );
        report.record(
            "vcycle_cold",
            &[
                ("engine", "in_memory".into()),
                ("threads", threads.into()),
                ("k", K.into()),
                ("secs", cold_secs.into()),
            ],
        );
        report.record(
            "vcycle_warm",
            &[
                ("engine", "in_memory".into()),
                ("threads", threads.into()),
                ("k", K.into()),
                ("secs", secs.into()),
                ("medges_per_s", medges.into()),
            ],
        );
        report.record(
            "workspace",
            &[
                ("engine", "in_memory".into()),
                ("threads", threads.into()),
                ("k", K.into()),
                ("peak_lease_bytes", warm_stats.peak_lease_bytes.into()),
                ("leases_created", (warm_stats.leases_created as usize).into()),
                (
                    "fresh_allocations",
                    (warm_stats.fresh_allocations as usize).into(),
                ),
            ],
        );
    }

    // ---- out-of-core: the same instance through SCLAPS2 shards ----
    {
        let dir = temp_dir("shards");
        let _ = std::fs::remove_dir_all(&dir);
        let store = sclap::graph::store::write_sharded_as(
            &g,
            &dir,
            4,
            sclap::graph::store::ShardFormat::V2,
        )
        .unwrap();
        let ctx = Arc::new(ExecutionCtx::new(4));
        let mut config = PartitionConfig::preset(Preset::CFast, K);
        config.memory_budget_bytes = Some(1); // force the external path

        let t = Timer::start();
        let cold = partition_store_with_ctx(&store, &config, 42, &ctx).unwrap();
        let cold_secs = t.elapsed_s();
        assert!(cold.external_levels >= 1, "external path not taken");

        let (secs, _) = time(iters, || {
            partition_store_with_ctx(&store, &config, 42, &ctx).unwrap().cut as u64
        });
        let warm_stats = ctx.workspace().stats();
        let medges = g.m() as f64 / secs / 1e6;
        println!(
            "out-of-core CFast k={K}, 4 shards (v2)       cold {:>8.1} ms, warm {:>8.1} ms \
             ({medges:.2} Medges/s, peak lease {} KiB)",
            cold_secs * 1e3,
            secs * 1e3,
            warm_stats.peak_lease_bytes / 1024,
        );
        report.record(
            "vcycle_warm",
            &[
                ("engine", "out_of_core".into()),
                ("threads", 4usize.into()),
                ("k", K.into()),
                ("secs", secs.into()),
                ("medges_per_s", medges.into()),
            ],
        );
        report.record(
            "workspace",
            &[
                ("engine", "out_of_core".into()),
                ("threads", 4usize.into()),
                ("k", K.into()),
                ("peak_lease_bytes", warm_stats.peak_lease_bytes.into()),
                ("leases_created", (warm_stats.leases_created as usize).into()),
                (
                    "fresh_allocations",
                    (warm_stats.fresh_allocations as usize).into(),
                ),
            ],
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    let path = report.write().expect("write BENCH_vcycle_e2e.json");
    println!("\nwrote {}", path.display());
}
