//! Service-layer throughput: requests/sec and per-request latency
//! through the batching service with the content-addressed result
//! cache cold vs. warm, plus the single-flight fan-in case — the perf
//! trajectory seed for the network service layer. Emits
//! `BENCH_serve_throughput.json` (`bench::harness::JsonReport`).
//!
//!     cargo bench --bench serve_throughput [-- --full]

use sclap::bench::harness::JsonReport;
use sclap::coordinator::net::CachedService;
use sclap::coordinator::queue::{GraphHandle, Request, ServiceConfig};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::util::rng::Rng;
use sclap::util::timer::Timer;
use std::sync::Arc;

fn request(graph: &Arc<sclap::graph::csr::Graph>, k: usize, seed: u64) -> Request {
    Request::new(
        format!("bench-k{k}-s{seed}"),
        GraphHandle::InMemory(graph.clone()),
        PartitionConfig::preset(Preset::CFast, k),
        vec![seed],
    )
}

fn main() {
    let quick = !std::env::args().any(|a| a == "--full");
    let (n, avg_degree) = if quick { (20_000, 8.0) } else { (100_000, 10.0) };
    let distinct = if quick { 8usize } else { 24 };
    let warm_rounds = if quick { 3usize } else { 5 };

    let mut rng = Rng::new(1);
    println!("building LFR-like instance: n={n}, avg degree {avg_degree}...");
    let (g, _) = sclap::generators::lfr::lfr_like(n, avg_degree, 0.15, &mut rng);
    let graph = Arc::new(g);
    println!("n={} m={}\n", graph.n(), graph.m());

    let mut report = JsonReport::new("serve_throughput");
    report.record(
        "instance",
        &[
            ("kind", "lfr".into()),
            ("n", graph.n().into()),
            ("m", graph.m().into()),
            ("quick", quick.into()),
            ("distinct_requests", distinct.into()),
        ],
    );

    let service = CachedService::new(
        ServiceConfig {
            workers: 0,
            max_pending: 64,
        },
        128,
    );

    // ---- cold: every request is a distinct key (seed sweep) ----
    let mut cold_lat = Vec::with_capacity(distinct);
    let t = Timer::start();
    for seed in 0..distinct as u64 {
        let t1 = Timer::start();
        let (_, cached) = service.run(request(&graph, 8, seed + 1), true).unwrap();
        assert!(!cached);
        cold_lat.push(t1.elapsed_s());
    }
    let cold_total = t.elapsed_s();
    let cold_rps = distinct as f64 / cold_total;
    let cold_mean = cold_lat.iter().sum::<f64>() / cold_lat.len() as f64;
    println!(
        "cold : {distinct} requests in {:>7.2} ms  ({cold_rps:>8.1} req/s, mean latency {:>7.2} ms)",
        cold_total * 1e3,
        cold_mean * 1e3
    );

    // ---- warm: the same requests again, repeatedly — pure hits ----
    let warm_n = distinct * warm_rounds;
    let mut warm_lat = Vec::with_capacity(warm_n);
    let t = Timer::start();
    for round in 0..warm_rounds {
        for seed in 0..distinct as u64 {
            let t1 = Timer::start();
            let (_, cached) = service.run(request(&graph, 8, seed + 1), true).unwrap();
            assert!(cached, "round {round}: warm request must hit");
            warm_lat.push(t1.elapsed_s());
        }
    }
    let warm_total = t.elapsed_s();
    let warm_rps = warm_n as f64 / warm_total;
    let warm_mean = warm_lat.iter().sum::<f64>() / warm_lat.len() as f64;
    println!(
        "warm : {warm_n} requests in {:>7.2} ms  ({warm_rps:>8.1} req/s, mean latency {:>7.2} ms)",
        warm_total * 1e3,
        warm_mean * 1e3
    );
    // A warm hit still streams the graph fingerprint — that is the
    // floor on hit latency and worth tracking on its own.
    println!(
        "       speedup {:.1}x (hit latency ≈ fingerprint stream)",
        cold_mean / warm_mean.max(1e-12)
    );

    // ---- fan-in: N concurrent identical requests, one computation ----
    let fan = if quick { 8usize } else { 32 };
    let fan_service = CachedService::new(
        ServiceConfig {
            workers: 0,
            max_pending: 64,
        },
        128,
    );
    let fan_service = Arc::new(fan_service);
    let t = Timer::start();
    let threads: Vec<_> = (0..fan)
        .map(|i| {
            let svc = fan_service.clone();
            let graph = graph.clone();
            std::thread::spawn(move || {
                let (_, cached) = svc
                    .run(request(&graph, 8, 999), true)
                    .expect("fan-in request succeeds");
                (i, cached)
            })
        })
        .collect();
    let mut cached_count = 0usize;
    for t in threads {
        if t.join().unwrap().1 {
            cached_count += 1;
        }
    }
    let fan_total = t.elapsed_s();
    let stats = fan_service.stats();
    println!(
        "fan-in: {fan} identical concurrent requests in {:>7.2} ms — {} computation(s), {cached_count} served by single-flight/cache",
        fan_total * 1e3,
        stats.misses
    );

    report.record(
        "throughput",
        &[
            ("cold_requests", distinct.into()),
            ("cold_seconds", cold_total.into()),
            ("cold_req_per_s", cold_rps.into()),
            ("cold_mean_latency_s", cold_mean.into()),
            ("warm_requests", warm_n.into()),
            ("warm_seconds", warm_total.into()),
            ("warm_req_per_s", warm_rps.into()),
            ("warm_mean_latency_s", warm_mean.into()),
            ("warm_speedup", (cold_mean / warm_mean.max(1e-12)).into()),
        ],
    );
    report.record(
        "fan_in",
        &[
            ("threads", fan.into()),
            ("seconds", fan_total.into()),
            ("computations", (stats.misses as usize).into()),
            ("dedup_served", cached_count.into()),
        ],
    );
    let path = report.write().expect("write BENCH_serve_throughput.json");
    println!("\nwrote {}", path.display());
}
