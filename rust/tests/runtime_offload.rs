//! Integration: the PJRT runtime + dense-LPA offload (requires the AOT
//! artifacts — `make artifacts` — which `make test` guarantees).
//!
//! Checks DESIGN.md invariant 7: the offloaded clustering satisfies the
//! same size constraint as the sequential path, and quality is in the
//! same regime.

use sclap::clustering::label_propagation::{size_constrained_lpa, LpaConfig};
use sclap::generators;
use sclap::graph::karate_club;
use sclap::runtime::dense_lpa::offload_sclap;
use sclap::runtime::pjrt::Runtime;
use sclap::util::rng::Rng;
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::new(&dir) {
        Ok(r) => Some(r),
        Err(e) => {
            // Default build: the PJRT backend is stubbed out (no `xla`
            // crate offline) and/or the artifacts are not built, so the
            // execution tests skip. Set SCLAP_REQUIRE_RUNTIME_TESTS in
            // an environment with `--features pjrt` + `make artifacts`
            // to make a silent skip impossible.
            if std::env::var("SCLAP_REQUIRE_RUNTIME_TESTS").is_ok() {
                panic!("PJRT runtime required but unavailable: {e}");
            }
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn offload_respects_size_constraint() {
    let Some(mut rt) = runtime() else { return };
    let g = karate_club();
    for upper in [3i64, 6, 10] {
        let (c, stats) = offload_sclap(&g, upper, 10, &mut rt)
            .expect("execute")
            .expect("karate fits smallest artifact");
        assert!(
            c.respects_bound(upper),
            "U={upper}: {:?}",
            c.cluster_weights.iter().max()
        );
        assert!(stats.rounds >= 1);
        assert_eq!(stats.artifact_n, 128);
    }
}

#[test]
fn offload_quality_comparable_to_sequential() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(5);
    let g = generators::barabasi_albert(120, 3, &mut rng);
    let upper = 12i64;
    let (off, _) = offload_sclap(&g, upper, 10, &mut rt)
        .expect("execute")
        .expect("fits");
    let (seq, _) = size_constrained_lpa(&g, upper, &LpaConfig::default(), None, None, &mut rng);
    // Both must find real structure; the synchronous variant may differ
    // but should be within 2x of the sequential cut.
    let (co, cs) = (off.cut(&g), seq.cut(&g));
    assert!(off.num_clusters < g.n(), "no merging happened");
    assert!(
        co <= cs * 2 + 20,
        "offload cut {co} way worse than sequential {cs}"
    );
}

#[test]
fn artifact_selection_picks_smallest_fit() {
    let Some(mut rt) = runtime() else { return };
    assert_eq!(rt.max_n(), 1024);
    let r = rt.round_for(34).unwrap().unwrap();
    assert_eq!(r.n, 128);
    let r = rt.round_for(129).unwrap().unwrap();
    assert_eq!(r.n, 256);
    let r = rt.round_for(1024).unwrap().unwrap();
    assert_eq!(r.n, 1024);
    assert!(rt.round_for(1025).unwrap().is_none());
}

#[test]
fn oversized_graph_returns_none() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(6);
    let g = generators::erdos_renyi(2000, 4000, &mut rng);
    let out = offload_sclap(&g, 50, 3, &mut rt).expect("no crash");
    assert!(out.is_none());
}

#[test]
fn compiled_round_rejects_bad_shapes() {
    let Some(mut rt) = runtime() else { return };
    let round = rt.round_for(10).unwrap().unwrap();
    let n = round.n;
    let err = round.execute(
        &vec![0f32; n], // wrong: should be n*n
        &vec![0i32; n],
        &vec![0f32; n],
        &vec![0f32; n],
        1.0,
    );
    assert!(err.is_err());
}

#[test]
fn offload_applies_only_positive_gain() {
    let Some(mut rt) = runtime() else { return };
    // A graph already at its LPA fixed point: two disjoint triangles with
    // U=3 — after the first convergence, further rounds apply nothing.
    let mut b = sclap::graph::builder::GraphBuilder::new(6);
    for base in [0u32, 3] {
        b.add_edge(base, base + 1, 1);
        b.add_edge(base + 1, base + 2, 1);
        b.add_edge(base, base + 2, 1);
    }
    let g = b.build();
    let (c, stats) = offload_sclap(&g, 3, 10, &mut rt)
        .expect("execute")
        .expect("fits");
    assert_eq!(c.num_clusters, 2);
    assert_eq!(c.cut(&g), 0);
    // converged well before the round cap
    assert!(stats.rounds < 10);
}
