//! Integration: deterministic cooperative cancellation (`util::cancel`
//! threaded through the queue, the scheduler, and the net layer).
//!
//! The contract under test (ISSUE 9 acceptance):
//!
//! 1. a cancel token that never fires changes **no result byte** —
//!    across worker counts {1, 4} and both storage backends, with or
//!    without an armed (but unexpired) deadline;
//! 2. an ensemble `race=` request's winning aggregate is
//!    **byte-identical** to running the winning config alone;
//! 3. cancellation — deadline timeout, abandoned ticket, race loss,
//!    client disconnect — frees queue slots and arena leases, and the
//!    service keeps serving deterministically afterward.

use sclap::coordinator::net::{parse_response, NetClient, NetServer, NetServerConfig};
use sclap::coordinator::queue::spec::render_result_line;
use sclap::coordinator::queue::{
    BatchService, GraphHandle, RaceEntry, Request, ServiceConfig, SubmitError,
};
use sclap::coordinator::service::{Aggregate, Coordinator, RunOutcome};
use sclap::graph::csr::{Graph, Weight};
use sclap::graph::karate_club;
use sclap::graph::store::{write_sharded, ShardedStore};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::util::cancel::CancelReason;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The deterministic projection of an `Aggregate` (same shape as
/// `tests/batch_queue.rs`): everything except the wall-clock fields.
type Det = (
    Vec<(u64, Weight, bool, Vec<u32>)>,
    String, // avg_cut, via its exact decimal rendering
    Weight, // best_cut
    Vec<u32>,
    usize, // infeasible_runs
);

fn det(agg: &Aggregate) -> Det {
    (
        agg.runs
            .iter()
            .map(|r| (r.seed, r.cut, r.feasible, r.blocks.clone()))
            .collect(),
        format!("{}", agg.avg_cut),
        agg.best_cut,
        agg.best_blocks.clone(),
        agg.infeasible_runs,
    )
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sclap-cancel-{tag}-{}", std::process::id()))
}

/// Community instance big enough for the budget-1 external path (same
/// parameters as `tests/batch_queue.rs`).
fn lfr() -> Graph {
    let mut rng = sclap::util::rng::Rng::new(4);
    sclap::generators::lfr::lfr_like(1200, 6.0, 0.15, &mut rng).0
}

fn karate_request(id: &str, graph: &Arc<Graph>, seeds: Vec<u64>) -> Request {
    Request::new(
        id,
        GraphHandle::InMemory(graph.clone()),
        PartitionConfig::preset(Preset::CFast, 2),
        seeds,
    )
}

// ---------------------------------------------------------------------
// Invariant 1: an unfired token changes no result byte.
// ---------------------------------------------------------------------

#[test]
fn unfired_token_changes_no_result_byte() {
    let karate = Arc::new(karate_club());
    let community = Arc::new(lfr());
    let dir = temp_dir("unfired");
    write_sharded(&community, &dir, 3).unwrap();
    let mut budgeted = PartitionConfig::preset(Preset::CFast, 4);
    budgeted.memory_budget_bytes = Some(1); // force the external path

    // Serial references, computed exactly like the queue computes them.
    let mem_config = PartitionConfig::preset(Preset::CFast, 2);
    let mem_seeds = vec![1u64, 2, 3];
    let mem_expected = det(&Coordinator::new(2).partition_repeated(
        karate.clone(),
        &mem_config,
        &mem_seeds,
    ));
    let coord = Coordinator::new(2);
    let store = ShardedStore::open(&dir).unwrap();
    let shard_seeds = vec![3u64, 4];
    let shard_runs: Vec<RunOutcome> = shard_seeds
        .iter()
        .map(|&s| {
            RunOutcome::from_out_of_core(s, &coord.partition_store(&store, &budgeted, s).unwrap())
        })
        .collect();
    let shard_expected = det(&Aggregate::from_runs(shard_runs));
    drop(store);

    for workers in [1usize, 4] {
        let service = BatchService::new(ServiceConfig {
            workers,
            max_pending: 8,
        });
        // Every request carries a live token; "armed" variants also
        // carry a far-future deadline (one hour — never expires inside
        // the test). Neither may change a byte of the result.
        let mem_plain = karate_request("mem-plain", &karate, mem_seeds.clone());
        let mut mem_armed = karate_request("mem-armed", &karate, mem_seeds.clone());
        mem_armed.timeout_ms = Some(3_600_000);
        let shard_plain = Request::new(
            "shard-plain",
            GraphHandle::Shards(dir.clone()),
            budgeted.clone(),
            shard_seeds.clone(),
        );
        let mut shard_armed = shard_plain.clone(); // clone = fresh token
        shard_armed.id = "shard-armed".into();
        shard_armed.timeout_ms = Some(3_600_000);

        let tickets: Vec<_> = [mem_plain, mem_armed, shard_plain, shard_armed]
            .into_iter()
            .map(|r| service.submit(r).unwrap())
            .collect();
        let results: Vec<Det> = tickets
            .into_iter()
            .map(|t| det(&t.wait().unwrap_or_else(|e| panic!("workers={workers}: {e}"))))
            .collect();
        assert_eq!(results[0], mem_expected, "workers={workers}: plain mem");
        assert_eq!(results[1], mem_expected, "workers={workers}: armed mem");
        assert_eq!(results[2], shard_expected, "workers={workers}: plain shards");
        assert_eq!(results[3], shard_expected, "workers={workers}: armed shards");
        // No cancellation happened anywhere.
        assert_eq!(service.ctx().metrics().counter("requests_cancelled").get(), 0);
        service.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Invariant 2: the race winner is byte-identical to running it alone.
// ---------------------------------------------------------------------

#[test]
fn race_winner_is_byte_identical_to_running_the_winner_alone() {
    let karate = Arc::new(karate_club());
    let seeds = vec![1u64, 2, 3];
    let racers = [
        ("CFast", PartitionConfig::preset(Preset::CFast, 2)),
        ("UFast", PartitionConfig::preset(Preset::UFast, 2)),
    ];

    // Decide the winner offline, exactly like the scheduler does: each
    // racer runs the first seed; lowest cut wins, ties break on race
    // order. Then the whole-request reference is the winning config
    // alone over every seed.
    let coord = Coordinator::new(2);
    let first_cuts: Vec<Weight> = racers
        .iter()
        .map(|(_, config)| {
            coord
                .partition_repeated(karate.clone(), config, &seeds[..1])
                .best_cut
        })
        .collect();
    let winner = (0..racers.len())
        .min_by_key(|&i| (first_cuts[i], i))
        .unwrap();
    let expected = det(&coord.partition_repeated(karate.clone(), &racers[winner].1, &seeds));

    for workers in [1usize, 4] {
        let service = BatchService::new(ServiceConfig {
            workers,
            max_pending: 8,
        });
        let mut request = karate_request("race", &karate, seeds.clone());
        request.race = racers
            .iter()
            .map(|(name, config)| RaceEntry {
                name: (*name).to_string(),
                config: config.clone(),
            })
            .collect();
        let agg = service.submit(request).unwrap().wait().unwrap();
        assert_eq!(
            det(&agg),
            expected,
            "workers={workers}: race aggregate must be byte-identical to \
             running the winning config alone"
        );
        let metrics = service.ctx().metrics();
        assert_eq!(metrics.counter("race_losers_cancelled").get(), 1);
        assert_eq!(metrics.counter("requests_cancelled").get(), 0, "the request itself completed");
        service.shutdown();
    }
}

// ---------------------------------------------------------------------
// Invariant 3: cancellation frees queue slots and arena leases, and
// the service keeps serving deterministically afterward.
// ---------------------------------------------------------------------

#[test]
fn cancellation_frees_slots_and_leases_and_the_service_keeps_serving() {
    let karate = Arc::new(karate_club());
    let reference = det(&Coordinator::new(2).partition_repeated(
        karate.clone(),
        &PartitionConfig::preset(Preset::CFast, 2),
        &[1, 2, 3],
    ));

    let service = BatchService::new(ServiceConfig {
        workers: 2,
        max_pending: 2,
    });
    let ctx = service.ctx().clone();
    // Pause so both doomed requests are still queued when their tokens
    // fire — cancellation deterministically precedes any dispatch.
    service.pause();
    let mut doomed = karate_request("doomed", &karate, vec![1, 2, 3]);
    doomed.timeout_ms = Some(1); // armed at submission, expires below
    let doomed = service.submit(doomed).unwrap();
    let walkaway = service
        .submit(karate_request("walkaway", &karate, vec![1, 2, 3]))
        .unwrap();
    drop(walkaway); // fires Abandoned
    // Both slots are genuinely held until the scheduler reaps.
    match service.try_submit(karate_request("overflow", &karate, vec![9])) {
        Err(SubmitError::Busy) => {}
        other => panic!("queue at max_pending must report Busy, got {other:?}"),
    }
    // Let the 1 ms deadline pass unambiguously, then release the
    // scheduler: its pre-dispatch poll reaps both requests as cancelled.
    let armed_at = Instant::now();
    while armed_at.elapsed() < Duration::from_millis(20) {
        std::thread::sleep(Duration::from_millis(5));
    }
    service.resume();
    let err = doomed.wait().unwrap_err();
    assert_eq!(err.id, "doomed");
    assert_eq!(err.cancelled, Some(CancelReason::Timeout), "{err}");
    assert!(err.message.contains("timeout"), "{err}");

    // The freed slots accept new work (blocking submit would deadlock
    // the test if cancellation leaked slots), and results are
    // byte-identical to the serial reference — cancelled neighbours
    // never perturb live work.
    let tickets: Vec<_> = (0..3)
        .map(|i| {
            service
                .submit(karate_request(&format!("after-{i}"), &karate, vec![1, 2, 3]))
                .unwrap()
        })
        .collect();
    for t in tickets {
        assert_eq!(det(&t.wait().unwrap()), reference);
    }

    let metrics = ctx.metrics();
    assert_eq!(metrics.counter("requests_cancelled").get(), 2);
    assert_eq!(metrics.counter("cancel_reason_timeout").get(), 1);
    assert_eq!(metrics.counter("cancel_reason_abandoned").get(), 1);
    assert_eq!(metrics.counter("requests_completed").get(), 3);
    service.shutdown();
    // Every arena lease returned — cancelled or completed alike.
    assert_eq!(ctx.workspace().stats().current_lease_bytes, 0);
}

/// The net layer: an abruptly vanishing client must leave the server
/// healthy, and later clients must receive responses byte-identical to
/// the offline rendering. (The disconnect-abort *cancellation* itself
/// is timing-dependent — the invariant here is that it is never
/// observable in anyone else's bytes.)
#[test]
fn disconnect_leaves_the_server_healthy_and_deterministic() {
    let tiny_ba = Arc::new(
        sclap::generators::instances::by_name("tiny-ba")
            .unwrap()
            .build(),
    );
    let config = PartitionConfig::preset(Preset::CFast, 2);
    let agg = Coordinator::new(2).partition_repeated(tiny_ba.clone(), &config, &[1, 2]);
    let expected = render_result_line("after", &agg, false);

    let server = NetServer::bind(
        "127.0.0.1:0",
        NetServerConfig {
            workers: 2,
            max_pending: 8,
            cache_entries: 0, // no cache: every response is a fresh computation
            timing: false,
            trace: None,
            journal: None,
        },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());

    // Client A submits work and vanishes without reading a byte.
    let mut rude = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    rude.send_line("id=vanishing instance=tiny-ba k=2 preset=CFast seeds=1,2")
        .unwrap();
    drop(rude);

    // Client B (twice, to cover "keeps serving") gets byte-identical
    // results regardless of what happened to client A's request.
    for round in 0..2 {
        let mut polite = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
        let line = polite
            .request("id=after instance=tiny-ba k=2 preset=CFast seeds=1,2")
            .unwrap();
        assert_eq!(line, expected, "round {round}");
        assert_eq!(parse_response(&line).unwrap().status, "ok");
    }
    handle.shutdown();
    runner.join().unwrap().unwrap();
}
