//! Integration: graph file I/O — format round-trips on generated
//! instances plus malformed-input hardening (bad headers, out-of-range
//! vertices, truncated binaries must all surface as `Err`, never as a
//! panic or an abort).

use sclap::graph::csr::Graph;
use sclap::graph::io::{
    read_binary, read_edge_list, read_metis, write_binary, write_edge_list, write_metis,
};
use sclap::util::rng::Rng;
use std::io::Cursor;

fn weighted_sample() -> Graph {
    // A generated graph with non-trivial node weights: contract a BA
    // graph once so coarse node/edge weights exceed 1.
    let mut rng = Rng::new(42);
    let g = sclap::generators::barabasi_albert(400, 3, &mut rng);
    let (clustering, _) = sclap::clustering::label_propagation::size_constrained_lpa(
        &g,
        12,
        &Default::default(),
        None,
        None,
        &mut rng,
    );
    sclap::coarsening::contract::contract(&g, &clustering).coarse
}

#[test]
fn metis_roundtrip_weighted_generated() {
    let g = weighted_sample();
    assert!(g.max_node_weight() > 1, "sample should be weighted");
    let mut buf = Vec::new();
    write_metis(&g, &mut buf).unwrap();
    let g2 = read_metis(Cursor::new(buf)).unwrap();
    assert_eq!(g, g2);
    assert!(g2.validate().is_ok());
}

#[test]
fn binary_roundtrip_weighted_generated() {
    let g = weighted_sample();
    let mut buf = Vec::new();
    write_binary(&g, &mut buf).unwrap();
    let g2 = read_binary(Cursor::new(buf)).unwrap();
    assert_eq!(g, g2);
}

#[test]
fn edge_list_roundtrip_preserves_topology() {
    let mut rng = Rng::new(7);
    let g = sclap::generators::erdos_renyi(300, 900, &mut rng);
    let mut buf = Vec::new();
    write_edge_list(&g, &mut buf).unwrap();
    let g2 = read_edge_list(Cursor::new(buf), Some(g.n())).unwrap();
    assert_eq!(g.n(), g2.n());
    assert_eq!(g.m(), g2.m());
    assert_eq!(g.total_edge_weight(), g2.total_edge_weight());
}

#[test]
fn metis_malformed_inputs_error() {
    // bad header tokens
    assert!(read_metis(Cursor::new("x y\n")).is_err());
    // header too short
    assert!(read_metis(Cursor::new("5\n")).is_err());
    // neighbor id out of range (node 3 in a 2-node graph)
    assert!(read_metis(Cursor::new("2 1\n3\n\n")).is_err());
    // neighbor id zero (METIS is 1-indexed)
    assert!(read_metis(Cursor::new("2 1\n0\n\n")).is_err());
    // fewer adjacency lines than the header promises
    assert!(read_metis(Cursor::new("3 2\n2\n")).is_err());
    // more adjacency lines than nodes
    assert!(read_metis(Cursor::new("1 0\n\n2\n")).is_err());
    // non-integer token
    assert!(read_metis(Cursor::new("2 1\ntwo\n1\n")).is_err());
    // missing node weight with fmt=10
    assert!(read_metis(Cursor::new("2 1 10\n\n\n")).is_err());
}

#[test]
fn edge_list_malformed_inputs_error() {
    assert!(read_edge_list(Cursor::new("0\n"), None).is_err()); // lone endpoint
    assert!(read_edge_list(Cursor::new("0 x\n"), None).is_err()); // bad v
    assert!(read_edge_list(Cursor::new("0 1 w\n"), None).is_err()); // bad weight
}

#[test]
fn binary_truncations_error_not_panic() {
    let g = weighted_sample();
    let mut buf = Vec::new();
    write_binary(&g, &mut buf).unwrap();
    // Truncate at the magic, inside the header, inside the node
    // weights, inside the degree table and inside the arc stream.
    for cut in [0usize, 4, 8, 12, 20, 24 + 3, buf.len() / 3, buf.len() - 5] {
        let r = read_binary(Cursor::new(buf[..cut].to_vec()));
        assert!(r.is_err(), "truncation at {cut} bytes must fail");
    }
}

#[test]
fn binary_bad_magic_and_corrupt_header_error() {
    assert!(read_binary(Cursor::new(b"WRONGMAG".to_vec())).is_err());
    // Valid magic, absurd node count, no payload: must be a clean error
    // (the reader clamps pre-reservation, so no allocation abort).
    let mut buf = Vec::new();
    buf.extend_from_slice(b"SCLAPG1\0");
    buf.extend_from_slice(&u64::MAX.to_le_bytes()); // n
    buf.extend_from_slice(&0u64.to_le_bytes()); // arcs
    assert!(read_binary(Cursor::new(buf)).is_err());
}

#[test]
fn binary_out_of_range_target_errors() {
    // Hand-build: n=2, arcs=2, symmetric edge, then corrupt one target.
    let g = sclap::graph::builder::GraphBuilder::new(2).edge(0, 1).build();
    let mut buf = Vec::new();
    write_binary(&g, &mut buf).unwrap();
    // Layout: magic(8) n(8) arcs(8) node_w(2*8) degrees(2*8) then arcs
    // as (target, weight) pairs — corrupt the first target.
    let first_target_at = 8 + 8 + 8 + 16 + 16;
    buf[first_target_at..first_target_at + 8].copy_from_slice(&99u64.to_le_bytes());
    assert!(read_binary(Cursor::new(buf)).is_err());
}

#[test]
fn binary_negative_weights_error() {
    let g = sclap::graph::builder::GraphBuilder::new(2).edge(0, 1).build();
    let base = {
        let mut buf = Vec::new();
        write_binary(&g, &mut buf).unwrap();
        buf
    };
    // Node weight with the sign bit set (would become negative as i64).
    let mut buf = base.clone();
    buf[24..32].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(read_binary(Cursor::new(buf)).is_err());
    // First arc's edge weight: zero and sign-bit-set are both invalid.
    let weight_at = 8 + 8 + 8 + 16 + 16 + 8;
    for bad in [0u64, u64::MAX] {
        let mut buf = base.clone();
        buf[weight_at..weight_at + 8].copy_from_slice(&bad.to_le_bytes());
        assert!(read_binary(Cursor::new(buf)).is_err(), "weight {bad:#x}");
    }
}

#[test]
fn binary_degree_sum_mismatch_errors() {
    let g = sclap::graph::builder::GraphBuilder::new(2).edge(0, 1).build();
    let mut buf = Vec::new();
    write_binary(&g, &mut buf).unwrap();
    // Corrupt the degree table: node 0 now claims degree 5.
    let degrees_at = 8 + 8 + 8 + 16;
    buf[degrees_at..degrees_at + 8].copy_from_slice(&5u64.to_le_bytes());
    assert!(read_binary(Cursor::new(buf)).is_err());
}
