//! Integration: the content-addressed result cache
//! (`coordinator::net::cache`).
//!
//! Under test: content addressing (equal-size distinct graphs never
//! collide into one entry; the same topology through either storage
//! backend shares one), key sensitivity (any algorithmic config change
//! misses; the `threads` execution knob does not), single-flight
//! deduplication (N concurrent identical requests, exactly one
//! computation — proven deterministically with the pause/resume
//! technique from `tests/batch_queue.rs`), and the bounded LRU.

use sclap::coordinator::net::{CachedService, ServeError};
use sclap::coordinator::queue::spec::render_result_line_cached;
use sclap::coordinator::queue::{GraphHandle, Request, ServiceConfig};
use sclap::graph::csr::Graph;
use sclap::graph::karate_club;
use sclap::graph::store::{write_sharded, write_sharded_as, ShardFormat, ShardedStore};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::external::partition_store;
use std::path::PathBuf;
use std::sync::Arc;

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sclap-cache-{tag}-{}", std::process::id()))
}

fn request(id: &str, graph: Arc<Graph>, config: PartitionConfig, seeds: Vec<u64>) -> Request {
    Request::new(id, GraphHandle::InMemory(graph), config, seeds)
}

/// A community graph large enough for the budget-1 external path (the
/// same parameters `tests/batch_queue.rs` uses).
fn lfr() -> Graph {
    let mut rng = sclap::util::rng::Rng::new(4);
    sclap::generators::lfr::lfr_like(1200, 6.0, 0.15, &mut rng).0
}

#[test]
fn equal_sized_distinct_graphs_get_distinct_entries() {
    use sclap::graph::GraphBuilder;
    // Same n, same m — only the arcs differ. A name- or size-keyed
    // cache would serve one graph's partition for the other.
    let mut cycle = GraphBuilder::new(6);
    for v in 0..6u32 {
        cycle.add_edge(v, (v + 1) % 6, 1);
    }
    let mut triangles = GraphBuilder::new(6);
    for base in [0u32, 3] {
        triangles.add_edge(base, base + 1, 1);
        triangles.add_edge(base + 1, base + 2, 1);
        triangles.add_edge(base + 2, base, 1);
    }
    let (a, b) = (Arc::new(cycle.build()), Arc::new(triangles.build()));
    assert_eq!((a.n(), a.m()), (b.n(), b.m()));
    let svc = CachedService::new(
        ServiceConfig {
            workers: 2,
            max_pending: 4,
        },
        8,
    );
    let config = PartitionConfig::preset(Preset::CFast, 2);
    let (ra, cached_a) = svc.run(request("a", a, config.clone(), vec![1]), true).unwrap();
    let (rb, cached_b) = svc.run(request("b", b, config, vec![1]), true).unwrap();
    assert!(!cached_a && !cached_b, "distinct content must both miss");
    assert_eq!(svc.stats().misses, 2);
    // The two triangle components are clean halves; the cycle's best
    // 2-cut differs — regardless, the aggregates are independent.
    assert_eq!(ra.best_blocks.len(), 6);
    assert_eq!(rb.best_blocks.len(), 6);
}

#[test]
fn config_change_misses_thread_change_hits() {
    let svc = CachedService::new(ServiceConfig::default(), 8);
    let karate = Arc::new(karate_club());
    let base = PartitionConfig::preset(Preset::CFast, 2);
    let (_, cached) = svc
        .run(request("r1", karate.clone(), base.clone(), vec![1, 2]), true)
        .unwrap();
    assert!(!cached);
    // A different imbalance is a different computation.
    let mut wider = base.clone();
    wider.epsilon = 0.10;
    let (_, cached) = svc
        .run(request("r2", karate.clone(), wider, vec![1, 2]), true)
        .unwrap();
    assert!(!cached, "epsilon change must miss");
    // A different k, seed list, or algorithm toggle likewise.
    let (_, cached) = svc
        .run(request("r3", karate.clone(), base.clone(), vec![1, 2, 3]), true)
        .unwrap();
    assert!(!cached, "seed change must miss");
    let mut parallel = base.clone();
    parallel.parallel_coarsening = true;
    let (_, cached) = svc
        .run(request("r4", karate.clone(), parallel, vec![1, 2]), true)
        .unwrap();
    assert!(!cached, "algorithm toggle must miss");
    // The original again — now resident — and with a different thread
    // count (an execution knob, unobservable in results).
    let mut threaded = base.clone();
    threaded.threads = 3;
    let (_, cached) = svc
        .run(request("r5", karate.clone(), threaded, vec![2, 1]), true)
        .unwrap();
    assert!(cached, "threads + seed order must not split the entry");
    let stats = svc.stats();
    assert_eq!((stats.misses, stats.hits), (4, 1));
}

#[test]
fn backends_share_entries_and_rendered_lines_are_identical() {
    let community = Arc::new(lfr());
    let dir = temp_dir("backends");
    write_sharded(&community, &dir, 3).unwrap();
    let mut config = PartitionConfig::preset(Preset::CFast, 4);
    config.memory_budget_bytes = Some(1); // both backends take the external path
    let svc = CachedService::new(
        ServiceConfig {
            workers: 2,
            max_pending: 4,
        },
        8,
    );
    let (mem, cached) = svc
        .run(
            request("mem", community.clone(), config.clone(), vec![3, 4]),
            true,
        )
        .unwrap();
    assert!(!cached);
    let (sharded, cached) = svc
        .run(
            Request::new("sharded", GraphHandle::Shards(dir.clone()), config, vec![3, 4]),
            true,
        )
        .unwrap();
    assert!(
        cached,
        "same topology through the on-disk backend must hit the in-memory entry"
    );
    assert!(Arc::ptr_eq(&mem, &sharded));
    // The deterministic rendering of the shared aggregate is what goes
    // over the wire — identical under either id's request.
    assert_eq!(
        render_result_line_cached("x", &mem, false, false),
        render_result_line_cached("x", &sharded, false, false),
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_flight_dedups_concurrent_identical_requests() {
    let svc = Arc::new(CachedService::new(
        ServiceConfig {
            workers: 2,
            max_pending: 1, // one queue slot: joiners must not consume any
        },
        8,
    ));
    let karate = Arc::new(karate_club());
    let config = PartitionConfig::preset(Preset::CFast, 2);
    // Pause the scheduler so the leader's computation cannot finish:
    // every concurrent duplicate deterministically *joins* in flight.
    svc.pause();
    let leader = {
        let svc = svc.clone();
        let req = request("leader", karate.clone(), config.clone(), vec![1, 2]);
        std::thread::spawn(move || svc.run(req, true))
    };
    // Wait until the leader holds the in-flight slot.
    while svc.stats().misses == 0 {
        std::thread::yield_now();
    }
    let followers: Vec<_> = (0..4)
        .map(|i| {
            let svc = svc.clone();
            let req = request(&format!("f{i}"), karate.clone(), config.clone(), vec![1, 2]);
            std::thread::spawn(move || svc.run(req, true))
        })
        .collect();
    while svc.stats().joined < 4 {
        std::thread::yield_now();
    }
    // All five identical requests, one queue slot consumed: with
    // max_pending = 1 a second submission would have been refused, so
    // a distinct request's non-blocking admission reports Busy — the
    // deterministic proof that the joiners never submitted.
    let distinct = request("other", karate.clone(), config.clone(), vec![9]);
    match svc.run(distinct, false) {
        Err(ServeError::Busy) => {}
        other => panic!("queue must hold exactly the leader, got {other:?}"),
    }
    svc.resume();
    let (lead_agg, lead_cached) = leader.join().unwrap().unwrap();
    assert!(!lead_cached, "the leader computes");
    for f in followers {
        let (agg, cached) = f.join().unwrap().unwrap();
        assert!(cached, "joiners are served from the in-flight slot");
        assert!(Arc::ptr_eq(&agg, &lead_agg), "one computation, one aggregate");
    }
    let stats = svc.stats();
    assert_eq!(
        (stats.misses, stats.joined),
        (2, 4),
        "leader + refused-distinct misses; 4 joins: {stats:?}"
    );
    // Later identical requests hit the completed entry.
    let (_, cached) = svc
        .run(request("late", karate, config, vec![1, 2]), true)
        .unwrap();
    assert!(cached);
    assert_eq!(svc.stats().hits, 1);
}

#[test]
fn lru_bound_evicts_least_recently_used() {
    let svc = CachedService::new(ServiceConfig::default(), 2);
    let karate = Arc::new(karate_club());
    let config = |k: usize| PartitionConfig::preset(Preset::CFast, k);
    svc.run(request("a", karate.clone(), config(2), vec![1]), true)
        .unwrap();
    svc.run(request("b", karate.clone(), config(3), vec![1]), true)
        .unwrap();
    // Touch `a` so `b` is the least recently used…
    let (_, cached) = svc
        .run(request("a2", karate.clone(), config(2), vec![1]), true)
        .unwrap();
    assert!(cached);
    // …then overflow the two-entry bound.
    svc.run(request("c", karate.clone(), config(4), vec![1]), true)
        .unwrap();
    assert_eq!(svc.stats().evictions, 1);
    assert_eq!(svc.resident_entries(), 2);
    let (_, cached) = svc
        .run(request("a3", karate.clone(), config(2), vec![1]), true)
        .unwrap();
    assert!(cached, "recently used entry survived");
    let (_, cached) = svc
        .run(request("b2", karate, config(3), vec![1]), true)
        .unwrap();
    assert!(!cached, "least recently used entry was evicted");
}

/// Regression (stale-stamp bug): the fingerprint memo used to stamp a
/// shard directory by `meta.bin`'s (len, mtime) alone. Rewriting the
/// directory with a same-length `meta.bin` at a forced-equal mtime then
/// served the OLD graph's cached partition for the new content. The
/// stamp now folds in the format version and a content hash, so the
/// rewrite must recompute the fingerprint and miss.
#[test]
fn rewritten_shard_dir_with_same_len_and_mtime_is_not_served_stale() {
    use sclap::graph::GraphBuilder;
    // Same topology, different node weights: meta.bin keeps the same
    // byte length (n, arcs, bounds, and the weight array's size are all
    // unchanged) while the logical graph differs.
    let build = |w0: i64| {
        let mut b = GraphBuilder::new(12);
        for v in 0..12u32 {
            b.set_node_weight(v, if v == 0 { w0 } else { 1 });
            if v > 0 {
                b.add_edge(v - 1, v, 1);
            }
        }
        b.build()
    };
    let (ga, gb) = (build(1), build(9));
    assert_ne!(ga, gb);
    let dir = temp_dir("stamp");
    std::fs::remove_dir_all(&dir).ok();
    write_sharded(&ga, &dir, 2).unwrap();
    let meta = dir.join("meta.bin");
    let len_a = std::fs::metadata(&meta).unwrap().len();
    let mtime_a = std::fs::metadata(&meta).unwrap().modified().unwrap();

    let svc = CachedService::new(
        ServiceConfig {
            workers: 2,
            max_pending: 4,
        },
        8,
    );
    let config = PartitionConfig::preset(Preset::CFast, 2);
    let shard_req =
        |id: &str| Request::new(id, GraphHandle::Shards(dir.clone()), config.clone(), vec![7]);
    let (ra, cached) = svc.run(shard_req("old"), true).unwrap();
    assert!(!cached);

    // The adversarial rewrite: identical length, identical mtime.
    std::fs::remove_dir_all(&dir).unwrap();
    write_sharded(&gb, &dir, 2).unwrap();
    assert_eq!(std::fs::metadata(&meta).unwrap().len(), len_a);
    let f = std::fs::File::options().write(true).open(&meta).unwrap();
    f.set_modified(mtime_a).unwrap();
    drop(f);
    assert_eq!(std::fs::metadata(&meta).unwrap().modified().unwrap(), mtime_a);

    let (rb, cached) = svc.run(shard_req("new"), true).unwrap();
    assert!(!cached, "stale (len, mtime) stamp served the old graph");
    assert!(!Arc::ptr_eq(&ra, &rb));
    let expected = partition_store(&ShardedStore::open(&dir).unwrap(), &config, 7).unwrap();
    assert_eq!(
        rb.best_blocks, expected.blocks,
        "the fresh entry must reflect the rewritten graph"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The shard *format* must be invisible to the cache: re-encoding the
/// same graph from v1 to v2 (here with a different shard count too)
/// changes the stamp, the fingerprint is recomputed — and the
/// recomputed key hits the entry the v1 run produced.
#[test]
fn v1_and_v2_encodings_of_one_graph_share_a_cache_entry() {
    let g = lfr();
    let dir = temp_dir("fmt-share");
    std::fs::remove_dir_all(&dir).ok();
    write_sharded_as(&g, &dir, 3, ShardFormat::V1).unwrap();
    let svc = CachedService::new(
        ServiceConfig {
            workers: 2,
            max_pending: 4,
        },
        8,
    );
    let mut config = PartitionConfig::preset(Preset::CFast, 4);
    config.memory_budget_bytes = Some(1);
    let shard_req =
        |id: &str| Request::new(id, GraphHandle::Shards(dir.clone()), config.clone(), vec![3]);
    let (v1, cached) = svc.run(shard_req("v1"), true).unwrap();
    assert!(!cached);
    std::fs::remove_dir_all(&dir).unwrap();
    write_sharded_as(&g, &dir, 5, ShardFormat::V2).unwrap();
    let (v2, cached) = svc.run(shard_req("v2"), true).unwrap();
    assert!(cached, "a v2 re-encoding of identical content must hit");
    assert!(Arc::ptr_eq(&v1, &v2));
    assert_eq!(svc.stats().hits, 1);
    std::fs::remove_dir_all(&dir).ok();
}
