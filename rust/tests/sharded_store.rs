//! Integration: the `graph::store` subsystem end to end.
//!
//! The contract under test (graph::store module docs): **sharding is an
//! execution knob, never an algorithmic one** — same seed + same config
//! ⇒ byte-identical partition for any shard count, any thread count,
//! and either storage backend (`InMemoryStore` vs on-disk
//! `ShardedStore`); and the streaming METIS→shards→contract path
//! produces exactly the coarse graph the in-memory path produces.

use sclap::clustering::external_lpa::{dense_from_labels, external_sclap};
use sclap::clustering::label_propagation::{LpaConfig, NodeOrdering};
use sclap::coarsening::contract::{contract, contract_store};
use sclap::graph::csr::Graph;
use sclap::graph::io::{read_metis, write_metis};
use sclap::graph::store::{
    convert_metis_to_shards, streaming_cut, write_sharded, GraphStore, InMemoryStore,
};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::external::partition_store;
use sclap::partitioning::metrics::cut_value;
use sclap::partitioning::multilevel::MultilevelPartitioner;
use sclap::util::exec::ExecutionCtx;
use sclap::util::rng::Rng;
use std::io::Cursor;
use std::path::PathBuf;

fn temp_dir(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sclap-itest-{}-{label}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Community-structured test instance (what the out-of-core path is
/// for): large enough to coarsen, small enough for CI.
fn lfr() -> Graph {
    let mut rng = Rng::new(8);
    sclap::generators::lfr::lfr_like(1500, 6.0, 0.15, &mut rng).0
}

/// metis → ShardedStore (1/2/7 shards) → level-0 contraction must equal
/// the in-memory path exactly (round-trip property of the ISSUE).
#[test]
fn metis_to_shards_to_level0_matches_in_memory() {
    let g = lfr();
    let mut metis = Vec::new();
    write_metis(&g, &mut metis).unwrap();
    let parsed = read_metis(Cursor::new(&metis)).unwrap();
    assert_eq!(parsed, g, "metis round-trip must be exact");

    // In-memory reference: the same semi-external engine over a
    // single-shard in-memory view, contracted by the in-memory
    // contraction.
    let upper = (g.total_node_weight() / 32).max(g.max_node_weight()).max(1);
    let cfg = LpaConfig::clustering(5, NodeOrdering::Degree);
    let reference_labels = {
        let store = InMemoryStore::new(&g);
        let ctx = ExecutionCtx::sequential();
        external_sclap(&store, upper, &cfg, None, &ctx, &mut Rng::new(13))
            .unwrap()
            .0
    };
    let reference_clustering = dense_from_labels(g.node_weights(), reference_labels.clone());
    let reference_coarse = contract(&g, &reference_clustering).coarse;
    assert!(
        reference_clustering.num_clusters < g.n(),
        "clustering must shrink for the test to be meaningful"
    );

    for shards in [1usize, 2, 7] {
        let dir = temp_dir(&format!("level0-{shards}"));
        let store = convert_metis_to_shards(Cursor::new(&metis), &dir, shards).unwrap();
        assert_eq!(store.to_graph().unwrap(), g, "shards={shards}");
        let ctx = ExecutionCtx::sequential();
        let (labels, _) =
            external_sclap(&store, upper, &cfg, None, &ctx, &mut Rng::new(13)).unwrap();
        assert_eq!(labels, reference_labels, "shards={shards}: labels diverged");
        let clustering = dense_from_labels(store.node_weights(), labels);
        let contraction = contract_store(&store, &clustering).unwrap();
        assert_eq!(
            contraction.coarse, reference_coarse,
            "shards={shards}: coarse graph diverged from the in-memory path"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Determinism tentpole: `--memory-budget 1` (forced out-of-core path)
/// must give byte-identical partitions across shard counts {1, 3, 8} ×
/// threads {1, 4}, and across storage backends.
#[test]
fn forced_external_partition_invariant_across_shards_and_threads() {
    let g = lfr();
    let base = {
        let mut c = PartitionConfig::preset(Preset::CFast, 4);
        c.memory_budget_bytes = Some(1);
        c
    };
    let seed = 29;

    let reference = {
        let mut cfg = base.clone();
        cfg.threads = 1;
        let store = InMemoryStore::with_shards(&g, 1);
        partition_store(&store, &cfg, seed).unwrap()
    };
    assert!(reference.external_levels >= 1, "budget 1 must force the external path");
    assert_eq!(reference.cut, cut_value(&g, &reference.blocks));

    for shards in [1usize, 3, 8] {
        for threads in [1usize, 4] {
            let mut cfg = base.clone();
            cfg.threads = threads;
            let store = InMemoryStore::with_shards(&g, shards);
            let r = partition_store(&store, &cfg, seed).unwrap();
            assert_eq!(
                reference.blocks, r.blocks,
                "shards={shards} threads={threads}: partition diverged"
            );
            assert_eq!(reference.cut, r.cut);
        }
    }

    // The on-disk backend must be indistinguishable from the in-memory
    // one — this is the CI smoke job's property, asserted natively.
    for shards in [3usize, 8] {
        let dir = temp_dir(&format!("det-{shards}"));
        let store = write_sharded(&g, &dir, shards).unwrap();
        let mut cfg = base.clone();
        cfg.threads = 4;
        let r = partition_store(&store, &cfg, seed).unwrap();
        assert_eq!(
            reference.blocks, r.blocks,
            "on-disk shards={shards}: partition diverged from in-memory backend"
        );
        assert_eq!(streaming_cut(&store, &r.blocks).unwrap(), reference.cut);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Without a budget (or with a roomy one) the store path is the plain
/// in-memory pipeline, bit for bit — the switch never changes results
/// when it selects in-memory.
#[test]
fn roomy_budget_is_the_plain_pipeline() {
    let g = lfr();
    let mut cfg = PartitionConfig::preset(Preset::UFast, 4);
    cfg.memory_budget_bytes = Some(64 << 20);
    assert!(g.memory_bytes() < (64 << 20));
    let direct = MultilevelPartitioner::new(cfg.clone()).partition(&g, 17);
    let dir = temp_dir("roomy");
    let store = write_sharded(&g, &dir, 5).unwrap();
    let r = partition_store(&store, &cfg, 17).unwrap();
    assert_eq!(r.external_levels, 0);
    assert_eq!(r.blocks, direct.partition.blocks);
    assert_eq!(r.cut, direct.metrics.cut);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same-seed reruns of the external path are identical; different seeds
/// differ (the seed is not ignored).
#[test]
fn external_path_seeded_reproducibility() {
    let g = lfr();
    let mut cfg = PartitionConfig::preset(Preset::CFast, 4);
    cfg.memory_budget_bytes = Some(1);
    cfg.threads = 2;
    let store = InMemoryStore::with_shards(&g, 4);
    let a = partition_store(&store, &cfg, 5).unwrap();
    let b = partition_store(&store, &cfg, 5).unwrap();
    assert_eq!(a.blocks, b.blocks);
    let c = partition_store(&store, &cfg, 6).unwrap();
    assert_ne!(a.blocks, c.blocks, "seed ignored by the external path");
}

/// An unsatisfiable budget (clustering stalls at level 0) proceeds on
/// an in-memory input (it evidently fits) but must ERROR on an
/// out-of-core input instead of silently materializing it — the OOM
/// the budget exists to prevent.
#[test]
fn unsatisfiable_budget_errors_on_disk_but_proceeds_in_memory() {
    // Heavy nodes: no merge fits under U = max node weight, so the
    // semi-external clustering keeps every node a singleton (stall).
    let mut b = sclap::graph::GraphBuilder::new(8);
    for v in 0..8u32 {
        b.set_node_weight(v, 100);
        if v > 0 {
            b.add_edge(v - 1, v, 1);
        }
    }
    let g = b.build();
    let mut cfg = PartitionConfig::preset(Preset::CFast, 2);
    cfg.memory_budget_bytes = Some(1);
    let mem = partition_store(&InMemoryStore::new(&g), &cfg, 3).unwrap();
    assert_eq!(mem.external_levels, 0);
    assert_eq!(mem.blocks.len(), 8);
    let dir = temp_dir("unsat");
    let store = write_sharded(&g, &dir, 2).unwrap();
    let err = partition_store(&store, &cfg, 3).unwrap_err();
    assert!(err.to_string().contains("unsatisfiable"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The external path must produce a sane partition: all blocks
/// populated, cut far below the trivial random cut, balance reported
/// honestly.
#[test]
fn external_partition_quality_and_metrics() {
    let g = lfr();
    let k = 4;
    let mut cfg = PartitionConfig::preset(Preset::CFast, k);
    cfg.memory_budget_bytes = Some(1);
    let store = InMemoryStore::with_shards(&g, 3);
    let r = partition_store(&store, &cfg, 77).unwrap();
    assert_eq!(r.blocks.len(), g.n());
    for b in 0..k as u32 {
        assert!(r.blocks.iter().any(|&x| x == b), "block {b} empty");
    }
    // Random 4-partitions cut ≈ 3/4 of the edges; structure must beat
    // that comfortably on a community graph.
    assert!(
        (r.cut as f64) < 0.5 * g.total_edge_weight() as f64,
        "cut {} of {} total edge weight",
        r.cut,
        g.total_edge_weight()
    );
    let mut weights = vec![0i64; k];
    for (v, &b) in r.blocks.iter().enumerate() {
        weights[b as usize] += g.node_weight(v as u32);
    }
    assert_eq!(r.max_block_weight, *weights.iter().max().unwrap());
    assert_eq!(r.min_block_weight, *weights.iter().min().unwrap());
}
