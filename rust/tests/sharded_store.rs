//! Integration: the `graph::store` subsystem end to end.
//!
//! The contract under test (graph::store module docs): **sharding is an
//! execution knob, never an algorithmic one** — same seed + same config
//! ⇒ byte-identical partition for any shard count, any thread count,
//! and either storage backend (`InMemoryStore` vs on-disk
//! `ShardedStore`); and the streaming METIS→shards→contract path
//! produces exactly the coarse graph the in-memory path produces.

use sclap::clustering::external_lpa::{dense_from_labels, external_sclap};
use sclap::clustering::label_propagation::{LpaConfig, NodeOrdering};
use sclap::coarsening::contract::{contract, contract_store};
use sclap::graph::csr::Graph;
use sclap::graph::io::{read_metis, write_metis};
use sclap::graph::store::{
    convert_metis_to_shards, recompress_store, store_fingerprints, streaming_cut, write_sharded,
    write_sharded_as, GraphStore, InMemoryStore, ShardFormat, ShardedStore,
};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::external::partition_store;
use sclap::partitioning::metrics::cut_value;
use sclap::partitioning::multilevel::MultilevelPartitioner;
use sclap::util::exec::ExecutionCtx;
use sclap::util::proptest::{for_random_cases, PropConfig};
use sclap::util::rng::Rng;
use std::io::Cursor;
use std::path::PathBuf;

fn temp_dir(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sclap-itest-{}-{label}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Community-structured test instance (what the out-of-core path is
/// for): large enough to coarsen, small enough for CI.
fn lfr() -> Graph {
    let mut rng = Rng::new(8);
    sclap::generators::lfr::lfr_like(1500, 6.0, 0.15, &mut rng).0
}

/// metis → ShardedStore (1/2/7 shards) → level-0 contraction must equal
/// the in-memory path exactly (round-trip property of the ISSUE).
#[test]
fn metis_to_shards_to_level0_matches_in_memory() {
    let g = lfr();
    let mut metis = Vec::new();
    write_metis(&g, &mut metis).unwrap();
    let parsed = read_metis(Cursor::new(&metis)).unwrap();
    assert_eq!(parsed, g, "metis round-trip must be exact");

    // In-memory reference: the same semi-external engine over a
    // single-shard in-memory view, contracted by the in-memory
    // contraction.
    let upper = (g.total_node_weight() / 32).max(g.max_node_weight()).max(1);
    let cfg = LpaConfig::clustering(5, NodeOrdering::Degree);
    let reference_labels = {
        let store = InMemoryStore::new(&g);
        let ctx = ExecutionCtx::sequential();
        external_sclap(&store, upper, &cfg, None, &ctx, &mut Rng::new(13))
            .unwrap()
            .0
    };
    let reference_clustering = dense_from_labels(g.node_weights(), reference_labels.clone());
    let reference_coarse = contract(&g, &reference_clustering).coarse;
    assert!(
        reference_clustering.num_clusters < g.n(),
        "clustering must shrink for the test to be meaningful"
    );

    for shards in [1usize, 2, 7] {
        let dir = temp_dir(&format!("level0-{shards}"));
        let store = convert_metis_to_shards(Cursor::new(&metis), &dir, shards).unwrap();
        assert_eq!(store.to_graph().unwrap(), g, "shards={shards}");
        let ctx = ExecutionCtx::sequential();
        let (labels, _) =
            external_sclap(&store, upper, &cfg, None, &ctx, &mut Rng::new(13)).unwrap();
        assert_eq!(labels, reference_labels, "shards={shards}: labels diverged");
        let clustering = dense_from_labels(store.node_weights(), labels);
        let contraction = contract_store(&store, &clustering).unwrap();
        assert_eq!(
            contraction.coarse, reference_coarse,
            "shards={shards}: coarse graph diverged from the in-memory path"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Determinism tentpole: `--memory-budget 1` (forced out-of-core path)
/// must give byte-identical partitions across shard counts {1, 3, 8} ×
/// threads {1, 4}, and across storage backends.
#[test]
fn forced_external_partition_invariant_across_shards_and_threads() {
    let g = lfr();
    let base = {
        let mut c = PartitionConfig::preset(Preset::CFast, 4);
        c.memory_budget_bytes = Some(1);
        c
    };
    let seed = 29;

    let reference = {
        let mut cfg = base.clone();
        cfg.threads = 1;
        let store = InMemoryStore::with_shards(&g, 1);
        partition_store(&store, &cfg, seed).unwrap()
    };
    assert!(reference.external_levels >= 1, "budget 1 must force the external path");
    assert_eq!(reference.cut, cut_value(&g, &reference.blocks));

    for shards in [1usize, 3, 8] {
        for threads in [1usize, 4] {
            let mut cfg = base.clone();
            cfg.threads = threads;
            let store = InMemoryStore::with_shards(&g, shards);
            let r = partition_store(&store, &cfg, seed).unwrap();
            assert_eq!(
                reference.blocks, r.blocks,
                "shards={shards} threads={threads}: partition diverged"
            );
            assert_eq!(reference.cut, r.cut);
        }
    }

    // The on-disk backend must be indistinguishable from the in-memory
    // one — this is the CI smoke job's property, asserted natively.
    for shards in [3usize, 8] {
        let dir = temp_dir(&format!("det-{shards}"));
        let store = write_sharded(&g, &dir, shards).unwrap();
        let mut cfg = base.clone();
        cfg.threads = 4;
        let r = partition_store(&store, &cfg, seed).unwrap();
        assert_eq!(
            reference.blocks, r.blocks,
            "on-disk shards={shards}: partition diverged from in-memory backend"
        );
        assert_eq!(streaming_cut(&store, &r.blocks).unwrap(), reference.cut);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Without a budget (or with a roomy one) the store path is the plain
/// in-memory pipeline, bit for bit — the switch never changes results
/// when it selects in-memory.
#[test]
fn roomy_budget_is_the_plain_pipeline() {
    let g = lfr();
    let mut cfg = PartitionConfig::preset(Preset::UFast, 4);
    cfg.memory_budget_bytes = Some(64 << 20);
    assert!(g.memory_bytes() < (64 << 20));
    let direct = MultilevelPartitioner::new(cfg.clone()).partition(&g, 17);
    let dir = temp_dir("roomy");
    let store = write_sharded(&g, &dir, 5).unwrap();
    let r = partition_store(&store, &cfg, 17).unwrap();
    assert_eq!(r.external_levels, 0);
    assert_eq!(r.blocks, direct.partition.blocks);
    assert_eq!(r.cut, direct.metrics.cut);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Same-seed reruns of the external path are identical; different seeds
/// differ (the seed is not ignored).
#[test]
fn external_path_seeded_reproducibility() {
    let g = lfr();
    let mut cfg = PartitionConfig::preset(Preset::CFast, 4);
    cfg.memory_budget_bytes = Some(1);
    cfg.threads = 2;
    let store = InMemoryStore::with_shards(&g, 4);
    let a = partition_store(&store, &cfg, 5).unwrap();
    let b = partition_store(&store, &cfg, 5).unwrap();
    assert_eq!(a.blocks, b.blocks);
    let c = partition_store(&store, &cfg, 6).unwrap();
    assert_ne!(a.blocks, c.blocks, "seed ignored by the external path");
}

/// An unsatisfiable budget (clustering stalls at level 0) proceeds on
/// an in-memory input (it evidently fits) but must ERROR on an
/// out-of-core input instead of silently materializing it — the OOM
/// the budget exists to prevent.
#[test]
fn unsatisfiable_budget_errors_on_disk_but_proceeds_in_memory() {
    // Heavy nodes: no merge fits under U = max node weight, so the
    // semi-external clustering keeps every node a singleton (stall).
    let mut b = sclap::graph::GraphBuilder::new(8);
    for v in 0..8u32 {
        b.set_node_weight(v, 100);
        if v > 0 {
            b.add_edge(v - 1, v, 1);
        }
    }
    let g = b.build();
    let mut cfg = PartitionConfig::preset(Preset::CFast, 2);
    cfg.memory_budget_bytes = Some(1);
    let mem = partition_store(&InMemoryStore::new(&g), &cfg, 3).unwrap();
    assert_eq!(mem.external_levels, 0);
    assert_eq!(mem.blocks.len(), 8);
    let dir = temp_dir("unsat");
    let store = write_sharded(&g, &dir, 2).unwrap();
    let err = partition_store(&store, &cfg, 3).unwrap_err();
    assert!(err.to_string().contains("unsatisfiable"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The external path must produce a sane partition: all blocks
/// populated, cut far below the trivial random cut, balance reported
/// honestly.
#[test]
fn external_partition_quality_and_metrics() {
    let g = lfr();
    let k = 4;
    let mut cfg = PartitionConfig::preset(Preset::CFast, k);
    cfg.memory_budget_bytes = Some(1);
    let store = InMemoryStore::with_shards(&g, 3);
    let r = partition_store(&store, &cfg, 77).unwrap();
    assert_eq!(r.blocks.len(), g.n());
    for b in 0..k as u32 {
        assert!(r.blocks.iter().any(|&x| x == b), "block {b} empty");
    }
    // Random 4-partitions cut ≈ 3/4 of the edges; structure must beat
    // that comfortably on a community graph.
    assert!(
        (r.cut as f64) < 0.5 * g.total_edge_weight() as f64,
        "cut {} of {} total edge weight",
        r.cut,
        g.total_edge_weight()
    );
    let mut weights = vec![0i64; k];
    for (v, &b) in r.blocks.iter().enumerate() {
        weights[b as usize] += g.node_weight(v as u32);
    }
    assert_eq!(r.max_block_weight, *weights.iter().max().unwrap());
    assert_eq!(r.min_block_weight, *weights.iter().min().unwrap());
}

/// SCLAPS2 tentpole: the shard *format* is an encoding knob, never an
/// algorithmic one. v1, v2, and the in-memory backend must produce
/// byte-identical partitions across shard counts {1, 3, 8} × threads
/// {1, 4}, and v1/v2 stores of the same graph must report identical
/// `store_fingerprints` — that is what lets `net::cache` serve one
/// cached result for both encodings.
#[test]
fn partition_is_invariant_across_shard_formats() {
    let g = lfr();
    let base = {
        let mut c = PartitionConfig::preset(Preset::CFast, 4);
        c.memory_budget_bytes = Some(1);
        c
    };
    let seed = 29;
    let reference = {
        let mut cfg = base.clone();
        cfg.threads = 1;
        partition_store(&InMemoryStore::with_shards(&g, 1), &cfg, seed).unwrap()
    };
    assert!(reference.external_levels >= 1, "budget 1 must force the external path");

    let mem_fp = store_fingerprints(&InMemoryStore::new(&g)).unwrap();
    for format in ShardFormat::ALL {
        for shards in [1usize, 3, 8] {
            let dir = temp_dir(&format!("fmt-{}-{shards}", format.name()));
            let store = write_sharded_as(&g, &dir, shards, format).unwrap();
            assert_eq!(store.format(), format);
            assert_eq!(
                store_fingerprints(&store).unwrap(),
                mem_fp,
                "{} shards={shards}: fingerprint must be format-invariant",
                format.name()
            );
            for threads in [1usize, 4] {
                let mut cfg = base.clone();
                cfg.threads = threads;
                let r = partition_store(&store, &cfg, seed).unwrap();
                assert_eq!(
                    reference.blocks,
                    r.blocks,
                    "{} shards={shards} threads={threads}: partition diverged",
                    format.name()
                );
                assert_eq!(reference.cut, r.cut);
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    // `shard recompress` output is pipeline-equivalent to a direct
    // write: v1 → v2 with a reshard must still partition identically.
    let src = temp_dir("fmt-recompress-src");
    let dst = temp_dir("fmt-recompress-dst");
    write_sharded_as(&g, &src, 3, ShardFormat::V1).unwrap();
    let store = recompress_store(&src, &dst, Some(8), ShardFormat::V2).unwrap();
    assert_eq!(store_fingerprints(&store).unwrap(), mem_fp);
    let mut cfg = base.clone();
    cfg.threads = 4;
    let r = partition_store(&store, &cfg, seed).unwrap();
    assert_eq!(reference.blocks, r.blocks, "recompressed store diverged");
    let _ = std::fs::remove_dir_all(&src);
    let _ = std::fs::remove_dir_all(&dst);
}

/// Hostile-bytes satellite: corrupting a v2 shard file must surface as
/// a structured `io::Error` from open/to_graph — never a panic and
/// never an unclamped allocation driven by attacker-controlled lengths.
#[test]
fn corrupt_v2_shards_error_instead_of_panicking() {
    let g = lfr();
    let dir = temp_dir("hostile-v2");
    write_sharded_as(&g, &dir, 1, ShardFormat::V2).unwrap();
    let shard = dir.join("shard_0.bin");
    let pristine = std::fs::read(&shard).unwrap();
    assert_eq!(&pristine[..8], b"SCLAPS2\0");
    // Fixed layout this test indexes into: header = magic, version, lo,
    // hi, arcs, block_nodes, nblocks, payload_len (8 B each, ends at
    // 64), then nblocks × (offset, arc_start) index entries (16 B
    // each), then the varint payload. span 1500 / 1024-node blocks →
    // exactly 2 index entries, payload at byte 96.
    let nblocks = u64::from_le_bytes(pristine[48..56].try_into().unwrap());
    assert_eq!(nblocks, 2, "layout assumption behind the offsets below");

    let open = |bytes: &[u8]| -> std::io::Result<Graph> {
        std::fs::write(&shard, bytes).unwrap();
        ShardedStore::open(&dir).and_then(|s| s.to_graph())
    };
    assert_eq!(open(&pristine).unwrap(), g, "pristine file must round-trip");

    // Truncation at every structural boundary (and mid-field, and
    // mid-varint) is an error, not a panic.
    let half = pristine.len() / 2;
    let last = pristine.len() - 1;
    for cut in [0, 1, 7, 8, 15, 16, 40, 56, 63, 64, 79, 80, 95, 96, 97, half, last] {
        assert!(open(&pristine[..cut]).is_err(), "truncation at {cut} accepted");
    }

    // A payload length of u64::MAX must hit the capped read, not a
    // pre-allocation of the claimed size.
    let mut t = pristine.clone();
    t[56..64].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(open(&t).is_err(), "huge claimed payload accepted");

    // Index entry 0 must be exactly (0, 0).
    let mut t = pristine.clone();
    t[64..72].copy_from_slice(&7u64.to_le_bytes());
    assert!(open(&t).is_err(), "lying first index entry accepted");

    // Entry 1 lying about the payload offset or the arc prefix must be
    // caught by the cross-check at the block boundary.
    let mut t = pristine.clone();
    t[80..88].copy_from_slice(&1u64.to_le_bytes());
    assert!(open(&t).is_err(), "lying block offset accepted");
    let mut t = pristine.clone();
    t[88..96].copy_from_slice(&u64::MAX.to_le_bytes());
    assert!(open(&t).is_err(), "lying arc_start accepted");

    // A non-canonical (overlong) varint smuggled into the payload is
    // rejected even though it decodes to the right value.
    let mut t = pristine.clone();
    t[96] = 0x80;
    t.insert(97, 0x00);
    assert!(open(&t).is_err(), "overlong varint accepted");

    // Random single-byte corruption: any Result is acceptable, a panic
    // is not (for_random_cases catches panics and reports the seed).
    for_random_cases(&PropConfig::quick(), |rng, _| {
        let mut t = pristine.clone();
        let pos = rng.below(t.len());
        t[pos] ^= (1 + rng.below(255)) as u8;
        let _ = open(&t);
    });

    let _ = std::fs::remove_dir_all(&dir);
}
