//! Integration: the batching service front end (`coordinator::queue`).
//!
//! The contract under test: N interleaved requests — mixed in-memory
//! and sharded backends, mixed seed counts — produce per-request
//! `Aggregate`s whose deterministic fields are byte-identical to serial
//! `partition_repeated` / `partition_store` calls, across worker counts
//! {1, 4} and reversed submission order; the bounded queue's
//! backpressure is observable (`max_pending` exceeded ⇒ blocking or
//! `Busy`); a panicking request is isolated; shutdown drains.

use sclap::coordinator::queue::{
    BatchService, GraphHandle, Request, ServiceConfig, SubmitError,
};
use sclap::coordinator::service::{Aggregate, Coordinator, RunOutcome};
use sclap::graph::csr::{Graph, Weight};
use sclap::graph::karate_club;
use sclap::graph::store::{write_sharded, InMemoryStore, ShardedStore};
use sclap::partitioning::config::{PartitionConfig, Preset};
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// The deterministic projection of an `Aggregate`: everything except
/// the wall-clock fields. Two runs of the same request must agree on
/// this exactly.
type Det = (
    Vec<(u64, Weight, bool, Vec<u32>)>,
    String, // avg_cut, via its exact decimal rendering
    Weight, // best_cut
    Vec<u32>,
    usize, // infeasible_runs
);

fn det(agg: &Aggregate) -> Det {
    (
        agg.runs
            .iter()
            .map(|r| (r.seed, r.cut, r.feasible, r.blocks.clone()))
            .collect(),
        format!("{}", agg.avg_cut),
        agg.best_cut,
        agg.best_blocks.clone(),
        agg.infeasible_runs,
    )
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sclap-batchq-{tag}-{}", std::process::id()))
}

/// A community graph big enough for the semi-external path to shrink
/// under `--memory-budget 1` (the external cluster-size bound
/// `l_max / (f·k)` collapses to 1 on tiny graphs like karate, which
/// makes a budgeted *on-disk* request error as unsatisfiable — the
/// same parameters `tests/sharded_store.rs` uses).
fn lfr() -> Graph {
    let mut rng = sclap::util::rng::Rng::new(4);
    sclap::generators::lfr::lfr_like(1200, 6.0, 0.15, &mut rng).0
}

/// One request blueprint plus its serially-computed expected result.
struct Case {
    request: Request,
    expected: Det,
}

fn in_memory_case(id: &str, graph: Arc<Graph>, config: PartitionConfig, seeds: Vec<u64>) -> Case {
    let expected = if config.memory_budget_bytes.is_some() {
        // Budgeted in-memory requests route through the out-of-core
        // driver; the serial reference does the same.
        let coord = Coordinator::new(2);
        let store = InMemoryStore::new(&graph);
        let runs: Vec<RunOutcome> = seeds
            .iter()
            .map(|&s| {
                RunOutcome::from_out_of_core(
                    s,
                    &coord.partition_store(&store, &config, s).unwrap(),
                )
            })
            .collect();
        det(&Aggregate::from_runs(runs))
    } else {
        det(&Coordinator::new(2).partition_repeated(graph.clone(), &config, &seeds))
    };
    Case {
        request: Request::new(id, GraphHandle::InMemory(graph), config, seeds),
        expected,
    }
}

fn sharded_case(id: &str, dir: &Path, config: PartitionConfig, seeds: Vec<u64>) -> Case {
    let coord = Coordinator::new(2);
    let store = ShardedStore::open(dir).unwrap();
    let runs: Vec<RunOutcome> = seeds
        .iter()
        .map(|&s| {
            RunOutcome::from_out_of_core(s, &coord.partition_store(&store, &config, s).unwrap())
        })
        .collect();
    Case {
        request: Request::new(id, GraphHandle::Shards(dir.to_path_buf()), config, seeds),
        expected: det(&Aggregate::from_runs(runs)),
    }
}

#[test]
fn interleaved_requests_match_serial_for_any_workers_and_order() {
    let karate = Arc::new(karate_club());
    let ba = Arc::new(
        sclap::generators::instances::by_name("tiny-ba")
            .unwrap()
            .build(),
    );
    let community = Arc::new(lfr());
    let dir = temp_dir("determinism");
    write_sharded(&community, &dir, 3).unwrap();

    let mut budgeted = PartitionConfig::preset(Preset::CFast, 4);
    budgeted.memory_budget_bytes = Some(1); // force the external path
    let cases: Vec<Case> = vec![
        in_memory_case(
            "mem-5seeds",
            karate.clone(),
            PartitionConfig::preset(Preset::CFast, 2),
            vec![1, 2, 3, 4, 5],
        ),
        in_memory_case(
            "mem-1seed",
            ba.clone(),
            PartitionConfig::preset(Preset::UFast, 4),
            vec![7],
        ),
        sharded_case("shard-budget", &dir, budgeted.clone(), vec![1, 2]),
        sharded_case(
            "shard-roomy",
            &dir,
            PartitionConfig::preset(Preset::CFast, 4),
            vec![4],
        ),
        in_memory_case("mem-budget", community.clone(), budgeted, vec![2]),
        in_memory_case(
            "mem-2seeds",
            karate.clone(),
            PartitionConfig::preset(Preset::CEco, 3),
            vec![9, 11],
        ),
    ];

    for workers in [1usize, 4] {
        for reverse in [false, true] {
            let service = BatchService::new(ServiceConfig {
                workers,
                max_pending: 8,
            });
            let order: Vec<usize> = if reverse {
                (0..cases.len()).rev().collect()
            } else {
                (0..cases.len()).collect()
            };
            let tickets: Vec<(usize, sclap::coordinator::queue::Ticket)> = order
                .iter()
                .map(|&i| (i, service.submit(cases[i].request.clone()).unwrap()))
                .collect();
            for (i, ticket) in tickets {
                let agg = ticket.wait().unwrap_or_else(|e| {
                    panic!("workers={workers} reverse={reverse}: {e}")
                });
                assert_eq!(
                    det(&agg),
                    cases[i].expected,
                    "request {:?} diverged from the serial reference \
                     (workers={workers}, reverse={reverse})",
                    cases[i].request.id
                );
            }
            service.shutdown();
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn backpressure_bounds_the_queue() {
    let service = BatchService::new(ServiceConfig {
        workers: 2,
        max_pending: 2,
    });
    let karate = Arc::new(karate_club());
    let request = |id: &str| {
        Request::new(
            id,
            GraphHandle::InMemory(karate.clone()),
            PartitionConfig::preset(Preset::CFast, 2),
            vec![1, 2],
        )
    };
    // Pause the scheduler so nothing drains: the bound is deterministic.
    service.pause();
    let t1 = service.submit(request("q1")).unwrap();
    let t2 = service.submit(request("q2")).unwrap();
    match service.try_submit(request("q3")) {
        Err(SubmitError::Busy) => {}
        other => panic!("queue at max_pending must report Busy, got {other:?}"),
    }
    // A blocking submit parks until the scheduler frees a slot.
    let service_ref = &service;
    std::thread::scope(|scope| {
        let (done_tx, done_rx) = mpsc::channel();
        scope.spawn(move || {
            let ticket = service_ref.submit(request("q3")).unwrap();
            done_tx.send(ticket).unwrap();
        });
        assert!(
            done_rx.recv_timeout(Duration::from_millis(200)).is_err(),
            "submit must block while the queue is full"
        );
        service_ref.resume();
        let t3 = done_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("blocked submit completes once a slot frees");
        assert_eq!(t3.wait().unwrap().runs.len(), 2);
    });
    assert!(t1.wait().is_ok());
    assert!(t2.wait().is_ok());
}

#[test]
fn panicking_request_is_isolated() {
    let service = BatchService::new(ServiceConfig {
        workers: 2,
        max_pending: 8,
    });
    let karate = Arc::new(karate_club());
    let good = |id: &str| {
        Request::new(
            id,
            GraphHandle::InMemory(karate.clone()),
            PartitionConfig::preset(Preset::CFast, 2),
            vec![1, 2, 3],
        )
    };
    // k = 0 violates the partitioner's precondition and panics inside
    // the repetition.
    let mut poisoned = PartitionConfig::preset(Preset::CFast, 2);
    poisoned.k = 0;
    let before = service.submit(good("before")).unwrap();
    let bad = service
        .submit(Request::new(
            "poisoned",
            GraphHandle::InMemory(karate.clone()),
            poisoned,
            vec![1, 2],
        ))
        .unwrap();
    let after = service.submit(good("after")).unwrap();

    let err = bad.wait().unwrap_err();
    assert_eq!(err.id, "poisoned");
    assert!(err.message.contains("panicked"), "{err}");
    // Neighbors in the same waves — and later submissions on the same
    // long-lived service — are unaffected.
    let a = before.wait().unwrap();
    let b = after.wait().unwrap();
    assert_eq!(det(&a), det(&b), "identical requests, identical results");
    let later = service.submit(good("later")).unwrap();
    assert_eq!(det(&later.wait().unwrap()), det(&a));
}

#[test]
fn shutdown_drains_accepted_requests() {
    let service = BatchService::new(ServiceConfig {
        workers: 2,
        max_pending: 8,
    });
    let karate = Arc::new(karate_club());
    let tickets: Vec<_> = (0..4u64)
        .map(|i| {
            service
                .submit(Request::new(
                    format!("drain-{i}"),
                    GraphHandle::InMemory(karate.clone()),
                    PartitionConfig::preset(Preset::CFast, 2),
                    vec![i + 1],
                ))
                .unwrap()
        })
        .collect();
    // Graceful: every accepted request resolves even though the service
    // is torn down immediately after submission.
    service.shutdown();
    for t in tickets {
        let agg = t.wait().expect("accepted requests are drained");
        assert_eq!(agg.runs.len(), 1);
    }
}

#[test]
fn sharded_and_in_memory_backends_agree_through_the_queue() {
    // The storage backend must be unobservable in results: the same
    // graph submitted as an in-memory handle and as a shard directory
    // (same budget) produces identical partitions.
    let community = Arc::new(lfr());
    let dir = temp_dir("backends");
    write_sharded(&community, &dir, 2).unwrap();
    let mut config = PartitionConfig::preset(Preset::CFast, 4);
    config.memory_budget_bytes = Some(1);
    let service = BatchService::new(ServiceConfig {
        workers: 2,
        max_pending: 4,
    });
    let mem = service
        .submit(Request::new(
            "mem",
            GraphHandle::InMemory(community.clone()),
            config.clone(),
            vec![3, 4],
        ))
        .unwrap();
    let sharded = service
        .submit(Request::new(
            "sharded",
            GraphHandle::Shards(dir.clone()),
            config,
            vec![3, 4],
        ))
        .unwrap();
    let a = mem.wait().unwrap();
    let b = sharded.wait().unwrap();
    assert_eq!(det(&a), det(&b));
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression: the shutdown drain used to *compute* still-queued
/// repetitions of requests whose submitter had dropped the ticket —
/// work nobody would ever read. Dropping an unwaited ticket now fires
/// the request's token (`Abandoned`), so the drain reaps it as
/// cancelled instead, while every still-wanted request completes.
#[test]
fn shutdown_drain_cancels_abandoned_requests_instead_of_computing() {
    let service = BatchService::new(ServiceConfig {
        workers: 2,
        max_pending: 8,
    });
    let ctx = service.ctx().clone();
    let karate = Arc::new(karate_club());
    // Pause the scheduler so both requests are still queued when the
    // ticket is dropped — the abandonment deterministically precedes
    // any dispatch.
    service.pause();
    let abandoned = service
        .submit(Request::new(
            "abandoned",
            GraphHandle::InMemory(karate.clone()),
            PartitionConfig::preset(Preset::CFast, 2),
            vec![1, 2, 3],
        ))
        .unwrap();
    drop(abandoned); // submitter walks away without waiting
    let kept = service
        .submit(Request::new(
            "kept",
            GraphHandle::InMemory(karate.clone()),
            PartitionConfig::preset(Preset::CFast, 2),
            vec![5],
        ))
        .unwrap();
    service.resume();
    service.shutdown();
    // The still-wanted request drains normally...
    assert_eq!(kept.wait().unwrap().runs.len(), 1);
    // ...and the abandoned one was cancelled, not silently computed.
    let metrics = ctx.metrics();
    assert_eq!(metrics.counter("requests_cancelled").get(), 1);
    assert_eq!(metrics.counter("cancel_reason_abandoned").get(), 1);
    assert_eq!(metrics.counter("requests_completed").get(), 1);
}
