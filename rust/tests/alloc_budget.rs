//! Steady-state allocation budget for the V-cycle workspace
//! (`partitioning::workspace`): once a shared [`ExecutionCtx`] has run
//! one cold partition, every later run on the same context must lease
//! all of its scratch from the warm arena — **zero** fresh scratch
//! allocations — and its total heap traffic must drop below the cold
//! run's. Measured two ways at once: exactly, via the workspace's own
//! `fresh_allocations` counter, and end-to-end, via a counting
//! `#[global_allocator]` wrapped around `System`.
//!
//! The tests share one process-global allocator, so they serialize on a
//! mutex; assertions on the global counters use the cold run as their
//! own baseline (ratios, not absolutes) to stay robust against harness
//! noise, while the arena counters — private to each test's context —
//! are asserted exactly.

use sclap::coordinator::service::Coordinator;
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::multilevel::MultilevelPartitioner;
use sclap::util::exec::ExecutionCtx;
use sclap::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

/// Counts allocation events and requested bytes; frees are not tracked
/// (the budget is about *new* heap traffic, not residency).
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One test at a time: the allocator counters are process-global.
static SERIAL: Mutex<()> = Mutex::new(());

/// Run `f` and return (result, allocation calls, allocated bytes).
fn measure<R>(f: impl FnOnce() -> R) -> (R, u64, u64) {
    let calls0 = ALLOC_CALLS.load(Ordering::Relaxed);
    let bytes0 = ALLOC_BYTES.load(Ordering::Relaxed);
    let r = f();
    let calls = ALLOC_CALLS.load(Ordering::Relaxed) - calls0;
    let bytes = ALLOC_BYTES.load(Ordering::Relaxed) - bytes0;
    (r, calls, bytes)
}

fn instance() -> sclap::graph::csr::Graph {
    let mut rng = Rng::new(1);
    sclap::generators::lfr::lfr_like(1200, 6.0, 0.15, &mut rng).0
}

/// A V-cycled partitioner on a shared context: the first run stocks the
/// arena; from then on every cycle of every run leases warm buffers.
#[test]
fn steady_state_vcycle_reuses_scratch() {
    let _serial = SERIAL.lock().unwrap_or_else(PoisonError::into_inner);
    let g = instance();
    let ctx = Arc::new(ExecutionCtx::new(2));
    let mut config = PartitionConfig::preset(Preset::CFast, 4);
    config.vcycles = 3;
    let partitioner = MultilevelPartitioner::with_ctx(config, ctx.clone());

    let s0 = ctx.workspace().stats();
    let (cold, cold_calls, cold_bytes) = measure(|| partitioner.partition(&g, 42));
    let s1 = ctx.workspace().stats();
    assert!(
        s1.leases_created > s0.leases_created,
        "the V-cycle pipeline never touched the workspace"
    );
    assert!(
        s1.fresh_allocations > s0.fresh_allocations,
        "a cold arena must allocate its shelves"
    );

    let (warm, warm_calls, warm_bytes) = measure(|| partitioner.partition(&g, 42));
    let s2 = ctx.workspace().stats();

    // Reuse must be invisible in results: leases hand back capacity,
    // never contents.
    assert_eq!(cold.metrics.cut, warm.metrics.cut);
    assert_eq!(cold.partition.blocks, warm.partition.blocks);

    // The steady-state budget, exact: the warm run leased scratch
    // (plenty of it) and fresh-allocated none.
    assert!(s2.leases_created > s1.leases_created);
    assert_eq!(
        s2.fresh_allocations, s1.fresh_allocations,
        "warm V-cycle run fresh-allocated scratch buffers"
    );

    // End to end the warm run must be strictly cheaper — it skips every
    // O(n) scratch allocation the cold run paid for.
    assert!(
        warm_bytes < cold_bytes,
        "warm run allocated {warm_bytes} bytes vs cold {cold_bytes}"
    );
    assert!(
        warm_calls <= cold_calls,
        "warm run made {warm_calls} allocations vs cold {cold_calls}"
    );
    // Backstop: if lease reuse silently broke, per-round scratch would
    // add O(levels x rounds x buffers) allocations and blow this cap.
    assert!(
        warm_calls < 50_000,
        "warm V-cycle run made {warm_calls} allocations"
    );
}

/// Serve-style steady state: repeated aggregate requests on one
/// coordinator context. After the first request the arena is warm for
/// every later one — including across *different* seeds, because leases
/// are sized by capacity, not content.
#[test]
fn warm_repeated_requests_fresh_allocate_nothing() {
    let _serial = SERIAL.lock().unwrap_or_else(PoisonError::into_inner);
    let g = Arc::new(instance());
    let ctx = Arc::new(ExecutionCtx::new(1));
    let coordinator = Coordinator::with_ctx(ctx.clone());
    let config = PartitionConfig::preset(Preset::CFast, 4);
    let seeds = [3u64, 4, 5];

    let (cold_agg, _cold_calls, cold_bytes) =
        measure(|| coordinator.partition_repeated(g.clone(), &config, &seeds));
    let s1 = ctx.workspace().stats();

    let (warm_agg, _warm_calls, warm_bytes) =
        measure(|| coordinator.partition_repeated(g.clone(), &config, &seeds));
    let s2 = ctx.workspace().stats();

    assert_eq!(cold_agg.best_cut, warm_agg.best_cut);
    assert_eq!(cold_agg.avg_cut, warm_agg.avg_cut);

    assert!(s2.leases_created > s1.leases_created);
    assert_eq!(
        s2.fresh_allocations, s1.fresh_allocations,
        "warm repeated request fresh-allocated scratch buffers"
    );
    assert!(
        warm_bytes < cold_bytes,
        "warm request allocated {warm_bytes} bytes vs cold {cold_bytes}"
    );

    // A third round must hold the line too (no slow leak of fresh
    // allocations as requests repeat).
    let (_, _, third_bytes) =
        measure(|| coordinator.partition_repeated(g.clone(), &config, &seeds));
    let s3 = ctx.workspace().stats();
    assert_eq!(s3.fresh_allocations, s2.fresh_allocations);
    assert!(third_bytes < cold_bytes);
}
