//! Integration: the ExecutionCtx handoff caps total worker threads.
//!
//! Before the shared-context refactor, a coordinator job created its
//! own partitioner pool and a guard resolved `threads = 0` to 1 inside
//! jobs to bound oversubscription. Now one pool serves every nesting
//! level, so the configured worker count is a hard cap on live pool
//! worker threads — asserted here via the `util::pool` gauge while a
//! repetition batch (with every parallel engine enabled) runs.
//!
//! This file contains a single test on purpose: the gauge is process
//! global, and sibling tests creating pools concurrently would make the
//! cap assertion meaningless. Integration test files run in their own
//! process, so this is isolated from the rest of the suite.

use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::util::pool::live_pool_workers;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn worker_threads_never_exceed_the_configured_cap() {
    let base = live_pool_workers();
    let cap = 3usize; // 3 total workers ⇒ 2 background threads
    let coord = Coordinator::new(cap);
    assert_eq!(
        live_pool_workers(),
        base + cap - 1,
        "coordinator pool must own exactly cap-1 background workers"
    );

    // Sample the gauge concurrently with the batch: any nested pool
    // creation inside a job would push it above the cap.
    let stop = Arc::new(AtomicBool::new(false));
    let max_seen = Arc::new(AtomicUsize::new(0));
    let sampler = {
        let stop = stop.clone();
        let max_seen = max_seen.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                max_seen.fetch_max(live_pool_workers(), Ordering::SeqCst);
                std::thread::yield_now();
            }
        })
    };

    let g = Arc::new(
        sclap::generators::instances::by_name("tiny-ba")
            .unwrap()
            .build(),
    );
    // threads = 0 (auto) was exactly the old oversubscription scenario;
    // both parallel engines on makes the jobs exercise the shared pool.
    let mut config = PartitionConfig::preset(Preset::UFast, 4);
    config.threads = 0;
    config.parallel_coarsening = true;
    config.parallel_refinement = true;
    let agg = coord.partition_repeated(g.clone(), &config, &default_seeds(6));
    assert_eq!(agg.runs.len(), 6);

    stop.store(true, Ordering::SeqCst);
    sampler.join().unwrap();
    let peak = max_seen.load(Ordering::SeqCst);
    assert!(
        peak <= base + cap - 1,
        "live pool workers peaked at {peak}, above the cap of {} — a nested \
         pool was created during the batch",
        base + cap - 1
    );
    // The batch left no pools behind...
    assert_eq!(live_pool_workers(), base + cap - 1);
    // ...and dropping the coordinator joins its workers.
    drop(coord);
    assert_eq!(live_pool_workers(), base);
}
