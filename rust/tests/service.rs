//! Integration: the coordinator service (DESIGN.md invariant 6).

use sclap::coordinator::service::{default_seeds, Aggregate, Coordinator};
use sclap::partitioning::config::{PartitionConfig, Preset};
use std::sync::Arc;

#[test]
fn n_jobs_n_results() {
    let g = Arc::new(sclap::generators::instances::by_name("tiny-ba").unwrap().build());
    let coord = Coordinator::new(4);
    for reps in [1usize, 3, 10] {
        let agg = coord.partition_repeated(
            g.clone(),
            &PartitionConfig::preset(Preset::UFast, 4),
            &default_seeds(reps),
        );
        assert_eq!(agg.runs.len(), reps);
        assert!(agg.best_cut as f64 <= agg.avg_cut + 1e-9);
    }
}

#[test]
fn determinism_independent_of_worker_count() {
    let g = Arc::new(sclap::generators::instances::by_name("tiny-ws").unwrap().build());
    let config = PartitionConfig::preset(Preset::CFast, 4);
    let mut outcomes = Vec::new();
    for workers in [1usize, 2, 8] {
        let coord = Coordinator::new(workers);
        let agg = coord.partition_repeated(g.clone(), &config, &default_seeds(6));
        outcomes.push(
            agg.runs
                .iter()
                .map(|r| (r.seed, r.cut, r.blocks.clone()))
                .collect::<Vec<_>>(),
        );
    }
    assert_eq!(outcomes[0], outcomes[1]);
    assert_eq!(outcomes[1], outcomes[2]);
}

#[test]
fn aggregate_stats_consistent() {
    let g = Arc::new(sclap::graph::karate_club());
    let coord = Coordinator::new(2);
    let agg = coord.partition_repeated(
        g.clone(),
        &PartitionConfig::preset(Preset::CEco, 2),
        &default_seeds(8),
    );
    let manual_avg: f64 =
        agg.runs.iter().map(|r| r.cut as f64).sum::<f64>() / agg.runs.len() as f64;
    assert!((agg.avg_cut - manual_avg).abs() < 1e-9);
    let manual_best = agg.runs.iter().map(|r| r.cut).min().unwrap();
    assert_eq!(agg.best_cut, manual_best);
    // best_blocks must realize best_cut
    assert_eq!(
        sclap::partitioning::metrics::cut_value(&g, &agg.best_blocks),
        agg.best_cut
    );
}

#[test]
fn concurrent_different_configs() {
    // Two interleaved workloads on one pool must not cross-contaminate.
    let g = Arc::new(sclap::graph::karate_club());
    let coord = Coordinator::new(4);
    let fast = coord.partition_repeated(
        g.clone(),
        &PartitionConfig::preset(Preset::CFast, 2),
        &default_seeds(4),
    );
    let eco = coord.partition_repeated(
        g.clone(),
        &PartitionConfig::preset(Preset::CEco, 4),
        &default_seeds(4),
    );
    for r in &fast.runs {
        assert_eq!(r.blocks.iter().copied().max().unwrap(), 1); // k=2
    }
    for r in &eco.runs {
        assert_eq!(r.blocks.iter().copied().max().unwrap(), 3); // k=4
    }
}

#[test]
fn aggregate_from_runs_sorts_by_seed() {
    use sclap::coordinator::service::RunOutcome;
    let mk = |seed, cut| RunOutcome {
        seed,
        cut,
        seconds: 0.1,
        imbalance: 0.0,
        feasible: true,
        initial_cut: cut,
        levels: 1,
        coarsest_n: 10,
        blocks: vec![0, 1],
        phase_seconds: vec![("coarsening", 0.25), ("uncoarsening", 0.5)],
    };
    let agg = Aggregate::from_runs(vec![mk(3, 30), mk(1, 10), mk(2, 20)]);
    let seeds: Vec<u64> = agg.runs.iter().map(|r| r.seed).collect();
    assert_eq!(seeds, vec![1, 2, 3]);
    assert_eq!(agg.best_cut, 10);
    assert!((agg.avg_cut - 20.0).abs() < 1e-9);
    // phase totals sum across runs in fixed first-seen order
    assert_eq!(
        agg.phase_seconds,
        vec![("coarsening", 0.75), ("uncoarsening", 1.5)]
    );
}
