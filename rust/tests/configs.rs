//! Integration: qualitative Table-2 orderings on a small web-like
//! instance. These assert the *shape* of the paper's findings (who wins),
//! not absolute numbers — the quantitative reproduction lives in
//! `cargo bench --bench table2`.

use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::partitioning::config::{PartitionConfig, Preset};
use std::sync::Arc;

fn agg(
    coord: &Coordinator,
    g: &Arc<sclap::graph::csr::Graph>,
    preset: Preset,
    k: usize,
    reps: usize,
) -> (f64, f64) {
    let a = coord.partition_repeated(
        g.clone(),
        &PartitionConfig::preset(preset, k),
        &default_seeds(reps),
    );
    (a.avg_cut, a.avg_seconds)
}

/// Paper §5.1: cluster coarsening (CEco) beats matching coarsening
/// (KaFFPaEco-like) on complex networks in quality.
#[test]
fn cluster_beats_matching_on_web_like() {
    let g = Arc::new(sclap::generators::instances::by_name("tiny-rmat").unwrap().build());
    let coord = Coordinator::new(0);
    let (ceco, _) = agg(&coord, &g, Preset::CEco, 4, 5);
    let (kaffpa, _) = agg(&coord, &g, Preset::KaffpaEco, 4, 5);
    assert!(
        ceco < kaffpa * 1.05,
        "CEco {ceco:.0} should not lose clearly to KaFFPaEco {kaffpa:.0}"
    );
}

/// Paper §5.1: UStrong cuts less than kMetis-like by a clear margin on
/// complex networks.
#[test]
fn ustrong_beats_kmetis_like() {
    let g = Arc::new(sclap::generators::instances::by_name("tiny-ba").unwrap().build());
    let coord = Coordinator::new(0);
    let (strong, _) = agg(&coord, &g, Preset::UStrong, 4, 3);
    let (kmetis, _) = agg(&coord, &g, Preset::KMetisLike, 4, 3);
    assert!(
        strong < kmetis,
        "UStrong {strong:.0} must beat kMetis-like {kmetis:.0}"
    );
}

/// Paper §5.1: the Fast family is faster than the Strong family.
#[test]
fn fast_is_faster_than_strong() {
    let g = Arc::new(sclap::generators::instances::by_name("tiny-rmat").unwrap().build());
    let coord = Coordinator::new(1);
    let (_, fast_t) = agg(&coord, &g, Preset::UFast, 4, 3);
    let (_, strong_t) = agg(&coord, &g, Preset::UStrong, 4, 3);
    assert!(
        fast_t < strong_t,
        "UFast {fast_t:.3}s should be faster than UStrong {strong_t:.3}s"
    );
}

/// Paper §5.1: Scotch-like produces the worst quality of the pack.
#[test]
fn scotch_like_is_worst() {
    let g = Arc::new(sclap::generators::instances::by_name("tiny-ba").unwrap().build());
    let coord = Coordinator::new(0);
    let (scotch, _) = agg(&coord, &g, Preset::ScotchLike, 4, 3);
    let (ueco, _) = agg(&coord, &g, Preset::UEcoVB, 4, 3);
    assert!(
        ueco <= scotch,
        "UEcoV/B {ueco:.0} must not lose to Scotch-like {scotch:.0}"
    );
}

/// Best-of-10 ≤ average (trivial but guards the aggregation plumbing
/// the table benches rely on).
#[test]
fn best_cut_bounded_by_avg() {
    let g = Arc::new(sclap::generators::instances::by_name("tiny-ws").unwrap().build());
    let coord = Coordinator::new(0);
    for preset in [Preset::CFast, Preset::CEco, Preset::KMetisLike] {
        let a = coord.partition_repeated(
            g.clone(),
            &PartitionConfig::preset(preset, 8),
            &default_seeds(10),
        );
        assert!(a.best_cut as f64 <= a.avg_cut + 1e-9, "{}", preset.name());
    }
}
