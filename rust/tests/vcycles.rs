//! Integration: iterated V-cycles (§4, §B.1, Fig. 3/4).
//!
//! Invariants: (a) with a partition-respecting clustering, no cut edge
//! is ever contracted, so the coarsest graph inherits the partition with
//! identical cut; (b) the final result of a V-cycled run is never worse
//! than its first iteration (Fig. 3's guarantee).

use sclap::clustering::label_propagation::{
    size_constrained_lpa, LpaConfig, NodeOrdering,
};
use sclap::coarsening::contract::contract;
use sclap::coarsening::hierarchy::{coarsen, CoarseningParams, CoarseningScheme};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::metrics::cut_value;
use sclap::partitioning::multilevel::MultilevelPartitioner;
use sclap::util::rng::Rng;

fn web_like() -> sclap::graph::csr::Graph {
    sclap::generators::instances::by_name("tiny-rmat").unwrap().build()
}

/// Fig. 4: clusters are subsets of blocks ⇒ contraction preserves the
/// partition with identical cut and balance on every level.
#[test]
fn respecting_coarsening_preserves_cut_exactly() {
    let g = web_like();
    // some partition (here: from a quick run)
    let p = MultilevelPartitioner::new(PartitionConfig::preset(Preset::CFast, 4))
        .partition(&g, 1)
        .partition;
    let fine_cut = cut_value(&g, &p.blocks);

    let params = CoarseningParams::new(
        4,
        0.03,
        CoarseningScheme::ClusterLpa {
            lpa: LpaConfig::clustering(10, NodeOrdering::Degree),
            size_factor: 18.0,
            ensemble: None,
        },
    );
    let mut rng = Rng::new(2);
    let h = coarsen(&g, &params, Some(&p.blocks), &mut rng);
    assert!(h.depth() >= 1, "should coarsen at least once");
    let coarsest = h.coarsest(&g);
    let coarse_part = h.coarsest_partition.as_ref().expect("projected partition");
    let coarse_cut = cut_value(coarsest, coarse_part);
    assert_eq!(fine_cut, coarse_cut, "V-cycle contraction changed the cut");

    // block weights preserved too
    for b in 0..4u32 {
        let fine_w: i64 = g
            .nodes()
            .filter(|&v| p.blocks[v as usize] == b)
            .map(|v| g.node_weight(v))
            .sum();
        let coarse_w: i64 = coarsest
            .nodes()
            .filter(|&v| coarse_part[v as usize] == b)
            .map(|v| coarsest.node_weight(v))
            .sum();
        assert_eq!(fine_w, coarse_w, "block {b} weight changed");
    }
}

/// §B.1: every cluster contains nodes of one unique block.
#[test]
fn clusters_never_cross_blocks() {
    let g = web_like();
    let blocks: Vec<u32> = {
        let mut rng = Rng::new(3);
        (0..g.n()).map(|_| rng.below(4) as u32).collect()
    };
    for seed in 0..5 {
        let mut rng = Rng::new(seed);
        let (c, _) = size_constrained_lpa(
            &g,
            30,
            &LpaConfig::clustering(8, NodeOrdering::Random),
            None,
            Some(&blocks),
            &mut rng,
        );
        // cluster -> block must be single-valued
        let mut block_of_cluster = vec![u32::MAX; c.num_clusters];
        for v in 0..g.n() {
            let cl = c.labels[v] as usize;
            if block_of_cluster[cl] == u32::MAX {
                block_of_cluster[cl] = blocks[v];
            } else {
                assert_eq!(
                    block_of_cluster[cl], blocks[v],
                    "cluster {cl} crosses blocks (seed {seed})"
                );
            }
        }
        // and contraction keeps every cut edge
        let cont = contract(&g, &c);
        let fine_cut = cut_value(&g, &blocks);
        let coarse_blocks: Vec<u32> = {
            let mut cb = vec![0u32; cont.coarse.n()];
            for v in 0..g.n() {
                cb[cont.map[v] as usize] = blocks[v];
            }
            cb
        };
        assert_eq!(fine_cut, cut_value(&cont.coarse, &coarse_blocks));
    }
}

/// Fig. 3's guarantee: iterated V-cycles never end worse than cycle 1
/// (our driver keeps the best cycle, and each cycle starts from the
/// previous partition, so this must hold for every preset and seed).
#[test]
fn vcycles_monotone_improvement() {
    let g = web_like();
    for preset in [Preset::CFastV, Preset::CEcoV, Preset::UFastV] {
        for seed in [1u64, 7, 42] {
            let mut one = PartitionConfig::preset(preset, 4);
            one.vcycles = 1;
            let mut three = PartitionConfig::preset(preset, 4);
            three.vcycles = 3;
            let r1 = MultilevelPartitioner::new(one).partition(&g, seed);
            let r3 = MultilevelPartitioner::new(three).partition(&g, seed);
            assert!(
                r3.metrics.cut <= r1.metrics.cut,
                "{} seed {seed}: V3 {} > V1 {}",
                preset.name(),
                r3.metrics.cut,
                r1.metrics.cut
            );
        }
    }
}

/// The imbalance schedule (§4) must deliver a *feasible* partition at
/// the finest level even though coarse levels were allowed to overflow.
#[test]
fn coarse_imbalance_ends_feasible() {
    let g = web_like();
    let config = PartitionConfig::preset(Preset::CEcoVB, 8);
    let r = MultilevelPartitioner::new(config).partition(&g, 11);
    let lmax = sclap::coarsening::hierarchy::l_max(
        g.total_node_weight(),
        8,
        0.03,
        g.max_node_weight(),
    );
    assert!(
        r.partition.max_block_weight() <= lmax,
        "{:?} > {lmax}",
        r.partition.block_weights
    );
}
