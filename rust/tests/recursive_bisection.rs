//! Integration: parallel recursive bisection (initial partitioning) —
//! determinism across thread counts and the per-split balance
//! guarantee, on the karate sanity instance and the LFR community
//! instance ("tiny-ba" models a citation network via the LFR-style
//! generator).
//!
//! Balance semantics: every bisection bounds each side by
//! `⌈(1+ε)·target⌉ + max_v c(v)` (the ε slack plus the heaviest-node
//! allowance, `multilevel_bisect`). Recursive bisection *compounds*
//! that per-split guarantee over ⌈log₂ k⌉ levels, so the sharp bound
//! for a leaf block is `⌈(1+ε)^⌈log₂ k⌉ · c(V)/k⌉ + ⌈log₂ k⌉·max_v
//! c(v)` — that (not the single-level L_max, which only the full
//! pipeline's refinement/rebalance stage restores) is what we assert.

use sclap::generators::instances::by_name;
use sclap::graph::csr::{Graph, Weight};
use sclap::initial_partitioning::recursive_bisection::{
    recursive_bisection, InitialPartitionConfig,
};
use sclap::partitioning::metrics::evaluate;
use sclap::util::exec::ExecutionCtx;
use sclap::util::rng::Rng;

/// The compounded per-split balance bound (see the module docs).
fn compounded_bound(g: &Graph, k: usize, eps: f64) -> Weight {
    let levels = (k as f64).log2().ceil() as i32;
    ((1.0 + eps).powi(levels) * g.total_node_weight() as f64 / k as f64).ceil() as Weight
        + levels as Weight * g.max_node_weight()
}

#[test]
fn balance_respected_on_karate_and_lfr() {
    for name in ["karate", "tiny-ba"] {
        let g = by_name(name).unwrap().build();
        for k in [2usize, 4, 8] {
            for config in [
                InitialPartitionConfig::matching_based(0.03),
                InitialPartitionConfig::cluster_based(0.03),
            ] {
                let ctx = ExecutionCtx::new(2);
                let p = recursive_bisection(&g, k, &config, &ctx, &mut Rng::new(5));
                assert_eq!(p.k, k);
                assert!(p.validate(&g).is_ok());
                assert_eq!(p.nonempty_blocks(), k, "{name} k={k}: empty block");
                let bound = compounded_bound(&g, k, 0.03);
                assert!(
                    p.max_block_weight() <= bound,
                    "{name} k={k}: max block {} exceeds compounded ε bound {bound} \
                     (weights {:?})",
                    p.max_block_weight(),
                    p.block_weights
                );
            }
        }
    }
}

#[test]
fn bisection_balance_is_tight_for_k2() {
    // A single bisection has no compounding: one level of slack only.
    for name in ["karate", "tiny-ba"] {
        let g = by_name(name).unwrap().build();
        let config = InitialPartitionConfig::matching_based(0.03);
        let ctx = ExecutionCtx::new(2);
        let p = recursive_bisection(&g, 2, &config, &ctx, &mut Rng::new(7));
        let m = evaluate(&g, &p, 0.03);
        assert!(
            m.feasible,
            "{name}: single bisection infeasible, weights {:?}",
            p.block_weights
        );
    }
}

#[test]
fn deterministic_across_threads_1_2_4() {
    for name in ["karate", "tiny-ba"] {
        let g = by_name(name).unwrap().build();
        for k in [2usize, 4, 8] {
            for config in [
                InitialPartitionConfig::matching_based(0.03),
                InitialPartitionConfig::cluster_based(0.03),
            ] {
                let run = |threads: usize| {
                    let ctx = ExecutionCtx::new(threads);
                    recursive_bisection(&g, k, &config, &ctx, &mut Rng::new(9)).blocks
                };
                let reference = run(1);
                for threads in [2usize, 4] {
                    assert_eq!(
                        reference,
                        run(threads),
                        "{name} k={k}: threads={threads} diverged"
                    );
                }
            }
        }
    }
}

#[test]
fn shared_ctx_reuse_is_stable() {
    // One context serving many bisections back to back (the coordinator
    // pattern) must give the same answers as fresh contexts.
    let g = by_name("tiny-ba").unwrap().build();
    let config = InitialPartitionConfig::matching_based(0.03);
    let shared = ExecutionCtx::new(4);
    for k in [2usize, 4, 8] {
        let a = recursive_bisection(&g, k, &config, &shared, &mut Rng::new(11)).blocks;
        let fresh = ExecutionCtx::new(4);
        let b = recursive_bisection(&g, k, &config, &fresh, &mut Rng::new(11)).blocks;
        assert_eq!(a, b, "k={k}: shared-context run diverged");
    }
}
