//! Property tests (proptest-lite, DESIGN.md §7) over random graphs,
//! seeds and parameters. Each property runs dozens of seeded cases; a
//! failure reports seed + size for exact reproduction.

use sclap::clustering::async_lpa::parallel_async_sclap;
use sclap::clustering::ensemble::{ensemble_sclap, overlay_clustering};
use sclap::clustering::label_propagation::{
    size_constrained_lpa, LpaConfig, LpaMode, NodeOrdering,
};
use sclap::clustering::parallel_lpa::parallel_sclap;
use sclap::coarsening::contract::{contract, contract_parallel, project_partition};
use sclap::generators;
use sclap::graph::csr::{Graph, Weight};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::metrics::cut_value;
use sclap::partitioning::multilevel::MultilevelPartitioner;
use sclap::partitioning::partition::Partition;
use sclap::refinement::lpa_refine::parallel_lpa_refine;
use sclap::util::exec::ExecutionCtx;
use sclap::util::pool::ThreadPool;
use sclap::util::proptest::{for_random_cases, PropConfig};
use sclap::util::rng::Rng;

/// Random graph mixing the generator families, sized by the hint.
fn arb_graph(rng: &mut Rng, size: usize) -> Graph {
    let n = (size * 8).max(8);
    match rng.below(4) {
        0 => generators::erdos_renyi(n, 3 * n, rng),
        1 => generators::barabasi_albert(n, 3, rng),
        2 => generators::watts_strogatz(n.max(12), 3, 0.2, rng),
        _ => {
            let scale = (n as f64).log2().ceil() as u32;
            generators::rmat(scale, 4 * n, 0.57, 0.19, 0.19, rng)
        }
    }
}

/// Invariant 1: SCLaP never violates the size constraint.
#[test]
fn prop_sclap_respects_bound() {
    for_random_cases(&PropConfig::default(), |rng, size| {
        let g = arb_graph(rng, size);
        let upper = (rng.range(1, 20)) as Weight;
        let upper = upper.max(g.max_node_weight());
        let ordering = *rng.choose(&[
            NodeOrdering::Random,
            NodeOrdering::Degree,
            NodeOrdering::WeightedDegree,
        ]);
        let mut cfg = LpaConfig::clustering(rng.range(1, 6), ordering);
        cfg.active_nodes = rng.chance(0.5);
        let (c, _) = size_constrained_lpa(&g, upper, &cfg, None, None, rng);
        assert!(
            c.respects_bound(upper),
            "bound {upper} violated: {:?}",
            c.cluster_weights.iter().max()
        );
        // labels dense and complete
        assert_eq!(c.labels.len(), g.n());
        assert!(c.labels.iter().all(|&l| (l as usize) < c.num_clusters));
    });
}

/// Invariant 2: contraction preserves totals and lifts partitions with
/// identical cut + balance.
#[test]
fn prop_contraction_preserves_cut() {
    for_random_cases(&PropConfig::default(), |rng, size| {
        let g = arb_graph(rng, size);
        let upper = g.max_node_weight().max(rng.range(2, 12) as Weight);
        let (c, _) =
            size_constrained_lpa(&g, upper, &LpaConfig::default(), None, None, rng);
        let cont = contract(&g, &c);
        assert_eq!(cont.coarse.total_node_weight(), g.total_node_weight());
        assert_eq!(cont.coarse.total_edge_weight(), c.cut(&g));
        assert!(cont.coarse.validate().is_ok());

        // random coarse partition lifts with identical cut
        let k = rng.range(2, 5);
        let coarse_blocks: Vec<u32> =
            (0..cont.coarse.n()).map(|_| rng.below(k) as u32).collect();
        let fine_blocks = project_partition(&cont.map, &coarse_blocks);
        assert_eq!(
            cut_value(&cont.coarse, &coarse_blocks),
            cut_value(&g, &fine_blocks)
        );
    });
}

/// Invariant 3: the overlay refines every input clustering and stays
/// feasible if the inputs are.
#[test]
fn prop_overlay_refines_inputs() {
    for_random_cases(&PropConfig::quick(), |rng, size| {
        let g = arb_graph(rng, size.min(32));
        let upper = g.max_node_weight().max(8);
        let inputs: Vec<Vec<u32>> = (0..3)
            .map(|_| {
                size_constrained_lpa(
                    &g,
                    upper,
                    &LpaConfig::clustering(4, NodeOrdering::Random),
                    None,
                    None,
                    rng,
                )
                .0
                .labels
            })
            .collect();
        let o = overlay_clustering(&g, &inputs);
        assert!(o.respects_bound(upper));
        for v in 0..g.n() {
            for u in (v + 1)..g.n().min(v + 50) {
                if o.labels[v] == o.labels[u] {
                    for input in &inputs {
                        assert_eq!(input[v], input[u], "overlay merged separated nodes");
                    }
                }
            }
        }
        // ensemble wrapper too
        let e = ensemble_sclap(&g, upper, &LpaConfig::default(), 3, None, rng);
        assert!(e.respects_bound(upper));
    });
}

/// Invariant 5: refinement mode never overflows the bound (if feasible
/// on entry) and never empties a block.
#[test]
fn prop_refinement_safety() {
    for_random_cases(&PropConfig::default(), |rng, size| {
        let g = arb_graph(rng, size);
        let k = rng.range(2, 5).min(g.n());
        let blocks: Vec<u32> = (0..g.n()).map(|v| (v % k) as u32).collect();
        let per_block = (g.total_node_weight() as f64 / k as f64).ceil() as Weight;
        let upper = per_block + g.max_node_weight() + rng.range(0, 5) as Weight;
        let mut cfg = LpaConfig::refinement(rng.range(1, 8));
        cfg.mode = LpaMode::Refinement;
        let before_blocks = blocks.clone();
        let (c, _) = size_constrained_lpa(&g, upper, &cfg, Some(blocks), None, rng);
        assert_eq!(c.num_clusters, k, "block vanished (had {k})");
        assert!(
            c.respects_bound(upper),
            "refinement overflowed: {:?} > {upper}",
            c.cluster_weights
        );
        // sanity: it never *increases* the cut
        let before_cut = cut_value(&g, &before_blocks);
        assert!(c.cut(&g) <= before_cut);
    });
}

/// Invariant 8: the full driver always emits valid feasible partitions.
#[test]
fn prop_multilevel_valid_output() {
    let presets = [
        Preset::CFast,
        Preset::UFast,
        Preset::CEco,
        Preset::KMetisLike,
        Preset::CFastVB,
    ];
    for_random_cases(&PropConfig::quick(), |rng, size| {
        let g = arb_graph(rng, size);
        let k = *rng.choose(&[2usize, 3, 4, 8]);
        let k = k.min(g.n().max(1));
        let preset = *rng.choose(&presets);
        let config = PartitionConfig::preset(preset, k);
        let r = MultilevelPartitioner::new(config).partition(&g, rng.next_u64());
        assert!(r.partition.validate(&g).is_ok(), "{}", preset.name());
        assert_eq!(r.partition.nonempty_blocks(), k);
        let lmax = sclap::coarsening::hierarchy::l_max(
            g.total_node_weight(),
            k,
            0.03,
            g.max_node_weight(),
        );
        assert!(
            r.partition.max_block_weight() <= lmax,
            "{} k={k}: {:?} > {lmax}",
            preset.name(),
            r.partition.block_weights
        );
    });
}

/// Pool invariant A: parallel SCLaP ≡ sequential SCLaP — the 1-thread
/// pool executes the identical logical schedule, so labels match the
/// multi-thread pools bit for bit, per seed. And the size constraint
/// holds after *every* round (checked by truncating the round budget).
#[test]
fn prop_parallel_sclap_thread_invariant_and_bounded() {
    let ctxs = [
        ExecutionCtx::new(1),
        ExecutionCtx::new(2),
        ExecutionCtx::new(4),
    ];
    for_random_cases(&PropConfig::quick(), |rng, size| {
        let g = arb_graph(rng, size);
        let upper = g.max_node_weight().max(rng.range(2, 16) as Weight);
        let seed = rng.next_u64();
        // Size constraint after every round: run the same seed with
        // every prefix of the round budget.
        for rounds in 1..=3 {
            let c = parallel_sclap(&g, upper, rounds, &ctxs[0], &mut Rng::new(seed));
            assert!(
                c.respects_bound(upper),
                "bound {upper} violated after round {rounds}: {:?}",
                c.cluster_weights.iter().max()
            );
        }
        let sequential = parallel_sclap(&g, upper, 5, &ctxs[0], &mut Rng::new(seed));
        assert!(sequential.respects_bound(upper));
        for ctx in &ctxs[1..] {
            let parallel = parallel_sclap(&g, upper, 5, ctx, &mut Rng::new(seed));
            assert_eq!(
                sequential.labels,
                parallel.labels,
                "pool size {} diverged from sequential",
                ctx.threads()
            );
        }
    });
}

/// Pool invariant A′: the coloring-based parallel *asynchronous* SCLaP
/// (arXiv 1404.4797 engine) is thread-count-invariant and never
/// violates the size constraint, for any round budget.
#[test]
fn prop_parallel_async_sclap_thread_invariant_and_bounded() {
    let ctxs = [
        ExecutionCtx::new(1),
        ExecutionCtx::new(2),
        ExecutionCtx::new(4),
    ];
    for_random_cases(&PropConfig::quick(), |rng, size| {
        let g = arb_graph(rng, size);
        let upper = g.max_node_weight().max(rng.range(2, 16) as Weight);
        let seed = rng.next_u64();
        for rounds in 1..=3 {
            let cfg = LpaConfig::clustering(rounds, NodeOrdering::Degree);
            let (c, _) =
                parallel_async_sclap(&g, upper, &cfg, None, &ctxs[0], &mut Rng::new(seed));
            assert!(
                c.respects_bound(upper),
                "bound {upper} violated after round {rounds}: {:?}",
                c.cluster_weights.iter().max()
            );
        }
        let cfg = LpaConfig::clustering(5, NodeOrdering::Degree);
        let (sequential, _) =
            parallel_async_sclap(&g, upper, &cfg, None, &ctxs[0], &mut Rng::new(seed));
        for ctx in &ctxs[1..] {
            let (parallel, _) =
                parallel_async_sclap(&g, upper, &cfg, None, ctx, &mut Rng::new(seed));
            assert_eq!(
                sequential.labels,
                parallel.labels,
                "pool size {} diverged from sequential",
                ctx.threads()
            );
        }
    });
}

/// Pool invariant B: parallel contraction is bit-identical to the
/// sequential contraction for every pool size.
#[test]
fn prop_parallel_contract_equals_sequential() {
    let pools = [ThreadPool::new(2), ThreadPool::new(4)];
    for_random_cases(&PropConfig::quick(), |rng, size| {
        let g = arb_graph(rng, size);
        let upper = g.max_node_weight().max(rng.range(2, 12) as Weight);
        let (c, _) = size_constrained_lpa(&g, upper, &LpaConfig::default(), None, None, rng);
        let seq = contract(&g, &c);
        for pool in &pools {
            let par = contract_parallel(&g, &c, pool);
            assert_eq!(seq.coarse, par.coarse, "pool size {}", pool.threads());
            assert_eq!(seq.map, par.map);
        }
    });
}

/// Pool invariant C: parallel LPA refinement is thread-count-invariant,
/// never overflows a feasible bound, and never empties a block.
#[test]
fn prop_parallel_refine_safety_and_invariance() {
    let ctxs = [
        ExecutionCtx::new(1),
        ExecutionCtx::new(2),
        ExecutionCtx::new(4),
    ];
    for_random_cases(&PropConfig::quick(), |rng, size| {
        let g = arb_graph(rng, size);
        let k = rng.range(2, 5).min(g.n());
        let blocks: Vec<u32> = (0..g.n()).map(|v| (v % k) as u32).collect();
        let per_block = (g.total_node_weight() as f64 / k as f64).ceil() as Weight;
        let lmax = per_block + g.max_node_weight() + rng.range(0, 5) as Weight;
        let seed = rng.next_u64();
        let mut reference: Option<Vec<u32>> = None;
        for ctx in &ctxs {
            let mut p = Partition::from_blocks(&g, k, blocks.clone());
            parallel_lpa_refine(&g, &mut p, lmax, 5, ctx, &mut Rng::new(seed));
            assert!(
                p.max_block_weight() <= lmax,
                "pool {} overflowed: {:?} > {lmax}",
                ctx.threads(),
                p.block_weights
            );
            assert_eq!(p.nonempty_blocks(), k, "block vanished");
            assert!(p.validate(&g).is_ok());
            match &reference {
                None => reference = Some(p.blocks),
                Some(r) => assert_eq!(r, &p.blocks, "pool size {}", ctx.threads()),
            }
        }
    });
}

/// Matching is a matching for every graph family and bound.
#[test]
fn prop_matching_invariant() {
    for_random_cases(&PropConfig::default(), |rng, size| {
        let g = arb_graph(rng, size);
        let bound = g.max_node_weight().max(rng.range(2, 10) as Weight);
        let two_hop = rng.chance(0.5);
        let c = sclap::coarsening::matching::heavy_edge_matching(&g, bound, two_hop, rng);
        assert!(sclap::coarsening::matching::is_matching(&c));
        assert!(c.respects_bound(bound));
    });
}
