//! Integration: the network service layer (`coordinator::net`) —
//! end-to-end determinism across the wire.
//!
//! The contract under test (ISSUE 5 acceptance): concurrent TCP
//! clients receive results **byte-identical** to offline
//! `Coordinator`-computed renderings, for both storage backends,
//! across worker counts {1, 4}, client interleavings, and cache
//! enabled vs. disabled; a duplicated request is served from the cache
//! (observable via `"cached":true`) with an identical partition
//! fingerprint; backpressure surfaces as structured `busy` responses;
//! `!shutdown` drains before closing.

use sclap::coordinator::net::{parse_response, NetClient, NetServer, NetServerConfig};
use sclap::coordinator::queue::spec::render_result_line;
use sclap::coordinator::service::{Aggregate, Coordinator, RunOutcome};
use sclap::graph::csr::Graph;
use sclap::graph::store::{write_sharded, ShardedStore};
use sclap::partitioning::config::{PartitionConfig, Preset};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn temp_path(tag: &str) -> PathBuf {
    // pid first so `tag`'s file extension stays the real extension
    std::env::temp_dir().join(format!("sclap-net-{}-{tag}", std::process::id()))
}

/// The shared community instance (big enough for the budget-1
/// external path, same parameters as `tests/batch_queue.rs`).
fn lfr() -> Graph {
    let mut rng = sclap::util::rng::Rng::new(4);
    sclap::generators::lfr::lfr_like(1200, 6.0, 0.15, &mut rng).0
}

/// One request line plus its offline-computed expected response line.
struct Case {
    line: String,
    expected: String,
}

/// Offline reference for an in-memory request: the plain coordinator
/// path, rendered exactly like `serve` renders it.
fn mem_case(
    id: &str,
    line: String,
    graph: &Arc<Graph>,
    config: &PartitionConfig,
    seeds: &[u64],
) -> Case {
    let agg = Coordinator::new(2).partition_repeated(graph.clone(), config, seeds);
    Case {
        line,
        expected: render_result_line(id, &agg, false),
    }
}

/// Offline reference for a shard-directory request: the out-of-core
/// driver per seed, aggregated like the queue does.
fn shard_case(
    id: &str,
    line: String,
    dir: &std::path::Path,
    config: &PartitionConfig,
    seeds: &[u64],
) -> Case {
    let coord = Coordinator::new(2);
    let store = ShardedStore::open(dir).unwrap();
    let runs: Vec<RunOutcome> = seeds
        .iter()
        .map(|&s| {
            RunOutcome::from_out_of_core(s, &coord.partition_store(&store, config, s).unwrap())
        })
        .collect();
    let agg = Aggregate::from_runs(runs);
    Case {
        line,
        expected: render_result_line(id, &agg, false),
    }
}

struct Fixture {
    graph_path: String,
    shard_dir: PathBuf,
    cases: Vec<Case>,
    dup_line: String,
}

/// Build the instance files and the offline references once.
fn fixture() -> Fixture {
    let community = Arc::new(lfr());
    let graph_path = temp_path("graph.bin");
    sclap::graph::io::save_path(&community, &graph_path).unwrap();
    let shard_dir = temp_path("shards");
    write_sharded(&community, &shard_dir, 3).unwrap();
    let graph_path = graph_path.to_string_lossy().to_string();
    let shard_str = shard_dir.to_string_lossy().to_string();

    let cfast4 = PartitionConfig::preset(Preset::CFast, 4);
    let mut budgeted = PartitionConfig::preset(Preset::CFast, 4);
    budgeted.memory_budget_bytes = Some(1);
    let tiny_ba = Arc::new(
        sclap::generators::instances::by_name("tiny-ba")
            .unwrap()
            .build(),
    );
    let ufast2 = PartitionConfig::preset(Preset::UFast, 2);

    let cases = vec![
        mem_case(
            "r1",
            format!("id=r1 graph={graph_path} k=4 preset=CFast seeds=1,2"),
            &community,
            &cfast4,
            &[1, 2],
        ),
        shard_case(
            "r2",
            format!("id=r2 shards={shard_str} k=4 preset=CFast memory-budget=1 seeds=3"),
            &shard_dir,
            &budgeted,
            &[3],
        ),
        mem_case(
            "r3",
            "id=r3 instance=tiny-ba k=2 preset=UFast seeds=5,6".to_string(),
            &tiny_ba,
            &ufast2,
            &[5, 6],
        ),
    ];
    // Identical to r1 in everything but the id (labels are not key
    // material): with the cache enabled this is served without
    // recomputation.
    let dup_line = format!("id=r1dup graph={graph_path} k=4 preset=CFast seeds=1,2");
    Fixture {
        graph_path,
        shard_dir,
        cases,
        dup_line,
    }
}

type ServerRunner = std::thread::JoinHandle<std::io::Result<()>>;

fn spawn_server(
    config: NetServerConfig,
) -> (sclap::coordinator::net::NetServerHandle, ServerRunner, String) {
    let server = NetServer::bind("127.0.0.1:0", config).unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (handle, runner, addr)
}

/// Drive one client connection: send `lines` (plus a blank and a
/// comment, which must be ignored), half-close, and collect all
/// responses by id.
fn run_client(addr: &str, lines: &[String]) -> HashMap<String, String> {
    let client = NetClient::connect_retry(addr, Duration::from_secs(10)).unwrap();
    let (mut sender, mut receiver) = client.split();
    sender.send_line("").unwrap();
    sender.send_line("# comment lines are accepted on the wire too").unwrap();
    for line in lines {
        sender.send_line(line).unwrap();
    }
    sender.finish().unwrap();
    let mut responses = HashMap::new();
    while let Some(line) = receiver.recv_line().unwrap() {
        let response = parse_response(&line).unwrap_or_else(|e| panic!("{e}: {line}"));
        let id = response.id.clone().expect("request responses carry ids");
        assert!(
            responses.insert(id, line).is_none(),
            "one response per request"
        );
    }
    responses
}

#[test]
fn wire_results_are_byte_identical_to_offline_for_any_workers_and_cache_state() {
    let fx = fixture();
    for workers in [1usize, 4] {
        for cache_entries in [0usize, 16] {
            let (handle, runner, addr) = spawn_server(NetServerConfig {
                workers,
                max_pending: 16,
                cache_entries,
                timing: false,
                trace: None,
                journal: None,
            });
            // Two concurrent clients, interleaved: client A carries the
            // duplicate pair (same connection ⇒ deterministic cache
            // marker), client B the other backends.
            let a_lines = [
                fx.cases[0].line.clone(),
                fx.dup_line.clone(),
                fx.cases[2].line.clone(),
            ];
            let b_lines = [fx.cases[1].line.clone()];
            let (a, b) = std::thread::scope(|scope| {
                let ta = scope.spawn(|| run_client(&addr, &a_lines));
                let tb = scope.spawn(|| run_client(&addr, &b_lines));
                (ta.join().unwrap(), tb.join().unwrap())
            });
            let ctx = format!("workers={workers} cache={cache_entries}");
            // Every first-occurrence response is byte-identical to the
            // offline rendering — cache on or off.
            assert_eq!(a["r1"], fx.cases[0].expected, "{ctx}");
            assert_eq!(b["r2"], fx.cases[1].expected, "{ctx}");
            assert_eq!(a["r3"], fx.cases[2].expected, "{ctx}");
            // The duplicate: identical partition fingerprint always;
            // with the cache on, served from cache with only the
            // cached marker (and the id) differing from r1's bytes.
            let dup = parse_response(&a["r1dup"]).unwrap();
            let first = parse_response(&a["r1"]).unwrap();
            assert_eq!(dup.blocks_fnv(), first.blocks_fnv(), "{ctx}");
            assert_eq!(dup.best_cut(), first.best_cut(), "{ctx}");
            let offline_dup = fx.cases[0]
                .expected
                .replacen("\"id\":\"r1\"", "\"id\":\"r1dup\"", 1);
            if cache_entries == 0 {
                assert!(!dup.cached, "{ctx}: no cache, no marker");
                assert_eq!(a["r1dup"], offline_dup, "{ctx}");
            } else {
                assert!(dup.cached, "{ctx}: duplicate must be served from cache");
                let tagged = format!(
                    "{},\"cached\":true}}",
                    &offline_dup[..offline_dup.len() - 1]
                );
                assert_eq!(a["r1dup"], tagged, "{ctx}");
                assert!(handle.cache_stats().hits + handle.cache_stats().joined >= 1);
            }
            handle.shutdown();
            runner.join().unwrap().unwrap();
        }
    }
    std::fs::remove_dir_all(&fx.shard_dir).ok();
    std::fs::remove_file(&fx.graph_path).ok();
}

#[test]
fn busy_backpressure_is_structured_and_deterministic() {
    let (handle, runner, addr) = spawn_server(NetServerConfig {
        workers: 1,
        max_pending: 1,
        cache_entries: 8,
        timing: false,
        trace: None,
        journal: None,
    });
    // Pause the scheduler: the single queue slot fills and stays full.
    handle.pause();
    let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    client
        .send_line("id=first instance=tiny-ba k=2 preset=CFast seeds=1")
        .unwrap();
    // A *distinct* request while the queue is full: structured refusal.
    let busy_line = client
        .request("id=second instance=tiny-ba k=2 preset=CFast seeds=2")
        .unwrap();
    let busy = parse_response(&busy_line).unwrap();
    assert_eq!((busy.status.as_str(), busy.id.as_deref()), ("busy", Some("second")));
    // An *identical* request joins the in-flight leader instead of
    // needing a queue slot — no busy, a real (cached) result later.
    client
        .send_line("id=firstdup instance=tiny-ba k=2 preset=CFast seeds=1")
        .unwrap();
    handle.resume();
    client.finish_sending().unwrap();
    let mut seen = HashMap::new();
    while let Some(line) = client.recv_line().unwrap() {
        let r = parse_response(&line).unwrap();
        seen.insert(r.id.clone().unwrap(), r);
    }
    assert_eq!(seen["first"].status, "ok");
    assert_eq!(seen["firstdup"].status, "ok");
    assert!(seen["firstdup"].cached);
    assert_eq!(seen["first"].blocks_fnv(), seen["firstdup"].blocks_fnv());
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn control_commands_and_drain_then_close_shutdown() {
    let (_handle, runner, addr) = spawn_server(NetServerConfig::default());
    let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let pong = client.request("!ping").unwrap();
    assert_eq!(parse_response(&pong).unwrap().status, "pong");
    let unknown = client.request("!frobnicate").unwrap();
    assert_eq!(parse_response(&unknown).unwrap().status, "error");
    // Submit work, then immediately ask for shutdown: the accepted
    // request must still be answered before the connection closes.
    client
        .send_line("id=last instance=tiny-ba k=2 preset=CFast seeds=7")
        .unwrap();
    client.send_line("!shutdown").unwrap();
    let mut statuses = Vec::new();
    let mut last_ok = None;
    while let Some(line) = client.recv_line().unwrap() {
        let r = parse_response(&line).unwrap();
        if r.id.as_deref() == Some("last") {
            last_ok = Some(r.status.clone());
        }
        statuses.push(r.status);
    }
    assert_eq!(last_ok.as_deref(), Some("ok"), "drain must answer accepted work");
    assert!(
        statuses.iter().any(|s| s == "shutdown"),
        "shutdown ack missing: {statuses:?}"
    );
    // The server exits on its own — no handle.shutdown() needed.
    runner.join().unwrap().unwrap();
    // New connections are refused (connect may succeed briefly, but no
    // service remains; a fresh connect must fail once the listener is
    // gone).
    assert!(NetClient::connect(&addr).is_err() || {
        // raced the close: the next attempt must fail
        std::thread::sleep(Duration::from_millis(100));
        NetClient::connect(&addr).is_err()
    });
}
