//! Integration: the pool determinism contract, end to end.
//!
//! The hard invariant of the thread-pool runtime (`util::pool` module
//! docs): **same seed + same config ⇒ byte-identical `Partition.blocks`
//! for `threads ∈ {1, 2, 4}`** — the thread count is an execution knob,
//! never an algorithmic one.
//!
//! Coverage is budgeted for CI wall-clock (tier-1 runs tests in debug):
//! the *full* 22-preset ladder sweeps the two smallest instances, a
//! representative preset subset (covering both coarsening schemes, both
//! IP families, every refinement kind, V-cycles/ensembles and the
//! tolerant baseline) sweeps the whole tiny suite, and the synchronous
//! parallel-refinement engine gets its own sweep since it is the one
//! configuration whose hot loop actually fans out on small inputs.

use sclap::generators::instances::{by_name, tiny_suite};
use sclap::graph::csr::Graph;
use sclap::initial_partitioning::recursive_bisection::{
    recursive_bisection, InitialPartitionConfig,
};
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::multilevel::MultilevelPartitioner;
use sclap::util::exec::ExecutionCtx;

fn blocks(cfg: &PartitionConfig, g: &Graph, seed: u64) -> Vec<u32> {
    MultilevelPartitioner::new(cfg.clone())
        .partition(g, seed)
        .partition
        .blocks
}

/// Run `cfg` at threads ∈ {1, 2, 4} and assert byte-identical blocks.
fn assert_thread_invariant(
    label: &str,
    instance: &str,
    mut cfg: PartitionConfig,
    g: &Graph,
    seed: u64,
) {
    let mut reference: Option<Vec<u32>> = None;
    for threads in [1usize, 2, 4] {
        cfg.threads = threads;
        let b = blocks(&cfg, g, seed);
        match &reference {
            None => reference = Some(b),
            Some(r) => assert_eq!(
                r, &b,
                "{label} on {instance}: threads={threads} diverged from threads=1"
            ),
        }
    }
}

#[test]
fn every_preset_identical_across_thread_counts() {
    for name in ["karate", "tiny-rmat"] {
        let g = by_name(name).unwrap().build();
        let k = 4.min(g.n());
        for preset in Preset::ALL {
            assert_thread_invariant(
                preset.name(),
                name,
                PartitionConfig::preset(preset, k),
                &g,
                42,
            );
        }
    }
}

#[test]
fn representative_presets_on_the_full_tiny_suite() {
    let subset = [
        Preset::CFast,
        Preset::UFast,
        Preset::CEco,
        Preset::CEcoVB,
        Preset::CFastVBE,
        Preset::KMetisLike,
        Preset::ScotchLike,
    ];
    for spec in tiny_suite() {
        let g = spec.build();
        let k = 4.min(g.n());
        for preset in subset {
            assert_thread_invariant(
                preset.name(),
                spec.name,
                PartitionConfig::preset(preset, k),
                &g,
                7,
            );
        }
    }
}

#[test]
fn same_seed_reruns_are_identical() {
    for spec in tiny_suite() {
        let g = spec.build();
        let mut cfg = PartitionConfig::preset(Preset::UFast, 4.min(g.n()));
        cfg.threads = 4;
        assert_eq!(
            blocks(&cfg, &g, 7),
            blocks(&cfg, &g, 7),
            "{}: same-seed rerun differed",
            spec.name
        );
        // ...and a different seed really is a different run (guards
        // against the seed being silently ignored).
        if g.n() > 40 {
            assert_ne!(
                blocks(&cfg, &g, 7),
                blocks(&cfg, &g, 8),
                "{}: seeds 7 and 8 gave identical partitions",
                spec.name
            );
        }
    }
}

#[test]
fn parallel_async_coarsening_thread_invariant() {
    // The coloring-based parallel asynchronous LPA (arXiv 1404.4797
    // engine) through the full coarsening path: same seed + config ⇒
    // byte-identical partition for threads ∈ {1, 2, 4}. tiny-rmat and
    // tiny-ba are large enough to actually coarsen, so the engine runs
    // on every level of the hierarchy.
    for name in ["tiny-rmat", "tiny-ba"] {
        let g = by_name(name).unwrap().build();
        for preset in [Preset::CFast, Preset::UFast, Preset::CEco] {
            let mut cfg = PartitionConfig::preset(preset, 4);
            cfg.parallel_coarsening = true;
            assert_thread_invariant(
                preset.name(),
                &format!("{name} (parallel async coarsening)"),
                cfg,
                &g,
                31,
            );
        }
    }
}

#[test]
fn parallel_async_coarsening_with_vcycles_thread_invariant() {
    // V-cycles exercise the `respect` path of the parallel async engine
    // (clusters must not cross the input partition's block boundaries).
    let g = by_name("tiny-ba").unwrap().build();
    let mut cfg = PartitionConfig::preset(Preset::CFastVB, 4);
    cfg.parallel_coarsening = true;
    assert_thread_invariant("CFastV/B", "tiny-ba (async coarsening + V-cycles)", cfg, &g, 37);
}

#[test]
fn parallel_recursive_bisection_thread_invariant() {
    // The initial-partitioning engine directly: the split frontier fans
    // out on the pool, per-branch streams derive from the split path —
    // same seed ⇒ byte-identical blocks for threads ∈ {1, 2, 4}.
    for name in ["karate", "tiny-rmat"] {
        let g = by_name(name).unwrap().build();
        for k in [2usize, 4, 8] {
            let config = InitialPartitionConfig::matching_based(0.03);
            let run = |threads: usize| {
                let ctx = ExecutionCtx::new(threads);
                recursive_bisection(
                    &g,
                    k,
                    &config,
                    &ctx,
                    &mut sclap::util::rng::Rng::new(41),
                )
                .blocks
            };
            let reference = run(1);
            for threads in [2usize, 4] {
                assert_eq!(
                    reference,
                    run(threads),
                    "{name} k={k}: threads={threads} diverged"
                );
            }
        }
    }
}

#[test]
fn parallel_refinement_engine_thread_invariant() {
    // n = 2000 spans several scoring chunks, so the synchronous rounds
    // genuinely fan out across the pool here.
    let g = by_name("tiny-ba").unwrap().build();
    for preset in [Preset::CFast, Preset::UFast, Preset::CEco] {
        let mut cfg = PartitionConfig::preset(preset, 4);
        cfg.parallel_refinement = true;
        assert_thread_invariant(
            preset.name(),
            "tiny-ba (parallel refinement)",
            cfg,
            &g,
            99,
        );
    }
}
