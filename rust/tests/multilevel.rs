//! Integration: the full multilevel pipeline (Fig. 1 contract) across
//! configurations and instance families.

use sclap::coarsening::hierarchy::l_max;
use sclap::generators::instances::tiny_suite;
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::partitioning::metrics::cut_value;
use sclap::partitioning::multilevel::MultilevelPartitioner;

/// Every preset must produce a valid, feasible partition on every tiny
/// instance (except Scotch-like, which is allowed to be imbalanced —
/// exactly like the real Scotch in the paper's §5.1).
#[test]
fn every_preset_on_every_tiny_instance() {
    for spec in tiny_suite() {
        let g = spec.build();
        for preset in Preset::ALL {
            // Strong presets are slow; skip them on the largest tiny instances.
            let heavy = matches!(
                preset,
                Preset::CStrong | Preset::UStrong | Preset::KaffpaStrong | Preset::HMetisLike
            );
            if heavy && g.n() > 2000 {
                continue;
            }
            let k = 4.min(g.n());
            let config = PartitionConfig::preset(preset, k);
            let r = MultilevelPartitioner::new(config).partition(&g, 123);
            assert!(
                r.partition.validate(&g).is_ok(),
                "{} on {}",
                preset.name(),
                spec.name
            );
            assert_eq!(r.partition.nonempty_blocks(), k, "{} on {}", preset.name(), spec.name);
            assert_eq!(r.metrics.cut, cut_value(&g, &r.partition.blocks));
            let lmax = l_max(g.total_node_weight(), k, 0.03, g.max_node_weight());
            if preset != Preset::ScotchLike {
                assert!(
                    r.partition.max_block_weight() <= lmax,
                    "{} on {}: {:?} > {lmax}",
                    preset.name(),
                    spec.name,
                    r.partition.block_weights
                );
            }
        }
    }
}

/// The Fig. 1 multilevel contract: a coarse partition projects to the
/// finest level with the same cut, and refinement only improves it. We
/// verify through the driver's reported phases.
#[test]
fn multilevel_improves_on_initial() {
    // tiny-ba (n=2000) with k=4: above the coarsest-size threshold
    // (max(240, n/240) = 240) AND with a non-degenerate cluster bound
    // W = L_max/(f·k) ≈ 7, so the hierarchy is non-trivial.
    let g = sclap::generators::instances::by_name("tiny-ba").unwrap().build();
    let config = PartitionConfig::preset(Preset::CEco, 4);
    let r = MultilevelPartitioner::new(config).partition(&g, 7);
    // refinement must not be worse than the projected initial partition
    assert!(
        r.metrics.cut <= r.initial_cut,
        "final {} > initial {}",
        r.metrics.cut,
        r.initial_cut
    );
    assert!(r.levels >= 1);
    assert!(r.coarsest_n < g.n());
}

/// k sweep of the paper (§5): all six values produce valid partitions.
#[test]
fn paper_k_sweep() {
    let g = sclap::generators::instances::by_name("tiny-ba").unwrap().build();
    for k in [2usize, 4, 8, 16, 32, 64] {
        let config = PartitionConfig::preset(Preset::UFast, k);
        let r = MultilevelPartitioner::new(config).partition(&g, k as u64);
        assert_eq!(r.partition.nonempty_blocks(), k, "k={k}");
        let lmax = l_max(g.total_node_weight(), k, 0.03, g.max_node_weight());
        assert!(r.partition.max_block_weight() <= lmax, "k={k}");
    }
}

/// Cluster coarsening must beat matching coarsening on hierarchy depth
/// for complex networks (the paper's §3 claim: aggressive shrinkage).
#[test]
fn cluster_coarsening_is_more_aggressive() {
    // Needs enough nodes that the cluster bound W = L_max/(f·k) is well
    // above 2, else SCLaP degenerates to pair-merging (the paper's
    // instances are 10^4..10^9 nodes; scale-13 R-MAT suffices here).
    let mut rng = sclap::util::rng::Rng::new(77);
    let g = sclap::graph::subgraph::largest_component(&sclap::generators::rmat(
        13, 40_000, 0.57, 0.19, 0.19, &mut rng,
    ));
    let cluster = MultilevelPartitioner::new(PartitionConfig::preset(Preset::CFast, 4))
        .partition(&g, 5);
    let matching = MultilevelPartitioner::new(PartitionConfig::preset(Preset::KaffpaEco, 4))
        .partition(&g, 5);
    assert!(
        cluster.first_shrink > matching.first_shrink,
        "cluster {} vs matching {}",
        cluster.first_shrink,
        matching.first_shrink
    );
}

/// Regular meshes: both schemes must still work (the paper's method is
/// *also* correct on meshes, merely not uniquely better).
#[test]
fn mesh_contrast_instance() {
    let g = sclap::generators::instances::by_name("tiny-grid").unwrap().build();
    for preset in [Preset::CFast, Preset::KaffpaEco] {
        let r = MultilevelPartitioner::new(PartitionConfig::preset(preset, 4)).partition(&g, 9);
        assert!(r.partition.validate(&g).is_ok());
        // a 40x40 grid 4-partition should cut well under 200 of 3120 edges
        assert!(r.metrics.cut < 400, "{}: {}", preset.name(), r.metrics.cut);
    }
}
