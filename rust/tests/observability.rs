//! Integration: the observability layer (`obs::trace` + `obs::metrics`)
//! and its hard invariants.
//!
//! The contract under test:
//!
//! 1. **Tracing never changes results** — partitions and rendered
//!    result lines are byte-identical with tracing on vs. off, across
//!    worker counts {1, 4} and both backends (in-memory multilevel and
//!    the out-of-core shard driver).
//! 2. **The merged span stream is deterministic** — the ts-free
//!    [`logical_stream`](sclap::obs::trace::Tracer::logical_stream) is
//!    line-identical for any worker count.
//! 3. **`!stats` reconciles with the client** — the wire snapshot's
//!    cache/queue/scheduler counters match a scripted session's
//!    observed hits, busy refusals, and single-flight joins exactly.
//! 4. **`serve --trace` exports valid Chrome `trace_event` JSON** with
//!    balanced B/E spans, while responses stay byte-identical to the
//!    offline rendering.
//! 5. **Histogram bucket boundaries** are the documented log₂ bins.
//! 6. **`explain=true` is observation-only and deterministic** — the
//!    explained response is the plain response plus exactly one
//!    appended field, byte-identical across worker counts {1, 4} and
//!    across shard formats/layouts for the out-of-core backend.
//! 7. **`--journal` records the full request lifecycle** — every line
//!    parses, seqs strictly increase, per-id event order is coherent,
//!    the final event is `shutdown`, and event counts reconcile with
//!    the `!stats` counters — without changing a response byte.
//! 8. **`!metrics` renders valid Prometheus text** — framed between
//!    `# sclap metrics` and `# EOF` on the wire, with cumulative
//!    histogram buckets and hostile label values escaped.

use sclap::coordinator::net::{parse_response, NetClient, NetServer, NetServerConfig};
use sclap::coordinator::queue::spec::render_result_line;
use sclap::coordinator::service::{Aggregate, Coordinator, RunOutcome};
use sclap::graph::csr::Graph;
use sclap::graph::store::{write_sharded, write_sharded_as, ShardFormat, ShardedStore};
use sclap::obs::journal::JournalConfig;
use sclap::obs::metrics::{
    bucket_index, bucket_upper_bound, escape_label_value, Histogram, MetricsRegistry,
};
use sclap::obs::trace::Tracer;
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::util::json::{parse_json, Json};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sclap-obs-{}-{tag}", std::process::id()))
}

/// A community instance with a real multilevel hierarchy.
fn lfr(n: usize) -> Graph {
    let mut rng = sclap::util::rng::Rng::new(4);
    sclap::generators::lfr::lfr_like(n, 6.0, 0.15, &mut rng).0
}

/// Coordinator with (optionally) a tracer attached to its context.
fn traced_coordinator(workers: usize, traced: bool) -> (Coordinator, Option<Arc<Tracer>>) {
    let coord = Coordinator::new(workers);
    let tracer = traced.then(|| {
        let t = Arc::new(Tracer::new());
        coord.ctx().set_tracer(t.clone());
        t
    });
    (coord, tracer)
}

/// `ph` of every span/counter event in a trace-file export.
fn phases_of(events: &[Json]) -> Vec<&str> {
    events
        .iter()
        .filter_map(|e| e.get("ph").and_then(Json::as_str))
        .collect()
}

#[test]
fn tracing_never_changes_in_memory_results_and_streams_are_worker_invariant() {
    let g = Arc::new(lfr(800));
    let config = PartitionConfig::preset(Preset::CFast, 4);
    let seeds = [1u64, 2];
    let mut lines = Vec::new();
    let mut streams = Vec::new();
    for workers in [1usize, 4] {
        for traced in [false, true] {
            let (coord, tracer) = traced_coordinator(workers, traced);
            let agg = coord.partition_repeated(g.clone(), &config, &seeds);
            lines.push(render_result_line("t", &agg, false));
            if let Some(t) = tracer {
                assert_eq!(t.dropped(), 0, "workload must fit the track buffers");
                streams.push(t.logical_stream());
            }
        }
    }
    // Byte-identical rendered results: trace off/on × workers 1/4.
    assert!(
        lines.iter().all(|l| *l == lines[0]),
        "tracing or worker count changed result bytes: {lines:#?}"
    );
    // The merged logical stream is worker-count-invariant...
    assert_eq!(streams[0], streams[1], "span stream must not depend on workers");
    // ...and actually contains the hierarchy: V-cycle spans, per-level
    // refinement spans with level indices, and cut counters.
    let stream = &streams[0];
    assert!(!stream.is_empty());
    for needle in [
        " B vcycle",
        " B coarsening",
        " B initial",
        " B uncoarsening",
        " B refine_level",
        " C level_quality",
        " C cycle_cut",
        " C hierarchy",
    ] {
        assert!(
            stream.iter().any(|l| l.contains(needle)),
            "missing {needle:?} in logical stream"
        );
    }
    assert!(
        stream.iter().any(|l| l.contains(" B refine_level level=")),
        "refine spans must carry their level index"
    );
    // Every Begin has its End (per-lane balance holds in the merge too,
    // because lanes are contiguous under the (track, instance, seq) sort).
    let begins = stream.iter().filter(|l| l.split_whitespace().nth(2) == Some("B")).count();
    let ends = stream.iter().filter(|l| l.split_whitespace().nth(2) == Some("E")).count();
    assert_eq!(begins, ends, "unbalanced spans in the logical stream");
    // Two seeds ⇒ two logical tracks.
    let tracks: std::collections::BTreeSet<&str> = stream
        .iter()
        .filter_map(|l| l.split_whitespace().next())
        .collect();
    assert_eq!(tracks.len(), 2, "one track per repetition seed");
}

#[test]
fn tracing_never_changes_out_of_core_results() {
    let g = lfr(1000);
    let dir = temp_path("shards");
    write_sharded(&g, &dir, 3).unwrap();
    let mut config = PartitionConfig::preset(Preset::CFast, 4);
    config.memory_budget_bytes = Some(1); // force the external path
    let seeds = [3u64, 4];
    let mut lines = Vec::new();
    let mut streams = Vec::new();
    for workers in [1usize, 4] {
        for traced in [false, true] {
            let (coord, tracer) = traced_coordinator(workers, traced);
            let store = ShardedStore::open(&dir).unwrap();
            let runs: Vec<RunOutcome> = seeds
                .iter()
                .map(|&s| {
                    RunOutcome::from_out_of_core(
                        s,
                        &coord.partition_store(&store, &config, s).unwrap(),
                    )
                })
                .collect();
            let agg = Aggregate::from_runs(runs);
            lines.push(render_result_line("t", &agg, false));
            if let Some(t) = tracer {
                streams.push(t.logical_stream());
            }
        }
    }
    assert!(
        lines.iter().all(|l| *l == lines[0]),
        "tracing or worker count changed out-of-core result bytes: {lines:#?}"
    );
    assert_eq!(streams[0], streams[1], "external span stream must not depend on workers");
    for needle in [" B external_coarsen_level", " B external_refinement", " C external_level"] {
        assert!(
            streams[0].iter().any(|l| l.contains(needle)),
            "missing {needle:?} in external logical stream"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn phase_timings_attribute_per_level_without_collapsing() {
    let g = Arc::new(lfr(800));
    let coord = Coordinator::new(2);
    let agg = coord.partition_repeated(g, &PartitionConfig::preset(Preset::CFast, 4), &[1]);
    assert_eq!(agg.runs.len(), 1);
    // The per-level view keeps one (name, level) entry per hierarchy
    // level — the old `&'static str`-only table collapsed all of these
    // into a single "refine_level" bucket.
    let by_level = coord.ctx().phase_stats_by_level();
    let refine_levels: Vec<u32> = by_level
        .iter()
        .filter(|((name, _), _)| *name == "refine_level")
        .map(|((_, level), _)| level.expect("refine_level records carry a level"))
        .collect();
    assert!(
        refine_levels.len() >= 2,
        "a multilevel run must attribute refinement to ≥ 2 levels, got {refine_levels:?}"
    );
    // The flat view still aggregates across levels (the legacy shape).
    let flat = coord.ctx().phase_stats();
    let refine_flat: Vec<_> = flat.iter().filter(|(n, _)| *n == "refine_level").collect();
    assert_eq!(refine_flat.len(), 1);
    let per_level_calls: usize = by_level
        .iter()
        .filter(|((name, _), _)| *name == "refine_level")
        .map(|(_, stat)| stat.calls)
        .sum();
    assert_eq!(refine_flat[0].1.calls, per_level_calls);
}

fn spawn_server(
    config: NetServerConfig,
) -> (
    sclap::coordinator::net::NetServerHandle,
    std::thread::JoinHandle<std::io::Result<()>>,
    String,
) {
    let server = NetServer::bind("127.0.0.1:0", config).unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (handle, runner, addr)
}

#[test]
fn stats_and_ping_reconcile_with_a_scripted_session() {
    let (handle, runner, addr) = spawn_server(NetServerConfig {
        workers: 1,
        max_pending: 1,
        cache_entries: 8,
        timing: false,
        trace: None,
        journal: None,
    });
    let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    // `!ping` reports the server version and the registry's uptime.
    let pong = parse_response(&client.request("!ping").unwrap()).unwrap();
    assert_eq!(pong.status, "pong");
    assert_eq!(
        pong.json.get("version").and_then(Json::as_str),
        Some(env!("CARGO_PKG_VERSION"))
    );
    assert!(pong.json.get("uptime_seconds").and_then(Json::as_f64).unwrap() >= 0.0);

    // A scripted session with a fully predictable counter trail:
    // - "first" leads a computation (1 miss, 1 queue submission);
    // - "second" is distinct and hits the full 1-slot queue while the
    //   scheduler is paused (1 more miss, then 1 busy rejection);
    // - "firstdup" joins "first" in flight (1 single-flight join).
    handle.pause();
    client
        .send_line("id=first instance=tiny-ba k=2 preset=CFast seeds=1")
        .unwrap();
    let busy = parse_response(
        &client
            .request("id=second instance=tiny-ba k=2 preset=CFast seeds=2")
            .unwrap(),
    )
    .unwrap();
    assert_eq!((busy.status.as_str(), busy.id.as_deref()), ("busy", Some("second")));
    client
        .send_line("id=firstdup instance=tiny-ba k=2 preset=CFast seeds=1")
        .unwrap();
    handle.resume();
    client.finish_sending().unwrap();
    let mut seen = HashMap::new();
    while let Some(line) = client.recv_line().unwrap() {
        let r = parse_response(&line).unwrap();
        seen.insert(r.id.clone().expect("request responses carry ids"), r);
    }
    assert_eq!(seen["first"].status, "ok");
    assert_eq!(seen["firstdup"].status, "ok");
    assert!(seen["firstdup"].cached, "the joiner is served from the leader");

    // A fresh connection snapshots the registry; every counter must
    // equal what the scripted session observed.
    let mut probe = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let stats = parse_response(&probe.request("!stats").unwrap()).unwrap();
    assert_eq!(stats.status, "stats");
    assert!(stats.json.get("uptime_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
    assert_eq!(stats.json.get("connection").and_then(Json::as_i64), Some(2));
    assert_eq!(
        stats.json.get("connection_requests").and_then(Json::as_i64),
        Some(0),
        "control commands are not requests"
    );
    let counters = stats.json.get("counters").expect("counters section");
    let counter = |name: &str| counters.get(name).and_then(Json::as_i64).unwrap_or(0);
    assert_eq!(counter("net_connections"), 2);
    assert_eq!(counter("net_requests"), 3, "first + second + firstdup");
    assert_eq!(counter("cache_misses"), 2, "first and second both led");
    assert_eq!(counter("cache_joined"), 1, "firstdup joined in flight");
    assert_eq!(counter("cache_hits"), 0);
    assert_eq!(counter("cache_uncached"), 0);
    assert_eq!(counter("cache_evictions"), 0);
    assert_eq!(counter("queue_submitted"), 1, "only the leader took a slot");
    assert_eq!(counter("queue_busy_rejections"), 1);
    assert_eq!(counter("requests_activated"), 1);
    assert_eq!(counter("requests_completed"), 1);
    assert_eq!(counter("requests_failed"), 0);
    assert_eq!(counter("scheduler_waves"), 1);
    assert_eq!(counter("scheduler_repetitions"), 1, "seeds=1 is one repetition");
    // The wire snapshot and the in-process cache view agree.
    let cs = handle.cache_stats();
    assert_eq!((cs.hits, cs.misses, cs.joined, cs.uncached), (0, 2, 1, 0));
    let gauges = stats.json.get("gauges").expect("gauges section");
    assert_eq!(gauges.get("queue_depth").and_then(Json::as_i64), Some(0));
    assert!(
        gauges.get("arena_leases_created").and_then(Json::as_i64).unwrap() >= 0,
        "arena gauges are refreshed at snapshot time"
    );
    let wave = stats
        .json
        .get("histograms")
        .and_then(|h| h.get("scheduler_wave_size"))
        .expect("wave-size histogram");
    assert_eq!(wave.get("count").and_then(Json::as_i64), Some(1));
    assert_eq!(wave.get("sum").and_then(Json::as_i64), Some(1));
    // Phase timings recorded by "first" surface in the same snapshot.
    let phases = stats.json.get("phases").and_then(Json::as_array).unwrap();
    assert!(
        phases
            .iter()
            .any(|p| p.get("name").and_then(Json::as_str) == Some("coarsening")),
        "phase table must surface in !stats"
    );
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn serve_trace_exports_chrome_json_and_responses_stay_identical() {
    let trace_path = temp_path("serve-trace.json");
    // Offline reference: the same request through the plain coordinator.
    let offline = {
        let g = Arc::new(
            sclap::generators::instances::by_name("tiny-ba")
                .unwrap()
                .build(),
        );
        let agg = Coordinator::new(2).partition_repeated(
            g,
            &PartitionConfig::preset(Preset::CFast, 2),
            &[1, 2],
        );
        render_result_line("t1", &agg, false)
    };
    let (handle, runner, addr) = spawn_server(NetServerConfig {
        workers: 2,
        max_pending: 16,
        cache_entries: 8,
        timing: false,
        trace: Some(trace_path.clone()),
        journal: None,
    });
    let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let line = client
        .request("id=t1 instance=tiny-ba k=2 preset=CFast seeds=1,2")
        .unwrap();
    assert_eq!(line, offline, "tracing must not change response bytes");
    drop(client);
    handle.shutdown();
    runner.join().unwrap().unwrap();
    // The trace file is written after the accept loop drains.
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let json = parse_json(&text).expect("trace file is valid JSON");
    let events = json
        .get("traceEvents")
        .and_then(Json::as_array)
        .expect("traceEvents array");
    let phs = phases_of(events);
    assert_eq!(phs.first(), Some(&"M"), "metadata record leads the export");
    let begins = phs.iter().filter(|p| **p == "B").count();
    let ends = phs.iter().filter(|p| **p == "E").count();
    assert!(begins > 0, "server-side repetitions must record spans");
    assert_eq!(begins, ends, "exported spans must balance");
    let vcycles = events
        .iter()
        .filter(|e| {
            e.get("name").and_then(Json::as_str) == Some("vcycle")
                && e.get("ph").and_then(Json::as_str) == Some("B")
        })
        .count();
    assert!(vcycles >= 2, "one vcycle span per repetition, got {vcycles}");
    // otherData's bookkeeping matches the event list (metadata excluded).
    let other = json.get("otherData").expect("otherData section");
    assert_eq!(
        other.get("events").and_then(Json::as_i64),
        Some((events.len() - 1) as i64)
    );
    assert_eq!(other.get("dropped").and_then(Json::as_i64), Some(0));
    std::fs::remove_file(&trace_path).ok();
}

#[test]
fn explain_reports_are_deterministic_and_observation_only() {
    // Shard-backed fixture in both on-disk formats AND different shard
    // counts: neither may be observable in the explain payload.
    let g = lfr(1000);
    let dir_v1 = temp_path("explain-v1");
    let dir_v2 = temp_path("explain-v2");
    write_sharded_as(&g, &dir_v1, 3, ShardFormat::V1).unwrap();
    write_sharded_as(&g, &dir_v2, 4, ShardFormat::V2).unwrap();
    let shard_line = |dir: &PathBuf| {
        format!(
            "id=x shards={} k=4 preset=CFast memory-budget=1 seeds=3 explain=true",
            dir.display()
        )
    };
    let mut per_worker = Vec::new();
    for workers in [1usize, 4] {
        let (handle, runner, addr) = spawn_server(NetServerConfig {
            workers,
            max_pending: 16,
            cache_entries: 0,
            timing: false,
            trace: None,
            journal: None,
        });
        let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
        let plain = client
            .request("id=p instance=tiny-ba k=2 preset=CFast seeds=1,2")
            .unwrap();
        let explained = client
            .request("id=e instance=tiny-ba k=2 preset=CFast seeds=1,2 explain=true")
            .unwrap();
        let v1 = client.request(&shard_line(&dir_v1)).unwrap();
        let v2 = client.request(&shard_line(&dir_v2)).unwrap();
        assert_eq!(v1, v2, "workers={workers}: shard format/layout leaked into explain");
        // explain= is observation-only: the explained response is the
        // plain response (modulo id) with exactly one appended field.
        let plain_as_e = plain.replacen("\"id\":\"p\"", "\"id\":\"e\"", 1);
        let prefix = &plain_as_e[..plain_as_e.len() - 1];
        assert!(
            explained.starts_with(prefix) && explained.ends_with('}'),
            "workers={workers}: explain must only append a field: {explained}"
        );
        assert!(
            explained[prefix.len()..].starts_with(",\"explain\":{\"reps\":["),
            "workers={workers}: {explained}"
        );
        per_worker.push((explained, v1));
        handle.shutdown();
        runner.join().unwrap().unwrap();
    }
    // The tentpole invariant: explain reports are worker-count-
    // invariant, byte for byte, for both backends.
    assert_eq!(per_worker[0], per_worker[1], "explain must not depend on workers");
    // The payload is valid JSON with one rep per aggregate seed, and
    // the shard-backed rep carries the out-of-core section.
    let in_memory = parse_response(&per_worker[0].0).unwrap();
    let reps = in_memory
        .json
        .get("explain")
        .and_then(|e| e.get("reps"))
        .and_then(Json::as_array)
        .expect("explain carries a reps array");
    let seeds: Vec<i64> = reps
        .iter()
        .filter_map(|r| r.get("seed").and_then(Json::as_i64))
        .collect();
    assert_eq!(seeds, vec![1, 2], "one rep per aggregate seed, in seed order");
    assert!(
        reps.iter().all(|r| {
            r.get("cycles")
                .and_then(Json::as_array)
                .is_some_and(|c| !c.is_empty())
        }),
        "in-memory reps narrate their V-cycles"
    );
    let external = parse_response(&per_worker[0].1).unwrap();
    let ext = external
        .json
        .get("explain")
        .and_then(|e| e.get("reps"))
        .and_then(Json::as_array)
        .and_then(|arr| arr.first())
        .and_then(|r| r.get("external"))
        .expect("shard-backed rep carries the external section");
    assert!(
        ext.get("external_levels").and_then(Json::as_i64).unwrap() >= 1,
        "budget-1 run must report external levels"
    );
    std::fs::remove_dir_all(&dir_v1).ok();
    std::fs::remove_dir_all(&dir_v2).ok();
}

#[test]
fn journal_records_the_lifecycle_and_reconciles_with_stats() {
    let journal_path = temp_path("journal.jsonl");
    std::fs::remove_file(&journal_path).ok();
    let (handle, runner, addr) = spawn_server(NetServerConfig {
        workers: 1,
        max_pending: 1,
        cache_entries: 8,
        timing: false,
        trace: None,
        journal: Some(JournalConfig::new(&journal_path)),
    });
    // The same scripted session as the !stats test: "first" leads,
    // "second" bounces off the full 1-slot queue, "firstdup" joins.
    handle.pause();
    let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    client
        .send_line("id=first instance=tiny-ba k=2 preset=CFast seeds=1")
        .unwrap();
    let busy = parse_response(
        &client
            .request("id=second instance=tiny-ba k=2 preset=CFast seeds=2")
            .unwrap(),
    )
    .unwrap();
    assert_eq!(busy.status, "busy");
    client
        .send_line("id=firstdup instance=tiny-ba k=2 preset=CFast seeds=1")
        .unwrap();
    handle.resume();
    client.finish_sending().unwrap();
    let mut lines = HashMap::new();
    while let Some(line) = client.recv_line().unwrap() {
        let r = parse_response(&line).unwrap();
        lines.insert(r.id.clone().expect("request responses carry ids"), line);
    }
    // Journaling is observation-only: the leader's response is still
    // byte-identical to the offline rendering.
    let tiny_ba = Arc::new(
        sclap::generators::instances::by_name("tiny-ba")
            .unwrap()
            .build(),
    );
    let agg = Coordinator::new(2).partition_repeated(
        tiny_ba,
        &PartitionConfig::preset(Preset::CFast, 2),
        &[1],
    );
    assert_eq!(lines["first"], render_result_line("first", &agg, false));
    let leader_cut = parse_response(&lines["first"]).unwrap().best_cut();

    // Snapshot the live counters and the Prometheus block before
    // shutdown, over a fresh probe connection.
    let mut probe = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let stats = parse_response(&probe.request("!stats").unwrap()).unwrap();
    assert_eq!(stats.status, "stats");
    let counters = stats.json.get("counters").expect("counters section");
    let counter = |name: &str| counters.get(name).and_then(Json::as_i64).unwrap_or(0);
    // The queue-wait histogram surfaces the derived quantiles and its
    // raw `[bucket_index, count]` pairs in !stats.
    let wait = stats
        .json
        .get("histograms")
        .and_then(|h| h.get("queue_wait_us"))
        .expect("queue-wait histogram");
    assert_eq!(wait.get("count").and_then(Json::as_i64), Some(1));
    for key in ["p50", "p99"] {
        assert!(
            wait.get(key).and_then(Json::as_i64).is_some(),
            "!stats histograms carry {key}"
        );
    }
    let buckets = wait.get("buckets").and_then(Json::as_array).expect("buckets");
    assert_eq!(buckets.len(), 1, "one observation, one non-empty bucket");
    let pair = buckets[0].as_array().expect("bucket pairs are arrays");
    assert_eq!(pair.len(), 2, "[bucket_index, count]");
    assert_eq!(pair[1].as_i64(), Some(1));
    // `!metrics` arrives as one framed block: sentinel first line,
    // Prometheus text, `# EOF` terminator.
    probe.send_line("!metrics").unwrap();
    let mut metrics = Vec::new();
    loop {
        let line = probe.recv_line().unwrap().expect("unterminated metrics block");
        let done = line == "# EOF";
        metrics.push(line);
        if done {
            break;
        }
    }
    assert_eq!(metrics.first().map(String::as_str), Some("# sclap metrics"));
    assert!(
        metrics.iter().any(|l| l == "# TYPE sclap_net_requests_total counter"),
        "{metrics:?}"
    );
    assert!(
        metrics.iter().any(|l| l.starts_with("sclap_queue_wait_us_bucket{le=")),
        "histogram bucket series must surface on the wire"
    );
    handle.shutdown();
    runner.join().unwrap().unwrap();

    // Replay the journal: every line parses, seqs strictly increase,
    // and the per-id lifecycle is ordered and complete.
    let text = std::fs::read_to_string(&journal_path).unwrap();
    let mut events = Vec::new();
    let mut last_seq = -1i64;
    for line in text.lines() {
        let json = parse_json(line).unwrap_or_else(|e| panic!("{e}: {line}"));
        let seq = json.get("seq").and_then(Json::as_i64).expect("seq field");
        assert!(seq > last_seq, "seqs must strictly increase: {line}");
        last_seq = seq;
        assert!(json.get("ts_ms").and_then(Json::as_i64).unwrap() > 0, "{line}");
        events.push(json);
    }
    let tags: Vec<(String, Option<String>)> = events
        .iter()
        .map(|e| {
            (
                e.get("event").and_then(Json::as_str).unwrap().to_string(),
                e.get("id").and_then(Json::as_str).map(str::to_string),
            )
        })
        .collect();
    let pos = |event: &str, id: &str| {
        tags.iter()
            .position(|(e, i)| e == event && i.as_deref() == Some(id))
            .unwrap_or_else(|| panic!("missing {event} for {id}: {tags:?}"))
    };
    assert!(pos("admitted", "first") < pos("started", "first"));
    assert!(pos("started", "first") < pos("completed", "first"));
    assert!(pos("admitted", "firstdup") < pos("cache_hit", "firstdup"));
    assert!(pos("cache_hit", "firstdup") < pos("completed", "firstdup"));
    pos("busy", "second");
    assert_eq!(tags.last().map(|(e, _)| e.as_str()), Some("shutdown"));
    // Event counts reconcile with the snapshotted !stats counters.
    let count = |event: &str| tags.iter().filter(|(e, _)| e == event).count() as i64;
    assert_eq!(count("admitted"), 2, "first and firstdup; busy is not an admission");
    assert_eq!(count("started"), counter("requests_activated"));
    assert_eq!(count("busy"), counter("queue_busy_rejections"));
    assert_eq!(count("cache_hit"), counter("cache_hits") + counter("cache_joined"));
    assert_eq!(count("completed"), 2);
    assert_eq!(count("cancelled") + count("error"), 0);
    // Completion events carry the outcome: cache marker and best cut.
    let completed = |id: &str| {
        events
            .iter()
            .find(|e| {
                e.get("event").and_then(Json::as_str) == Some("completed")
                    && e.get("id").and_then(Json::as_str) == Some(id)
            })
            .unwrap()
    };
    let lead = completed("first");
    assert_eq!(lead.get("cached").and_then(Json::as_bool), Some(false));
    assert_eq!(lead.get("cut").and_then(Json::as_i64), leader_cut);
    assert!(lead.get("seconds").and_then(Json::as_f64).unwrap() >= 0.0);
    let dup = completed("firstdup");
    assert_eq!(dup.get("cached").and_then(Json::as_bool), Some(true));
    assert_eq!(dup.get("cut").and_then(Json::as_i64), leader_cut);
    // Listen-mode admissions carry their connection id.
    let admitted = events
        .iter()
        .find(|e| e.get("event").and_then(Json::as_str) == Some("admitted"))
        .unwrap();
    assert!(admitted.get("connection").and_then(Json::as_i64).unwrap() >= 1);
    std::fs::remove_file(&journal_path).ok();
}

#[test]
fn prometheus_exposition_is_structured_and_escapes_hostile_labels() {
    let registry = MetricsRegistry::new();
    registry.counter("requests").inc();
    registry.counter("requests").inc();
    registry.gauge("depth").set(7);
    let lat = registry.histogram("lat");
    for v in [0u64, 1, 5, 5, 300] {
        lat.observe(v);
    }
    // A hostile phase name: quotes, backslashes and a newline must not
    // break the line-oriented text format.
    const HOSTILE: &str = "lpa \"inner\"\\\n2";
    registry.record_phase(HOSTILE, Some(3), 0.25);
    let out = registry.render_prometheus();
    // Line discipline survives the hostile label: every line is a TYPE
    // comment or a sclap_-prefixed sample.
    for line in out.lines() {
        assert!(
            line.starts_with("# TYPE sclap_") || line.starts_with("sclap_"),
            "unexpected exposition line: {line:?}"
        );
    }
    assert_eq!(escape_label_value(HOSTILE), "lpa \\\"inner\\\"\\\\\\n2");
    let label = format!("phase=\"{}\",level=\"3\"", escape_label_value(HOSTILE));
    assert!(out.contains(&format!("sclap_phase_calls_total{{{label}}} 1\n")));
    assert!(out.contains(&format!("sclap_phase_seconds_total{{{label}}} 0.250000\n")));
    // Counter / gauge shapes, TYPE line immediately before the sample.
    assert!(out.contains("# TYPE sclap_requests_total counter\nsclap_requests_total 2\n"));
    assert!(out.contains("# TYPE sclap_depth gauge\nsclap_depth 7\n"));
    // Histogram: cumulative buckets, mandatory +Inf == _count, derived
    // quantile gauges declared with their own TYPE lines.
    let bucket_lines: Vec<&str> = out
        .lines()
        .filter(|l| l.starts_with("sclap_lat_bucket{le="))
        .collect();
    let counts: Vec<u64> = bucket_lines
        .iter()
        .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
        .collect();
    assert!(counts.windows(2).all(|w| w[0] <= w[1]), "cumulative: {counts:?}");
    assert_eq!(bucket_lines.last().unwrap().split('"').nth(1), Some("+Inf"));
    assert_eq!(counts.last(), Some(&5));
    assert!(out.contains("sclap_lat_sum 311\n"));
    assert!(out.contains("sclap_lat_count 5\n"));
    assert!(out.contains("# TYPE sclap_lat_p50 gauge\nsclap_lat_p50 "));
    assert!(out.contains("# TYPE sclap_lat_p99 gauge\nsclap_lat_p99 "));
    let type_pos = out.find("# TYPE sclap_lat histogram").unwrap();
    assert!(type_pos < out.find("sclap_lat_bucket").unwrap());
}

#[test]
fn histogram_buckets_follow_the_documented_log2_boundaries() {
    // Bucket 0 is exactly the value 0; bucket i ≥ 1 holds 2^(i-1) ≤ v < 2^i.
    assert_eq!(bucket_index(0), 0);
    for i in 1..=16usize {
        let lo = 1u64 << (i - 1);
        let hi = (1u64 << i) - 1;
        assert_eq!(bucket_index(lo), i, "low edge of bucket {i}");
        assert_eq!(bucket_index(hi), i, "high edge of bucket {i}");
        assert_eq!(bucket_index(bucket_upper_bound(i)), i);
        assert_eq!(bucket_index(bucket_upper_bound(i) + 1), i + 1);
    }
    assert_eq!(bucket_upper_bound(0), 0);
    assert_eq!(bucket_index(u64::MAX), 64);
    assert_eq!(bucket_upper_bound(64), u64::MAX);
    let h = Histogram::default();
    for v in [0u64, 1, 2, 3, 8, 9] {
        h.observe(v);
    }
    assert_eq!(h.count(), 6);
    assert_eq!(h.sum(), 23);
    assert_eq!(h.nonzero_buckets(), vec![(0, 1), (1, 1), (2, 2), (4, 2)]);
}
