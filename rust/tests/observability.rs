//! Integration: the observability layer (`obs::trace` + `obs::metrics`)
//! and its hard invariants.
//!
//! The contract under test:
//!
//! 1. **Tracing never changes results** — partitions and rendered
//!    result lines are byte-identical with tracing on vs. off, across
//!    worker counts {1, 4} and both backends (in-memory multilevel and
//!    the out-of-core shard driver).
//! 2. **The merged span stream is deterministic** — the ts-free
//!    [`logical_stream`](sclap::obs::trace::Tracer::logical_stream) is
//!    line-identical for any worker count.
//! 3. **`!stats` reconciles with the client** — the wire snapshot's
//!    cache/queue/scheduler counters match a scripted session's
//!    observed hits, busy refusals, and single-flight joins exactly.
//! 4. **`serve --trace` exports valid Chrome `trace_event` JSON** with
//!    balanced B/E spans, while responses stay byte-identical to the
//!    offline rendering.
//! 5. **Histogram bucket boundaries** are the documented log₂ bins.

use sclap::coordinator::net::{parse_response, NetClient, NetServer, NetServerConfig};
use sclap::coordinator::queue::spec::render_result_line;
use sclap::coordinator::service::{Aggregate, Coordinator, RunOutcome};
use sclap::graph::csr::Graph;
use sclap::graph::store::{write_sharded, ShardedStore};
use sclap::obs::metrics::{bucket_index, bucket_upper_bound, Histogram};
use sclap::obs::trace::Tracer;
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::util::json::{parse_json, Json};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sclap-obs-{}-{tag}", std::process::id()))
}

/// A community instance with a real multilevel hierarchy.
fn lfr(n: usize) -> Graph {
    let mut rng = sclap::util::rng::Rng::new(4);
    sclap::generators::lfr::lfr_like(n, 6.0, 0.15, &mut rng).0
}

/// Coordinator with (optionally) a tracer attached to its context.
fn traced_coordinator(workers: usize, traced: bool) -> (Coordinator, Option<Arc<Tracer>>) {
    let coord = Coordinator::new(workers);
    let tracer = traced.then(|| {
        let t = Arc::new(Tracer::new());
        coord.ctx().set_tracer(t.clone());
        t
    });
    (coord, tracer)
}

/// `ph` of every span/counter event in a trace-file export.
fn phases_of(events: &[Json]) -> Vec<&str> {
    events
        .iter()
        .filter_map(|e| e.get("ph").and_then(Json::as_str))
        .collect()
}

#[test]
fn tracing_never_changes_in_memory_results_and_streams_are_worker_invariant() {
    let g = Arc::new(lfr(800));
    let config = PartitionConfig::preset(Preset::CFast, 4);
    let seeds = [1u64, 2];
    let mut lines = Vec::new();
    let mut streams = Vec::new();
    for workers in [1usize, 4] {
        for traced in [false, true] {
            let (coord, tracer) = traced_coordinator(workers, traced);
            let agg = coord.partition_repeated(g.clone(), &config, &seeds);
            lines.push(render_result_line("t", &agg, false));
            if let Some(t) = tracer {
                assert_eq!(t.dropped(), 0, "workload must fit the track buffers");
                streams.push(t.logical_stream());
            }
        }
    }
    // Byte-identical rendered results: trace off/on × workers 1/4.
    assert!(
        lines.iter().all(|l| *l == lines[0]),
        "tracing or worker count changed result bytes: {lines:#?}"
    );
    // The merged logical stream is worker-count-invariant...
    assert_eq!(streams[0], streams[1], "span stream must not depend on workers");
    // ...and actually contains the hierarchy: V-cycle spans, per-level
    // refinement spans with level indices, and cut counters.
    let stream = &streams[0];
    assert!(!stream.is_empty());
    for needle in [
        " B vcycle",
        " B coarsening",
        " B initial",
        " B uncoarsening",
        " B refine_level",
        " C level_quality",
        " C cycle_cut",
        " C hierarchy",
    ] {
        assert!(
            stream.iter().any(|l| l.contains(needle)),
            "missing {needle:?} in logical stream"
        );
    }
    assert!(
        stream.iter().any(|l| l.contains(" B refine_level level=")),
        "refine spans must carry their level index"
    );
    // Every Begin has its End (per-lane balance holds in the merge too,
    // because lanes are contiguous under the (track, instance, seq) sort).
    let begins = stream.iter().filter(|l| l.split_whitespace().nth(2) == Some("B")).count();
    let ends = stream.iter().filter(|l| l.split_whitespace().nth(2) == Some("E")).count();
    assert_eq!(begins, ends, "unbalanced spans in the logical stream");
    // Two seeds ⇒ two logical tracks.
    let tracks: std::collections::BTreeSet<&str> = stream
        .iter()
        .filter_map(|l| l.split_whitespace().next())
        .collect();
    assert_eq!(tracks.len(), 2, "one track per repetition seed");
}

#[test]
fn tracing_never_changes_out_of_core_results() {
    let g = lfr(1000);
    let dir = temp_path("shards");
    write_sharded(&g, &dir, 3).unwrap();
    let mut config = PartitionConfig::preset(Preset::CFast, 4);
    config.memory_budget_bytes = Some(1); // force the external path
    let seeds = [3u64, 4];
    let mut lines = Vec::new();
    let mut streams = Vec::new();
    for workers in [1usize, 4] {
        for traced in [false, true] {
            let (coord, tracer) = traced_coordinator(workers, traced);
            let store = ShardedStore::open(&dir).unwrap();
            let runs: Vec<RunOutcome> = seeds
                .iter()
                .map(|&s| {
                    RunOutcome::from_out_of_core(
                        s,
                        &coord.partition_store(&store, &config, s).unwrap(),
                    )
                })
                .collect();
            let agg = Aggregate::from_runs(runs);
            lines.push(render_result_line("t", &agg, false));
            if let Some(t) = tracer {
                streams.push(t.logical_stream());
            }
        }
    }
    assert!(
        lines.iter().all(|l| *l == lines[0]),
        "tracing or worker count changed out-of-core result bytes: {lines:#?}"
    );
    assert_eq!(streams[0], streams[1], "external span stream must not depend on workers");
    for needle in [" B external_coarsen_level", " B external_refinement", " C external_level"] {
        assert!(
            streams[0].iter().any(|l| l.contains(needle)),
            "missing {needle:?} in external logical stream"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn phase_timings_attribute_per_level_without_collapsing() {
    let g = Arc::new(lfr(800));
    let coord = Coordinator::new(2);
    let agg = coord.partition_repeated(g, &PartitionConfig::preset(Preset::CFast, 4), &[1]);
    assert_eq!(agg.runs.len(), 1);
    // The per-level view keeps one (name, level) entry per hierarchy
    // level — the old `&'static str`-only table collapsed all of these
    // into a single "refine_level" bucket.
    let by_level = coord.ctx().phase_stats_by_level();
    let refine_levels: Vec<u32> = by_level
        .iter()
        .filter(|((name, _), _)| *name == "refine_level")
        .map(|((_, level), _)| level.expect("refine_level records carry a level"))
        .collect();
    assert!(
        refine_levels.len() >= 2,
        "a multilevel run must attribute refinement to ≥ 2 levels, got {refine_levels:?}"
    );
    // The flat view still aggregates across levels (the legacy shape).
    let flat = coord.ctx().phase_stats();
    let refine_flat: Vec<_> = flat.iter().filter(|(n, _)| *n == "refine_level").collect();
    assert_eq!(refine_flat.len(), 1);
    let per_level_calls: usize = by_level
        .iter()
        .filter(|((name, _), _)| *name == "refine_level")
        .map(|(_, stat)| stat.calls)
        .sum();
    assert_eq!(refine_flat[0].1.calls, per_level_calls);
}

fn spawn_server(
    config: NetServerConfig,
) -> (
    sclap::coordinator::net::NetServerHandle,
    std::thread::JoinHandle<std::io::Result<()>>,
    String,
) {
    let server = NetServer::bind("127.0.0.1:0", config).unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.handle();
    let runner = std::thread::spawn(move || server.run());
    (handle, runner, addr)
}

#[test]
fn stats_and_ping_reconcile_with_a_scripted_session() {
    let (handle, runner, addr) = spawn_server(NetServerConfig {
        workers: 1,
        max_pending: 1,
        cache_entries: 8,
        timing: false,
        trace: None,
    });
    let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    // `!ping` reports the server version and the registry's uptime.
    let pong = parse_response(&client.request("!ping").unwrap()).unwrap();
    assert_eq!(pong.status, "pong");
    assert_eq!(
        pong.json.get("version").and_then(Json::as_str),
        Some(env!("CARGO_PKG_VERSION"))
    );
    assert!(pong.json.get("uptime_seconds").and_then(Json::as_f64).unwrap() >= 0.0);

    // A scripted session with a fully predictable counter trail:
    // - "first" leads a computation (1 miss, 1 queue submission);
    // - "second" is distinct and hits the full 1-slot queue while the
    //   scheduler is paused (1 more miss, then 1 busy rejection);
    // - "firstdup" joins "first" in flight (1 single-flight join).
    handle.pause();
    client
        .send_line("id=first instance=tiny-ba k=2 preset=CFast seeds=1")
        .unwrap();
    let busy = parse_response(
        &client
            .request("id=second instance=tiny-ba k=2 preset=CFast seeds=2")
            .unwrap(),
    )
    .unwrap();
    assert_eq!((busy.status.as_str(), busy.id.as_deref()), ("busy", Some("second")));
    client
        .send_line("id=firstdup instance=tiny-ba k=2 preset=CFast seeds=1")
        .unwrap();
    handle.resume();
    client.finish_sending().unwrap();
    let mut seen = HashMap::new();
    while let Some(line) = client.recv_line().unwrap() {
        let r = parse_response(&line).unwrap();
        seen.insert(r.id.clone().expect("request responses carry ids"), r);
    }
    assert_eq!(seen["first"].status, "ok");
    assert_eq!(seen["firstdup"].status, "ok");
    assert!(seen["firstdup"].cached, "the joiner is served from the leader");

    // A fresh connection snapshots the registry; every counter must
    // equal what the scripted session observed.
    let mut probe = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let stats = parse_response(&probe.request("!stats").unwrap()).unwrap();
    assert_eq!(stats.status, "stats");
    assert!(stats.json.get("uptime_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
    assert_eq!(stats.json.get("connection").and_then(Json::as_i64), Some(2));
    assert_eq!(
        stats.json.get("connection_requests").and_then(Json::as_i64),
        Some(0),
        "control commands are not requests"
    );
    let counters = stats.json.get("counters").expect("counters section");
    let counter = |name: &str| counters.get(name).and_then(Json::as_i64).unwrap_or(0);
    assert_eq!(counter("net_connections"), 2);
    assert_eq!(counter("net_requests"), 3, "first + second + firstdup");
    assert_eq!(counter("cache_misses"), 2, "first and second both led");
    assert_eq!(counter("cache_joined"), 1, "firstdup joined in flight");
    assert_eq!(counter("cache_hits"), 0);
    assert_eq!(counter("cache_uncached"), 0);
    assert_eq!(counter("cache_evictions"), 0);
    assert_eq!(counter("queue_submitted"), 1, "only the leader took a slot");
    assert_eq!(counter("queue_busy_rejections"), 1);
    assert_eq!(counter("requests_activated"), 1);
    assert_eq!(counter("requests_completed"), 1);
    assert_eq!(counter("requests_failed"), 0);
    assert_eq!(counter("scheduler_waves"), 1);
    assert_eq!(counter("scheduler_repetitions"), 1, "seeds=1 is one repetition");
    // The wire snapshot and the in-process cache view agree.
    let cs = handle.cache_stats();
    assert_eq!((cs.hits, cs.misses, cs.joined, cs.uncached), (0, 2, 1, 0));
    let gauges = stats.json.get("gauges").expect("gauges section");
    assert_eq!(gauges.get("queue_depth").and_then(Json::as_i64), Some(0));
    assert!(
        gauges.get("arena_leases_created").and_then(Json::as_i64).unwrap() >= 0,
        "arena gauges are refreshed at snapshot time"
    );
    let wave = stats
        .json
        .get("histograms")
        .and_then(|h| h.get("scheduler_wave_size"))
        .expect("wave-size histogram");
    assert_eq!(wave.get("count").and_then(Json::as_i64), Some(1));
    assert_eq!(wave.get("sum").and_then(Json::as_i64), Some(1));
    // Phase timings recorded by "first" surface in the same snapshot.
    let phases = stats.json.get("phases").and_then(Json::as_array).unwrap();
    assert!(
        phases
            .iter()
            .any(|p| p.get("name").and_then(Json::as_str) == Some("coarsening")),
        "phase table must surface in !stats"
    );
    handle.shutdown();
    runner.join().unwrap().unwrap();
}

#[test]
fn serve_trace_exports_chrome_json_and_responses_stay_identical() {
    let trace_path = temp_path("serve-trace.json");
    // Offline reference: the same request through the plain coordinator.
    let offline = {
        let g = Arc::new(
            sclap::generators::instances::by_name("tiny-ba")
                .unwrap()
                .build(),
        );
        let agg = Coordinator::new(2).partition_repeated(
            g,
            &PartitionConfig::preset(Preset::CFast, 2),
            &[1, 2],
        );
        render_result_line("t1", &agg, false)
    };
    let (handle, runner, addr) = spawn_server(NetServerConfig {
        workers: 2,
        max_pending: 16,
        cache_entries: 8,
        timing: false,
        trace: Some(trace_path.clone()),
    });
    let mut client = NetClient::connect_retry(&addr, Duration::from_secs(10)).unwrap();
    let line = client
        .request("id=t1 instance=tiny-ba k=2 preset=CFast seeds=1,2")
        .unwrap();
    assert_eq!(line, offline, "tracing must not change response bytes");
    drop(client);
    handle.shutdown();
    runner.join().unwrap().unwrap();
    // The trace file is written after the accept loop drains.
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let json = parse_json(&text).expect("trace file is valid JSON");
    let events = json
        .get("traceEvents")
        .and_then(Json::as_array)
        .expect("traceEvents array");
    let phs = phases_of(events);
    assert_eq!(phs.first(), Some(&"M"), "metadata record leads the export");
    let begins = phs.iter().filter(|p| **p == "B").count();
    let ends = phs.iter().filter(|p| **p == "E").count();
    assert!(begins > 0, "server-side repetitions must record spans");
    assert_eq!(begins, ends, "exported spans must balance");
    let vcycles = events
        .iter()
        .filter(|e| {
            e.get("name").and_then(Json::as_str) == Some("vcycle")
                && e.get("ph").and_then(Json::as_str) == Some("B")
        })
        .count();
    assert!(vcycles >= 2, "one vcycle span per repetition, got {vcycles}");
    // otherData's bookkeeping matches the event list (metadata excluded).
    let other = json.get("otherData").expect("otherData section");
    assert_eq!(
        other.get("events").and_then(Json::as_i64),
        Some((events.len() - 1) as i64)
    );
    assert_eq!(other.get("dropped").and_then(Json::as_i64), Some(0));
    std::fs::remove_file(&trace_path).ok();
}

#[test]
fn histogram_buckets_follow_the_documented_log2_boundaries() {
    // Bucket 0 is exactly the value 0; bucket i ≥ 1 holds 2^(i-1) ≤ v < 2^i.
    assert_eq!(bucket_index(0), 0);
    for i in 1..=16usize {
        let lo = 1u64 << (i - 1);
        let hi = (1u64 << i) - 1;
        assert_eq!(bucket_index(lo), i, "low edge of bucket {i}");
        assert_eq!(bucket_index(hi), i, "high edge of bucket {i}");
        assert_eq!(bucket_index(bucket_upper_bound(i)), i);
        assert_eq!(bucket_index(bucket_upper_bound(i) + 1), i + 1);
    }
    assert_eq!(bucket_upper_bound(0), 0);
    assert_eq!(bucket_index(u64::MAX), 64);
    assert_eq!(bucket_upper_bound(64), u64::MAX);
    let h = Histogram::default();
    for v in [0u64, 1, 2, 3, 8, 9] {
        h.observe(v);
    }
    assert_eq!(h.count(), 6);
    assert_eq!(h.sum(), 23);
    assert_eq!(h.nonzero_buckets(), vec![(0, 1), (1, 1), (2, 2), (4, 2)]);
}
