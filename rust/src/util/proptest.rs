//! Minimal property-testing harness (the `proptest` crate is not
//! available in this offline environment — see DESIGN.md §3).
//!
//! `for_random_cases` runs a check over `cases` seeded inputs produced
//! by a generator closure. On failure it retries the failing seed with
//! progressively *smaller* size hints (a poor man's shrinker: our
//! generators all take a size hint, so re-running the same seed at a
//! smaller size usually yields a small counterexample) and panics with
//! the seed so the failure is exactly reproducible.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub base_seed: u64,
    /// Size hints handed to the generator, cycled across cases.
    pub sizes: Vec<usize>,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 32,
            base_seed: 0xC0FFEE,
            sizes: vec![2, 3, 5, 8, 16, 32, 64, 128],
        }
    }
}

impl PropConfig {
    pub fn quick() -> Self {
        PropConfig {
            cases: 12,
            ..Default::default()
        }
    }
}

/// Run `property(rng, size)` for many seeded cases. The property should
/// panic (assert) on violation; we annotate the panic with seed + size.
pub fn for_random_cases<F>(config: &PropConfig, mut property: F)
where
    F: FnMut(&mut Rng, usize),
{
    for case in 0..config.cases {
        let size = config.sizes[case % config.sizes.len()];
        let seed = config
            .base_seed
            .wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            property(&mut rng, size);
        }));
        if let Err(payload) = result {
            // Shrink attempt: same seed, smaller sizes.
            let mut shrunk: Option<usize> = None;
            for &small in config.sizes.iter().filter(|&&s| s < size) {
                let fails = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut rng = Rng::new(seed);
                    property(&mut rng, small);
                }))
                .is_err();
                if fails {
                    shrunk = Some(small);
                    break;
                }
            }
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed: case={case} seed={seed:#x} size={size} \
                 (shrinks to size={shrunk:?}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        for_random_cases(&PropConfig::default(), |_rng, size| {
            count += 1;
            assert!(size >= 2);
        });
        assert_eq!(count, PropConfig::default().cases);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        for_random_cases(&PropConfig::default(), |_rng, size| {
            assert!(size < 8, "too big");
        });
    }

    #[test]
    fn failure_is_deterministic() {
        // Run the same failing property twice; the reported panic should
        // occur at the same case both times (determinism of seeds).
        let capture = |_: ()| -> String {
            let r = std::panic::catch_unwind(|| {
                for_random_cases(&PropConfig::default(), |rng, _| {
                    assert!(rng.below(10) != 3);
                });
            });
            match r {
                Err(p) => p
                    .downcast_ref::<String>()
                    .cloned()
                    .unwrap_or_default(),
                Ok(()) => String::new(),
            }
        };
        assert_eq!(capture(()), capture(()));
    }
}
