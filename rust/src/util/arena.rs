//! Typed, size-tagged buffer reuse for the multilevel V-cycle.
//!
//! Every phase of the pipeline needs short-lived scratch — cluster
//! weight tables, proposal vectors, FIFO queues, bit vectors, gain
//! buckets. Allocating them fresh per level (or per request, once the
//! batching service fans repetitions out) makes the steady-state
//! V-cycle allocator-bound instead of cache-bound. An [`Arena`] keeps
//! retired buffers on per-type shelves; a [`Lease`] hands one out
//! *cleared but capacitated* and returns it on drop.
//!
//! # Determinism
//!
//! Reuse can never change results: [`Reusable::recycle`] clears
//! contents on return and [`Reusable::ensure`] re-dimensions on grant,
//! so a leased buffer is observationally identical to a freshly
//! allocated one — only its *capacity* (never visible to algorithms)
//! is recycled. The shelf policy (largest footprint first) affects
//! which allocation backs a lease, not what the lease contains.
//!
//! # Locking
//!
//! Each arena guards its shelves with one `Mutex`. The intended use —
//! see `partitioning::workspace` — is one arena per pool worker, so
//! steady-state leases are uncontended; the lock is what keeps the
//! design sound when pool re-entrancy runs two nested jobs under the
//! same worker index on different OS threads.

use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::fast_reset::{BitVec, FastResetArray};

/// A buffer type an [`Arena`] can shelve and re-issue.
///
/// The contract that keeps reuse invisible: after `recycle` the buffer
/// holds **no observable contents** (only capacity), and after
/// `ensure(hint)` it is ready for a use sized by `hint` exactly as a
/// `fresh(hint)` instance would be.
pub trait Reusable: Send + 'static {
    /// Allocate a new instance sized for `hint`.
    fn fresh(hint: usize) -> Self;
    /// Clear contents, keeping capacity (called when a lease ends).
    fn recycle(&mut self);
    /// Re-dimension for a use sized by `hint` (called when a lease is
    /// granted, after `recycle` has already run).
    fn ensure(&mut self, hint: usize);
    /// Approximate heap bytes held (drives shelf policy and stats).
    fn footprint(&self) -> usize;
}

impl<T: Send + 'static> Reusable for Vec<T> {
    fn fresh(hint: usize) -> Self {
        Vec::with_capacity(hint)
    }

    fn recycle(&mut self) {
        self.clear();
    }

    fn ensure(&mut self, hint: usize) {
        debug_assert!(self.is_empty());
        if self.capacity() < hint {
            self.reserve(hint);
        }
    }

    fn footprint(&self) -> usize {
        self.capacity() * std::mem::size_of::<T>()
    }
}

impl<T: Send + 'static> Reusable for VecDeque<T> {
    fn fresh(hint: usize) -> Self {
        VecDeque::with_capacity(hint)
    }

    fn recycle(&mut self) {
        self.clear();
    }

    fn ensure(&mut self, hint: usize) {
        debug_assert!(self.is_empty());
        if self.capacity() < hint {
            self.reserve(hint);
        }
    }

    fn footprint(&self) -> usize {
        self.capacity() * std::mem::size_of::<T>()
    }
}

impl<T: Copy + Default + Send + 'static> Reusable for FastResetArray<T> {
    fn fresh(hint: usize) -> Self {
        FastResetArray::new(hint)
    }

    fn recycle(&mut self) {
        self.clear();
    }

    fn ensure(&mut self, hint: usize) {
        self.ensure_capacity(hint);
    }

    fn footprint(&self) -> usize {
        self.capacity() * (std::mem::size_of::<T>() + std::mem::size_of::<u32>())
    }
}

impl<K, V, S> Reusable for HashMap<K, V, S>
where
    K: Eq + std::hash::Hash + Send + 'static,
    V: Send + 'static,
    S: std::hash::BuildHasher + Default + Send + 'static,
{
    fn fresh(hint: usize) -> Self {
        HashMap::with_capacity_and_hasher(hint, S::default())
    }

    fn recycle(&mut self) {
        self.clear();
    }

    fn ensure(&mut self, hint: usize) {
        debug_assert!(self.is_empty());
        if self.capacity() < hint {
            self.reserve(hint - self.len());
        }
    }

    fn footprint(&self) -> usize {
        // Approximate: buckets hold (K, V) plus ~1 byte of control
        // metadata each.
        self.capacity() * (std::mem::size_of::<K>() + std::mem::size_of::<V>() + 1)
    }
}

impl Reusable for BitVec {
    fn fresh(hint: usize) -> Self {
        BitVec::new(hint)
    }

    fn recycle(&mut self) {
        self.clear();
    }

    fn ensure(&mut self, hint: usize) {
        self.reset_len(hint);
    }

    fn footprint(&self) -> usize {
        self.len().div_ceil(64) * std::mem::size_of::<u64>()
    }
}

/// Lease accounting shared by every shard of a workspace: how many
/// leases were granted, how many had to allocate fresh (the number the
/// steady state drives to zero), and the live/peak bytes charged to
/// outstanding leases.
#[derive(Debug, Default)]
pub struct ArenaStats {
    leases_created: AtomicU64,
    fresh_allocations: AtomicU64,
    current_lease_bytes: AtomicUsize,
    peak_lease_bytes: AtomicUsize,
}

/// One point-in-time read of an [`ArenaStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LeaseStatsSnapshot {
    pub leases_created: u64,
    pub fresh_allocations: u64,
    pub current_lease_bytes: usize,
    pub peak_lease_bytes: usize,
}

impl ArenaStats {
    pub fn snapshot(&self) -> LeaseStatsSnapshot {
        LeaseStatsSnapshot {
            leases_created: self.leases_created.load(Ordering::Relaxed),
            fresh_allocations: self.fresh_allocations.load(Ordering::Relaxed),
            current_lease_bytes: self.current_lease_bytes.load(Ordering::Relaxed),
            peak_lease_bytes: self.peak_lease_bytes.load(Ordering::Relaxed),
        }
    }

    fn charge(&self, bytes: usize, fresh: bool) {
        self.leases_created.fetch_add(1, Ordering::Relaxed);
        if fresh {
            self.fresh_allocations.fetch_add(1, Ordering::Relaxed);
        }
        let now = self
            .current_lease_bytes
            .fetch_add(bytes, Ordering::Relaxed)
            .wrapping_add(bytes);
        self.peak_lease_bytes.fetch_max(now, Ordering::Relaxed);
    }

    fn release(&self, bytes: usize) {
        self.current_lease_bytes.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// Retired buffers of one type: `(footprint, buffer)` pairs.
type Shelf = Vec<(usize, Box<dyn Any + Send>)>;

/// A shelf of retired scratch buffers, keyed by type.
pub struct Arena {
    shelves: Mutex<HashMap<TypeId, Shelf>>,
    stats: Arc<ArenaStats>,
}

impl Arena {
    /// Arena reporting into a shared stats sink (the workspace path).
    pub fn new(stats: Arc<ArenaStats>) -> Self {
        Arena {
            shelves: Mutex::new(HashMap::new()),
            stats,
        }
    }

    /// Arena with its own private stats (tests and one-off callers).
    pub fn standalone() -> Self {
        Self::new(Arc::new(ArenaStats::default()))
    }

    /// The stats sink this arena charges leases to.
    pub fn stats(&self) -> &ArenaStats {
        &self.stats
    }

    /// Lease a cleared buffer dimensioned for `hint`. Reuses the
    /// largest shelved buffer of the type if one exists (the biggest
    /// retired buffer serves every smaller request, so a shrinking
    /// V-cycle settles on one buffer per type); allocates fresh
    /// otherwise. The buffer returns to this arena when the lease
    /// drops.
    pub fn lease<R: Reusable>(&self, hint: usize) -> Lease<'_, R> {
        let (mut buf, fresh) = match self.take::<R>() {
            Some(b) => (b, false),
            None => (R::fresh(hint), true),
        };
        buf.ensure(hint);
        let charged = buf.footprint();
        self.stats.charge(charged, fresh);
        Lease {
            buf: Some(buf),
            home: self,
            charged,
        }
    }

    fn take<R: Reusable>(&self) -> Option<R> {
        let mut shelves = self.shelves.lock().unwrap_or_else(|p| p.into_inner());
        let shelf = shelves.get_mut(&TypeId::of::<R>())?;
        let best = shelf
            .iter()
            .enumerate()
            .max_by_key(|(_, (footprint, _))| *footprint)?
            .0;
        let (_, boxed) = shelf.swap_remove(best);
        Some(*boxed.downcast::<R>().expect("shelf is keyed by TypeId"))
    }

    fn put_back<R: Reusable>(&self, buf: R, footprint: usize) {
        let mut shelves = self.shelves.lock().unwrap_or_else(|p| p.into_inner());
        shelves
            .entry(TypeId::of::<R>())
            .or_default()
            .push((footprint, Box::new(buf)));
    }
}

impl std::fmt::Debug for Arena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let shelves = self.shelves.lock().unwrap_or_else(|p| p.into_inner());
        let shelved: usize = shelves.values().map(Vec::len).sum();
        f.debug_struct("Arena").field("shelved", &shelved).finish()
    }
}

/// An exclusive borrow of an arena buffer. Dereferences to the buffer;
/// on drop the buffer is recycled (contents cleared, capacity kept)
/// and shelved back in its home arena.
pub struct Lease<'a, R: Reusable> {
    buf: Option<R>,
    home: &'a Arena,
    charged: usize,
}

impl<R: Reusable> Deref for Lease<'_, R> {
    type Target = R;

    #[inline]
    fn deref(&self) -> &R {
        self.buf.as_ref().expect("lease buffer present until drop")
    }
}

impl<R: Reusable> DerefMut for Lease<'_, R> {
    #[inline]
    fn deref_mut(&mut self) -> &mut R {
        self.buf.as_mut().expect("lease buffer present until drop")
    }
}

impl<R: Reusable> Drop for Lease<'_, R> {
    fn drop(&mut self) {
        if let Some(mut buf) = self.buf.take() {
            buf.recycle();
            let footprint = buf.footprint();
            self.home.put_back(buf, footprint);
            self.home.stats.release(self.charged);
        }
    }
}

/// Leased-or-owned scratch selection, for code paths that lease when a
/// workspace is available and fall back to a plain buffer otherwise:
///
/// ```ignore
/// let mut leased = arena.map(|a| a.lease::<Vec<u32>>(n));
/// let mut owned = Vec::new();
/// let buf = scratch(&mut leased, &mut owned);
/// ```
///
/// Callers keep the fallback default-constructed (allocation-free) and
/// size the chosen buffer afterwards, so nothing is allocated on the
/// road not taken.
#[inline]
pub fn scratch<'a, R: Reusable>(
    lease: &'a mut Option<Lease<'_, R>>,
    fallback: &'a mut R,
) -> &'a mut R {
    match lease.as_mut() {
        Some(l) => &mut **l,
        None => fallback,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_reuses_capacity_but_never_contents() {
        let arena = Arena::standalone();
        let ptr;
        {
            let mut v: Lease<'_, Vec<u64>> = arena.lease(100);
            assert!(v.is_empty());
            assert!(v.capacity() >= 100);
            v.push(7);
            ptr = v.as_ptr();
        }
        // Second lease gets the same allocation back, cleared.
        let v: Lease<'_, Vec<u64>> = arena.lease(50);
        assert!(v.is_empty());
        assert!(v.capacity() >= 100);
        assert_eq!(v.as_ptr(), ptr);
        let s = arena.stats().snapshot();
        assert_eq!(s.leases_created, 2);
        assert_eq!(s.fresh_allocations, 1);
    }

    #[test]
    fn distinct_types_do_not_collide() {
        let arena = Arena::standalone();
        {
            let mut a: Lease<'_, Vec<u32>> = arena.lease(8);
            let mut b: Lease<'_, Vec<u64>> = arena.lease(8);
            a.push(1);
            b.push(2);
        }
        let a: Lease<'_, Vec<u32>> = arena.lease(4);
        let b: Lease<'_, Vec<u64>> = arena.lease(4);
        assert!(a.is_empty() && b.is_empty());
        assert_eq!(arena.stats().snapshot().fresh_allocations, 2);
    }

    #[test]
    fn largest_shelved_buffer_serves_first() {
        let arena = Arena::standalone();
        {
            let _small: Lease<'_, Vec<u8>> = arena.lease(16);
            let _large: Lease<'_, Vec<u8>> = arena.lease(4096);
        }
        let v: Lease<'_, Vec<u8>> = arena.lease(1);
        assert!(v.capacity() >= 4096, "largest-first policy");
    }

    #[test]
    fn fast_reset_and_bitvec_come_back_cleared() {
        let arena = Arena::standalone();
        {
            let mut f: Lease<'_, FastResetArray<i64>> = arena.lease(10);
            f.accumulate(3, 42);
            let mut b: Lease<'_, BitVec> = arena.lease(70);
            b.set(65, true);
            let mut q: Lease<'_, VecDeque<u32>> = arena.lease(4);
            q.push_back(9);
        }
        let f: Lease<'_, FastResetArray<i64>> = arena.lease(10);
        assert!(!f.contains(3));
        assert_eq!(f.get(3), 0);
        let b: Lease<'_, BitVec> = arena.lease(70);
        assert_eq!(b.len(), 70);
        assert_eq!(b.count_ones(), 0);
        let q: Lease<'_, VecDeque<u32>> = arena.lease(4);
        assert!(q.is_empty());
        assert_eq!(arena.stats().snapshot().fresh_allocations, 3);
    }

    #[test]
    fn bitvec_lease_redimensions() {
        let arena = Arena::standalone();
        {
            let _b: Lease<'_, BitVec> = arena.lease(256);
        }
        let b: Lease<'_, BitVec> = arena.lease(13);
        assert_eq!(b.len(), 13);
        assert_eq!(b.count_ones(), 0);
    }

    #[test]
    fn hashmap_leases_and_scratch_helper() {
        let arena = Arena::standalone();
        {
            let mut m: Lease<'_, HashMap<(u32, u32), usize>> = arena.lease(16);
            m.insert((1, 2), 3);
        }
        let mut leased = Some(arena.lease::<HashMap<(u32, u32), usize>>(4));
        let mut owned = HashMap::new();
        let m = scratch(&mut leased, &mut owned);
        assert!(m.is_empty(), "recycled leases hand back no contents");
        drop(leased);
        let mut none: Option<Lease<'_, Vec<u8>>> = None;
        let mut owned_v = Vec::new();
        scratch(&mut none, &mut owned_v).push(1u8);
        assert_eq!(owned_v, vec![1]);
    }

    #[test]
    fn stats_track_peak_and_release() {
        let arena = Arena::standalone();
        {
            let _v: Lease<'_, Vec<u64>> = arena.lease(128);
            let s = arena.stats().snapshot();
            assert!(s.current_lease_bytes >= 128 * 8);
            assert!(s.peak_lease_bytes >= s.current_lease_bytes);
        }
        let s = arena.stats().snapshot();
        assert_eq!(s.current_lease_bytes, 0);
        assert!(s.peak_lease_bytes >= 128 * 8);
    }
}
