//! Lightweight timing + aggregate statistics for the bench harness.

use std::time::{Duration, Instant};

/// Stopwatch with lap support.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

impl Default for Timer {
    fn default() -> Self {
        Timer::start()
    }
}

/// Online mean/min/max/geomean accumulator used by the paper-table
/// harness (§5: arithmetic average per instance, geometric mean across
/// instances).
///
/// Zero samples are legal (a cut of 0 on a disconnected instance) and
/// are handled *explicitly* rather than smuggled into the log-sum via a
/// tiny epsilon (which silently skewed the reported geometric mean):
/// [`geomean`](Stats::geomean) is the true geometric mean — 0 the
/// moment any sample is non-positive — while
/// [`positive_geomean`](Stats::positive_geomean) aggregates only the
/// strictly positive samples and
/// [`nonpositive_count`](Stats::nonpositive_count) says how many were
/// excluded, so callers can report "geomean over the nonzero cells
/// (N excluded)" honestly.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: usize,
    sum: f64,
    /// Sum of `ln(x)` over the strictly positive samples only.
    log_sum: f64,
    /// Samples with `x <= 0` (excluded from the log-sum).
    nonpositive: usize,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            sum: 0.0,
            log_sum: 0.0,
            nonpositive: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x > 0.0 {
            self.log_sum += x.ln();
        } else {
            self.nonpositive += 1;
        }
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    /// Number of samples that were `<= 0` and therefore excluded from
    /// [`positive_geomean`](Stats::positive_geomean).
    pub fn nonpositive_count(&self) -> usize {
        self.nonpositive
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// True geometric mean: 0 if there are no samples or any sample is
    /// non-positive (a single zero zeroes the product — report it,
    /// don't fudge it).
    pub fn geomean(&self) -> f64 {
        if self.n == 0 || self.nonpositive > 0 {
            0.0
        } else {
            (self.log_sum / self.n as f64).exp()
        }
    }

    /// Geometric mean over the strictly positive samples only (0 if
    /// there are none); pair with
    /// [`nonpositive_count`](Stats::nonpositive_count) when reporting.
    pub fn positive_geomean(&self) -> f64 {
        let positives = self.n - self.nonpositive;
        if positives == 0 {
            0.0
        } else {
            (self.log_sum / positives as f64).exp()
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_min_max() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 8.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 14.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 8.0);
    }

    #[test]
    fn stats_geomean() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 8.0] {
            s.add(x);
        }
        assert!((s.geomean() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn stats_empty() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.geomean(), 0.0);
        assert_eq!(s.positive_geomean(), 0.0);
        assert_eq!(s.nonpositive_count(), 0);
    }

    #[test]
    fn stats_zero_samples_zero_the_geomean() {
        // A run that cut 0 (disconnected instance) must not be fudged
        // into the log-sum via an epsilon: the true geomean is 0, and
        // the positive-only geomean excludes the zero with a count.
        let mut s = Stats::new();
        for x in [0.0, 2.0, 8.0] {
            s.add(x);
        }
        assert_eq!(s.geomean(), 0.0);
        assert_eq!(s.nonpositive_count(), 1);
        assert!((s.positive_geomean() - 4.0).abs() < 1e-9);
        // mean/min/max still see every sample
        assert!((s.mean() - 10.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 8.0);
    }

    #[test]
    fn stats_all_nonpositive() {
        let mut s = Stats::new();
        s.add(0.0);
        s.add(-1.0); // negative samples count as non-positive too
        assert_eq!(s.geomean(), 0.0);
        assert_eq!(s.positive_geomean(), 0.0);
        assert_eq!(s.nonpositive_count(), 2);
    }

    #[test]
    fn stats_geomean_positive_only_matches_geomean() {
        // With no zeros the two aggregations agree.
        let mut s = Stats::new();
        for x in [2.0, 4.0, 8.0] {
            s.add(x);
        }
        assert!((s.geomean() - s.positive_geomean()).abs() < 1e-12);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
    }
}
