//! Lightweight timing + aggregate statistics for the bench harness.

use std::time::{Duration, Instant};

/// Stopwatch with lap support.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

impl Default for Timer {
    fn default() -> Self {
        Timer::start()
    }
}

/// Online mean/min/max/geomean accumulator used by the paper-table
/// harness (§5: arithmetic average per instance, geometric mean across
/// instances).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: usize,
    sum: f64,
    log_sum: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            sum: 0.0,
            log_sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        // Geometric mean over values that may legitimately be 0 (a cut of
        // zero on a disconnected toy instance): clamp like the DIMACS
        // challenge scripts do (add 1 inside the log? No — use max with
        // tiny epsilon so a single zero doesn't zero the whole geomean).
        self.log_sum += x.max(1e-12).ln();
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn geomean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            (self.log_sum / self.n as f64).exp()
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_min_max() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 8.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 3);
        assert!((s.mean() - 14.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 8.0);
    }

    #[test]
    fn stats_geomean() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 8.0] {
            s.add(x);
        }
        assert!((s.geomean() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn stats_empty() {
        let s = Stats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.geomean(), 0.0);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
    }
}
