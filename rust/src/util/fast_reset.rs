//! Timestamped "fast-reset" containers.
//!
//! The inner loop of size-constrained label propagation accumulates, for
//! the node under consideration, the total edge weight towards each
//! neighboring cluster, then clears the accumulator before the next node.
//! Clearing a `HashMap` or zeroing a dense array per node would cost
//! O(n) or allocator traffic; the classic algorithm-engineering trick is
//! a dense array with a per-slot timestamp — "clearing" is a single
//! counter increment.

/// Dense map from `usize` keys in `[0, capacity)` to values, with O(1)
/// bulk clear. Used for per-node cluster-weight accumulation in SCLaP
/// and gain tables in FM refinement.
#[derive(Debug)]
pub struct FastResetArray<T: Copy + Default> {
    values: Vec<T>,
    stamp: Vec<u32>,
    current: u32,
    /// Keys touched since the last clear (for sparse iteration).
    touched: Vec<usize>,
}

impl<T: Copy + Default> FastResetArray<T> {
    pub fn new(capacity: usize) -> Self {
        FastResetArray {
            values: vec![T::default(); capacity],
            stamp: vec![0; capacity],
            current: 1,
            touched: Vec::new(),
        }
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.values.len()
    }

    /// Grow to at least `capacity` slots (preserves the current epoch).
    pub fn ensure_capacity(&mut self, capacity: usize) {
        if capacity > self.values.len() {
            self.values.resize(capacity, T::default());
            self.stamp.resize(capacity, 0);
        }
    }

    #[inline]
    pub fn contains(&self, key: usize) -> bool {
        self.stamp[key] == self.current
    }

    #[inline]
    pub fn get(&self, key: usize) -> T {
        if self.contains(key) {
            self.values[key]
        } else {
            T::default()
        }
    }

    #[inline]
    pub fn set(&mut self, key: usize, value: T) {
        if !self.contains(key) {
            self.stamp[key] = self.current;
            self.touched.push(key);
        }
        self.values[key] = value;
    }

    /// Keys written since the last `clear`, in first-touch order.
    #[inline]
    pub fn touched(&self) -> &[usize] {
        &self.touched
    }

    /// O(1) amortized clear (epoch bump; full rewrite on wraparound).
    #[inline]
    pub fn clear(&mut self) {
        self.touched.clear();
        self.current = self.current.wrapping_add(1);
        if self.current == 0 {
            // Epoch wrapped: lazily-stale stamps could now collide.
            self.stamp.fill(0);
            self.current = 1;
        }
    }
}

impl FastResetArray<f64> {
    /// Accumulate `delta` into `key` (the SCLaP scoring primitive).
    #[inline]
    pub fn add(&mut self, key: usize, delta: f64) {
        let v = self.get(key);
        self.set(key, v + delta);
    }
}

impl FastResetArray<i64> {
    #[inline]
    pub fn add_i64(&mut self, key: usize, delta: i64) {
        let v = self.get(key);
        self.set(key, v + delta);
    }

    /// Hot-path accumulate with a single stamp check (vs `add_i64`'s
    /// two): the SCLaP inner loop runs this once per graph arc, so the
    /// saved load+branch is measurable (§Perf iteration 1).
    #[inline(always)]
    pub fn accumulate(&mut self, key: usize, delta: i64) {
        if self.stamp[key] == self.current {
            self.values[key] += delta;
        } else {
            self.stamp[key] = self.current;
            self.values[key] = delta;
            self.touched.push(key);
        }
    }

    /// Read a key that is known to be touched in the current epoch
    /// (skips the stamp check). Debug-asserted.
    #[inline(always)]
    pub fn value_of_touched(&self, key: usize) -> i64 {
        debug_assert!(self.contains(key));
        self.values[key]
    }

    /// `accumulate` without bounds checks.
    ///
    /// # Safety
    /// `key < self.capacity()` must hold. The SCLaP inner loop calls this
    /// once per graph arc with `key = label[u] < n ≤ capacity`, which the
    /// engine guarantees by construction (§Perf iteration 3).
    #[inline(always)]
    pub unsafe fn accumulate_unchecked(&mut self, key: usize, delta: i64) {
        debug_assert!(key < self.values.len());
        if *self.stamp.get_unchecked(key) == self.current {
            *self.values.get_unchecked_mut(key) += delta;
        } else {
            *self.stamp.get_unchecked_mut(key) = self.current;
            *self.values.get_unchecked_mut(key) = delta;
            self.touched.push(key);
        }
    }
}

/// Bit vector with the operations needed by the active-nodes rounds
/// (§B.2 of the paper: two FIFO queues + two bit vectors).
#[derive(Debug, Clone)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    pub fn new(len: usize) -> Self {
        BitVec {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i >> 6];
        let mask = 1u64 << (i & 63);
        if v {
            *w |= mask;
        } else {
            *w &= !mask;
        }
    }

    pub fn clear(&mut self) {
        self.words.fill(0);
    }

    /// Re-dimension to `len` bits, all zero, reusing the word buffer's
    /// capacity (no allocation when it suffices) — the arena-lease
    /// re-dimension hook (`util::arena`).
    pub fn reset_len(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(len.div_ceil(64), 0);
        self.len = len;
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_reset_roundtrip() {
        let mut a: FastResetArray<f64> = FastResetArray::new(10);
        a.set(3, 1.5);
        a.add(3, 2.0);
        a.add(7, 1.0);
        assert_eq!(a.get(3), 3.5);
        assert_eq!(a.get(7), 1.0);
        assert_eq!(a.get(0), 0.0);
        assert_eq!(a.touched(), &[3, 7]);
        a.clear();
        assert_eq!(a.get(3), 0.0);
        assert!(a.touched().is_empty());
        assert!(!a.contains(3));
    }

    #[test]
    fn fast_reset_many_epochs() {
        let mut a: FastResetArray<i64> = FastResetArray::new(4);
        for epoch in 0..1000i64 {
            a.add_i64(2, epoch);
            assert_eq!(a.get(2), epoch);
            a.clear();
        }
    }

    #[test]
    fn fast_reset_epoch_wraparound() {
        let mut a: FastResetArray<i64> = FastResetArray::new(2);
        a.current = u32::MAX - 1;
        a.set(0, 42);
        a.clear(); // -> u32::MAX
        a.set(1, 7);
        a.clear(); // wraps to 0 -> full reset path
        assert!(!a.contains(0));
        assert!(!a.contains(1));
        a.set(0, 9);
        assert_eq!(a.get(0), 9);
    }

    #[test]
    fn fast_reset_grow() {
        let mut a: FastResetArray<f64> = FastResetArray::new(2);
        a.set(1, 5.0);
        a.ensure_capacity(10);
        assert_eq!(a.get(1), 5.0);
        a.set(9, 2.0);
        assert_eq!(a.get(9), 2.0);
    }

    #[test]
    fn bitvec_basics() {
        let mut b = BitVec::new(130);
        assert_eq!(b.count_ones(), 0);
        b.set(0, true);
        b.set(64, true);
        b.set(129, true);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1));
        assert_eq!(b.count_ones(), 3);
        b.set(64, false);
        assert_eq!(b.count_ones(), 2);
        b.clear();
        assert_eq!(b.count_ones(), 0);
    }
}
