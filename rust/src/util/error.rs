//! Minimal error + context plumbing (the `anyhow` crate is not available
//! in this offline environment — DESIGN.md §3).
//!
//! A string-backed [`Error`], the [`Context`] extension trait for
//! `Result`/`Option`, and the [`bail!`]/[`ensure!`] macros — just enough
//! surface for the CLI and the PJRT runtime plumbing, with the same call
//! shapes as `anyhow` so the code reads familiarly.

use std::fmt;

/// String-backed error. Context is prepended `"{context}: {cause}"`, so
/// `{e}` (and `{e:#}`) print the full chain in one line.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-shaped extension for `Result` and `Option`.
pub trait Context<T> {
    /// Replace/augment the error with `context: {original}`.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Lazily-built variant of [`Context::context`].
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)).into())
    };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("not an integer")?;
        Ok(v)
    }

    #[test]
    fn context_prepends() {
        let e = parse("zzz").unwrap_err();
        let text = format!("{e}");
        assert!(text.starts_with("not an integer:"), "{text}");
        assert_eq!(parse("42").unwrap(), 42);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: std::result::Result<u32, String> = Ok(1);
        let v = ok
            .with_context(|| panic!("must not evaluate on Ok"))
            .unwrap();
        assert_eq!(v, 1);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            crate::ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                crate::bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
    }

    #[test]
    fn io_error_converts() {
        fn open() -> Result<String> {
            let text = std::fs::read_to_string("/definitely/not/a/file/xyz")?;
            Ok(text)
        }
        assert!(open().is_err());
    }
}
