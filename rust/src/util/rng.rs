//! Deterministic pseudo-random number generation.
//!
//! The registry cache in this environment does not contain the `rand`
//! crate, so we implement xoshiro256++ (Blackman & Vigna) in-tree. It is
//! more than adequate for randomized algorithm engineering: the paper
//! only needs random node orderings, random tie-breaking and seedable
//! repetition (§5: "ten repetitions for each configuration").

/// One step of a splitmix64 stream seeded at `z`: advance by the golden
/// gamma and finalize. The single home of the splitmix64 magic
/// constants — shared by [`Rng::new`] seed expansion and
/// `util::exec::derive_seed`, so the two can never drift apart.
#[inline]
pub fn splitmix64(z: u64) -> u64 {
    let mut z = z.wrapping_add(SPLITMIX_GAMMA);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The splitmix64 state increment (the 64-bit golden ratio).
const SPLITMIX_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// xoshiro256++ PRNG. Deterministic for a given seed; `jump()` provides
/// 2^128 non-overlapping subsequence splits for parallel workers.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion
    /// (the initialization recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            let out = splitmix64(sm);
            sm = sm.wrapping_add(SPLITMIX_GAMMA);
            out
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        // splitmix64 never yields all-zero state from distinct outputs,
        // but guard anyway: xoshiro must not start at the zero state.
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, bound);
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return hi as usize;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Choose one element uniformly (panics on empty slice).
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> &'a T {
        &slice[self.below(slice.len())]
    }

    /// Split off an independent generator (xoshiro256++ long-jump keyed
    /// re-seed: good enough statistical independence for worker streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Rng::new(7);
        for bound in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_small_range() {
        let mut rng = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[rng.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        // Overwhelmingly unlikely to be identity.
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive() {
        let mut rng = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let x = rng.range(3, 6);
            assert!((3..=6).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 6;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn split_streams_differ() {
        let mut base = Rng::new(13);
        let mut a = base.split();
        let mut b = base.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut rng = Rng::new(17);
        let n = 10_000;
        let sum: f64 = (0..n).map(|_| rng.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
