//! Shared deterministic thread pool — the runtime substrate for every
//! parallel phase of the multilevel pipeline (std-only; rayon/crossbeam
//! are not available offline, DESIGN.md §3).
//!
//! # The determinism contract
//!
//! Every pool primitive executes a **fixed logical schedule** whose
//! result is a pure function of its inputs, never of the thread count or
//! the OS scheduler:
//!
//! 1. Work is decomposed into tasks *before* dispatch, by the caller,
//!    using only input sizes (e.g. fixed-size node chunks). The
//!    decomposition must not depend on [`ThreadPool::threads`].
//! 2. Tasks are claimed dynamically (idle workers steal the next chunk
//!    index from a shared counter — cheap work stealing), but each task
//!    writes only to its own result slot, so *which* worker ran a task
//!    is unobservable.
//! 3. Any randomness inside a task comes from an RNG stream seeded by
//!    the task index (plus a caller-provided seed), never from a
//!    worker-local or time-derived source.
//! 4. Reductions over task results happen on the caller in task-index
//!    order.
//! 5. Tasks of a **multi-task** job run with the ambient trace track
//!    masked ([`crate::obs::trace::mask`]) on every path — dispatched
//!    to a worker, claimed by the participating caller, or inline under
//!    `threads = 1` / re-entrant submission. Whether a task's trace
//!    events exist therefore never depends on which thread claimed it.
//!    Single-task jobs run inline on the submitting thread for every
//!    pool size, so they keep the submitter's track; tasks that own a
//!    whole repetition open their *own* track (masking parks, it does
//!    not forbid).
//!
//! Under this contract `threads = 1` and `threads = N` produce
//! bit-identical results — the invariant `rust/tests/determinism.rs`
//! enforces for the whole partitioning pipeline ("same seed + same
//! config ⇒ byte-identical partition, regardless of thread count").
//!
//! # Implementation notes
//!
//! A pool of `threads` has `threads - 1` background workers; the calling
//! thread participates as worker 0, so `threads = 1` runs everything
//! inline (one uncontended lock, no worker dispatch). One job is active
//! at a time — `run` serializes through an internal lock on *every*
//! path, including the inline one, because the `WorkerLocal` contract
//! (at most one task per worker id) must hold even for concurrent
//! `run` calls on a shared pool.
//!
//! # Re-entrancy (the `ExecutionCtx` handoff)
//!
//! A task may submit to its *own* pool: the nested `run` detects (via a
//! thread-local set of entered pool ids) that the calling thread is
//! already inside a job of this pool and executes the nested job
//! **inline, sequentially, as worker 0** — no locks taken, no extra
//! threads, no deadlock on the job slot. This is what lets one shared
//! pool serve every nesting level (coordinator repetitions → partitioner
//! phases → recursive-bisection branches) while capping total live
//! worker threads at the configured count: by the thread-count-invariance
//! contract the inline schedule produces bit-identical results to a
//! fanned-out one. Two rules follow for nested jobs: (1) a nested job's
//! [`WorkerLocal`] must be created *inside* the nesting task (distinct
//! outer tasks run nested jobs concurrently, each as its own worker 0),
//! which all in-tree callers do naturally by allocating scratch per
//! call; (2) nested use of a *different* pool still dispatches normally.
//!
//! Borrowed closures are handed to the long-lived workers by erasing the
//! closure lifetime. Soundness: `run` does not return until `remaining`
//! hits zero, i.e. until every claimed task has finished; workers that
//! observe the job afterwards only perform a failed claim
//! (`next >= count`) and never touch the closure again. Panics inside
//! tasks are caught per task (a panicking job must not take the worker —
//! and every later job — down) and re-raised on the caller after the
//! job drains.

use crate::obs::trace;
use crate::util::cancel::{self, CancelToken, Cancelled};
use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// Unique id per pool (for the thread-local re-entrancy set).
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

/// Gauge of live background pool worker threads in this process.
/// Incremented at spawn (in [`ThreadPool::new`], before it returns) and
/// decremented when a worker thread exits; `Drop` joins the workers, so
/// after a pool is dropped its workers have left the gauge.
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Pool ids this thread is currently executing a job of (a stack:
    /// nested distinct pools push multiple entries). Used by `run` to
    /// detect re-entrant submission and go inline.
    static ACTIVE_POOLS: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// Number of live background pool worker threads in the whole process —
/// the observable for the "worker threads never exceed the configured
/// cap" invariant (`rust/tests/thread_cap.rs`). The calling threads of
/// pools are not counted (they exist regardless).
pub fn live_pool_workers() -> usize {
    LIVE_WORKERS.load(Ordering::SeqCst)
}

fn pool_entered(id: u64) -> bool {
    ACTIVE_POOLS.with(|s| s.borrow().contains(&id))
}

/// RAII marker: this thread is executing a job of pool `id`.
struct ActiveGuard(u64);

fn enter_pool(id: u64) -> ActiveGuard {
    ACTIVE_POOLS.with(|s| s.borrow_mut().push(id));
    ActiveGuard(id)
}

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        ACTIVE_POOLS.with(|s| {
            let mut v = s.borrow_mut();
            if let Some(pos) = v.iter().rposition(|&x| x == self.0) {
                v.remove(pos);
            }
        });
    }
}

/// Decrements the live-worker gauge when a worker thread exits.
struct WorkerGauge;

impl Drop for WorkerGauge {
    fn drop(&mut self) {
        LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One in-flight job: a lifetime-erased task closure plus claim/progress
/// counters. Held in an `Arc` so late-waking workers can do a failed
/// claim safely after the job completed.
struct JobCtrl {
    /// `f(worker, task)` — lifetime-erased borrow of the caller's
    /// closure; only dereferenced for successfully claimed task indices.
    task: &'static (dyn Fn(usize, usize) + Sync),
    count: usize,
    next: AtomicUsize,
    remaining: AtomicUsize,
    /// Any task of **this job** panicked. Job-scoped by construction: a
    /// fresh `JobCtrl` is allocated per [`ThreadPool::run`] call, so a
    /// contained panic in one batch can never poison a later, unrelated
    /// batch on the same long-lived pool (regression test:
    /// `panic_flag_is_scoped_to_its_job`).
    panicked: AtomicBool,
    /// The submitter's ambient [`CancelToken`] at dispatch time, carried
    /// into the job so workers poll it at task boundaries and re-enter
    /// it around each task (nested checkpoints see it). `None` when the
    /// submitter had no ambient token — zero per-task overhead then.
    cancel: Option<CancelToken>,
    /// Nonzero once the token fired mid-job: the `CancelReason` code.
    /// Remaining tasks are skipped (the job still drains normally) and
    /// `run` re-raises the typed [`Cancelled`] payload on the caller.
    cancelled: AtomicU8,
}

impl JobCtrl {
    /// Store the cancellation verdict (first reason wins, like the token).
    fn mark_cancelled(&self, reason: cancel::CancelReason) {
        let _ = self.cancelled.compare_exchange(
            0,
            reason.code(),
            Ordering::AcqRel,
            Ordering::Acquire,
        );
    }

    /// The cancellation verdict, if any task boundary observed a fire.
    fn cancelled_reason(&self) -> Option<cancel::CancelReason> {
        cancel::CancelReason::from_code(self.cancelled.load(Ordering::Acquire))
    }
}

struct PoolState {
    job: Option<Arc<JobCtrl>>,
    /// Bumped per job so a worker never re-enters a job it has finished.
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new job (or shutdown).
    work_cv: Condvar,
    /// The caller waits here for `remaining == 0`.
    done_cv: Condvar,
}

/// Lock that survives poisoning: a panicking *caller* (task panics are
/// re-raised after the job drains) must not brick the pool for later
/// jobs.
fn lock(m: &Mutex<PoolState>) -> MutexGuard<'_, PoolState> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Deterministic work-sharing thread pool. See the module docs for the
/// determinism contract.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Serializes `run` calls: a single job slot is active at a time.
    run_lock: Mutex<()>,
    /// Process-unique id for re-entrancy detection.
    id: u64,
}

impl ThreadPool {
    /// Create a pool of `threads` total workers (including the calling
    /// thread). `0` means [`std::thread::available_parallelism`];
    /// `1` means fully inline sequential execution.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
        } else {
            threads
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let pool_id = NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed);
        let workers = (1..threads)
            .map(|id| {
                let shared = shared.clone();
                // Count the worker before the spawn returns so the gauge
                // is exact the moment `new` completes.
                LIVE_WORKERS.fetch_add(1, Ordering::SeqCst);
                std::thread::Builder::new()
                    .name(format!("sclap-pool-{id}"))
                    .spawn(move || {
                        let _gauge = WorkerGauge;
                        // A worker executes tasks of this pool only; mark
                        // it entered for the thread's whole lifetime so
                        // re-entrant submission from tasks goes inline.
                        let _active = enter_pool(pool_id);
                        worker_loop(shared, id)
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            shared,
            workers,
            threads,
            run_lock: Mutex::new(()),
            id: pool_id,
        }
    }

    /// Total worker count, including the calling thread.
    #[inline]
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(worker, task)` for every `task in 0..count`, blocking
    /// until all tasks finished. `worker` is a stable id in
    /// `0..threads()` — at most one task runs per worker id at a time,
    /// so it may index caller-owned scratch (see [`WorkerLocal`]).
    ///
    /// Tasks are claimed in index order from a shared counter; per the
    /// module contract, `f`'s effect must depend only on `task`.
    /// Panics (once, after the job drains) if any task panicked.
    pub fn run<F>(&self, count: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if count == 0 {
            return;
        }
        // Re-entrant submission (the ExecutionCtx handoff): a task of
        // this pool calling back into it runs the nested job inline,
        // sequentially, as worker 0 — same results by thread-count
        // invariance, no deadlock on the job slot, no extra threads.
        // Safe for WorkerLocal because nested jobs allocate their own
        // scratch inside the nesting task (module docs, re-entrancy).
        if pool_entered(self.id) {
            for i in 0..count {
                // Inline jobs poll the ambient token at the same task
                // granularity as dispatched ones (no-op when unfired).
                cancel::checkpoint();
                let _mask = (count > 1).then(trace::mask);
                f(0, i);
            }
            return;
        }
        // One job at a time — also across the inline fast path below:
        // WorkerLocal's &mut-per-worker-id contract relies on worker id
        // 0 (the caller slot) never being active twice concurrently.
        let _serial = self
            .run_lock
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        // Mark entered for the whole job — including the inline path, so
        // phases nested under an inline job (threads = 1, or count = 1)
        // also go inline instead of deadlocking on `run_lock`.
        let _active = enter_pool(self.id);
        if self.workers.is_empty() || count == 1 {
            // Sequential fast path: same schedule, no worker dispatch.
            for i in 0..count {
                cancel::checkpoint();
                let _mask = (count > 1).then(trace::mask);
                f(0, i);
            }
            return;
        }

        // Erase the closure lifetime; see module docs for the soundness
        // argument (no dereference after `remaining == 0`).
        let task: &(dyn Fn(usize, usize) + Sync) = &f;
        let task: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(task) };
        let ctrl = Arc::new(JobCtrl {
            task,
            count,
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(count),
            panicked: AtomicBool::new(false),
            cancel: cancel::current(),
            cancelled: AtomicU8::new(0),
        });

        {
            let mut st = lock(&self.shared.state);
            st.epoch += 1;
            st.job = Some(ctrl.clone());
            self.shared.work_cv.notify_all();
        }

        // The caller is worker 0.
        work_on(&ctrl, 0, &self.shared);

        let mut st = lock(&self.shared.state);
        while ctrl.remaining.load(Ordering::Acquire) != 0 {
            st = self
                .shared
                .done_cv
                .wait(st)
                .unwrap_or_else(|p| p.into_inner());
        }
        st.job = None;
        drop(st);

        if ctrl.panicked.load(Ordering::Relaxed) {
            panic!("sclap::util::pool: a pool task panicked (see stderr above)");
        }
        if let Some(reason) = ctrl.cancelled_reason() {
            // Some tasks were skipped (or unwound) because the token
            // fired mid-job: the partial job result is meaningless, so
            // re-raise the typed payload for the repetition boundary.
            std::panic::panic_any(Cancelled { reason });
        }
    }

    /// Deterministic parallel map: `out[i] = f(worker, i)`, results in
    /// task order regardless of scheduling.
    pub fn map_indexed<R, F>(&self, count: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, usize) -> R + Sync,
    {
        let mut out: Vec<Option<R>> = Vec::with_capacity(count);
        out.resize_with(count, || None);
        let slots = SendPtr(out.as_mut_ptr());
        self.run(count, |worker, i| {
            let r = f(worker, i);
            // SAFETY: each task index is claimed exactly once, so slot
            // `i` is written by exactly one thread; `out` outlives `run`
            // (which blocks until every task completed).
            unsafe { *slots.0.add(i) = Some(r) };
        });
        out.into_iter()
            .map(|r| r.expect("pool task completed"))
            .collect()
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer courier for disjoint slot writes from pool tasks.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

fn worker_loop(shared: Arc<Shared>, worker: usize) {
    let mut last_epoch = 0u64;
    loop {
        let ctrl = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    if let Some(ctrl) = &st.job {
                        last_epoch = st.epoch;
                        break ctrl.clone();
                    }
                }
                st = shared
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
        };
        work_on(&ctrl, worker, &shared);
    }
}

/// Claim-and-execute loop shared by workers and the calling thread.
fn work_on(ctrl: &JobCtrl, worker: usize, shared: &Shared) {
    loop {
        let i = ctrl.next.fetch_add(1, Ordering::Relaxed);
        if i >= ctrl.count {
            return;
        }
        // Cooperative cancellation at task granularity: once the
        // submitter's token fires, remaining tasks are skipped — but the
        // claim/decrement protocol is unchanged, so the job drains and
        // the caller wakes normally (no deadlock, no leaked state).
        let skip = match &ctrl.cancel {
            Some(token) => {
                let fired = ctrl.cancelled_reason().or_else(|| token.poll());
                if let Some(reason) = fired {
                    ctrl.mark_cancelled(reason);
                }
                fired.is_some()
            }
            None => false,
        };
        if !skip {
            // Re-enter the submitter's token ambiently so checkpoints
            // inside the task (nested pool use, inner loops) see it.
            let _scope = ctrl.cancel.clone().map(cancel::enter);
            // Mask the ambient trace track (`obs::trace::mask`): only
            // multi-task jobs reach dispatch, and their tasks must emit
            // nothing no matter which thread claims them — the calling
            // thread participates as worker 0 and *does* carry a track
            // when a repetition fans work out from its own thread.
            let _mask = trace::mask();
            let result = catch_unwind(AssertUnwindSafe(|| (ctrl.task)(worker, i)));
            if let Err(payload) = result {
                if let Some(c) = payload.downcast_ref::<Cancelled>() {
                    // A checkpoint inside the task unwound: cancellation,
                    // not a bug — no stderr noise, no panic flag.
                    ctrl.mark_cancelled(c.reason);
                } else {
                    // Context for batch operators: which task blew up
                    // (callers add their own domain context, e.g. the
                    // coordinator prints the repetition seed before
                    // rethrowing).
                    eprintln!("sclap pool worker {worker}: task {i} panicked");
                    ctrl.panicked.store(true, Ordering::Relaxed);
                }
            }
        }
        if ctrl.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last task: wake the caller. Lock pairs the notify with the
            // caller's checked wait so the wakeup cannot be lost.
            let _st = lock(&shared.state);
            shared.done_cv.notify_all();
        }
    }
}

/// Per-worker mutable scratch for pool tasks (e.g. a fast-reset
/// accumulator per worker instead of one allocation per chunk).
///
/// # Safety contract
///
/// [`WorkerLocal::get_mut`] hands out `&mut T` indexed by the worker id
/// a pool primitive passed to the task closure. The pool guarantees at
/// most one task runs per worker id at a time, which makes the access
/// exclusive. Do not call `get_mut` with anything other than the worker
/// id of the current task.
pub struct WorkerLocal<T> {
    slots: Vec<std::cell::UnsafeCell<T>>,
}

// SAFETY: access is partitioned by worker id (one thread per id at a
// time, enforced by the pool); T crosses thread boundaries, hence Send.
unsafe impl<T: Send> Sync for WorkerLocal<T> {}

impl<T> WorkerLocal<T> {
    /// One slot per worker, built by `init` (called `workers` times).
    pub fn new<F: FnMut() -> T>(workers: usize, mut init: F) -> Self {
        WorkerLocal {
            slots: (0..workers.max(1))
                .map(|_| std::cell::UnsafeCell::new(init()))
                .collect(),
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether the scratch set is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Exclusive access to worker `worker`'s slot.
    ///
    /// # Safety
    /// `worker` must be the worker id passed by the pool to the calling
    /// task (or the pool must be otherwise quiescent); two simultaneous
    /// calls with the same id are undefined behavior.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, worker: usize) -> &mut T {
        &mut *self.slots[worker].get()
    }
}

/// Disjoint-range writer for pool tasks: a lifetime-tracked courier
/// that lets tasks write non-overlapping ranges of one caller-owned
/// slice without per-task allocation or a post-job gather — the
/// "decompose by index, write your own slot" pattern of the module
/// contract, generalized from single slots ([`ThreadPool::map_indexed`])
/// to ranges.
///
/// # Safety contract
///
/// [`DisjointSlice::range_mut`] hands out `&mut [T]` windows. The
/// *caller's task decomposition* must guarantee that ranges requested
/// by concurrently running tasks never overlap (e.g. fixed-size chunks
/// by task index). The pool guarantees each task index is claimed once,
/// so index-derived ranges are exclusive by construction.
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: access is partitioned into caller-guaranteed disjoint ranges;
// T crosses thread boundaries, hence Send.
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    /// Wrap `slice` for disjoint-range access from pool tasks.
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Length of the wrapped slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the wrapped slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exclusive access to `start..end` of the wrapped slice.
    ///
    /// # Safety
    /// No other live borrow (from this or any thread) may overlap
    /// `start..end` — the caller's task decomposition must make ranges
    /// of concurrent tasks disjoint. Bounds are checked (`start <= end
    /// <= len`), overlap is not.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, start: usize, end: usize) -> &mut [T] {
        assert!(start <= end && end <= self.len, "range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(start), end - start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = ThreadPool::new(4);
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run(n, |_w, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_indexed_preserves_order() {
        for threads in [1usize, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let out = pool.map_indexed(257, |_w, i| i * i);
            assert_eq!(out.len(), 257);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "threads={threads}");
            }
        }
    }

    #[test]
    fn results_identical_across_thread_counts() {
        // The determinism contract at the pool level: same task function,
        // different pool sizes, identical output.
        let compute = |i: usize| {
            let mut rng = crate::util::rng::Rng::new(i as u64);
            (0..10).map(|_| rng.next_u64()).fold(0u64, u64::wrapping_add)
        };
        let reference: Vec<u64> = (0..100).map(compute).collect();
        for threads in [1usize, 2, 3, 4, 8] {
            let pool = ThreadPool::new(threads);
            let out = pool.map_indexed(100, |_w, i| compute(i));
            assert_eq!(out, reference, "threads={threads}");
        }
    }

    #[test]
    fn worker_ids_are_in_range_and_exclusive() {
        let threads = 4;
        let pool = ThreadPool::new(threads);
        let in_use: Vec<AtomicUsize> = (0..threads).map(|_| AtomicUsize::new(0)).collect();
        pool.run(500, |w, _i| {
            assert!(w < threads);
            let prev = in_use[w].fetch_add(1, Ordering::SeqCst);
            assert_eq!(prev, 0, "worker id {w} used concurrently");
            std::thread::yield_now();
            in_use[w].fetch_sub(1, Ordering::SeqCst);
        });
    }

    #[test]
    fn worker_local_scratch_accumulates() {
        let threads = 4;
        let pool = ThreadPool::new(threads);
        let scratch: WorkerLocal<u64> = WorkerLocal::new(threads, || 0);
        pool.run(100, |w, i| {
            let slot = unsafe { scratch.get_mut(w) };
            *slot += i as u64;
        });
        let total: u64 = (0..threads)
            .map(|w| unsafe { *scratch.get_mut(w) })
            .sum();
        assert_eq!(total, (0..100u64).sum());
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, |_w, i| {
                assert!(i != 7, "task 7 exploded");
            });
        }));
        assert!(r.is_err());
        // The pool must still execute later jobs.
        let out = pool.map_indexed(8, |_w, i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn multi_task_jobs_are_trace_masked_on_every_path() {
        use crate::obs::trace::{self, Tracer};
        // Contract rule 5: a multi-task job's tasks emit nothing no
        // matter which thread claims them (the caller participates as
        // worker 0 and would otherwise emit a racy subset), while a
        // single-task job — inline on the submitter everywhere — keeps
        // the ambient track. The streams must agree across pool sizes.
        let mut streams = Vec::new();
        for threads in [1usize, 4] {
            let pool = ThreadPool::new(threads);
            let tracer = Arc::new(Tracer::new());
            {
                let _track = tracer.enter(7);
                pool.run(1, |_w, _i| {
                    trace::counter("solo", &[("i", 0)]);
                });
                pool.run(3, |_w, i| {
                    trace::counter("fanned", &[("i", i as i64)]);
                });
            }
            streams.push(tracer.logical_stream());
        }
        assert_eq!(streams[0], streams[1], "masking must not depend on pool size");
        assert!(streams[0].iter().any(|l| l.contains(" C solo")));
        assert!(streams[0].iter().all(|l| !l.contains("fanned")));
    }

    #[test]
    fn panic_flag_is_scoped_to_its_job() {
        // The panic marker lives on the per-job `JobCtrl`, not on the
        // pool: after a batch with a contained task panic, a clean batch
        // submitted to the same long-lived pool must complete without a
        // spurious "a pool task panicked" report — the service
        // coordinator keeps one pool alive across many client batches.
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for round in 0..3 {
                let bad = catch_unwind(AssertUnwindSafe(|| {
                    pool.run(8, |_w, i| assert!(i != 3, "task 3 exploded"));
                }));
                assert!(bad.is_err(), "threads={threads} round={round}: panic must surface");
                let clean = catch_unwind(AssertUnwindSafe(|| {
                    pool.map_indexed(8, |_w, i| i)
                }));
                assert_eq!(
                    clean.ok(),
                    Some((0..8).collect::<Vec<_>>()),
                    "threads={threads} round={round}: clean job poisoned by earlier panic"
                );
            }
        }
    }

    #[test]
    fn unfired_ambient_token_changes_nothing() {
        // The cancellation invariant at the pool level: a live-but-
        // unfired ambient token is unobservable in results.
        for threads in [1usize, 4] {
            let pool = ThreadPool::new(threads);
            let reference = pool.map_indexed(100, |_w, i| i * 3);
            let token = cancel::CancelToken::new();
            let _scope = cancel::enter(token);
            let out = pool.map_indexed(100, |_w, i| i * 3);
            assert_eq!(out, reference, "threads={threads}");
        }
    }

    #[test]
    fn fired_token_cancels_job_with_typed_payload_and_pool_survives() {
        for threads in [1usize, 4] {
            let pool = ThreadPool::new(threads);
            let token = cancel::CancelToken::new();
            let executed = AtomicUsize::new(0);
            let err = {
                let _scope = cancel::enter(token.clone());
                token.fire(cancel::CancelReason::Timeout);
                catch_unwind(AssertUnwindSafe(|| {
                    pool.run(64, |_w, _i| {
                        executed.fetch_add(1, Ordering::Relaxed);
                    });
                }))
                .unwrap_err()
            };
            let cancelled = err
                .downcast_ref::<Cancelled>()
                .unwrap_or_else(|| panic!("threads={threads}: expected typed payload"));
            assert_eq!(cancelled.reason, cancel::CancelReason::Timeout);
            // A pre-fired token stops the job at the first boundary.
            assert_eq!(executed.load(Ordering::Relaxed), 0, "threads={threads}");
            // The pool is healthy for later jobs (no ambient token now).
            let out = pool.map_indexed(8, |_w, i| i + 1);
            assert_eq!(out, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        }
    }

    #[test]
    fn checkpoint_inside_a_task_cancels_the_whole_job() {
        // A mid-task checkpoint (workers re-enter the submitter's token)
        // unwinds as cancellation, not as a task panic: the job drains,
        // the caller gets the typed payload, no "task panicked" report.
        let pool = ThreadPool::new(3);
        let token = cancel::CancelToken::new();
        let _scope = cancel::enter(token.clone());
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.run(32, |_w, i| {
                if i == 0 {
                    token.fire(cancel::CancelReason::RaceLost);
                }
                cancel::checkpoint();
            });
        }))
        .unwrap_err();
        assert_eq!(
            err.downcast_ref::<Cancelled>().expect("typed payload").reason,
            cancel::CancelReason::RaceLost
        );
    }

    #[test]
    fn zero_tasks_and_auto_threads() {
        let pool = ThreadPool::new(0);
        assert!(pool.threads() >= 1);
        pool.run(0, |_w, _i| panic!("must not run"));
    }

    #[test]
    fn single_thread_is_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let main_id = std::thread::current().id();
        pool.run(10, |w, _i| {
            assert_eq!(w, 0);
            assert_eq!(std::thread::current().id(), main_id);
        });
    }

    #[test]
    fn drop_joins_quickly() {
        let pool = ThreadPool::new(6);
        pool.run(10, |_w, _i| {});
        drop(pool); // must not hang
    }

    #[test]
    fn reentrant_same_pool_runs_inline() {
        // The ExecutionCtx handoff pattern: a task submits to its own
        // pool. The nested job must execute inline as worker 0 and
        // produce the deterministic result.
        let pool = ThreadPool::new(3);
        let pool_ref = &pool;
        let sums = pool_ref.map_indexed(6, |_w, i| {
            pool_ref
                .map_indexed(20, |w, j| {
                    assert_eq!(w, 0, "nested tasks run inline as worker 0");
                    (i * j) as u64
                })
                .into_iter()
                .sum::<u64>()
        });
        for (i, s) in sums.iter().enumerate() {
            assert_eq!(*s, (0..20).map(|j| (i * j) as u64).sum::<u64>());
        }
    }

    #[test]
    fn reentrant_under_inline_job() {
        // threads = 1: the outer job runs inline while holding run_lock;
        // the nested submission must not deadlock.
        let pool = ThreadPool::new(1);
        let pool_ref = &pool;
        let out = pool_ref.map_indexed(3, |_w, i| {
            pool_ref.map_indexed(4, |_w, j| i * 10 + j).len()
        });
        assert_eq!(out, vec![4, 4, 4]);
    }

    #[test]
    fn reentrant_two_levels_deep() {
        let pool = ThreadPool::new(4);
        let pool_ref = &pool;
        let total: u64 = pool_ref
            .map_indexed(4, |_w, i| {
                pool_ref
                    .map_indexed(3, |_w, j| {
                        pool_ref
                            .map_indexed(2, |_w, l| (i + j + l) as u64)
                            .into_iter()
                            .sum::<u64>()
                    })
                    .into_iter()
                    .sum::<u64>()
            })
            .into_iter()
            .sum();
        let expect: u64 = (0..4u64)
            .flat_map(|i| (0..3u64).flat_map(move |j| (0..2u64).map(move |l| i + j + l)))
            .sum();
        assert_eq!(total, expect);
    }

    #[test]
    fn disjoint_slice_chunked_writes() {
        let n = 1000;
        let chunk = 64;
        let mut out = vec![0u64; n];
        let pool = ThreadPool::new(4);
        let slots = DisjointSlice::new(&mut out);
        assert_eq!(slots.len(), n);
        assert!(!slots.is_empty());
        let tasks = n.div_ceil(chunk);
        pool.run(tasks, |_w, t| {
            let (start, end) = (t * chunk, ((t + 1) * chunk).min(n));
            // SAFETY: chunks are disjoint by task index.
            let window = unsafe { slots.range_mut(start, end) };
            for (off, slot) in window.iter_mut().enumerate() {
                *slot = (start + off) as u64 + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 + 1);
        }
    }

    #[test]
    fn nested_distinct_pools() {
        // The coordinator pattern: outer repetition pool, inner per-job
        // pools. Nested *distinct* pools must compose without deadlock.
        let outer = ThreadPool::new(3);
        let sums = outer.map_indexed(6, |_w, i| {
            let inner = ThreadPool::new(2);
            inner
                .map_indexed(20, |_iw, j| (i * j) as u64)
                .into_iter()
                .sum::<u64>()
        });
        for (i, s) in sums.iter().enumerate() {
            assert_eq!(*s, (0..20).map(|j| (i * j) as u64).sum::<u64>());
        }
    }
}
