//! Shared deterministic thread pool — the runtime substrate for every
//! parallel phase of the multilevel pipeline (std-only; rayon/crossbeam
//! are not available offline, DESIGN.md §3).
//!
//! # The determinism contract
//!
//! Every pool primitive executes a **fixed logical schedule** whose
//! result is a pure function of its inputs, never of the thread count or
//! the OS scheduler:
//!
//! 1. Work is decomposed into tasks *before* dispatch, by the caller,
//!    using only input sizes (e.g. fixed-size node chunks). The
//!    decomposition must not depend on [`ThreadPool::threads`].
//! 2. Tasks are claimed dynamically (idle workers steal the next chunk
//!    index from a shared counter — cheap work stealing), but each task
//!    writes only to its own result slot, so *which* worker ran a task
//!    is unobservable.
//! 3. Any randomness inside a task comes from an RNG stream seeded by
//!    the task index (plus a caller-provided seed), never from a
//!    worker-local or time-derived source.
//! 4. Reductions over task results happen on the caller in task-index
//!    order.
//!
//! Under this contract `threads = 1` and `threads = N` produce
//! bit-identical results — the invariant `rust/tests/determinism.rs`
//! enforces for the whole partitioning pipeline ("same seed + same
//! config ⇒ byte-identical partition, regardless of thread count").
//!
//! # Implementation notes
//!
//! A pool of `threads` has `threads - 1` background workers; the calling
//! thread participates as worker 0, so `threads = 1` runs everything
//! inline (one uncontended lock, no worker dispatch). One job is active
//! at a time — `run` serializes through an internal lock on *every*
//! path, including the inline one, because the `WorkerLocal` contract
//! (at most one task per worker id) must hold even for concurrent
//! `run` calls on a shared pool. Tasks must therefore never submit to
//! their *own* pool (nested use of a *different* pool is fine — the
//! coordinator's repetition pool runs partitioners that own scoring
//! pools).
//!
//! Borrowed closures are handed to the long-lived workers by erasing the
//! closure lifetime. Soundness: `run` does not return until `remaining`
//! hits zero, i.e. until every claimed task has finished; workers that
//! observe the job afterwards only perform a failed claim
//! (`next >= count`) and never touch the closure again. Panics inside
//! tasks are caught per task (a panicking job must not take the worker —
//! and every later job — down) and re-raised on the caller after the
//! job drains.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// One in-flight job: a lifetime-erased task closure plus claim/progress
/// counters. Held in an `Arc` so late-waking workers can do a failed
/// claim safely after the job completed.
struct JobCtrl {
    /// `f(worker, task)` — lifetime-erased borrow of the caller's
    /// closure; only dereferenced for successfully claimed task indices.
    task: &'static (dyn Fn(usize, usize) + Sync),
    count: usize,
    next: AtomicUsize,
    remaining: AtomicUsize,
    panicked: AtomicBool,
}

struct PoolState {
    job: Option<Arc<JobCtrl>>,
    /// Bumped per job so a worker never re-enters a job it has finished.
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new job (or shutdown).
    work_cv: Condvar,
    /// The caller waits here for `remaining == 0`.
    done_cv: Condvar,
}

/// Lock that survives poisoning: a panicking *caller* (task panics are
/// re-raised after the job drains) must not brick the pool for later
/// jobs.
fn lock(m: &Mutex<PoolState>) -> MutexGuard<'_, PoolState> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Deterministic work-sharing thread pool. See the module docs for the
/// determinism contract.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Serializes `run` calls: a single job slot is active at a time.
    run_lock: Mutex<()>,
}

impl ThreadPool {
    /// Create a pool of `threads` total workers (including the calling
    /// thread). `0` means [`std::thread::available_parallelism`];
    /// `1` means fully inline sequential execution.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
        } else {
            threads
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|id| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("sclap-pool-{id}"))
                    .spawn(move || worker_loop(shared, id))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            shared,
            workers,
            threads,
            run_lock: Mutex::new(()),
        }
    }

    /// Total worker count, including the calling thread.
    #[inline]
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(worker, task)` for every `task in 0..count`, blocking
    /// until all tasks finished. `worker` is a stable id in
    /// `0..threads()` — at most one task runs per worker id at a time,
    /// so it may index caller-owned scratch (see [`WorkerLocal`]).
    ///
    /// Tasks are claimed in index order from a shared counter; per the
    /// module contract, `f`'s effect must depend only on `task`.
    /// Panics (once, after the job drains) if any task panicked.
    pub fn run<F>(&self, count: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if count == 0 {
            return;
        }
        // One job at a time — also across the inline fast path below:
        // WorkerLocal's &mut-per-worker-id contract relies on worker id
        // 0 (the caller slot) never being active twice concurrently.
        let _serial = self
            .run_lock
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        if self.workers.is_empty() || count == 1 {
            // Sequential fast path: same schedule, no worker dispatch.
            for i in 0..count {
                f(0, i);
            }
            return;
        }

        // Erase the closure lifetime; see module docs for the soundness
        // argument (no dereference after `remaining == 0`).
        let task: &(dyn Fn(usize, usize) + Sync) = &f;
        let task: &'static (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(task) };
        let ctrl = Arc::new(JobCtrl {
            task,
            count,
            next: AtomicUsize::new(0),
            remaining: AtomicUsize::new(count),
            panicked: AtomicBool::new(false),
        });

        {
            let mut st = lock(&self.shared.state);
            st.epoch += 1;
            st.job = Some(ctrl.clone());
            self.shared.work_cv.notify_all();
        }

        // The caller is worker 0.
        work_on(&ctrl, 0, &self.shared);

        let mut st = lock(&self.shared.state);
        while ctrl.remaining.load(Ordering::Acquire) != 0 {
            st = self
                .shared
                .done_cv
                .wait(st)
                .unwrap_or_else(|p| p.into_inner());
        }
        st.job = None;
        drop(st);

        if ctrl.panicked.load(Ordering::Relaxed) {
            panic!("sclap::util::pool: a pool task panicked (see stderr above)");
        }
    }

    /// Deterministic parallel map: `out[i] = f(worker, i)`, results in
    /// task order regardless of scheduling.
    pub fn map_indexed<R, F>(&self, count: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, usize) -> R + Sync,
    {
        let mut out: Vec<Option<R>> = Vec::with_capacity(count);
        out.resize_with(count, || None);
        let slots = SendPtr(out.as_mut_ptr());
        self.run(count, |worker, i| {
            let r = f(worker, i);
            // SAFETY: each task index is claimed exactly once, so slot
            // `i` is written by exactly one thread; `out` outlives `run`
            // (which blocks until every task completed).
            unsafe { *slots.0.add(i) = Some(r) };
        });
        out.into_iter()
            .map(|r| r.expect("pool task completed"))
            .collect()
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer courier for disjoint slot writes from pool tasks.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

fn worker_loop(shared: Arc<Shared>, worker: usize) {
    let mut last_epoch = 0u64;
    loop {
        let ctrl = {
            let mut st = lock(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    if let Some(ctrl) = &st.job {
                        last_epoch = st.epoch;
                        break ctrl.clone();
                    }
                }
                st = shared
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
        };
        work_on(&ctrl, worker, &shared);
    }
}

/// Claim-and-execute loop shared by workers and the calling thread.
fn work_on(ctrl: &JobCtrl, worker: usize, shared: &Shared) {
    loop {
        let i = ctrl.next.fetch_add(1, Ordering::Relaxed);
        if i >= ctrl.count {
            return;
        }
        let result = catch_unwind(AssertUnwindSafe(|| (ctrl.task)(worker, i)));
        if result.is_err() {
            // Context for batch operators: which task blew up (callers
            // add their own domain context, e.g. the coordinator prints
            // the repetition seed before rethrowing).
            eprintln!("sclap pool worker {worker}: task {i} panicked");
            ctrl.panicked.store(true, Ordering::Relaxed);
        }
        if ctrl.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Last task: wake the caller. Lock pairs the notify with the
            // caller's checked wait so the wakeup cannot be lost.
            let _st = lock(&shared.state);
            shared.done_cv.notify_all();
        }
    }
}

/// Per-worker mutable scratch for pool tasks (e.g. a fast-reset
/// accumulator per worker instead of one allocation per chunk).
///
/// # Safety contract
///
/// [`WorkerLocal::get_mut`] hands out `&mut T` indexed by the worker id
/// a pool primitive passed to the task closure. The pool guarantees at
/// most one task runs per worker id at a time, which makes the access
/// exclusive. Do not call `get_mut` with anything other than the worker
/// id of the current task.
pub struct WorkerLocal<T> {
    slots: Vec<std::cell::UnsafeCell<T>>,
}

// SAFETY: access is partitioned by worker id (one thread per id at a
// time, enforced by the pool); T crosses thread boundaries, hence Send.
unsafe impl<T: Send> Sync for WorkerLocal<T> {}

impl<T> WorkerLocal<T> {
    /// One slot per worker, built by `init` (called `workers` times).
    pub fn new<F: FnMut() -> T>(workers: usize, mut init: F) -> Self {
        WorkerLocal {
            slots: (0..workers.max(1))
                .map(|_| std::cell::UnsafeCell::new(init()))
                .collect(),
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether the scratch set is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Exclusive access to worker `worker`'s slot.
    ///
    /// # Safety
    /// `worker` must be the worker id passed by the pool to the calling
    /// task (or the pool must be otherwise quiescent); two simultaneous
    /// calls with the same id are undefined behavior.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, worker: usize) -> &mut T {
        &mut *self.slots[worker].get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = ThreadPool::new(4);
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run(n, |_w, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_indexed_preserves_order() {
        for threads in [1usize, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let out = pool.map_indexed(257, |_w, i| i * i);
            assert_eq!(out.len(), 257);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "threads={threads}");
            }
        }
    }

    #[test]
    fn results_identical_across_thread_counts() {
        // The determinism contract at the pool level: same task function,
        // different pool sizes, identical output.
        let compute = |i: usize| {
            let mut rng = crate::util::rng::Rng::new(i as u64);
            (0..10).map(|_| rng.next_u64()).fold(0u64, u64::wrapping_add)
        };
        let reference: Vec<u64> = (0..100).map(compute).collect();
        for threads in [1usize, 2, 3, 4, 8] {
            let pool = ThreadPool::new(threads);
            let out = pool.map_indexed(100, |_w, i| compute(i));
            assert_eq!(out, reference, "threads={threads}");
        }
    }

    #[test]
    fn worker_ids_are_in_range_and_exclusive() {
        let threads = 4;
        let pool = ThreadPool::new(threads);
        let in_use: Vec<AtomicUsize> = (0..threads).map(|_| AtomicUsize::new(0)).collect();
        pool.run(500, |w, _i| {
            assert!(w < threads);
            let prev = in_use[w].fetch_add(1, Ordering::SeqCst);
            assert_eq!(prev, 0, "worker id {w} used concurrently");
            std::thread::yield_now();
            in_use[w].fetch_sub(1, Ordering::SeqCst);
        });
    }

    #[test]
    fn worker_local_scratch_accumulates() {
        let threads = 4;
        let pool = ThreadPool::new(threads);
        let scratch: WorkerLocal<u64> = WorkerLocal::new(threads, || 0);
        pool.run(100, |w, i| {
            let slot = unsafe { scratch.get_mut(w) };
            *slot += i as u64;
        });
        let total: u64 = (0..threads)
            .map(|w| unsafe { *scratch.get_mut(w) })
            .sum();
        assert_eq!(total, (0..100u64).sum());
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, |_w, i| {
                assert!(i != 7, "task 7 exploded");
            });
        }));
        assert!(r.is_err());
        // The pool must still execute later jobs.
        let out = pool.map_indexed(8, |_w, i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn zero_tasks_and_auto_threads() {
        let pool = ThreadPool::new(0);
        assert!(pool.threads() >= 1);
        pool.run(0, |_w, _i| panic!("must not run"));
    }

    #[test]
    fn single_thread_is_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let main_id = std::thread::current().id();
        pool.run(10, |w, _i| {
            assert_eq!(w, 0);
            assert_eq!(std::thread::current().id(), main_id);
        });
    }

    #[test]
    fn drop_joins_quickly() {
        let pool = ThreadPool::new(6);
        pool.run(10, |_w, _i| {});
        drop(pool); // must not hang
    }

    #[test]
    fn nested_distinct_pools() {
        // The coordinator pattern: outer repetition pool, inner per-job
        // pools. Nested *distinct* pools must compose without deadlock.
        let outer = ThreadPool::new(3);
        let sums = outer.map_indexed(6, |_w, i| {
            let inner = ThreadPool::new(2);
            inner
                .map_indexed(20, |_iw, j| (i * j) as u64)
                .into_iter()
                .sum::<u64>()
        });
        for (i, s) in sums.iter().enumerate() {
            assert_eq!(*s, (0..20).map(|j| (i * j) as u64).sum::<u64>());
        }
    }
}
