//! `ExecutionCtx` — the one shared execution context threaded through
//! every phase of the multilevel pipeline.
//!
//! Before this existed each layer owned its own runtime state: the
//! coordinator created a repetition pool, every `MultilevelPartitioner`
//! created a scoring pool, and the two composed only through a
//! "nested-pool guard" (`threads = 0 ⇒ 1` inside repetition jobs) that
//! bounded oversubscription instead of eliminating it. `ExecutionCtx`
//! replaces all of that with a single handle holding:
//!
//! - **one shared [`ThreadPool`]** — the coordinator creates the one
//!   process pool and hands it down; nested phases re-enter the same
//!   pool and run inline (see the re-entrancy notes in `util::pool`),
//!   so total live worker threads never exceed the configured cap;
//! - **deterministic RNG-stream derivation** — [`derive_seed`] gives
//!   every phase, split branch, or scoring chunk its own independent
//!   stream as a pure function of (seed, tag), never of the executing
//!   thread;
//! - **a timer/stats sink** — phases [`record`](ExecutionCtx::record)
//!   wall-clock into a shared table so the coordinator and benches can
//!   report a per-phase breakdown without threading timers through
//!   every signature.
//!
//! The context never influences *results*: the pool obeys the
//! thread-count-invariance contract, and the seed derivation is pure.
//! It only changes wall-clock and observability.

use crate::obs::metrics::MetricsRegistry;
use crate::obs::trace::Tracer;
use crate::partitioning::workspace::VcycleWorkspace;
use crate::util::cancel::{self, CancelScope, CancelToken};
use crate::util::pool::ThreadPool;
use crate::util::rng::splitmix64;
use std::sync::{Arc, Mutex};

pub use crate::obs::metrics::PhaseStat;

/// Derive an independent seed for a tagged sub-stream. Pure function of
/// `(seed, tag)` — the backbone of deterministic parallelism: a split
/// branch, scoring chunk, or repetition derives its stream from its
/// *position in the logical schedule*, never from the executing worker.
/// Built on the one [`splitmix64`] mixer `util::rng` also uses for seed
/// expansion.
#[inline]
pub fn derive_seed(seed: u64, tag: u64) -> u64 {
    splitmix64(seed ^ splitmix64(tag))
}

/// Shared execution context: one pool plus the observability handles
/// (stream derivation is the sibling [`derive_seed`] — a free function,
/// since it needs no shared state). Cheap to share via `Arc`; see the
/// module docs for what it replaces.
///
/// The phase-timing sink that used to live here is now a view over the
/// context's [`MetricsRegistry`] (`obs::metrics`) — one instrument
/// space shared by every layer built on this context (queue, cache,
/// net server), so the stdin and TCP serve paths report from the same
/// table and cannot drift.
pub struct ExecutionCtx {
    pool: Arc<ThreadPool>,
    metrics: Arc<MetricsRegistry>,
    tracer: Mutex<Option<Arc<Tracer>>>,
    workspace: VcycleWorkspace,
}

impl ExecutionCtx {
    /// Context owning a fresh pool of `threads` workers (`0` = available
    /// parallelism, `1` = fully inline sequential execution).
    pub fn new(threads: usize) -> Self {
        Self::with_pool(Arc::new(ThreadPool::new(threads)))
    }

    /// Fully sequential context (a 1-thread pool spawns no OS threads) —
    /// the zero-cost fallback for inputs too small to amortize dispatch.
    /// Results are identical to any other pool size by the pool's
    /// thread-count-invariance contract.
    pub fn sequential() -> Self {
        Self::new(1)
    }

    /// Context wrapping an existing shared pool (the coordinator handoff
    /// path: one process pool through every phase).
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        let workspace = VcycleWorkspace::new(pool.threads());
        ExecutionCtx {
            pool,
            metrics: Arc::new(MetricsRegistry::new()),
            tracer: Mutex::new(None),
            workspace,
        }
    }

    /// The shared worker pool.
    #[inline]
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Total worker count of the shared pool (including the caller).
    #[inline]
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The reusable multilevel scratch pool shared by every phase on
    /// this context — one arena shard per pool worker; leases hand out
    /// cleared-but-capacitated buffers (see `partitioning::workspace`).
    #[inline]
    pub fn workspace(&self) -> &VcycleWorkspace {
        &self.workspace
    }

    /// The context's metrics registry — the one instrument space every
    /// layer built on this context shares (`obs::metrics`).
    #[inline]
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Attach a tracer: subsequent repetitions entered on this context
    /// record spans/counters into it (`obs::trace`). Attaching (or
    /// never attaching) a tracer must not change results — only the
    /// trace output exists or not.
    pub fn set_tracer(&self, tracer: Arc<Tracer>) {
        *self.tracer.lock().unwrap_or_else(|p| p.into_inner()) = Some(tracer);
    }

    /// The attached tracer, if any. Cloning the `Arc` here happens once
    /// per repetition (track enter), never on the event hot path.
    pub fn tracer(&self) -> Option<Arc<Tracer>> {
        self.tracer.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Enter `token` as the ambient cancellation token for work run on
    /// this context's thread until the returned scope drops — the
    /// cancellation sibling of the tracer's track enter. Checkpoints in
    /// the pipeline ([`crate::util::cancel::checkpoint`]) and the
    /// pool's task boundaries poll it; a token that never fires changes
    /// no result byte. Tokens are hierarchical: the scheduler enters
    /// one [`CancelToken::child`] per repetition, so cancelling the
    /// request token cancels every repetition.
    pub fn cancel_scope(&self, token: CancelToken) -> CancelScope {
        cancel::enter(token)
    }

    /// Accumulate `seconds` of wall-clock into the named phase (a thin
    /// view over [`metrics`](Self::metrics); levelless — see
    /// [`record_level`](Self::record_level)).
    pub fn record(&self, phase: &'static str, seconds: f64) {
        self.metrics.record_phase(phase, None, seconds);
    }

    /// [`record`](Self::record) attributed to one hierarchy level, so
    /// drivers that reuse a phase name across levels no longer collapse
    /// into one bucket. The flat [`phase_stats`](Self::phase_stats)
    /// still aggregates across levels.
    pub fn record_level(&self, phase: &'static str, level: u32, seconds: f64) {
        self.metrics.record_phase(phase, Some(level), seconds);
    }

    /// Snapshot of the phase-timing table, aggregated across levels and
    /// sorted by phase name (deterministic iteration order).
    pub fn phase_stats(&self) -> Vec<(&'static str, PhaseStat)> {
        self.metrics.phase_stats()
    }

    /// The per-level phase view: `(name, level)` keys verbatim.
    pub fn phase_stats_by_level(&self) -> Vec<((&'static str, Option<u32>), PhaseStat)> {
        self.metrics.phase_stats_by_level()
    }
}

impl std::fmt::Debug for ExecutionCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecutionCtx")
            .field("threads", &self.threads())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_pure_and_spreads() {
        assert_eq!(derive_seed(1, 2), derive_seed(1, 2));
        assert_ne!(derive_seed(1, 2), derive_seed(1, 3));
        assert_ne!(derive_seed(1, 2), derive_seed(2, 2));
        // sibling branches of a split path get distinct streams
        assert_ne!(derive_seed(7, 2), derive_seed(7, 5));
    }

    #[test]
    fn derived_streams_are_independent() {
        use crate::util::rng::Rng;
        let mut a = Rng::new(derive_seed(42, 1));
        let mut b = Rng::new(derive_seed(42, 2));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
        // ...and reproducible
        let mut a2 = Rng::new(derive_seed(42, 1));
        let mut a3 = Rng::new(derive_seed(42, 1));
        for _ in 0..32 {
            assert_eq!(a2.next_u64(), a3.next_u64());
        }
    }

    #[test]
    fn stats_sink_accumulates() {
        let ctx = ExecutionCtx::sequential();
        ctx.record("coarsening", 0.5);
        ctx.record("coarsening", 0.25);
        ctx.record("initial", 1.0);
        let stats = ctx.phase_stats();
        assert_eq!(stats.len(), 2);
        let (name, s) = stats[0];
        assert_eq!(name, "coarsening");
        assert_eq!(s.calls, 2);
        assert!((s.seconds - 0.75).abs() < 1e-12);
    }

    #[test]
    fn per_level_records_stay_apart() {
        let ctx = ExecutionCtx::sequential();
        ctx.record_level("uncoarsening", 0, 0.5);
        ctx.record_level("uncoarsening", 1, 0.25);
        ctx.record_level("uncoarsening", 1, 0.25);
        let by_level = ctx.phase_stats_by_level();
        assert_eq!(by_level.len(), 2);
        assert_eq!(by_level[1].0, ("uncoarsening", Some(1)));
        assert_eq!(by_level[1].1.calls, 2);
        // The flat view still aggregates (the pre-registry shape).
        let flat = ctx.phase_stats();
        assert_eq!(flat.len(), 1);
        assert_eq!(flat[0].1.calls, 3);
        assert!((flat[0].1.seconds - 1.0).abs() < 1e-12);
    }

    #[test]
    fn with_pool_shares() {
        let pool = Arc::new(ThreadPool::new(2));
        let ctx = ExecutionCtx::with_pool(pool.clone());
        assert_eq!(ctx.threads(), 2);
        let out = ctx.pool().map_indexed(10, |_w, i| i * 2);
        assert_eq!(out[9], 18);
    }
}
