//! Deterministic cooperative cancellation.
//!
//! A [`CancelToken`] is a cheap, cloneable flag that long-running work
//! polls at natural checkpoints (pool task boundaries, LPA/FM rounds,
//! contraction passes, V-cycle levels, external levels). Tokens form a
//! shallow hierarchy: a request token is the parent of one child token
//! per repetition ([`CancelToken::child`]), so firing the request
//! cancels every repetition without the scheduler tracking them
//! individually.
//!
//! The governing invariant (same contract shape as tracing): **a token
//! that never fires changes no result byte.** Checkpoints only act on a
//! fired token; polling an unfired token is one relaxed atomic load
//! (plus one parent load, plus one clock read only when a deadline was
//! armed), so the partitioning pipeline is bit-identical with
//! cancellation compiled in, ambient, and dormant.
//!
//! # The ambient token and `checkpoint()`
//!
//! Like the tracer's thread-local track, the *current* token is
//! ambient: the scheduler enters a repetition's child token with
//! [`enter`] (a RAII scope), and every checkpoint in the pipeline calls
//! the free function [`checkpoint`] without any signature threading.
//! When the ambient token has fired, `checkpoint()` unwinds with a
//! typed [`Cancelled`] panic payload; the repetition boundary (the
//! scheduler's per-unit `catch_unwind`, the pool's per-task harness)
//! downcasts it into a structured cancelled outcome instead of an
//! error. Code with no ambient token (direct library calls, the CLI
//! `partition` path) polls nothing and can never unwind here.
//!
//! The thread pool cooperates at task granularity:
//! [`ThreadPool::run`](crate::util::pool::ThreadPool::run) captures the
//! submitter's ambient token into the job, workers re-enter it around
//! each task (so nested checkpoints see it) and skip still-unclaimed
//! tasks once it fires — the job drains normally and `run` re-raises
//! the typed payload on the submitting thread.
//!
//! # Reasons
//!
//! [`CancelReason`] records *why* work stopped — a request deadline
//! ([`CancelToken::set_deadline`], wired from the `timeout_ms=` spec
//! key), a client disconnect, losing an ensemble race, or an abandoned
//! ticket. The first fire wins; later fires (and the deadline) never
//! overwrite it. The reason is rendered on the wire as
//! `{"status":"cancelled","reason":"…"}`.

use std::cell::RefCell;
use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// Why a computation was cancelled. Rendered lowercase on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelReason {
    /// The request's `timeout_ms=` deadline passed.
    Timeout,
    /// The submitting client's connection went away.
    Disconnect,
    /// An ensemble race decided for a different config.
    RaceLost,
    /// The submitter dropped its ticket before the result existed.
    Abandoned,
}

impl CancelReason {
    /// Stable wire string (`{"status":"cancelled","reason":…}`).
    pub fn as_str(self) -> &'static str {
        match self {
            CancelReason::Timeout => "timeout",
            CancelReason::Disconnect => "disconnect",
            CancelReason::RaceLost => "race_lost",
            CancelReason::Abandoned => "abandoned",
        }
    }

    /// The per-reason metrics counter name (counter names must be
    /// `&'static str`, so each reason owns a fixed counter).
    pub fn counter_name(self) -> &'static str {
        match self {
            CancelReason::Timeout => "cancel_reason_timeout",
            CancelReason::Disconnect => "cancel_reason_disconnect",
            CancelReason::RaceLost => "cancel_reason_race_lost",
            CancelReason::Abandoned => "cancel_reason_abandoned",
        }
    }

    pub(crate) fn code(self) -> u8 {
        match self {
            CancelReason::Timeout => 1,
            CancelReason::Disconnect => 2,
            CancelReason::RaceLost => 3,
            CancelReason::Abandoned => 4,
        }
    }

    pub(crate) fn from_code(code: u8) -> Option<CancelReason> {
        match code {
            1 => Some(CancelReason::Timeout),
            2 => Some(CancelReason::Disconnect),
            3 => Some(CancelReason::RaceLost),
            4 => Some(CancelReason::Abandoned),
            _ => None,
        }
    }
}

impl fmt::Display for CancelReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The typed panic payload [`checkpoint`] unwinds with. Boundaries
/// (`queue::scheduler::run_unit`, the pool's task harness) downcast the
/// caught payload to this type to tell cancellation apart from a bug.
#[derive(Debug, Clone, Copy)]
pub struct Cancelled {
    pub reason: CancelReason,
}

struct Inner {
    /// 0 = live; otherwise a [`CancelReason`] code. First store wins.
    state: AtomicU8,
    /// Armed at most once ([`CancelToken::set_deadline`]); checked by
    /// every poll, firing `Timeout` the first time the clock passes it.
    deadline: OnceLock<Instant>,
    /// Request token for repetition children (depth ≤ 1 in practice).
    parent: Option<Arc<Inner>>,
}

impl Inner {
    fn fire(&self, reason: CancelReason) {
        // First reason wins; a later deadline never overwrites an
        // explicit fire (and vice versa).
        let _ = self
            .state
            .compare_exchange(0, reason.code(), Ordering::AcqRel, Ordering::Acquire);
    }

    fn poll(&self) -> Option<CancelReason> {
        let state = self.state.load(Ordering::Acquire);
        if let Some(reason) = CancelReason::from_code(state) {
            return Some(reason);
        }
        if let Some(deadline) = self.deadline.get() {
            if Instant::now() >= *deadline {
                self.fire(CancelReason::Timeout);
                return CancelReason::from_code(self.state.load(Ordering::Acquire));
            }
        }
        if let Some(parent) = &self.parent {
            if let Some(reason) = parent.poll() {
                // Cache the verdict locally so later polls stop walking.
                self.fire(reason);
                return CancelReason::from_code(self.state.load(Ordering::Acquire));
            }
        }
        None
    }
}

/// A cheap, cloneable cancellation flag (an `Arc` of two atomics).
/// Clones observe the same fire; [`child`](CancelToken::child) tokens
/// additionally observe their parent's.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken::new()
    }
}

impl fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CancelToken")
            .field("fired", &self.poll())
            .finish()
    }
}

impl CancelToken {
    /// A live token that will never fire unless asked to.
    pub fn new() -> Self {
        CancelToken {
            inner: Arc::new(Inner {
                state: AtomicU8::new(0),
                deadline: OnceLock::new(),
                parent: None,
            }),
        }
    }

    /// A child token: fires when either it or its parent fires. The
    /// scheduler hands one child per repetition, so cancelling a
    /// request cancels all its repetitions.
    pub fn child(&self) -> CancelToken {
        CancelToken {
            inner: Arc::new(Inner {
                state: AtomicU8::new(0),
                deadline: OnceLock::new(),
                parent: Some(self.inner.clone()),
            }),
        }
    }

    /// Fire the token. The first reason wins; firing an already-fired
    /// token is a no-op.
    pub fn fire(&self, reason: CancelReason) {
        self.inner.fire(reason);
    }

    /// Arm a wall-clock deadline (at most once). Any poll past the
    /// deadline fires `Timeout`.
    pub fn set_deadline(&self, deadline: Instant) {
        let _ = self.inner.deadline.set(deadline);
    }

    /// Has the token (or an ancestor, or the deadline) fired?
    pub fn poll(&self) -> Option<CancelReason> {
        self.inner.poll()
    }
}

thread_local! {
    /// The ambient token stack — entered per repetition by the
    /// scheduler and re-entered by pool workers around each task.
    static AMBIENT: RefCell<Vec<CancelToken>> = const { RefCell::new(Vec::new()) };
}

/// RAII guard for an ambient token scope (see [`enter`]).
pub struct CancelScope {
    _private: (),
}

impl Drop for CancelScope {
    fn drop(&mut self) {
        AMBIENT.with(|stack| {
            stack.borrow_mut().pop();
        });
    }
}

/// Make `token` the ambient token on this thread until the returned
/// scope drops. Scopes nest; the innermost token is the one
/// [`checkpoint`] polls.
pub fn enter(token: CancelToken) -> CancelScope {
    AMBIENT.with(|stack| stack.borrow_mut().push(token));
    CancelScope { _private: () }
}

/// The innermost ambient token, if any (cloned — used by the pool to
/// carry the submitter's token into its job).
pub fn current() -> Option<CancelToken> {
    AMBIENT.with(|stack| stack.borrow().last().cloned())
}

/// Poll the ambient token without unwinding. `None` when no token is
/// ambient or it has not fired.
pub fn ambient_poll() -> Option<CancelReason> {
    AMBIENT.with(|stack| stack.borrow().last().map(|t| t.poll()))?
}

/// The cooperative checkpoint: if the ambient token has fired, emit a
/// `cancelled` trace counter (so Perfetto shows where the repetition
/// stopped) and unwind with the typed [`Cancelled`] payload. With no
/// ambient token, or an unfired one, this is a no-op — the pipeline is
/// byte-identical.
#[inline]
pub fn checkpoint() {
    if let Some(reason) = ambient_poll() {
        crate::obs::trace::counter("cancelled", &[("reason", reason.code() as i64)]);
        std::panic::panic_any(Cancelled { reason });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn unfired_token_polls_none() {
        let t = CancelToken::new();
        assert_eq!(t.poll(), None);
        assert_eq!(t.child().poll(), None);
    }

    #[test]
    fn first_fire_wins() {
        let t = CancelToken::new();
        t.fire(CancelReason::Disconnect);
        t.fire(CancelReason::Timeout);
        assert_eq!(t.poll(), Some(CancelReason::Disconnect));
    }

    #[test]
    fn child_sees_parent_fire_and_caches_it() {
        let parent = CancelToken::new();
        let child = parent.child();
        assert_eq!(child.poll(), None);
        parent.fire(CancelReason::RaceLost);
        assert_eq!(child.poll(), Some(CancelReason::RaceLost));
        // A child's own earlier fire wins over a later parent fire.
        let parent2 = CancelToken::new();
        let child2 = parent2.child();
        child2.fire(CancelReason::Abandoned);
        parent2.fire(CancelReason::Timeout);
        assert_eq!(child2.poll(), Some(CancelReason::Abandoned));
        assert_eq!(parent2.poll(), Some(CancelReason::Timeout));
    }

    #[test]
    fn deadline_fires_timeout() {
        let t = CancelToken::new();
        t.set_deadline(Instant::now() - Duration::from_millis(1));
        assert_eq!(t.poll(), Some(CancelReason::Timeout));
        // Deadline on the parent reaches children too.
        let p = CancelToken::new();
        let c = p.child();
        p.set_deadline(Instant::now() - Duration::from_millis(1));
        assert_eq!(c.poll(), Some(CancelReason::Timeout));
    }

    #[test]
    fn ambient_scope_nests_and_restores() {
        assert!(current().is_none());
        let outer = CancelToken::new();
        let inner = CancelToken::new();
        inner.fire(CancelReason::Timeout);
        let _a = enter(outer.clone());
        assert_eq!(ambient_poll(), None);
        {
            let _b = enter(inner);
            assert_eq!(ambient_poll(), Some(CancelReason::Timeout));
        }
        assert_eq!(ambient_poll(), None);
        drop(_a);
        assert!(current().is_none());
    }

    #[test]
    fn checkpoint_unwinds_with_typed_payload() {
        let t = CancelToken::new();
        t.fire(CancelReason::Disconnect);
        let _scope = enter(t);
        let err = std::panic::catch_unwind(checkpoint).unwrap_err();
        let cancelled = err.downcast_ref::<Cancelled>().expect("typed payload");
        assert_eq!(cancelled.reason, CancelReason::Disconnect);
    }

    #[test]
    fn checkpoint_without_ambient_token_is_a_no_op() {
        checkpoint(); // must not panic
    }
}
