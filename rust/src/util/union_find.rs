//! Disjoint-set forest (union by rank + path halving).
//!
//! Used by the ensemble overlay clustering (connected components of the
//! graph minus the union of cut edges, §4 of the paper) and by graph
//! connectivity statistics.

#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
    components: usize,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize);
        UnionFind {
            parent: (0..n as u32).collect(),
            rank: vec![0; n],
            components: n,
        }
    }

    #[inline]
    pub fn find(&mut self, mut x: usize) -> usize {
        // Path halving.
        while self.parent[x] as usize != x {
            let gp = self.parent[self.parent[x] as usize];
            self.parent[x] = gp;
            x = gp as usize;
        }
        x
    }

    /// Union the sets of `a` and `b`; returns true if they were separate.
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (hi, lo) = if self.rank[ra] >= self.rank[rb] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[lo] = hi as u32;
        if self.rank[hi] == self.rank[lo] {
            self.rank[hi] += 1;
        }
        self.components -= 1;
        true
    }

    #[inline]
    pub fn same(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }

    pub fn component_count(&self) -> usize {
        self.components
    }

    /// Relabel roots to dense ids `0..components`; returns per-element ids.
    pub fn dense_labels(&mut self) -> Vec<u32> {
        let n = self.parent.len();
        let mut label = vec![u32::MAX; n];
        let mut next = 0u32;
        let mut out = vec![0u32; n];
        for i in 0..n {
            let r = self.find(i);
            if label[r] == u32::MAX {
                label[r] = next;
                next += 1;
            }
            out[i] = label[r];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_find_basic() {
        let mut uf = UnionFind::new(6);
        assert_eq!(uf.component_count(), 6);
        assert!(uf.union(0, 1));
        assert!(uf.union(2, 3));
        assert!(!uf.union(1, 0));
        assert!(uf.union(0, 2));
        assert_eq!(uf.component_count(), 3);
        assert!(uf.same(1, 3));
        assert!(!uf.same(1, 4));
    }

    #[test]
    fn dense_labels_are_consistent() {
        let mut uf = UnionFind::new(5);
        uf.union(0, 4);
        uf.union(1, 2);
        let labels = uf.dense_labels();
        assert_eq!(labels[0], labels[4]);
        assert_eq!(labels[1], labels[2]);
        assert_ne!(labels[0], labels[1]);
        assert_ne!(labels[3], labels[0]);
        let max = *labels.iter().max().unwrap();
        assert_eq!(max as usize + 1, uf.component_count());
    }

    #[test]
    fn chain_union_single_component() {
        let n = 1000;
        let mut uf = UnionFind::new(n);
        for i in 1..n {
            uf.union(i - 1, i);
        }
        assert_eq!(uf.component_count(), 1);
        assert!(uf.same(0, n - 1));
    }
}
