//! Minimal JSON support, shared by every JSON producer and consumer in
//! the crate: string escaping for the emitters
//! (`bench::harness::JsonReport`, the serve result lines in
//! `coordinator::queue::spec`) and a small recursive-descent value
//! parser ([`parse_json`]) for the consumers (the network client in
//! `coordinator::net::client` and the wire-protocol tests), so
//! responses can be validated *structurally* instead of by string
//! comparison. Std-only (DESIGN.md §3 — no serde offline).

/// Escape a string for embedding inside a JSON string literal
/// (quotes, backslashes, and control characters per RFC 8259).
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One parsed JSON value. Objects preserve key order (and keep
/// duplicate keys — [`Json::get`] returns the first), which is exactly
/// what validating a deterministically-rendered response line needs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All JSON numbers, including integers (f64 holds every integer
    /// the emitters in this crate produce exactly up to 2^53).
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// First value under `key` when this is an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as an integer, when it is one exactly.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() <= 2f64.powi(53) => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Nesting bound for the recursive-descent parser: adversarial inputs
/// like `[[[[…` must error, not overflow the stack.
const MAX_DEPTH: usize = 128;

/// Parse one complete JSON value. Strict where it matters for a
/// protocol consumer: escapes (including `\uXXXX` with surrogate
/// pairs), full number grammar, no trailing garbage, bounded nesting
/// depth. Errors carry the byte offset.
pub fn parse_json(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let value = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(value)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, byte: u8) -> Result<(), String> {
        if self.peek() == Some(byte) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}",
                byte as char, self.pos
            ))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(format!("unexpected {:?} at byte {}", c as char, self.pos)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value(depth + 1)?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: run of plain UTF-8 up to the next quote/escape.
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            if self.pos > start {
                // Safe: the input is a &str and we only stopped on
                // ASCII boundaries, so this slice is valid UTF-8.
                out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    out.push(self.escape()?);
                }
                Some(_) => {
                    return Err(format!("raw control character at byte {}", self.pos))
                }
                None => return Err("unterminated string".to_string()),
            }
        }
    }

    fn escape(&mut self) -> Result<char, String> {
        let c = self
            .peek()
            .ok_or_else(|| "unterminated escape".to_string())?;
        self.pos += 1;
        Ok(match c {
            b'"' => '"',
            b'\\' => '\\',
            b'/' => '/',
            b'b' => '\u{8}',
            b'f' => '\u{c}',
            b'n' => '\n',
            b'r' => '\r',
            b't' => '\t',
            b'u' => {
                let hi = self.hex4()?;
                if (0xD800..0xDC00).contains(&hi) {
                    // High surrogate: a low surrogate escape must follow.
                    if self.peek() == Some(b'\\') {
                        self.pos += 1;
                        self.expect(b'u')?;
                        let lo = self.hex4()?;
                        if !(0xDC00..0xE000).contains(&lo) {
                            return Err(format!("bad low surrogate before byte {}", self.pos));
                        }
                        let code = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                        char::from_u32(code)
                            .ok_or_else(|| format!("bad surrogate pair before byte {}", self.pos))?
                    } else {
                        return Err(format!("lone high surrogate before byte {}", self.pos));
                    }
                } else if (0xDC00..0xE000).contains(&hi) {
                    return Err(format!("lone low surrogate before byte {}", self.pos));
                } else {
                    char::from_u32(hi).expect("BMP code point outside surrogate range")
                }
            }
            other => return Err(format!("bad escape {:?} at byte {}", other as char, self.pos)),
        })
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let mut value = 0u32;
        for _ in 0..4 {
            let c = self
                .peek()
                .ok_or_else(|| "unterminated \\u escape".to_string())?;
            let digit = (c as char)
                .to_digit(16)
                .ok_or_else(|| format!("bad hex digit at byte {}", self.pos))?;
            value = value * 16 + digit;
            self.pos += 1;
        }
        Ok(value)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // Integer part: 0, or a nonzero-led digit run (no leading zeros).
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(format!("bad number at byte {start}")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(format!("bad number at byte {start}"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(format!("bad number at byte {start}"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_specials_and_controls() {
        assert_eq!(escape_json("plain"), "plain");
        assert_eq!(escape_json("a \"b\" \\ c"), "a \\\"b\\\" \\\\ c");
        assert_eq!(escape_json("x\ny\r\tz"), "x\\ny\\r\\tz");
        assert_eq!(escape_json("\u{1}"), "\\u0001");
        // non-ASCII passes through (JSON strings are UTF-8)
        assert_eq!(escape_json("ε=0.03"), "ε=0.03");
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse_json("null").unwrap(), Json::Null);
        assert_eq!(parse_json("true").unwrap(), Json::Bool(true));
        assert_eq!(parse_json(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse_json("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse_json("-0.5e2").unwrap(), Json::Num(-50.0));
        assert_eq!(parse_json("0").unwrap(), Json::Num(0.0));
        assert_eq!(parse_json("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse_json(r#"{"a":[1,{"b":"c"},[]],"d":{"e":null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().get("e"), Some(&Json::Null));
        assert_eq!(parse_json("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse_json("{}").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = parse_json("\"a \\\"b\\\" \\\\ \\n \\t \\u0041 \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("a \"b\" \\ \n \t A é"));
        // surrogate pair: U+1F600
        assert_eq!(
            parse_json("\"\\ud83d\\ude00\"").unwrap().as_str(),
            Some("😀")
        );
        // escape_json output parses back to the original
        let nasty = "quote \" slash \\ newline \n ctrl \u{1} ε";
        let parsed = parse_json(&format!("\"{}\"", escape_json(nasty))).unwrap();
        assert_eq!(parsed.as_str(), Some(nasty));
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in [
            "",
            "   ",
            "tru",
            "nul",
            "01",
            "1.",
            "1e",
            "+1",
            "-",
            "\"unterminated",
            "\"bad \\q escape\"",
            "\"lone \\ud800 surrogate\"",
            "\"raw \u{1} control\"",
            "[1,]",
            "[1 2]",
            "{\"a\"}",
            "{\"a\":}",
            "{a:1}",
            "{\"a\":1,}",
            "}",
        ] {
            assert!(parse_json(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse_json("1 2").is_err());
        assert!(parse_json("{} x").is_err());
        assert!(parse_json("[1]]").is_err());
        assert!(parse_json("null,").is_err());
        // whitespace is not garbage
        assert!(parse_json(" {\"a\":1} \n").is_ok());
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        let bomb = "[".repeat(100_000);
        assert!(parse_json(&bomb).is_err());
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(parse_json(&ok).is_ok());
    }

    #[test]
    fn duplicate_keys_kept_first_wins_on_get() {
        let v = parse_json(r#"{"k":1,"k":2}"#).unwrap();
        assert_eq!(v.get("k").unwrap().as_i64(), Some(1));
        match &v {
            Json::Obj(pairs) => assert_eq!(pairs.len(), 2),
            _ => panic!("object expected"),
        }
    }

    #[test]
    fn accessor_conversions() {
        assert_eq!(parse_json("7").unwrap().as_i64(), Some(7));
        assert_eq!(parse_json("7.5").unwrap().as_i64(), None);
        assert_eq!(parse_json("7").unwrap().as_str(), None);
        assert_eq!(parse_json("\"7\"").unwrap().as_f64(), None);
        assert_eq!(parse_json("true").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_a_real_result_line() {
        let line = "{\"id\":\"r1\",\"status\":\"ok\",\"n\":34,\"reps\":2,\"seeds\":[1,2],\
                    \"cuts\":[10,30],\"avg_cut\":20,\"best_cut\":10,\"infeasible_runs\":0,\
                    \"best_blocks_fnv\":\"32d748215c66e845\",\"cached\":true}";
        let v = parse_json(line).unwrap();
        assert_eq!(v.get("id").unwrap().as_str(), Some("r1"));
        assert_eq!(v.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(v.get("best_cut").unwrap().as_i64(), Some(10));
        assert_eq!(v.get("cached").unwrap().as_bool(), Some(true));
        let seeds: Vec<i64> = v
            .get("seeds")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|s| s.as_i64().unwrap())
            .collect();
        assert_eq!(seeds, vec![1, 2]);
    }
}
