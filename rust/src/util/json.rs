//! Minimal JSON string escaping, shared by every JSON emitter in the
//! crate (`bench::harness::JsonReport`, the serve result lines in
//! `coordinator::queue::spec`) so an escaping fix can never apply to
//! one emitter and miss another.

/// Escape a string for embedding inside a JSON string literal
/// (quotes, backslashes, and control characters per RFC 8259).
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_specials_and_controls() {
        assert_eq!(escape_json("plain"), "plain");
        assert_eq!(escape_json("a \"b\" \\ c"), "a \\\"b\\\" \\\\ c");
        assert_eq!(escape_json("x\ny\r\tz"), "x\\ny\\r\\tz");
        assert_eq!(escape_json("\u{1}"), "\\u0001");
        // non-ASCII passes through (JSON strings are UTF-8)
        assert_eq!(escape_json("ε=0.03"), "ε=0.03");
    }
}
