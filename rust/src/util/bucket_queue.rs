//! Bucket priority queue for FM-style refinement.
//!
//! Gains in Fiduccia–Mattheyses refinement are bounded integers
//! (|gain| ≤ max weighted degree), so the classic implementation keeps a
//! doubly linked list per gain value and a pointer to the maximum
//! non-empty bucket. All operations are O(1) except max-bucket pointer
//! decay, which amortizes over insertions.

/// Max-priority bucket queue over elements `0..n` with integer priorities
/// in `[-max_prio, +max_prio]`.
#[derive(Debug)]
pub struct BucketQueue {
    /// head of the intrusive list per bucket (offset priority), usize::MAX = empty
    buckets: Vec<usize>,
    next: Vec<usize>,
    prev: Vec<usize>,
    /// priority of each element, or `i64::MIN` if absent
    prio: Vec<i64>,
    max_prio: i64,
    max_bucket: usize,
    len: usize,
}

const NIL: usize = usize::MAX;

impl BucketQueue {
    /// `n` elements, priorities clamped to `[-max_prio, max_prio]`.
    pub fn new(n: usize, max_prio: i64) -> Self {
        let nb = (2 * max_prio + 1) as usize;
        BucketQueue {
            buckets: vec![NIL; nb],
            next: vec![NIL; n],
            prev: vec![NIL; n],
            prio: vec![i64::MIN; n],
            max_prio,
            max_bucket: 0,
            len: 0,
        }
    }

    #[inline]
    fn bucket_of(&self, p: i64) -> usize {
        (p.clamp(-self.max_prio, self.max_prio) + self.max_prio) as usize
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn contains(&self, x: usize) -> bool {
        self.prio[x] != i64::MIN
    }

    /// Current priority, if present.
    pub fn priority(&self, x: usize) -> Option<i64> {
        if self.contains(x) {
            Some(self.prio[x])
        } else {
            None
        }
    }

    /// Insert `x` with priority `p`; panics in debug if already present.
    pub fn push(&mut self, x: usize, p: i64) {
        debug_assert!(!self.contains(x));
        self.prio[x] = p.clamp(-self.max_prio, self.max_prio);
        let b = self.bucket_of(p);
        self.next[x] = self.buckets[b];
        self.prev[x] = NIL;
        if self.buckets[b] != NIL {
            self.prev[self.buckets[b]] = x;
        }
        self.buckets[b] = x;
        if b > self.max_bucket || self.len == 0 {
            self.max_bucket = b;
        }
        self.len += 1;
    }

    /// Remove `x` (no-op if absent).
    pub fn remove(&mut self, x: usize) {
        if !self.contains(x) {
            return;
        }
        let b = self.bucket_of(self.prio[x]);
        if self.prev[x] != NIL {
            self.next[self.prev[x]] = self.next[x];
        } else {
            self.buckets[b] = self.next[x];
        }
        if self.next[x] != NIL {
            self.prev[self.next[x]] = self.prev[x];
        }
        self.prio[x] = i64::MIN;
        self.len -= 1;
    }

    /// Change priority of a present element (or insert if absent).
    pub fn update(&mut self, x: usize, p: i64) {
        self.remove(x);
        self.push(x, p);
    }

    /// Pop the element with maximum priority.
    pub fn pop_max(&mut self) -> Option<(usize, i64)> {
        if self.len == 0 {
            return None;
        }
        while self.buckets[self.max_bucket] == NIL {
            debug_assert!(self.max_bucket > 0);
            self.max_bucket -= 1;
        }
        let x = self.buckets[self.max_bucket];
        let p = self.prio[x];
        self.remove(x);
        Some((x, p))
    }

    /// Peek the maximum priority without removing.
    pub fn peek_max(&mut self) -> Option<(usize, i64)> {
        if self.len == 0 {
            return None;
        }
        while self.buckets[self.max_bucket] == NIL {
            self.max_bucket -= 1;
        }
        let x = self.buckets[self.max_bucket];
        Some((x, self.prio[x]))
    }

    pub fn clear(&mut self) {
        if self.len == 0 {
            return;
        }
        self.buckets.fill(NIL);
        self.prio.fill(i64::MIN);
        self.max_bucket = 0;
        self.len = 0;
    }

    /// Re-dimension to `n` elements with priorities in
    /// `[-max_prio, max_prio]`, emptying the queue but reusing the
    /// backing allocations (no allocation when capacities suffice) —
    /// lets FM refinement keep one queue across passes and, via
    /// `util::arena`, across calls.
    pub fn reset(&mut self, n: usize, max_prio: i64) {
        let nb = (2 * max_prio + 1) as usize;
        self.buckets.clear();
        self.buckets.resize(nb, NIL);
        self.next.clear();
        self.next.resize(n, NIL);
        self.prev.clear();
        self.prev.resize(n, NIL);
        self.prio.clear();
        self.prio.resize(n, i64::MIN);
        self.max_prio = max_prio;
        self.max_bucket = 0;
        self.len = 0;
    }
}

impl crate::util::arena::Reusable for BucketQueue {
    fn fresh(hint: usize) -> Self {
        BucketQueue::new(hint, 8)
    }

    fn recycle(&mut self) {
        self.clear();
    }

    fn ensure(&mut self, hint: usize) {
        // The gain bound is per-use state a single lease hint cannot
        // carry, so lessees call `reset(n, max_prio)` right after
        // leasing; here we only guarantee element capacity so that
        // reset is allocation-free in the steady state.
        if self.next.len() < hint {
            let max_prio = self.max_prio;
            self.reset(hint, max_prio);
        }
    }

    fn footprint(&self) -> usize {
        (self.buckets.capacity() + self.next.capacity() + self.prev.capacity())
            * std::mem::size_of::<usize>()
            + self.prio.capacity() * std::mem::size_of::<i64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_order() {
        let mut q = BucketQueue::new(10, 100);
        q.push(0, 5);
        q.push(1, -3);
        q.push(2, 7);
        q.push(3, 7);
        assert_eq!(q.len(), 4);
        let (x, p) = q.pop_max().unwrap();
        assert_eq!(p, 7);
        assert!(x == 2 || x == 3);
        let (_, p) = q.pop_max().unwrap();
        assert_eq!(p, 7);
        assert_eq!(q.pop_max().unwrap(), (0, 5));
        assert_eq!(q.pop_max().unwrap(), (1, -3));
        assert!(q.pop_max().is_none());
    }

    #[test]
    fn update_moves_element() {
        let mut q = BucketQueue::new(4, 10);
        q.push(0, 1);
        q.push(1, 2);
        q.update(0, 9);
        assert_eq!(q.pop_max().unwrap(), (0, 9));
        assert_eq!(q.pop_max().unwrap(), (1, 2));
    }

    #[test]
    fn remove_absent_is_noop() {
        let mut q = BucketQueue::new(4, 10);
        q.push(2, 3);
        q.remove(1);
        assert_eq!(q.len(), 1);
        q.remove(2);
        q.remove(2);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn clamping_out_of_range_prio() {
        let mut q = BucketQueue::new(3, 5);
        q.push(0, 1000);
        q.push(1, -1000);
        assert_eq!(q.pop_max().unwrap(), (0, 5));
        assert_eq!(q.pop_max().unwrap(), (1, -5));
    }

    #[test]
    fn interleaved_stress_matches_reference() {
        use crate::util::rng::Rng;
        let n = 64;
        let mut q = BucketQueue::new(n, 50);
        let mut reference: Vec<Option<i64>> = vec![None; n];
        let mut rng = Rng::new(99);
        for _ in 0..5000 {
            let x = rng.below(n);
            match rng.below(3) {
                0 => {
                    if reference[x].is_none() {
                        let p = rng.range(0, 100) as i64 - 50;
                        q.push(x, p);
                        reference[x] = Some(p);
                    }
                }
                1 => {
                    q.remove(x);
                    reference[x] = None;
                }
                _ => {
                    if let Some((y, p)) = q.pop_max() {
                        let best = reference.iter().filter_map(|o| *o).max().unwrap();
                        assert_eq!(p, best);
                        assert_eq!(reference[y], Some(p));
                        reference[y] = None;
                    }
                }
            }
            assert_eq!(q.len(), reference.iter().filter(|o| o.is_some()).count());
        }
    }
}
