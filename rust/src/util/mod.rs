//! Shared algorithm-engineering substrate: deterministic RNG, fast-reset
//! accumulators, bucket queues, disjoint sets, timers, a minimal
//! property-testing harness, error plumbing, the deterministic thread
//! pool every parallel phase runs on, and the shared [`ExecutionCtx`]
//! (`exec`) that hands one pool + per-phase RNG streams + a stats sink
//! through every layer of the pipeline. All std-only (see DESIGN.md §3).

pub mod arena;
pub mod bucket_queue;
pub mod cancel;
pub mod error;
pub mod exec;
pub mod fast_reset;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod timer;
pub mod union_find;

pub use arena::{Arena, Lease};
pub use bucket_queue::BucketQueue;
pub use cancel::{CancelReason, CancelToken};
pub use error::{Context, Error};
pub use exec::ExecutionCtx;
pub use fast_reset::{BitVec, FastResetArray};
pub use pool::{ThreadPool, WorkerLocal};
pub use rng::Rng;
pub use timer::{Stats, Timer};
pub use union_find::UnionFind;
