//! Shared algorithm-engineering substrate: deterministic RNG, fast-reset
//! accumulators, bucket queues, disjoint sets, timers and a minimal
//! property-testing harness. All std-only (see DESIGN.md §3).

pub mod bucket_queue;
pub mod fast_reset;
pub mod proptest;
pub mod rng;
pub mod timer;
pub mod union_find;

pub use bucket_queue::BucketQueue;
pub use fast_reset::{BitVec, FastResetArray};
pub use rng::Rng;
pub use timer::{Stats, Timer};
pub use union_find::UnionFind;
