//! Table-formatting and aggregation helpers for the paper-reproduction
//! benches (criterion is unavailable offline; these benches are custom
//! `harness = false` binaries).

use crate::util::timer::Stats;

/// Common bench options parsed from `cargo bench -- [--full] [--reps N]`.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Quick mode (the DEFAULT): tiny instances, fewer repetitions, so a
    /// plain `cargo bench` finishes in CI time on one core. Pass
    /// `--full` (or `make bench-full`) for the paper's full protocol.
    pub quick: bool,
    pub reps: usize,
    /// Restrict k sweep (empty = default).
    pub ks: Vec<usize>,
}

impl BenchOpts {
    pub fn from_env() -> BenchOpts {
        let args: Vec<String> = std::env::args().collect();
        let quick = !args.iter().any(|a| a == "--full");
        let reps = args
            .iter()
            .position(|a| a == "--reps")
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(if quick { 3 } else { 10 });
        let ks = args
            .iter()
            .position(|a| a == "--k")
            .and_then(|i| args.get(i + 1))
            .map(|v| {
                v.split(',')
                    .filter_map(|t| t.parse().ok())
                    .collect::<Vec<usize>>()
            })
            .unwrap_or_default();
        BenchOpts { quick, reps, ks }
    }

    /// Paper §5 k sweep: 2, 4, 8, 16, 32, 64 (quick: 2, 8, 32).
    pub fn k_sweep(&self) -> Vec<usize> {
        if !self.ks.is_empty() {
            return self.ks.clone();
        }
        if self.quick {
            vec![2, 8, 32]
        } else {
            vec![2, 4, 8, 16, 32, 64]
        }
    }
}

/// Fixed-width table printer matching the paper's table style.
pub struct TableWriter {
    columns: Vec<(String, usize)>,
}

impl TableWriter {
    pub fn new(columns: &[(&str, usize)]) -> Self {
        let columns: Vec<(String, usize)> = columns
            .iter()
            .map(|(n, w)| (n.to_string(), (*w).max(n.len())))
            .collect();
        TableWriter { columns }
    }

    pub fn header(&self) {
        let mut line = String::new();
        for (name, width) in &self.columns {
            line.push_str(&format!("{name:>width$}  "));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
    }

    pub fn row(&self, cells: &[String]) {
        assert_eq!(cells.len(), self.columns.len());
        let mut line = String::new();
        for ((_, width), cell) in self.columns.iter().zip(cells) {
            line.push_str(&format!("{cell:>width$}  "));
        }
        println!("{line}");
    }
}

/// Format a float compactly (cut values, times).
pub fn fmt(x: f64) -> String {
    if x >= 1_000_000.0 {
        format!("{:.2}M", x / 1_000_000.0)
    } else if x >= 10_000.0 {
        format!("{:.1}k", x / 1000.0)
    } else if x >= 100.0 {
        format!("{x:.0}")
    } else {
        format!("{x:.2}")
    }
}

/// Geometric-mean aggregation across instances (the paper's cross-
/// instance score): input (avg_cut, best_cut, seconds) per instance.
pub fn geomean_row(cells: &[(f64, f64, f64)]) -> (f64, f64, f64) {
    let mut a = Stats::new();
    let mut b = Stats::new();
    let mut t = Stats::new();
    for &(avg, best, secs) in cells {
        a.add(avg);
        b.add(best);
        t.add(secs);
    }
    (a.geomean(), b.geomean(), t.geomean())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_row_matches_hand_calc() {
        let (a, b, t) = geomean_row(&[(2.0, 1.0, 1.0), (8.0, 4.0, 4.0)]);
        assert!((a - 4.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((t - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(3.25), "3.25");
        assert_eq!(fmt(512.0), "512");
        assert_eq!(fmt(51234.0), "51.2k");
        assert_eq!(fmt(3_250_000.0), "3.25M");
    }

    #[test]
    fn table_writer_accepts_rows() {
        let t = TableWriter::new(&[("a", 6), ("b", 8)]);
        t.header();
        t.row(&["1".into(), "x".into()]);
    }
}
