//! Table-formatting and aggregation helpers for the paper-reproduction
//! benches (criterion is unavailable offline; these benches are custom
//! `harness = false` binaries), plus the machine-readable result
//! writer: every bench that goes through [`JsonReport`] leaves a
//! `BENCH_<name>.json` behind (cut, imbalance, wall-time per config),
//! so successive commits accumulate a perf trajectory that scripts can
//! diff — no more copy-pasting numbers out of stdout.

use crate::util::json::escape_json;
use crate::util::timer::Stats;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Common bench options parsed from `cargo bench -- [--full] [--reps N]`.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Quick mode (the DEFAULT): tiny instances, fewer repetitions, so a
    /// plain `cargo bench` finishes in CI time on one core. Pass
    /// `--full` (or `make bench-full`) for the paper's full protocol.
    pub quick: bool,
    pub reps: usize,
    /// Restrict k sweep (empty = default).
    pub ks: Vec<usize>,
}

impl BenchOpts {
    pub fn from_env() -> BenchOpts {
        let args: Vec<String> = std::env::args().collect();
        let quick = !args.iter().any(|a| a == "--full");
        let reps = args
            .iter()
            .position(|a| a == "--reps")
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(if quick { 3 } else { 10 });
        let ks = args
            .iter()
            .position(|a| a == "--k")
            .and_then(|i| args.get(i + 1))
            .map(|v| {
                v.split(',')
                    .filter_map(|t| t.parse().ok())
                    .collect::<Vec<usize>>()
            })
            .unwrap_or_default();
        BenchOpts { quick, reps, ks }
    }

    /// Paper §5 k sweep: 2, 4, 8, 16, 32, 64 (quick: 2, 8, 32).
    pub fn k_sweep(&self) -> Vec<usize> {
        if !self.ks.is_empty() {
            return self.ks.clone();
        }
        if self.quick {
            vec![2, 8, 32]
        } else {
            vec![2, 4, 8, 16, 32, 64]
        }
    }
}

/// One JSON scalar (the std-only subset the bench records need).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Int(i64),
    Num(f64),
    Str(String),
    Bool(bool),
}

impl From<i64> for JsonValue {
    fn from(x: i64) -> Self {
        JsonValue::Int(x)
    }
}
impl From<usize> for JsonValue {
    fn from(x: usize) -> Self {
        JsonValue::Int(x as i64)
    }
}
impl From<f64> for JsonValue {
    fn from(x: f64) -> Self {
        JsonValue::Num(x)
    }
}
impl From<&str> for JsonValue {
    fn from(x: &str) -> Self {
        JsonValue::Str(x.to_string())
    }
}
impl From<String> for JsonValue {
    fn from(x: String) -> Self {
        JsonValue::Str(x)
    }
}
impl From<bool> for JsonValue {
    fn from(x: bool) -> Self {
        JsonValue::Bool(x)
    }
}

fn render_value(v: &JsonValue) -> String {
    match v {
        JsonValue::Int(x) => x.to_string(),
        // JSON has no NaN/∞: emit null so consumers fail loudly instead
        // of parsing garbage.
        JsonValue::Num(x) if !x.is_finite() => "null".to_string(),
        JsonValue::Num(x) => format!("{x}"),
        JsonValue::Str(s) => format!("\"{}\"", escape_json(s)),
        JsonValue::Bool(b) => b.to_string(),
    }
}

/// Machine-readable bench results. Records are flat key→scalar maps
/// tagged with a section name; [`JsonReport::write`] emits
/// `BENCH_<name>.json` into `SCLAP_BENCH_DIR` (default: the current
/// directory, i.e. `rust/` under `cargo bench`).
#[derive(Debug, Default)]
pub struct JsonReport {
    name: String,
    records: Vec<Vec<(String, JsonValue)>>,
}

impl JsonReport {
    pub fn new(name: &str) -> JsonReport {
        JsonReport {
            name: name.to_string(),
            records: Vec::new(),
        }
    }

    /// Append one record; `section` groups related records (e.g. one
    /// per thread count of the same engine).
    pub fn record(&mut self, section: &str, fields: &[(&str, JsonValue)]) {
        let mut rec: Vec<(String, JsonValue)> =
            vec![("section".to_string(), JsonValue::from(section))];
        rec.extend(fields.iter().map(|(k, v)| (k.to_string(), v.clone())));
        self.records.push(rec);
    }

    /// Serialize the whole report (stable field order = insertion order).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\n  \"name\": \"{}\",\n  \"records\": [\n",
            escape_json(&self.name)
        ));
        for (i, rec) in self.records.iter().enumerate() {
            out.push_str("    {");
            for (j, (k, v)) in rec.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("\"{}\": {}", escape_json(k), render_value(v)));
            }
            out.push('}');
            if i + 1 < self.records.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// `BENCH_<name>.json` under `dir`.
    pub fn path_in(&self, dir: &Path) -> PathBuf {
        dir.join(format!("BENCH_{}.json", self.name))
    }

    /// Write the report into `dir`; returns the file path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = self.path_in(dir);
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.to_json().as_bytes())?;
        Ok(path)
    }

    /// Write to `SCLAP_BENCH_DIR` (default `.`); returns the file path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var("SCLAP_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        self.write_to(Path::new(&dir))
    }
}

/// Fixed-width table printer matching the paper's table style.
pub struct TableWriter {
    columns: Vec<(String, usize)>,
}

impl TableWriter {
    pub fn new(columns: &[(&str, usize)]) -> Self {
        let columns: Vec<(String, usize)> = columns
            .iter()
            .map(|(n, w)| (n.to_string(), (*w).max(n.len())))
            .collect();
        TableWriter { columns }
    }

    pub fn header(&self) {
        let mut line = String::new();
        for (name, width) in &self.columns {
            line.push_str(&format!("{name:>width$}  "));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
    }

    pub fn row(&self, cells: &[String]) {
        assert_eq!(cells.len(), self.columns.len());
        let mut line = String::new();
        for ((_, width), cell) in self.columns.iter().zip(cells) {
            line.push_str(&format!("{cell:>width$}  "));
        }
        println!("{line}");
    }
}

/// Format a float compactly (cut values, times).
pub fn fmt(x: f64) -> String {
    if x >= 1_000_000.0 {
        format!("{:.2}M", x / 1_000_000.0)
    } else if x >= 10_000.0 {
        format!("{:.1}k", x / 1000.0)
    } else if x >= 100.0 {
        format!("{x:.0}")
    } else {
        format!("{x:.2}")
    }
}

/// Cross-instance geometric means of one configuration row, plus an
/// explicit account of the cells that could not participate (cut 0 —
/// e.g. a disconnected LFR draw). See [`geomean_row`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeomeanRow {
    /// Geomean of the per-instance average cuts (positive cells only).
    pub avg_cut: f64,
    /// Geomean of the per-instance best cuts (positive cells only).
    pub best_cut: f64,
    /// Geomean of the per-instance average times (positive cells only).
    pub seconds: f64,
    /// Cells whose avg or best cut was non-positive, excluded from the
    /// cut geomeans. Report this next to the numbers: a geomean over a
    /// silently shrunken cell set is not comparable across rows.
    pub zero_cut_cells: usize,
    /// Cells whose time was non-positive (sub-timer-resolution runs),
    /// excluded from the seconds geomean — same reporting rule.
    pub zero_time_cells: usize,
}

impl GeomeanRow {
    /// `"*N"` marker for cut cells when `N` zero-cut cells were
    /// excluded, empty otherwise.
    pub fn zero_marker(&self) -> String {
        Self::marker(self.zero_cut_cells)
    }

    /// `"*N"` marker for the seconds cell when `N` zero-time cells were
    /// excluded, empty otherwise.
    pub fn time_marker(&self) -> String {
        Self::marker(self.zero_time_cells)
    }

    fn marker(n: usize) -> String {
        if n == 0 {
            String::new()
        } else {
            format!("*{n}")
        }
    }
}

/// Geometric-mean aggregation across instances (the paper's cross-
/// instance score): input (avg_cut, best_cut, seconds) per instance.
///
/// Zero-cut cells are **excluded with a count**
/// ([`GeomeanRow::zero_cut_cells`]) instead of being clamped to a tiny
/// epsilon — the old clamp dragged the whole row's geomean toward 0 by
/// a factor of `(1e-12 / typical_cut)^(1/n)` per zero cell, which is
/// exactly the kind of silent skew a paper-reproduction table must not
/// have.
pub fn geomean_row(cells: &[(f64, f64, f64)]) -> GeomeanRow {
    let mut a = Stats::new();
    let mut b = Stats::new();
    let mut t = Stats::new();
    let mut zero_cut_cells = 0;
    for &(avg, best, secs) in cells {
        a.add(avg);
        b.add(best);
        t.add(secs);
        if avg <= 0.0 || best <= 0.0 {
            zero_cut_cells += 1;
        }
    }
    GeomeanRow {
        avg_cut: a.positive_geomean(),
        best_cut: b.positive_geomean(),
        seconds: t.positive_geomean(),
        zero_cut_cells,
        zero_time_cells: t.nonpositive_count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_row_matches_hand_calc() {
        let g = geomean_row(&[(2.0, 1.0, 1.0), (8.0, 4.0, 4.0)]);
        assert!((g.avg_cut - 4.0).abs() < 1e-9);
        assert!((g.best_cut - 2.0).abs() < 1e-9);
        assert!((g.seconds - 2.0).abs() < 1e-9);
        assert_eq!(g.zero_cut_cells, 0);
        assert_eq!(g.zero_marker(), "");
    }

    #[test]
    fn geomean_row_excludes_zero_cells_with_a_count() {
        // A disconnected instance with cut 0 must not skew the row (the
        // old epsilon clamp multiplied the geomean by ~(1e-12)^(1/n));
        // it is excluded and counted instead.
        let g = geomean_row(&[(0.0, 0.0, 1.0), (2.0, 1.0, 1.0), (8.0, 4.0, 4.0)]);
        assert!((g.avg_cut - 4.0).abs() < 1e-9);
        assert!((g.best_cut - 2.0).abs() < 1e-9);
        assert_eq!(g.zero_cut_cells, 1);
        assert_eq!(g.zero_marker(), "*1");
        assert_eq!(g.zero_time_cells, 0);
        assert_eq!(g.time_marker(), "");
    }

    #[test]
    fn geomean_row_counts_zero_time_cells() {
        // A sub-timer-resolution run (0.0s) is excluded from the time
        // geomean with a count, not silently dropped.
        let g = geomean_row(&[(2.0, 1.0, 0.0), (8.0, 4.0, 2.0)]);
        assert_eq!(g.zero_time_cells, 1);
        assert_eq!(g.time_marker(), "*1");
        assert!((g.seconds - 2.0).abs() < 1e-9);
        assert_eq!(g.zero_cut_cells, 0);
    }

    #[test]
    fn geomean_row_all_zero() {
        let g = geomean_row(&[(0.0, 0.0, 1.0), (0.0, 0.0, 2.0)]);
        assert_eq!(g.avg_cut, 0.0);
        assert_eq!(g.best_cut, 0.0);
        assert_eq!(g.zero_cut_cells, 2);
        // times are still positive and aggregate normally
        assert!((g.seconds - 2.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn geomean_row_counts_best_only_zero() {
        // best = 0 while avg > 0 (one lucky run) still flags the cell.
        let g = geomean_row(&[(2.0, 0.0, 1.0)]);
        assert_eq!(g.zero_cut_cells, 1);
        assert!((g.avg_cut - 2.0).abs() < 1e-12);
        assert_eq!(g.best_cut, 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt(3.25), "3.25");
        assert_eq!(fmt(512.0), "512");
        assert_eq!(fmt(51234.0), "51.2k");
        assert_eq!(fmt(3_250_000.0), "3.25M");
    }

    #[test]
    fn table_writer_accepts_rows() {
        let t = TableWriter::new(&[("a", 6), ("b", 8)]);
        t.header();
        t.row(&["1".into(), "x".into()]);
    }

    #[test]
    fn json_report_serializes() {
        let mut r = JsonReport::new("demo");
        r.record(
            "lpa",
            &[
                ("threads", 4usize.into()),
                ("secs", 0.5.into()),
                ("label", "a \"quoted\"\nname".into()),
                ("ok", true.into()),
            ],
        );
        r.record("lpa", &[("nan", f64::NAN.into())]);
        let json = r.to_json();
        assert!(json.contains("\"name\": \"demo\""));
        assert!(json.contains("\"section\": \"lpa\""));
        assert!(json.contains("\"threads\": 4"));
        assert!(json.contains("\"secs\": 0.5"));
        assert!(json.contains("\\\"quoted\\\"\\nname"));
        assert!(json.contains("\"nan\": null"));
        // exactly two records, comma-separated
        assert_eq!(json.matches("\"section\"").count(), 2);
    }

    #[test]
    fn json_report_writes_file() {
        let dir = std::env::temp_dir().join(format!(
            "sclap-bench-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = JsonReport::new("unit");
        r.record("s", &[("x", 1usize.into())]);
        let path = r.write_to(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_unit.json");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, r.to_json());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
