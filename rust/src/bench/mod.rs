//! Bench harness shared by `rust/benches/*`: instance loading, table
//! formatting and the paper's aggregation conventions (§5: arithmetic
//! mean per instance, geometric mean across instances).

pub mod harness;

pub use harness::{geomean_row, BenchOpts, JsonReport, JsonValue, TableWriter};
