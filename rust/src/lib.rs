//! # sclap — size-constrained label-propagation graph partitioning
//!
//! Production-quality reproduction of *"Partitioning Complex Networks via
//! Size-constrained Clustering"* (Meyerhenke, Sanders, Schulz; 2014) as a
//! three-layer rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the full multilevel partitioner — size-constrained
//!   label propagation (SCLaP), cluster contraction, initial partitioning,
//!   refinement, V-cycles, ensembles, the baselines, and a partitioning
//!   service coordinator with a batching request-queue front end.
//! - **L2/L1 (python/, build-time only)**: the dense synchronous SCLaP
//!   round (JAX) with a Pallas-tiled scoring matmul, AOT-lowered to HLO
//!   text in `artifacts/` and executed from [`runtime`] via PJRT.
//!
//! Quickstart:
//! ```no_run
//! use sclap::prelude::*;
//!
//! let graph = sclap::generators::instances::by_name("tiny-rmat").unwrap().build();
//! let config = PartitionConfig::preset(Preset::UFast, 8);
//! let result = MultilevelPartitioner::new(config).partition(&graph, 42);
//! println!("cut = {}", result.metrics.cut);
//! ```
//!
//! # ExecutionCtx: one pool through every phase
//!
//! All parallelism runs on a single shared [`util::exec::ExecutionCtx`]
//! — a handle bundling **the** process [`util::pool::ThreadPool`],
//! deterministic per-phase RNG-stream derivation
//! ([`util::exec::derive_seed`]), and a phase-timing sink. The
//! coordinator ([`coordinator::service::Coordinator`]) creates the one
//! pool and hands the context down into every repetition job
//! ([`partitioning::multilevel::MultilevelPartitioner::with_ctx`]);
//! nested parallel phases — coarsening LPA, cluster contraction,
//! recursive bisection, refinement — re-enter the same pool, where
//! re-entrant jobs execute inline, so total live worker threads never
//! exceed the configured cap (see `rust/tests/thread_cap.rs`).
//!
//! The hard invariant on top: **thread count is an execution knob,
//! never an algorithmic one.** Same seed + same config ⇒ byte-identical
//! partition for any pool size (`rust/tests/determinism.rs`). Parallel
//! *algorithms* are therefore selected by configuration, not by thread
//! count: `PartitionConfig::parallel_coarsening` enables the
//! coloring-based parallel asynchronous LPA
//! ([`clustering::async_lpa`], after arXiv 1404.4797) and
//! `PartitionConfig::parallel_refinement` the synchronous-round engine
//! ([`refinement::lpa_refine::parallel_lpa_refine`]); recursive
//! bisection always fans its independent splits out on the shared pool
//! with split-path-derived RNG streams
//! ([`initial_partitioning::recursive_bisection`]).
//!
//! ```no_run
//! use sclap::prelude::*;
//! use std::sync::Arc;
//!
//! // One process-wide context: 8 workers, total — repetitions and all
//! // nested phases share it.
//! let ctx = Arc::new(ExecutionCtx::new(8));
//! let coordinator = sclap::coordinator::service::Coordinator::with_ctx(ctx.clone());
//! let graph = Arc::new(sclap::generators::instances::by_name("tiny-rmat").unwrap().build());
//! let mut config = PartitionConfig::preset(Preset::UFast, 8);
//! config.parallel_coarsening = true; // async LPA on the shared pool
//! let agg = coordinator.partition_repeated(
//!     graph,
//!     &config,
//!     &sclap::coordinator::service::default_seeds(10),
//! );
//! println!("avg cut = {}", agg.avg_cut);
//! for (phase, stat) in ctx.phase_stats() {
//!     println!("{phase}: {} calls, {:.3}s", stat.calls, stat.seconds);
//! }
//! ```
//!
//! # partitioning::workspace: zero-steady-state-allocation V-cycles
//!
//! Riding on the context is the multilevel workspace
//! ([`partitioning::workspace::VcycleWorkspace`]): one typed buffer
//! arena ([`util::arena::Arena`]) per pool worker plus one for the
//! caller, all feeding a shared stats sink. Every phase of the
//! pipeline — LPA round scratch ([`clustering::label_propagation`],
//! [`clustering::parallel_lpa`], [`clustering::async_lpa`],
//! [`clustering::external_lpa`]), cluster contraction
//! ([`coarsening::contract`]), and refinement
//! ([`refinement::lpa_refine`], [`refinement::fm`]) — leases its
//! scratch ([`util::arena::Lease`]) instead of allocating it: the
//! lease hands out a *cleared but capacitated* buffer and returns the
//! capacity on drop. Parallel engines lease from their own worker's
//! shard, so pool jobs take no shared lock in the steady state.
//!
//! The effect: the first V-cycle of the first request pays the O(n)
//! scratch allocations once, and every later cycle, repetition
//! ([`coordinator::service::Coordinator::partition_repeated`]), and
//! warm `serve` request on the same context fresh-allocates **zero**
//! scratch buffers (`rust/tests/alloc_budget.rs` proves this with a
//! counting global allocator; `rust/benches/vcycle_e2e.rs` tracks the
//! cold/warm wall-clock delta). Because leases recycle capacity and
//! never contents, reuse is invisible to results — the determinism
//! contract below is unchanged — and the high-water mark of leased
//! bytes is a faithful peak-scratch-RSS proxy, exposed per arena via
//! [`partitioning::workspace::VcycleWorkspace::stats`] and on the wire
//! through `serve --timing` (`leases_created`, `peak_lease_bytes`).
//!
//! # graph::store: out-of-core instances beyond RAM
//!
//! Inputs whose CSR exceeds
//! [`PartitionConfig::memory_budget_bytes`](partitioning::config::PartitionConfig)
//! (CLI `--memory-budget`, env `SCLAP_MEMORY_BUDGET`) are partitioned
//! **semi-externally** (after arXiv 1404.4887): the
//! [`graph::store::GraphStore`] abstraction splits the node range into
//! contiguous on-disk CSR shards ([`graph::store::ShardedStore`]; a
//! streaming METIS→shards converter never materializes the full
//! graph), a shard cursor keeps at most one shard's adjacency
//! resident, and level 0 of the hierarchy is built by semi-external
//! SCLaP ([`clustering::external_lpa`]) + streaming contraction
//! ([`coarsening::contract::contract_store`]) with only O(n) node
//! state in RAM. The driver
//! ([`partitioning::external::partition_store`]) switches to the
//! ordinary in-memory pipeline the moment the contracted graph fits
//! the budget, and finishes with a semi-external refinement pass on
//! the input shards.
//!
//! The determinism contract extends over storage: same seed + same
//! config ⇒ byte-identical partition for **any thread count, any shard
//! count, and either storage backend** (`rust/tests/sharded_store.rs`).
//!
//! ```no_run
//! use sclap::prelude::*;
//!
//! let graph = sclap::generators::instances::by_name("tiny-rmat").unwrap().build();
//! let store = sclap::graph::store::InMemoryStore::with_shards(&graph, 8);
//! let mut config = PartitionConfig::preset(Preset::CFast, 8);
//! config.memory_budget_bytes = Some(1); // force the out-of-core path
//! let r = sclap::partitioning::external::partition_store(&store, &config, 42).unwrap();
//! println!("cut = {} via {} external level(s)", r.cut, r.external_levels);
//! ```
//!
//! # coordinator::queue: the batching service front end
//!
//! Many clients, one machine: [`coordinator::queue::BatchService`]
//! puts a **bounded multi-producer request queue** in front of the
//! coordinator. A request is (graph handle, config, seeds) — graph
//! handles are in-memory `Arc<Graph>`s or on-disk shard directories,
//! so both storage regimes flow through the same queue. A scheduler
//! thread drains the queue and fans out **individual repetitions**
//! (not whole requests) in round-robin waves across the one shared
//! pool, rotating the round-robin start each wave: a 1-seed request
//! submitted next to a 10-seed request rides an early wave instead of
//! queueing behind all ten repetitions, for any pool width. Results
//! are reassembled per request in seed order.
//!
//! Semantics:
//! - **backpressure** — the queue is bounded by
//!   [`ServiceConfig::max_pending`](coordinator::queue::ServiceConfig):
//!   `submit` blocks until a slot frees; `try_submit` returns
//!   [`SubmitError::Busy`](coordinator::queue::SubmitError).
//! - **graceful shutdown** — dropping (or `shutdown()`-ing) the
//!   service refuses new work, drains every accepted request, and
//!   resolves their tickets before the scheduler exits.
//! - **fault isolation** — a panicking repetition (poisoned config)
//!   or an I/O error fails only its own request; the pool and every
//!   other request keep going.
//! - **determinism** — each repetition is a pure function of (graph,
//!   config, seed), so a request's [`coordinator::service::Aggregate`]
//!   is byte-identical (modulo wall-clock fields) for any worker
//!   count, submission order, or interleaving with other requests
//!   (`rust/tests/batch_queue.rs`).
//!
//! The `sclap serve` subcommand exposes the queue on the command
//! line: newline-delimited request specs in, one deterministic JSON
//! result line per request out (`coordinator::queue::spec`).
//!
//! ```no_run
//! use sclap::coordinator::queue::{BatchService, GraphHandle, Request, ServiceConfig};
//! use sclap::prelude::*;
//! use std::sync::Arc;
//!
//! let service = BatchService::new(ServiceConfig { workers: 8, max_pending: 32 });
//! let graph = Arc::new(sclap::generators::instances::by_name("tiny-rmat").unwrap().build());
//! let ticket = service
//!     .submit(Request::new(
//!         "job-1",
//!         GraphHandle::InMemory(graph),
//!         PartitionConfig::preset(Preset::UFast, 8),
//!         (1..=10).collect(),
//!     ))
//!     .expect("queue accepts while below max_pending");
//! let agg = ticket.wait().expect("request succeeds");
//! println!("avg cut = {}", agg.avg_cut);
//! ```
//!
//! # util::cancel: deterministic cooperative cancellation
//!
//! Every layer above shares one cancellation fabric
//! ([`util::cancel`]): a [`util::cancel::CancelToken`] is a
//! fire-once verdict cell (first [`util::cancel::CancelReason`] wins,
//! optionally armed with a wall-clock deadline) with cheap
//! hierarchical children — a child observes its parent's verdict, so
//! cancelling a request cancels every repetition spawned under it
//! without touching the siblings. The scheduler enters a per-unit
//! child token *ambiently* (thread-local, propagated to pool workers
//! per job by [`util::pool`]), and the long-running inner loops —
//! SCLaP rounds in all four engines, contraction passes, FM and LPA
//! refinement passes, V-cycle and out-of-core drivers — poll it at
//! deterministic checkpoints via [`util::cancel::checkpoint`], which
//! unwinds with a typed payload that the scheduler catches and maps
//! to a structured `Cancelled` outcome (never an error, never a bug
//! report).
//!
//! Two invariants anchor the design. **Zero impact:** a token that
//! never fires changes no result byte — checkpoints cost one
//! thread-local check plus an atomic load, and cancellation state
//! (a request's deadline) is never key material for the
//! result cache (`rust/tests/cancellation.rs`). **Determinism at the
//! boundary:** *whether* a request is cancelled depends on wall
//! clock (deadlines) or I/O (disconnects), but a cancelled request
//! always yields the same structured reply
//! (`{"status":"cancelled","reason":…}` on the wire), frees its
//! queue slot and arena leases, and leaves every other request's
//! bytes untouched.
//!
//! Cancellation sources, all funnelled through the same token:
//! - **deadlines** — `timeout_ms=` in a request spec (or `sclap
//!   client --timeout`), armed at submission so queue wait counts;
//! - **disconnects** — the TCP server fires a connection's live
//!   request tokens when the client vanishes;
//! - **abandonment** — dropping an unwaited
//!   [`coordinator::queue::Ticket`] fires its token, so work nobody
//!   will read is cancelled instead of computed (including at
//!   shutdown drain);
//! - **races** — `race=P1,P2,…` runs one request's first seed under
//!   several configs as one scheduler wave; the best cut wins (ties
//!   break on race-list order, never timing), the winner's config
//!   takes over the remaining seeds, and the losers are cancelled.
//!   The winning aggregate is byte-identical to running the winning
//!   config alone.
//!
//! # coordinator::net: the network service layer
//!
//! The full service stack, from a TCP client down to the pool:
//!
//! ```text
//!  sclap client ─┐
//!  sclap client ─┼── TCP, line-framed request specs (queue::spec)
//!  nc, tests   ──┘                 │
//!                                  ▼
//!  NetServer ── per-connection reader ──► CachedService ──► BatchService
//!                     │               content-addressed     bounded queue,
//!                     │               single-flight LRU     scheduler waves
//!                     ▼                                          │
//!       per-connection writer ◄── waiter threads (out-of-order) ◄┘
//!       one JSON line per request                                │
//!                                            ExecutionCtx: the one pool
//! ```
//!
//! [`coordinator::net::NetServer`] wraps the batching queue behind a
//! zero-dependency TCP wire protocol (std `TcpListener` + threads):
//! line-framed request specs in (the same `queue::spec` grammar as
//! stdin `serve`, blank lines and `#` comments included), pipelined
//! one-JSON-line-per-request responses out, in completion order with
//! client-supplied ids. Backpressure is structural (`try_submit →
//! Busy` becomes a `{"status":"busy"}` response instead of a blocked
//! connection), faults are per-request, and `!shutdown` drains every
//! accepted request before closing. In front of the scheduler sits
//! [`coordinator::net::CachedService`] — a content-addressed result
//! cache keyed by ([`graph::store::store_fingerprint`] of the CSR
//! stream, canonical config, sorted seeds) with single-flight dedup
//! and a bounded LRU, so N concurrent identical requests cost one
//! computation and repeats cost none.
//!
//! The determinism contract extends across the wire: a request
//! answered by the server is **bit-identical** to the same request run
//! offline, for any client count, interleaving, worker count, and
//! cache state — the only cache-observable byte is the
//! `"cached":true` response field (`rust/tests/net_service.rs`, CI
//! `net-smoke`).
//!
//! ```no_run
//! use sclap::coordinator::net::{NetClient, NetServer, NetServerConfig};
//!
//! let server = NetServer::bind("127.0.0.1:0", NetServerConfig::default()).unwrap();
//! let addr = server.local_addr().to_string();
//! let handle = server.handle();
//! std::thread::spawn(move || server.run());
//!
//! let mut client = NetClient::connect(&addr).unwrap();
//! let line = client
//!     .request("id=job instance=tiny-rmat k=8 preset=UFast seeds=1,2,3")
//!     .unwrap();
//! let response = sclap::coordinator::net::parse_response(&line).unwrap();
//! println!("best cut = {:?}", response.best_cut());
//! handle.shutdown();
//! ```
//!
//! # obs: structured tracing and the metrics registry
//!
//! Observability is one std-only layer ([`obs`]) with two halves, both
//! reached through the same [`util::exec::ExecutionCtx`] that already
//! carries the pool and the workspace:
//!
//! - **Spans and counters** ([`obs::trace`]): when a [`obs::trace::Tracer`]
//!   is installed ([`util::exec::ExecutionCtx::set_tracer`], CLI
//!   `--trace FILE` on `partition` and `serve`), each repetition enters
//!   a *logical track* derived from its seed and the pipeline emits
//!   hierarchical spans (`vcycle` → `coarsening` / `initial` /
//!   `uncoarsening` → `refine_level level=…`) and structured counters
//!   (`cycle_cut`, `level_quality` with per-level cut and imbalance,
//!   `hierarchy`, LPA/FM round counts) into per-worker fixed-capacity
//!   buffers — no locks, no allocation in the steady state. The merged
//!   stream is ordered by (track, instance, sequence), so it is
//!   **byte-identical for any worker count**
//!   ([`obs::trace::Tracer::logical_stream`]), and exports as a Chrome
//!   `trace_event` JSON file openable in Perfetto / `chrome://tracing`
//!   ([`obs::trace::Tracer::write_chrome_trace_file`]; schema in the
//!   [`obs::trace`] module docs, validated by
//!   `scripts/trace_validate.py` in CI `obs-smoke`).
//! - **The metrics registry** ([`obs::metrics::MetricsRegistry`]): one
//!   process-wide home for typed counters, gauges, and log₂-bucketed
//!   histograms — queue depth/busy rejections/wait, cache
//!   hits/misses/single-flight joins/evictions, scheduler waves and
//!   wave sizes, arena lease gauges — plus per-phase wall-clock keyed
//!   by `(phase, Option<level>)`
//!   ([`util::exec::ExecutionCtx::phase_stats_by_level`]), so
//!   `refine_level` at level 0 and level 5 no longer collapse into one
//!   row. `serve --timing` and the wire `!stats` command (grammar in
//!   [`coordinator::net`]) are thin snapshots of this registry —
//!   `!stats` histograms carry `p50`/`p99` (bucket upper bounds via
//!   [`obs::metrics::Histogram::quantile`]) and the populated log₂
//!   `buckets` — and the wire `!metrics` command renders the same
//!   registry as Prometheus text between `# sclap metrics` and `# EOF`
//!   framing lines ([`obs::metrics::MetricsRegistry::render_prometheus`],
//!   validated by `scripts/prom_validate.py` in CI `obs-smoke`);
//!   `!ping` answers with the crate version and the registry's uptime
//!   clock.
//!
//! Two explainability/ops layers build on those primitives:
//!
//! - **Per-request quality reports** ([`obs::quality`]): the spec key
//!   `explain=true` makes the scheduler trace that request's
//!   repetitions into per-seed logical lanes and distill them into a
//!   [`obs::quality::QualityReport`] appended to the response as an
//!   `"explain":{"reps":[…]}` object — coarsening lineage with
//!   per-level shrink factors, LPA round/stop/moved telemetry, FM pass
//!   cut trajectories, per-level cut and imbalance. Reports consume
//!   only logical event content, so they are byte-identical for any
//!   worker count, storage backend, or shard layout, and
//!   observation-only: every response byte before the report matches
//!   the unexplained response.
//! - **Durable ops telemetry** ([`obs::journal`]): `serve --journal
//!   FILE` appends one JSON line per lifecycle event (admitted /
//!   started / completed / cancelled / busy / cache_hit / error /
//!   shutdown) with a monotone `seq`, size-rotated `FILE` → `FILE.1`;
//!   `scripts/journal_replay.py` replays a journal and reconciles it
//!   against the `!stats` counters. The `sclap report` subcommand
//!   drives a preset×instance matrix through the full service path and
//!   emits a JSON document of per-cell and per-preset geometric means
//!   that `scripts/make_tables.py` renders as paper-style result
//!   tables next to the reference numbers of arXiv 1402.3281.
//!
//! The governing invariant: **observability never changes results.**
//! Tracing on vs. off, `--timing` on vs. off, `explain=true` vs.
//! absent, journaling on vs. off, and any number of `!stats` or
//! `!metrics` probes produce byte-identical partitions and (up to the
//! appended report) response lines; disabled instrumentation costs one
//! `Option`/TLS check per site (`rust/tests/observability.rs`;
//! `rust/benches/vcycle_e2e.rs` gates warm throughput with tracing
//! compiled in but disabled).

pub mod bench;
pub mod clustering;
pub mod coarsening;
pub mod coordinator;
pub mod generators;
pub mod graph;
pub mod initial_partitioning;
pub mod obs;
pub mod partitioning;
pub mod refinement;
pub mod runtime;
pub mod util;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::coordinator::net::{CachedService, NetClient, NetServer, NetServerConfig};
    pub use crate::coordinator::queue::{BatchService, ServiceConfig};
    pub use crate::graph::store::{GraphStore, InMemoryStore, ShardedStore};
    pub use crate::graph::{Graph, GraphBuilder, NodeId, Weight};
    pub use crate::partitioning::config::{PartitionConfig, Preset};
    pub use crate::partitioning::metrics::PartitionMetrics;
    pub use crate::partitioning::multilevel::MultilevelPartitioner;
    pub use crate::partitioning::partition::Partition;
    pub use crate::util::exec::ExecutionCtx;
    pub use crate::util::pool::ThreadPool;
    pub use crate::util::rng::Rng;
}
