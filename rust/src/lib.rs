//! # sclap — size-constrained label-propagation graph partitioning
//!
//! Production-quality reproduction of *"Partitioning Complex Networks via
//! Size-constrained Clustering"* (Meyerhenke, Sanders, Schulz; 2014) as a
//! three-layer rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the full multilevel partitioner — size-constrained
//!   label propagation (SCLaP), cluster contraction, initial partitioning,
//!   refinement, V-cycles, ensembles, the baselines, and a partitioning
//!   service coordinator.
//! - **L2/L1 (python/, build-time only)**: the dense synchronous SCLaP
//!   round (JAX) with a Pallas-tiled scoring matmul, AOT-lowered to HLO
//!   text in `artifacts/` and executed from [`runtime`] via PJRT.
//!
//! Quickstart:
//! ```no_run
//! use sclap::prelude::*;
//!
//! let graph = sclap::generators::instances::by_name("tiny-rmat").unwrap().build();
//! let config = PartitionConfig::preset(Preset::UFast, 8);
//! let result = MultilevelPartitioner::new(config).partition(&graph, 42);
//! println!("cut = {}", result.metrics.cut);
//! ```

pub mod bench;
pub mod clustering;
pub mod coarsening;
pub mod coordinator;
pub mod generators;
pub mod graph;
pub mod initial_partitioning;
pub mod partitioning;
pub mod refinement;
pub mod runtime;
pub mod util;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::graph::{Graph, GraphBuilder, NodeId, Weight};
    pub use crate::partitioning::config::{PartitionConfig, Preset};
    pub use crate::partitioning::metrics::PartitionMetrics;
    pub use crate::partitioning::multilevel::MultilevelPartitioner;
    pub use crate::partitioning::partition::Partition;
    pub use crate::util::pool::ThreadPool;
    pub use crate::util::rng::Rng;
}
