//! LFR-style community-structured scale-free generator.
//!
//! Pure R-MAT/BA graphs have heavy-tailed degrees but essentially *no*
//! community structure — every k-partition cuts ≈ (1−1/k)·m, so nothing
//! separates good coarsening from bad. Real web crawls and social
//! networks (the paper's Table 1) combine power-law degrees with strong
//! locality. The standard benchmark family with both properties is LFR
//! (Lancichinetti–Fortunato–Radicchi); we implement its core recipe:
//!
//!  1. community sizes ~ power law (exponent τ₂ ≈ 1.5),
//!  2. node degrees ~ power law (exponent τ₁ ≈ 2.5),
//!  3. each node spends (1−μ) of its stubs inside its community and μ
//!     outside (μ = mixing parameter; web graphs ≈ 0.05–0.15, social
//!     networks ≈ 0.25–0.4),
//!  4. stubs are paired configuration-model style (self loops and
//!     duplicates dropped by the builder).

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{Graph, NodeId};
use crate::util::rng::Rng;

/// Sample from a bounded discrete power law `P(x) ∝ x^(−tau)` on
/// `[lo, hi]` via inverse transform on the continuous approximation.
fn power_law(rng: &mut Rng, lo: f64, hi: f64, tau: f64) -> f64 {
    let u = rng.f64();
    let a = 1.0 - tau;
    // inverse CDF of truncated power law
    ((lo.powf(a) + u * (hi.powf(a) - lo.powf(a))).powf(1.0 / a)).clamp(lo, hi)
}

/// LFR-like graph: `n` nodes, average degree ≈ `avg_degree`, mixing
/// parameter `mu`. Returns the graph and the ground-truth community of
/// every node.
pub fn lfr_like(n: usize, avg_degree: f64, mu: f64, rng: &mut Rng) -> (Graph, Vec<u32>) {
    assert!(n >= 16);
    assert!((0.0..=1.0).contains(&mu));

    // --- 1. community sizes ---
    let min_size = (2.0 * avg_degree).max(8.0) as usize;
    let max_size = (n / 8).max(min_size + 1);
    let mut sizes: Vec<usize> = Vec::new();
    let mut total = 0usize;
    while total < n {
        let s = power_law(rng, min_size as f64, max_size as f64, 1.5) as usize;
        let s = s.min(n - total).max(1);
        sizes.push(s);
        total += s;
    }
    // merge a trailing runt community into its predecessor
    if sizes.len() >= 2 && *sizes.last().unwrap() < min_size / 2 {
        let last = sizes.pop().unwrap();
        *sizes.last_mut().unwrap() += last;
    }

    let mut community = vec![0u32; n];
    let mut start = 0usize;
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(sizes.len());
    for (ci, &s) in sizes.iter().enumerate() {
        for v in start..start + s {
            community[v] = ci as u32;
        }
        ranges.push((start, start + s));
        start += s;
    }

    // --- 2. degrees ---
    let d_min = 2.0;
    let d_max = (n as f64).sqrt().max(8.0);
    // power law with tau=2.5 has mean ~ 2.4*d_min; rescale to avg_degree
    let mut degrees: Vec<f64> = (0..n).map(|_| power_law(rng, d_min, d_max, 2.5)).collect();
    let mean: f64 = degrees.iter().sum::<f64>() / n as f64;
    let scale = avg_degree / mean;
    for d in degrees.iter_mut() {
        *d = (*d * scale).max(1.0);
    }

    // --- 3+4. stub lists ---
    let mut intra_stubs: Vec<Vec<NodeId>> = vec![Vec::new(); sizes.len()];
    let mut inter_stubs: Vec<NodeId> = Vec::new();
    for v in 0..n {
        let d = degrees[v].round() as usize;
        let d_out = ((d as f64) * mu).round() as usize;
        let d_in = d.saturating_sub(d_out);
        // community must be able to host d_in neighbors
        let c = community[v] as usize;
        let cap = sizes[c].saturating_sub(1);
        let d_in = d_in.min(cap);
        for _ in 0..d_in {
            intra_stubs[c].push(v as NodeId);
        }
        for _ in 0..d_out {
            inter_stubs.push(v as NodeId);
        }
    }

    let mut builder = GraphBuilder::with_edge_capacity(n, (avg_degree as usize) * n / 2);
    for stubs in intra_stubs.iter_mut() {
        rng.shuffle(stubs);
        for pair in stubs.chunks_exact(2) {
            builder.add_edge(pair[0], pair[1], 1); // builder drops self/dup
        }
    }
    rng.shuffle(&mut inter_stubs);
    for pair in inter_stubs.chunks_exact(2) {
        builder.add_edge(pair[0], pair[1], 1);
    }

    (builder.build(), community)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::compute_stats;

    #[test]
    fn shape_and_validity() {
        let mut rng = Rng::new(1);
        let (g, comm) = lfr_like(3000, 12.0, 0.1, &mut rng);
        assert_eq!(g.n(), 3000);
        assert!(g.validate().is_ok());
        assert_eq!(comm.len(), 3000);
        let avg = 2.0 * g.m() as f64 / g.n() as f64;
        assert!((8.0..16.0).contains(&avg), "avg degree {avg}");
    }

    #[test]
    fn mixing_parameter_controls_locality() {
        let mut rng = Rng::new(2);
        let frac_cut = |mu: f64, rng: &mut Rng| {
            let (g, comm) = lfr_like(2000, 10.0, mu, rng);
            let inter = g
                .edges()
                .filter(|&(u, v, _)| comm[u as usize] != comm[v as usize])
                .count();
            inter as f64 / g.m() as f64
        };
        let low = frac_cut(0.05, &mut rng);
        let high = frac_cut(0.4, &mut rng);
        assert!(low < 0.15, "mu=0.05 -> inter fraction {low}");
        assert!(high > 0.25, "mu=0.4 -> inter fraction {high}");
        assert!(low < high);
    }

    #[test]
    fn degrees_are_heavy_tailed() {
        let mut rng = Rng::new(3);
        let (g, _) = lfr_like(5000, 15.0, 0.1, &mut rng);
        let s = compute_stats(&g, &mut rng);
        assert!(s.degree_gini > 0.25, "gini {}", s.degree_gini);
        assert!(s.max_degree > 3 * s.avg_degree as usize, "max {}", s.max_degree);
    }

    #[test]
    fn communities_are_cut_friendly() {
        // Partitioning along ground truth must beat a random partition
        // by a wide margin — the property the whole evaluation needs.
        let mut rng = Rng::new(4);
        let (g, comm) = lfr_like(2000, 12.0, 0.1, &mut rng);
        let truth_cut: i64 = g
            .edges()
            .filter(|&(u, v, _)| comm[u as usize] != comm[v as usize])
            .map(|(_, _, w)| w)
            .sum();
        let random_cut: i64 = {
            let blocks: Vec<u32> = (0..g.n()).map(|_| rng.below(8) as u32).collect();
            g.edges()
                .filter(|&(u, v, _)| blocks[u as usize] != blocks[v as usize])
                .map(|(_, _, w)| w)
                .sum()
        };
        assert!(
            (truth_cut as f64) < 0.3 * random_cut as f64,
            "truth {truth_cut} vs random {random_cut}"
        );
    }

    #[test]
    fn deterministic() {
        let a = lfr_like(500, 8.0, 0.2, &mut Rng::new(5));
        let b = lfr_like(500, 8.0, 0.2, &mut Rng::new(5));
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }
}
