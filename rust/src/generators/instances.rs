//! Named benchmark instance suite — the stand-in for the paper's Table 1.
//!
//! Each entry mirrors a *family* from the paper's collection (p2p,
//! e-mail, social, co-authorship, citation, web) with a deterministic
//! generator + seed, scaled so the full Table-2 protocol runs on one
//! container. The `huge` suite mirrors Table 3/4's web crawls at the
//! largest size practical here.

use super::*;
use crate::graph::csr::Graph;
use crate::util::rng::Rng;

/// Family tag — which paper instance class this stands in for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    PeerToPeer,
    Social,
    Email,
    Citation,
    CoAuthor,
    Web,
    Mesh,
    Synthetic,
}

/// A named, reproducible benchmark instance.
#[derive(Debug, Clone)]
pub struct InstanceSpec {
    pub name: &'static str,
    pub family: Family,
    /// Which paper instance this is modeled after.
    pub models: &'static str,
    pub seed: u64,
    gen: GenKind,
}

#[derive(Debug, Clone)]
enum GenKind {
    Rmat { scale: u32, m: usize, a: f64, b: f64, c: f64 },
    Ba { n: usize, attach: usize },
    Ws { n: usize, k: usize, beta: f64 },
    Er { n: usize, m: usize },
    /// LFR-style: community structure + power-law degrees. `mu` is the
    /// mixing parameter (fraction of inter-community stubs) — low for
    /// web crawls, higher for social networks.
    Lfr { n: usize, avg_deg: f64, mu: f64 },
    Grid { rows: usize, cols: usize },
    Karate,
}

impl InstanceSpec {
    /// Materialize the instance (deterministic for the stored seed).
    /// R-MAT and ER stand-ins are reduced to their largest connected
    /// component — the form in which the paper's real instances are
    /// distributed (crawl giant components, "PGPgiantcompo", …).
    pub fn build(&self) -> Graph {
        let mut rng = Rng::new(self.seed);
        match &self.gen {
            GenKind::Rmat { scale, m, a, b, c } => {
                crate::graph::subgraph::largest_component(&rmat(*scale, *m, *a, *b, *c, &mut rng))
            }
            GenKind::Ba { n, attach } => barabasi_albert(*n, *attach, &mut rng),
            GenKind::Ws { n, k, beta } => watts_strogatz(*n, *k, *beta, &mut rng),
            GenKind::Er { n, m } => {
                crate::graph::subgraph::largest_component(&erdos_renyi(*n, *m, &mut rng))
            }
            GenKind::Lfr { n, avg_deg, mu } => crate::graph::subgraph::largest_component(
                &super::lfr::lfr_like(*n, *avg_deg, *mu, &mut rng).0,
            ),
            GenKind::Grid { rows, cols } => grid2d(*rows, *cols),
            GenKind::Karate => crate::graph::karate::karate_club(),
        }
    }
}

/// The "large graphs" suite (stand-in for Table 1 top block, scaled).
pub fn large_suite() -> Vec<InstanceSpec> {
    vec![
        InstanceSpec {
            name: "karate",
            family: Family::Social,
            models: "sanity (real data)",
            seed: 0,
            gen: GenKind::Karate,
        },
        InstanceSpec {
            name: "p2p-sim",
            family: Family::PeerToPeer,
            models: "p2p-Gnutella04",
            seed: 101,
            gen: GenKind::Er { n: 6400, m: 29000 },
        },
        InstanceSpec {
            name: "word-sim",
            family: Family::Synthetic,
            models: "wordassociation-2011",
            seed: 102,
            gen: GenKind::Ba { n: 10600, attach: 6 },
        },
        InstanceSpec {
            name: "smallworld-sim",
            family: Family::Social,
            models: "small-world contrast (WS)",
            seed: 114,
            gen: GenKind::Ws { n: 20000, k: 4, beta: 0.08 },
        },
        InstanceSpec {
            name: "pgp-sim",
            family: Family::Social,
            models: "PGPgiantcompo",
            seed: 103,
            gen: GenKind::Lfr { n: 10700, avg_deg: 4.6, mu: 0.25 },
        },
        InstanceSpec {
            name: "email-sim",
            family: Family::Email,
            models: "email-EuAll",
            seed: 104,
            gen: GenKind::Rmat { scale: 14, m: 60000, a: 0.57, b: 0.19, c: 0.19 },
        },
        InstanceSpec {
            name: "as-sim",
            family: Family::Web,
            models: "as-22july06",
            seed: 105,
            gen: GenKind::Ba { n: 23000, attach: 2 },
        },
        InstanceSpec {
            name: "slashdot-sim",
            family: Family::Social,
            models: "soc-Slashdot0902",
            seed: 106,
            gen: GenKind::Lfr { n: 28500, avg_deg: 26.0, mu: 0.35 },
        },
        InstanceSpec {
            name: "brightkite-sim",
            family: Family::Social,
            models: "loc-brightkite",
            seed: 107,
            gen: GenKind::Lfr { n: 56700, avg_deg: 7.5, mu: 0.3 },
        },
        InstanceSpec {
            name: "enron-sim",
            family: Family::Email,
            models: "enron",
            seed: 108,
            gen: GenKind::Rmat { scale: 16, m: 254000, a: 0.55, b: 0.2, c: 0.2 },
        },
        InstanceSpec {
            name: "gowalla-sim",
            family: Family::Social,
            models: "loc-gowalla",
            seed: 109,
            gen: GenKind::Lfr { n: 196000, avg_deg: 9.7, mu: 0.3 },
        },
        InstanceSpec {
            name: "coauthor-sim",
            family: Family::CoAuthor,
            models: "coAuthorsCiteseer",
            seed: 110,
            gen: GenKind::Lfr { n: 227000, avg_deg: 7.2, mu: 0.15 },
        },
        InstanceSpec {
            name: "citation-sim",
            family: Family::Citation,
            models: "citationCiteseer",
            seed: 111,
            gen: GenKind::Lfr { n: 268000, avg_deg: 8.6, mu: 0.2 },
        },
        InstanceSpec {
            name: "web-sim",
            family: Family::Web,
            models: "cnr-2000 / web-Google",
            seed: 112,
            gen: GenKind::Lfr { n: 340000, avg_deg: 12.0, mu: 0.08 },
        },
        InstanceSpec {
            name: "mesh-contrast",
            family: Family::Mesh,
            models: "regular-mesh contrast (not in paper's set)",
            seed: 113,
            gen: GenKind::Grid { rows: 300, cols: 300 },
        },
    ]
}

/// Smaller suite for CI-speed tests (subset of `large_suite` shapes).
pub fn tiny_suite() -> Vec<InstanceSpec> {
    vec![
        InstanceSpec {
            name: "karate",
            family: Family::Social,
            models: "sanity",
            seed: 0,
            gen: GenKind::Karate,
        },
        InstanceSpec {
            name: "tiny-rmat",
            family: Family::Web,
            models: "web-like",
            seed: 201,
            gen: GenKind::Rmat { scale: 10, m: 5000, a: 0.57, b: 0.19, c: 0.19 },
        },
        InstanceSpec {
            name: "tiny-ba",
            family: Family::Citation,
            models: "citation-like",
            seed: 202,
            gen: GenKind::Lfr { n: 2000, avg_deg: 8.0, mu: 0.2 },
        },
        InstanceSpec {
            name: "tiny-ws",
            family: Family::Social,
            models: "small-world",
            seed: 203,
            gen: GenKind::Lfr { n: 1500, avg_deg: 10.0, mu: 0.35 },
        },
        InstanceSpec {
            name: "tiny-grid",
            family: Family::Mesh,
            models: "mesh contrast",
            seed: 204,
            gen: GenKind::Grid { rows: 40, cols: 40 },
        },
    ]
}

/// The "huge graphs" suite (stand-in for Tables 3/4, scaled to this
/// container: millions of edges instead of billions).
pub fn huge_suite() -> Vec<InstanceSpec> {
    vec![
        InstanceSpec {
            name: "uk2002-sim",
            family: Family::Web,
            models: "uk-2002 (≈262M edges)",
            seed: 301,
            gen: GenKind::Lfr { n: 1_000_000, avg_deg: 14.0, mu: 0.06 },
        },
        InstanceSpec {
            name: "arabic-sim",
            family: Family::Web,
            models: "arabic-2005 (≈553M edges)",
            seed: 302,
            gen: GenKind::Lfr { n: 1_400_000, avg_deg: 17.0, mu: 0.08 },
        },
        InstanceSpec {
            name: "sk-sim",
            family: Family::Web,
            models: "sk-2005 (≈1.8G edges)",
            seed: 303,
            gen: GenKind::Lfr { n: 1_800_000, avg_deg: 18.0, mu: 0.12 },
        },
        InstanceSpec {
            name: "uk2007-sim",
            family: Family::Web,
            models: "uk-2007 (≈3.3G edges)",
            seed: 304,
            gen: GenKind::Lfr { n: 2_400_000, avg_deg: 16.0, mu: 0.06 },
        },
    ]
}

/// Find an instance by name across all suites.
pub fn by_name(name: &str) -> Option<InstanceSpec> {
    large_suite()
        .into_iter()
        .chain(tiny_suite())
        .chain(huge_suite())
        .find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_suite_builds_and_validates() {
        for spec in tiny_suite() {
            let g = spec.build();
            assert!(g.n() > 0, "{}", spec.name);
            assert!(g.validate().is_ok(), "{}", spec.name);
        }
    }

    #[test]
    fn instances_deterministic() {
        let spec = &tiny_suite()[1];
        assert_eq!(spec.build(), spec.build());
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("karate").is_some());
        assert!(by_name("uk2007-sim").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn large_suite_spans_families() {
        let suite = large_suite();
        assert!(suite.len() >= 12);
        let has = |f: Family| suite.iter().any(|s| s.family == f);
        assert!(has(Family::Web) && has(Family::Social) && has(Family::Mesh));
    }
}
