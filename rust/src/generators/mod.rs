//! Synthetic graph generators — the substitution for the paper's
//! SNAP/LAW/DIMACS instance collection (DESIGN.md §3).
//!
//! The paper's claims depend on *structural class*, not on particular
//! crawls: cluster contraction wins on scale-free small-world networks
//! and ties on regular meshes. We therefore generate:
//!
//! - [`rmat`] — recursive-matrix graphs (Chakrabarti et al.); with the
//!   classic (0.57, 0.19, 0.19) web-graph parameters they reproduce the
//!   heavy-tailed, locally-dense structure of crawls like uk-2002.
//! - [`barabasi_albert`] — preferential attachment; citation /
//!   co-authorship degree laws (coAuthorsDBLP, citationCiteseer).
//! - [`watts_strogatz`] — small-world rewired rings (high clustering,
//!   small diameter; social-network-like neighborhoods).
//! - [`erdos_renyi`] — G(n, m) noise baseline.
//! - [`planted_partition`] — stochastic block model with known ground
//!   truth (used to sanity-check that the pipeline *finds* structure).
//! - [`lfr::lfr_like`] — LFR-style: power-law degrees AND power-law
//!   communities with a mixing parameter; the instance suite's stand-in
//!   for real crawls/social networks, which combine both properties
//!   (pure R-MAT has no community structure, see lfr.rs).
//! - [`grid2d`] / [`torus2d`] — regular meshes, the contrast class where
//!   matching-based coarsening is traditionally fine.

pub mod instances;
pub mod lfr;

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{Graph, NodeId};
use crate::util::rng::Rng;

/// R-MAT generator: `n = 2^scale` nodes, `m` undirected edges, recursive
/// quadrant probabilities (a, b, c); d = 1 - a - b - c.
/// Classic web-graph parameters: a=0.57, b=0.19, c=0.19.
pub fn rmat(scale: u32, m: usize, a: f64, b: f64, c: f64, rng: &mut Rng) -> Graph {
    assert!(a + b + c < 1.0 + 1e-9, "quadrant probabilities must sum < 1");
    let n = 1usize << scale;
    let mut builder = GraphBuilder::with_edge_capacity(n, m);
    let mut produced = 0usize;
    // Oversample: dedup + self-loop drop eats some edges.
    let mut attempts = 0usize;
    let max_attempts = m * 8 + 1024;
    while produced < m && attempts < max_attempts {
        attempts += 1;
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            // noise the quadrant probabilities slightly per level (common
            // practice to avoid exact self-similar striping)
            let (qa, qb, qc) = (a, b, c);
            u <<= 1;
            v <<= 1;
            if r < qa {
                // top-left
            } else if r < qa + qb {
                v |= 1;
            } else if r < qa + qb + qc {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        if u == v {
            continue;
        }
        builder.add_edge(u as NodeId, v as NodeId, 1);
        produced += 1;
    }
    builder.build()
}

/// Barabási–Albert preferential attachment: each new node attaches to
/// `attach` existing nodes, chosen proportionally to degree.
pub fn barabasi_albert(n: usize, attach: usize, rng: &mut Rng) -> Graph {
    assert!(attach >= 1);
    let attach = attach.min(n.saturating_sub(1)).max(1);
    let mut builder = GraphBuilder::with_edge_capacity(n, n * attach);
    // Repeated-endpoint list trick: sampling uniformly from the list of
    // all edge endpoints is sampling proportional to degree.
    let mut endpoints: Vec<NodeId> = Vec::with_capacity(2 * n * attach);
    // Seed with a small clique of `attach + 1` nodes.
    let seed = (attach + 1).min(n);
    for u in 0..seed {
        for v in (u + 1)..seed {
            builder.add_edge(u as NodeId, v as NodeId, 1);
            endpoints.push(u as NodeId);
            endpoints.push(v as NodeId);
        }
    }
    for v in seed..n {
        let mut chosen: Vec<NodeId> = Vec::with_capacity(attach);
        let mut guard = 0;
        while chosen.len() < attach && guard < 50 * attach {
            guard += 1;
            let t = endpoints[rng.below(endpoints.len())];
            if t as usize != v && !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            builder.add_edge(v as NodeId, t, 1);
            endpoints.push(v as NodeId);
            endpoints.push(t);
        }
    }
    builder.build()
}

/// Watts–Strogatz small world: ring lattice with `k` nearest neighbors
/// per side, each edge rewired with probability `beta`.
pub fn watts_strogatz(n: usize, k: usize, beta: f64, rng: &mut Rng) -> Graph {
    assert!(n > 2 * k, "need n > 2k");
    let mut builder = GraphBuilder::with_edge_capacity(n, n * k);
    for v in 0..n {
        for off in 1..=k {
            let u = (v + off) % n;
            if rng.chance(beta) {
                // Rewire the far endpoint uniformly (avoiding v).
                let mut t = rng.below(n);
                let mut guard = 0;
                while (t == v || t == u) && guard < 32 {
                    t = rng.below(n);
                    guard += 1;
                }
                builder.add_edge(v as NodeId, t as NodeId, 1);
            } else {
                builder.add_edge(v as NodeId, u as NodeId, 1);
            }
        }
    }
    builder.build()
}

/// Erdős–Rényi G(n, m): m uniform random edges.
pub fn erdos_renyi(n: usize, m: usize, rng: &mut Rng) -> Graph {
    let mut builder = GraphBuilder::with_edge_capacity(n, m);
    let mut produced = 0;
    let mut attempts = 0;
    while produced < m && attempts < 8 * m + 1024 {
        attempts += 1;
        let u = rng.below(n);
        let v = rng.below(n);
        if u == v {
            continue;
        }
        builder.add_edge(u as NodeId, v as NodeId, 1);
        produced += 1;
    }
    builder.build()
}

/// Planted-partition / stochastic block model: `blocks` groups of
/// `block_size` nodes; intra-block edge probability `p_in`, inter `p_out`.
/// Returns the graph and the ground-truth block of each node.
pub fn planted_partition(
    blocks: usize,
    block_size: usize,
    p_in: f64,
    p_out: f64,
    rng: &mut Rng,
) -> (Graph, Vec<u32>) {
    let n = blocks * block_size;
    let truth: Vec<u32> = (0..n).map(|v| (v / block_size) as u32).collect();
    let mut builder = GraphBuilder::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            let p = if truth[u] == truth[v] { p_in } else { p_out };
            if rng.chance(p) {
                builder.add_edge(u as NodeId, v as NodeId, 1);
            }
        }
    }
    (builder.build(), truth)
}

/// 2D grid mesh (rows × cols, 4-neighborhood) — the "regular" contrast.
pub fn grid2d(rows: usize, cols: usize) -> Graph {
    let n = rows * cols;
    let mut builder = GraphBuilder::with_edge_capacity(n, 2 * n);
    let id = |r: usize, c: usize| (r * cols + c) as NodeId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                builder.add_edge(id(r, c), id(r, c + 1), 1);
            }
            if r + 1 < rows {
                builder.add_edge(id(r, c), id(r + 1, c), 1);
            }
        }
    }
    builder.build()
}

/// 2D torus (wrap-around grid) — regular, no boundary effects.
pub fn torus2d(rows: usize, cols: usize) -> Graph {
    assert!(rows >= 3 && cols >= 3);
    let n = rows * cols;
    let mut builder = GraphBuilder::with_edge_capacity(n, 2 * n);
    let id = |r: usize, c: usize| (r * cols + c) as NodeId;
    for r in 0..rows {
        for c in 0..cols {
            builder.add_edge(id(r, c), id(r, (c + 1) % cols), 1);
            builder.add_edge(id(r, c), id((r + 1) % rows, c), 1);
        }
    }
    builder.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::{component_count, compute_stats};

    #[test]
    fn rmat_shape_and_validity() {
        let mut rng = Rng::new(1);
        let g = rmat(10, 4000, 0.57, 0.19, 0.19, &mut rng);
        assert_eq!(g.n(), 1024);
        assert!(g.m() > 2500, "m={}", g.m()); // dedup loses some
        assert!(g.validate().is_ok());
    }

    #[test]
    fn rmat_is_skewed() {
        let mut rng = Rng::new(2);
        let g = rmat(11, 8000, 0.57, 0.19, 0.19, &mut rng);
        let s = compute_stats(&g, &mut rng);
        assert!(
            s.degree_gini > 0.35,
            "rmat should be skewed, gini={}",
            s.degree_gini
        );
        assert!(s.max_degree > 20 * s.avg_degree as usize / 2);
    }

    #[test]
    fn ba_degree_law() {
        let mut rng = Rng::new(3);
        let g = barabasi_albert(2000, 4, &mut rng);
        assert_eq!(g.n(), 2000);
        assert!(g.validate().is_ok());
        // connected by construction
        assert_eq!(component_count(&g), 1);
        let s = compute_stats(&g, &mut rng);
        assert!(s.degree_gini > 0.25, "gini={}", s.degree_gini);
        assert!(s.min_degree >= 1);
    }

    #[test]
    fn ws_is_small_world() {
        let mut rng = Rng::new(4);
        let g = watts_strogatz(1000, 5, 0.1, &mut rng);
        assert!(g.validate().is_ok());
        let s = compute_stats(&g, &mut rng);
        // ring would have diameter ~100; rewiring collapses it
        assert!(s.approx_diameter < 30, "diam={}", s.approx_diameter);
        assert!(s.clustering_coeff > 0.2, "cc={}", s.clustering_coeff);
    }

    #[test]
    fn er_basic() {
        let mut rng = Rng::new(5);
        let g = erdos_renyi(500, 2000, &mut rng);
        assert_eq!(g.n(), 500);
        assert!(g.m() > 1800);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn sbm_ground_truth_denser_inside() {
        let mut rng = Rng::new(6);
        let (g, truth) = planted_partition(4, 50, 0.3, 0.01, &mut rng);
        assert_eq!(g.n(), 200);
        let (mut intra, mut inter) = (0usize, 0usize);
        for (u, v, _) in g.edges() {
            if truth[u as usize] == truth[v as usize] {
                intra += 1;
            } else {
                inter += 1;
            }
        }
        assert!(intra > 3 * inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn grid_structure() {
        let g = grid2d(10, 7);
        assert_eq!(g.n(), 70);
        assert_eq!(g.m(), 10 * 6 + 9 * 7); // horizontal + vertical
        assert!(g.validate().is_ok());
        assert_eq!(component_count(&g), 1);
    }

    #[test]
    fn torus_is_4_regular() {
        let g = torus2d(5, 6);
        assert_eq!(g.n(), 30);
        for v in g.nodes() {
            assert_eq!(g.degree(v), 4);
        }
        assert!(g.validate().is_ok());
    }

    #[test]
    fn generators_are_deterministic() {
        let g1 = rmat(8, 1000, 0.57, 0.19, 0.19, &mut Rng::new(7));
        let g2 = rmat(8, 1000, 0.57, 0.19, 0.19, &mut Rng::new(7));
        assert_eq!(g1, g2);
        let b1 = barabasi_albert(300, 3, &mut Rng::new(8));
        let b2 = barabasi_albert(300, 3, &mut Rng::new(8));
        assert_eq!(b1, b2);
    }
}
