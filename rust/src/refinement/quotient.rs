//! Quotient-graph pairwise refinement — KaFFPa's "more-localized local
//! searches" (§2.2): for every pair of blocks that share cut edges, run
//! a focused 2-way FM on the *band* around their mutual boundary.
//!
//! Band construction: the boundary nodes of the pair plus `hops` rings
//! of same-pair neighbors. Edges to nodes outside the band are
//! represented exactly by two *virtual terminal* nodes (one per block):
//! a band node's connection to the outside of block `b` becomes an edge
//! to terminal `b`, and the terminal's node weight equals the total
//! outside weight of its block — so block weights and move gains inside
//! the band equal their global values. Terminals are frozen.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{Graph, NodeId, Weight};
use crate::partitioning::metrics::cut_value;
use crate::partitioning::partition::Partition;
use crate::refinement::fm::{kway_fm_frozen, FmConfig};
use crate::util::fast_reset::BitVec;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Refine every cut-sharing block pair of `p` in place.
/// Returns (cut_before, cut_after).
pub fn quotient_pair_refine(
    g: &Graph,
    p: &mut Partition,
    lmax: Weight,
    config: &FmConfig,
    hops: usize,
    rng: &mut Rng,
) -> (Weight, Weight) {
    let before = cut_value(g, &p.blocks);

    // Collect adjacent block pairs (quotient-graph edges).
    let mut pairs: HashMap<(u32, u32), Weight> = HashMap::new();
    for (u, v, w) in g.edges() {
        let (a, b) = (p.block_of(u), p.block_of(v));
        if a != b {
            let key = (a.min(b), a.max(b));
            *pairs.entry(key).or_insert(0) += w;
        }
    }
    // Heaviest pairs first: most improvement potential.
    let mut order: Vec<((u32, u32), Weight)> = pairs.into_iter().collect();
    order.sort_by_key(|&(_, w)| std::cmp::Reverse(w));

    for ((a, b), _) in order {
        refine_pair(g, p, a, b, lmax, config, hops, rng);
    }

    let after = cut_value(g, &p.blocks);
    debug_assert!(after <= before);
    (before, after)
}

/// Run 2-way FM on the band around the (a, b) boundary.
#[allow(clippy::too_many_arguments)]
fn refine_pair(
    g: &Graph,
    p: &mut Partition,
    a: u32,
    b: u32,
    lmax: Weight,
    config: &FmConfig,
    hops: usize,
    rng: &mut Rng,
) {
    // --- band: boundary nodes of the pair + `hops` rings inside a/b ---
    let mut in_band = BitVec::new(g.n());
    let mut band: Vec<NodeId> = Vec::new();
    for v in g.nodes() {
        let bv = p.block_of(v);
        if bv != a && bv != b {
            continue;
        }
        let other = if bv == a { b } else { a };
        if g.adjacent(v).iter().any(|&u| p.block_of(u) == other) {
            in_band.set(v as usize, true);
            band.push(v);
        }
    }
    if band.is_empty() {
        return;
    }
    let mut frontier = band.clone();
    for _ in 0..hops {
        let mut next = Vec::new();
        for &v in &frontier {
            for &u in g.adjacent(v) {
                let bu = p.block_of(u);
                if (bu == a || bu == b) && !in_band.get(u as usize) {
                    in_band.set(u as usize, true);
                    band.push(u);
                    next.push(u);
                }
            }
        }
        frontier = next;
    }

    // --- build the band graph with 2 virtual terminals ---
    // local ids: band nodes 0..nb, terminal_a = nb, terminal_b = nb+1
    let nb = band.len();
    let mut local_of: HashMap<NodeId, u32> = HashMap::with_capacity(nb);
    for (i, &v) in band.iter().enumerate() {
        local_of.insert(v, i as u32);
    }
    let term_a = nb as u32;
    let term_b = nb as u32 + 1;

    let mut builder = GraphBuilder::new(nb + 2);
    let mut outside_weight = [0 as Weight; 2]; // [a, b]
    // outside weight = total block weight minus band part
    let mut band_weight = [0 as Weight; 2];
    for (i, &v) in band.iter().enumerate() {
        builder.set_node_weight(i as u32, g.node_weight(v));
        let bv = p.block_of(v);
        band_weight[if bv == a { 0 } else { 1 }] += g.node_weight(v);
        // edges
        let adj = g.adjacent(v);
        let ws = g.adjacent_weights(v);
        let mut to_term = [0 as Weight; 2];
        for j in 0..adj.len() {
            let u = adj[j];
            match local_of.get(&u) {
                Some(&lu) => {
                    if (i as u32) < lu {
                        builder.add_edge(i as u32, lu, ws[j]);
                    }
                }
                None => {
                    let bu = p.block_of(u);
                    if bu == a {
                        to_term[0] += ws[j];
                    } else if bu == b {
                        to_term[1] += ws[j];
                    }
                    // edges to other blocks are constant cut: ignore
                }
            }
        }
        if to_term[0] > 0 {
            builder.add_edge(i as u32, term_a, to_term[0]);
        }
        if to_term[1] > 0 {
            builder.add_edge(i as u32, term_b, to_term[1]);
        }
    }
    outside_weight[0] = p.block_weights[a as usize] - band_weight[0];
    outside_weight[1] = p.block_weights[b as usize] - band_weight[1];
    builder.set_node_weight(term_a, outside_weight[0].max(0));
    builder.set_node_weight(term_b, outside_weight[1].max(0));
    let band_graph = builder.build();

    // --- local 2-way FM ---
    let mut local_blocks = vec![0u32; nb + 2];
    for (i, &v) in band.iter().enumerate() {
        local_blocks[i] = if p.block_of(v) == a { 0 } else { 1 };
    }
    local_blocks[term_a as usize] = 0;
    local_blocks[term_b as usize] = 1;
    let mut local_p = Partition::from_blocks(&band_graph, 2, local_blocks);
    // Local block weights equal the *global* a/b weights (terminals carry
    // the outside), so the global L_max applies directly.
    let bounds = [lmax, lmax];
    let mut frozen = BitVec::new(nb + 2);
    frozen.set(term_a as usize, true);
    frozen.set(term_b as usize, true);
    let res = kway_fm_frozen(&band_graph, &mut local_p, &bounds, config, Some(&frozen), rng);

    // --- apply only if the local search improved ---
    if res.final_cut < res.initial_cut {
        for (i, &v) in band.iter().enumerate() {
            let target = if local_p.block_of(i as u32) == 0 { a } else { b };
            p.move_node(g, v, target);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::karate::karate_club;

    #[test]
    fn improves_bad_bisection() {
        let g = karate_club();
        let mut rng = Rng::new(1);
        let blocks: Vec<u32> = (0..34u32).map(|v| v % 2).collect();
        let mut p = Partition::from_blocks(&g, 2, blocks);
        let (before, after) =
            quotient_pair_refine(&g, &mut p, 20, &FmConfig::strong(), 2, &mut rng);
        assert!(after < before, "{after} !< {before}");
        assert!(p.validate(&g).is_ok());
        assert!(p.max_block_weight() <= 20);
    }

    #[test]
    fn never_worsens_and_respects_bound_kway() {
        let mut rng = Rng::new(2);
        let g = generators::instances::by_name("tiny-ba").unwrap().build();
        let k = 4;
        let blocks: Vec<u32> = (0..g.n() as u32).map(|v| v % k as u32).collect();
        let mut p = Partition::from_blocks(&g, k, blocks);
        let lmax = crate::coarsening::hierarchy::l_max(
            g.total_node_weight(),
            k,
            0.05,
            g.max_node_weight(),
        );
        let before = cut_value(g_ref(&g), &p.blocks);
        let (_, after) =
            quotient_pair_refine(&g, &mut p, lmax, &FmConfig::eco(), 1, &mut rng);
        assert!(after <= before);
        assert!(p.max_block_weight() <= lmax, "{:?}", p.block_weights);
        assert_eq!(p.nonempty_blocks(), k);
        assert!(p.validate(&g).is_ok());
    }

    fn g_ref(g: &Graph) -> &Graph {
        g
    }

    #[test]
    fn noop_on_optimal_pair() {
        // two cliques split correctly: nothing to improve
        let mut b = crate::graph::builder::GraphBuilder::new(8);
        for base in [0u32, 4] {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    b.add_edge(base + i, base + j, 1);
                }
            }
        }
        b.add_edge(3, 4, 1);
        let g = b.build();
        let mut p = Partition::from_blocks(&g, 2, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        let mut rng = Rng::new(3);
        let (before, after) =
            quotient_pair_refine(&g, &mut p, 5, &FmConfig::strong(), 2, &mut rng);
        assert_eq!(before, 1);
        assert_eq!(after, 1);
        assert_eq!(p.blocks, vec![0, 0, 0, 0, 1, 1, 1, 1]);
    }

    #[test]
    fn terminal_bookkeeping_preserves_global_semantics() {
        // Band-local cut improvement must equal the global improvement.
        let mut rng = Rng::new(4);
        let g = generators::watts_strogatz(300, 4, 0.15, &mut rng);
        let blocks: Vec<u32> = (0..g.n() as u32).map(|_| rng.below(3) as u32).collect();
        let mut p = Partition::from_blocks(&g, 3, blocks);
        let before = cut_value(&g, &p.blocks);
        let (b2, after) = quotient_pair_refine(&g, &mut p, 150, &FmConfig::eco(), 2, &mut rng);
        assert_eq!(before, b2);
        assert_eq!(after, cut_value(&g, &p.blocks));
        assert!(after <= before);
    }
}
