//! Uncoarsening local search: SCLaP-as-refinement (the paper's fast
//! path), k-way boundary FM (the Eco/Strong path) and the greedy
//! rebalancer used by the coarse-level imbalance schedule.

pub mod balance;
pub mod fm;
pub mod lpa_refine;
pub mod quotient;

pub use balance::rebalance;
pub use fm::{
    kway_fm, kway_fm_bounded, kway_fm_frozen, kway_fm_frozen_ws, kway_fm_ws, FmConfig,
    FmResult,
};
pub use lpa_refine::{lpa_refine, lpa_refine_ws, parallel_lpa_refine};
pub use quotient::quotient_pair_refine;
