//! K-way boundary Fiduccia–Mattheyses local search — the refinement
//! family used by KaFFPa's Eco/Strong configurations (§2.2, §5.1).
//!
//! Classic scheme: maintain a bucket priority queue of boundary nodes
//! keyed by the best move gain; pop, move, lock, update neighbors.
//! Negative-gain moves are allowed (hill climbing) and the best prefix
//! of the move sequence is kept — this is what distinguishes FM from
//! greedy refinement and why the Strong configs cut deeper.

use crate::graph::csr::{Graph, NodeId, Weight};
use crate::obs::trace;
use crate::partitioning::partition::Partition;
use crate::partitioning::workspace::VcycleWorkspace;
use crate::util::arena::scratch;
use crate::util::bucket_queue::BucketQueue;
use crate::util::fast_reset::{BitVec, FastResetArray};
use crate::util::rng::Rng;

/// FM tuning parameters.
#[derive(Debug, Clone)]
pub struct FmConfig {
    /// Maximum FM passes (each pass visits the boundary once).
    pub max_passes: usize,
    /// Abort a pass after this many consecutive non-improving moves
    /// (classic adaptive stopping rule).
    pub max_negative_moves: usize,
    /// Fraction of boundary nodes seeded per pass (1.0 = all).
    pub seed_fraction: f64,
}

impl FmConfig {
    /// Eco: cheap — few passes, early abort.
    pub fn eco() -> Self {
        FmConfig {
            max_passes: 3,
            max_negative_moves: 150,
            seed_fraction: 1.0,
        }
    }

    /// Strong: deep — more passes, long hill climbs.
    pub fn strong() -> Self {
        FmConfig {
            max_passes: 10,
            max_negative_moves: 1000,
            seed_fraction: 1.0,
        }
    }
}

/// Result of a refinement call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FmResult {
    pub initial_cut: Weight,
    pub final_cut: Weight,
    pub moves_applied: usize,
    pub passes: usize,
}

/// Connection strengths of `v` towards each adjacent block.
#[inline]
fn connections(
    g: &Graph,
    blocks: &[u32],
    v: NodeId,
    conn: &mut FastResetArray<i64>,
) {
    conn.clear();
    let adj = g.adjacent(v);
    let ws = g.adjacent_weights(v);
    for i in 0..adj.len() {
        conn.add_i64(blocks[adj[i] as usize] as usize, ws[i]);
    }
}

/// Best admissible move for `v`: returns (target, gain).
/// `bounds[b]` is the weight cap of block `b` (uniform `L_max` in k-way
/// refinement; proportional targets in recursive bisection).
#[inline]
fn best_move(
    g: &Graph,
    p: &Partition,
    v: NodeId,
    bounds: &[Weight],
    conn: &mut FastResetArray<i64>,
    rng: &mut Rng,
) -> Option<(u32, i64)> {
    let from = p.block_of(v);
    connections(g, &p.blocks, v, conn);
    let internal = conn.get(from as usize);
    let vw = g.node_weight(v);
    let mut best: Option<(u32, i64)> = None;
    let mut ties = 0u32;
    for &b in conn.touched() {
        let b32 = b as u32;
        if b32 == from {
            continue;
        }
        if p.block_weights[b] + vw > bounds[b] {
            continue;
        }
        let gain = conn.get(b) - internal;
        match best {
            Some((_, bg)) if gain < bg => {}
            Some((_, bg)) if gain == bg => {
                ties += 1;
                if rng.below(ties as usize + 1) == 0 {
                    best = Some((b32, gain));
                }
            }
            _ => {
                best = Some((b32, gain));
                ties = 0;
            }
        }
    }
    best
}

/// Run k-way boundary FM until no pass improves. The partition is
/// modified in place; moves that would push a block over its bound are
/// inadmissible. Blocks are never emptied.
///
/// Uniform-`L_max` convenience wrapper; see [`kway_fm_bounded`].
pub fn kway_fm(
    g: &Graph,
    p: &mut Partition,
    lmax: Weight,
    config: &FmConfig,
    rng: &mut Rng,
) -> FmResult {
    kway_fm_ws(g, p, lmax, config, None, rng)
}

/// [`kway_fm`] with pass scratch (bucket queue, lock bits, boundary
/// seed list, move log, block tables) leased from a workspace when one
/// is supplied — bit-identical result either way, only allocation
/// traffic changes.
pub fn kway_fm_ws(
    g: &Graph,
    p: &mut Partition,
    lmax: Weight,
    config: &FmConfig,
    ws: Option<&VcycleWorkspace>,
    rng: &mut Rng,
) -> FmResult {
    let k = p.k;
    let mut bounds_l = ws.map(|w| w.caller().lease::<Vec<Weight>>(k));
    let mut bounds_o = Vec::new();
    let bounds = scratch(&mut bounds_l, &mut bounds_o);
    bounds.resize(k, lmax);
    kway_fm_frozen_ws(g, p, bounds, config, None, ws, rng)
}

/// K-way boundary FM with a per-block weight bound (`bounds[b]`).
pub fn kway_fm_bounded(
    g: &Graph,
    p: &mut Partition,
    bounds: &[Weight],
    config: &FmConfig,
    rng: &mut Rng,
) -> FmResult {
    kway_fm_frozen(g, p, bounds, config, None, rng)
}

/// K-way boundary FM with per-block bounds and optionally frozen nodes
/// (used by the quotient-graph pair refinement to pin virtual terminals).
pub fn kway_fm_frozen(
    g: &Graph,
    p: &mut Partition,
    bounds: &[Weight],
    config: &FmConfig,
    frozen: Option<&BitVec>,
    rng: &mut Rng,
) -> FmResult {
    kway_fm_frozen_ws(g, p, bounds, config, frozen, None, rng)
}

/// [`kway_fm_frozen`] with all pass scratch leased from a workspace
/// when one is supplied. The per-pass buffers (bucket queue, lock bit
/// vector, boundary list, move log) are additionally hoisted out of the
/// pass loop — they are re-*dimensioned* per pass, never re-allocated —
/// so repeated passes and repeated V-cycle levels run allocation-free
/// once the workspace is warm.
pub fn kway_fm_frozen_ws(
    g: &Graph,
    p: &mut Partition,
    bounds: &[Weight],
    config: &FmConfig,
    frozen: Option<&BitVec>,
    ws: Option<&VcycleWorkspace>,
    rng: &mut Rng,
) -> FmResult {
    assert_eq!(bounds.len(), p.k);
    let arena = ws.map(|w| w.caller());
    let initial_cut = crate::partitioning::metrics::cut_value(g, &p.blocks);
    let mut current_cut = initial_cut;
    let mut conn_l = arena.map(|a| a.lease::<FastResetArray<i64>>(p.k.max(1)));
    let mut conn_o = FastResetArray::new(0);
    let conn = scratch(&mut conn_l, &mut conn_o);
    conn.ensure_capacity(p.k.max(1));
    let max_gain = (g.max_degree() as i64 + 1).max(8);
    let mut passes = 0;
    let mut total_moves = 0usize;

    let mut counts_l = arena.map(|a| a.lease::<Vec<u32>>(p.k));
    let mut counts_o = Vec::new();
    let block_counts = scratch(&mut counts_l, &mut counts_o);
    block_counts.resize(p.k, 0);
    for &b in &p.blocks {
        block_counts[b as usize] += 1;
    }

    // Pass scratch, hoisted: cleared or re-dimensioned at the top of
    // every pass, allocated (at most) once.
    let mut queue_l = arena.map(|a| a.lease::<BucketQueue>(g.n()));
    let mut queue_o = BucketQueue::new(0, 8);
    let queue = scratch(&mut queue_l, &mut queue_o);
    let mut locked_l = arena.map(|a| a.lease::<BitVec>(g.n()));
    let mut locked_o = BitVec::new(0);
    let locked = scratch(&mut locked_l, &mut locked_o);
    let mut boundary_l = arena.map(|a| a.lease::<Vec<NodeId>>(g.n()));
    let mut boundary_o = Vec::new();
    let boundary = scratch(&mut boundary_l, &mut boundary_o);
    let mut log_l = arena.map(|a| a.lease::<Vec<(NodeId, u32)>>(g.n()));
    let mut log_o = Vec::new();
    // Move log for rollback: (node, from_block).
    let log = scratch(&mut log_l, &mut log_o);

    for _ in 0..config.max_passes {
        crate::util::cancel::checkpoint();
        passes += 1;
        // Seed queue with boundary nodes.
        queue.reset(g.n(), max_gain);
        locked.reset_len(g.n());
        boundary.clear();
        boundary.extend(g.nodes().filter(|&v| {
            let bv = p.blocks[v as usize];
            g.adjacent(v).iter().any(|&u| p.blocks[u as usize] != bv)
        }));
        if config.seed_fraction < 1.0 {
            rng.shuffle(boundary);
            let keep = ((boundary.len() as f64) * config.seed_fraction).ceil() as usize;
            boundary.truncate(keep.max(1).min(boundary.len()));
        }
        for &v in boundary.iter() {
            if frozen.map(|f| f.get(v as usize)).unwrap_or(false) {
                continue;
            }
            if let Some((_, gain)) = best_move(g, p, v, bounds, conn, rng) {
                queue.push(v as usize, gain);
            }
        }

        log.clear();
        let mut best_cut = current_cut;
        let mut best_len = 0usize;
        let mut running_cut = current_cut;
        let mut negatives = 0usize;

        while let Some((vu, _stale_gain)) = queue.pop_max() {
            let v = vu as NodeId;
            if locked.get(vu) {
                continue;
            }
            // Revalidate lazily: the stored gain may be stale.
            let Some((target, gain)) = best_move(g, p, v, bounds, conn, rng) else {
                continue;
            };
            let from = p.block_of(v);
            if block_counts[from as usize] <= 1 {
                continue; // never empty a block
            }
            p.move_node(g, v, target);
            block_counts[from as usize] -= 1;
            block_counts[target as usize] += 1;
            locked.set(vu, true);
            log.push((v, from));
            running_cut -= gain;
            total_moves += 1;

            if running_cut < best_cut {
                best_cut = running_cut;
                best_len = log.len();
                negatives = 0;
            } else {
                negatives += 1;
                if negatives > config.max_negative_moves {
                    break;
                }
            }

            // Update neighbors in the queue.
            for &u in g.adjacent(v) {
                let uu = u as usize;
                if locked.get(uu) || frozen.map(|f| f.get(uu)).unwrap_or(false) {
                    continue;
                }
                match best_move(g, p, u, bounds, conn, rng) {
                    Some((_, ug)) => queue.update(uu, ug),
                    None => queue.remove(uu),
                }
            }
        }

        // Roll back past the best prefix.
        for &(v, from) in log[best_len..].iter().rev() {
            let cur = p.block_of(v);
            p.move_node(g, v, from);
            block_counts[cur as usize] -= 1;
            block_counts[from as usize] += 1;
        }
        debug_assert_eq!(
            crate::partitioning::metrics::cut_value(g, &p.blocks),
            best_cut
        );

        let improved = best_cut < current_cut;
        trace::counter(
            "fm_pass",
            &[
                ("pass", passes as i64),
                ("kept_moves", best_len as i64),
                ("cut", best_cut),
            ],
        );
        current_cut = best_cut;
        if !improved {
            break;
        }
    }

    trace::counter(
        "fm_done",
        &[
            ("passes", passes as i64),
            ("initial_cut", initial_cut),
            ("final_cut", current_cut),
            ("moves", total_moves as i64),
        ],
    );
    FmResult {
        initial_cut,
        final_cut: current_cut,
        moves_applied: total_moves,
        passes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::karate::karate_club;
    use crate::partitioning::metrics::cut_value;

    fn two_cliques() -> Graph {
        let mut b = GraphBuilder::new(8);
        for base in [0u32, 4] {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    b.add_edge(base + i, base + j, 1);
                }
            }
        }
        b.add_edge(3, 4, 1);
        b.build()
    }

    #[test]
    fn fm_recovers_clique_split() {
        let g = two_cliques();
        let mut p = Partition::from_blocks(&g, 2, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        let mut rng = Rng::new(1);
        let res = kway_fm(&g, &mut p, 5, &FmConfig::strong(), &mut rng);
        assert_eq!(res.final_cut, 1, "blocks: {:?}", p.blocks);
        assert_eq!(cut_value(&g, &p.blocks), 1);
        assert!(p.validate(&g).is_ok());
    }

    #[test]
    fn fm_never_violates_lmax() {
        let g = karate_club();
        for seed in 0..5 {
            let mut rng = Rng::new(seed);
            let blocks: Vec<u32> = (0..g.n() as u32).map(|v| v % 4).collect();
            let mut p = Partition::from_blocks(&g, 4, blocks);
            let lmax = 10;
            kway_fm(&g, &mut p, lmax, &FmConfig::eco(), &mut rng);
            assert!(p.max_block_weight() <= lmax, "{:?}", p.block_weights);
            assert!(p.validate(&g).is_ok());
        }
    }

    #[test]
    fn fm_never_increases_cut() {
        let g = karate_club();
        for seed in 0..5 {
            let mut rng = Rng::new(seed + 100);
            let blocks: Vec<u32> = (0..g.n() as u32).map(|_| rng.below(3) as u32).collect();
            let mut p = Partition::from_blocks(&g, 3, blocks);
            let before = cut_value(&g, &p.blocks);
            let res = kway_fm(&g, &mut p, 15, &FmConfig::strong(), &mut rng);
            assert!(res.final_cut <= before);
            assert_eq!(res.final_cut, cut_value(&g, &p.blocks));
        }
    }

    #[test]
    fn fm_keeps_all_blocks_nonempty() {
        let g = two_cliques();
        let mut rng = Rng::new(7);
        let mut p = Partition::from_blocks(&g, 4, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        kway_fm(&g, &mut p, 8, &FmConfig::strong(), &mut rng);
        assert_eq!(p.nonempty_blocks(), 4);
    }

    #[test]
    fn fm_noop_on_optimal() {
        let g = two_cliques();
        let mut p = Partition::from_blocks(&g, 2, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        let mut rng = Rng::new(9);
        let res = kway_fm(&g, &mut p, 5, &FmConfig::strong(), &mut rng);
        assert_eq!(res.final_cut, 1);
        assert_eq!(res.initial_cut, 1);
        assert_eq!(p.blocks, vec![0, 0, 0, 0, 1, 1, 1, 1]);
    }
}
