//! Greedy rebalancer: repair partitions that violate `L_max`.
//!
//! Needed because (a) the coarse-level imbalance schedule (§4 "Allowing
//! Larger Imbalances") deliberately produces over-loaded blocks that
//! must be legal by the finest level, and (b) LPA refinement is poor at
//! rebalancing on its own (the paper notes this for CFastV/B).
//!
//! Strategy: while a block exceeds the bound, move its boundary node
//! with the least cut damage (max gain) to the lightest eligible block.

use crate::graph::csr::{Graph, NodeId, Weight};
use crate::partitioning::partition::Partition;
use crate::util::bucket_queue::BucketQueue;
use crate::util::fast_reset::FastResetArray;

/// Rebalance `p` so every block weight ≤ `lmax`. Returns the number of
/// moves made; gives up (returns Err with the remaining overload) if no
/// progress is possible (e.g. a single node heavier than `lmax`).
pub fn rebalance(
    g: &Graph,
    p: &mut Partition,
    lmax: Weight,
) -> Result<usize, Weight> {
    let mut moves = 0usize;
    let mut conn: FastResetArray<i64> = FastResetArray::new(p.k);
    let max_gain = (g.max_degree() as i64 + 1).max(8);

    loop {
        // Find the most overloaded block.
        let Some((over_block, _)) = p
            .block_weights
            .iter()
            .enumerate()
            .filter(|&(_, &w)| w > lmax)
            .max_by_key(|&(_, &w)| w)
        else {
            return Ok(moves);
        };
        let over_block = over_block as u32;

        // Queue all nodes of the overloaded block by move gain.
        let mut queue = BucketQueue::new(g.n(), max_gain);
        for v in g.nodes() {
            if p.block_of(v) != over_block {
                continue;
            }
            if let Some((_, gain)) = best_target(g, p, v, lmax, &mut conn) {
                queue.push(v as usize, gain);
            }
        }

        let mut progressed = false;
        while p.block_weights[over_block as usize] > lmax {
            let Some((vu, _)) = queue.pop_max() else { break };
            let v = vu as NodeId;
            if p.block_of(v) != over_block {
                continue;
            }
            let Some((target, _)) = best_target(g, p, v, lmax, &mut conn) else {
                continue;
            };
            p.move_node(g, v, target);
            moves += 1;
            progressed = true;
        }

        if p.block_weights[over_block as usize] > lmax && !progressed {
            let overload = p.max_block_weight() - lmax;
            return Err(overload);
        }
    }
}

/// Best target block for evacuating `v`: the eligible block with the
/// strongest connection (fallback: the globally lightest block if no
/// neighbor block is eligible — evacuation must make progress even for
/// interior nodes).
fn best_target(
    g: &Graph,
    p: &Partition,
    v: NodeId,
    lmax: Weight,
    conn: &mut FastResetArray<i64>,
) -> Option<(u32, i64)> {
    let from = p.block_of(v);
    let vw = g.node_weight(v);
    conn.clear();
    let adj = g.adjacent(v);
    let ws = g.adjacent_weights(v);
    for i in 0..adj.len() {
        conn.add_i64(p.blocks[adj[i] as usize] as usize, ws[i]);
    }
    let internal = conn.get(from as usize);
    let mut best: Option<(u32, i64)> = None;
    for &b in conn.touched() {
        let b32 = b as u32;
        if b32 == from || p.block_weights[b] + vw > lmax {
            continue;
        }
        let gain = conn.get(b) - internal;
        if best.map(|(_, bg)| gain > bg).unwrap_or(true) {
            best = Some((b32, gain));
        }
    }
    if best.is_some() {
        return best;
    }
    // Interior node or all neighbor blocks full: lightest block overall.
    let (lightest, lw) = p
        .block_weights
        .iter()
        .enumerate()
        .filter(|&(b, _)| b as u32 != from)
        .min_by_key(|&(_, &w)| w)?;
    if lw + vw > lmax {
        return None;
    }
    Some((lightest as u32, -internal))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::karate::karate_club;

    #[test]
    fn rebalance_fixes_overload() {
        let g = karate_club();
        // Everything in block 0 of 2.
        let mut p = Partition::from_blocks(&g, 2, vec![0; 34]);
        let lmax = 18;
        let moves = rebalance(&g, &mut p, lmax).expect("balanceable");
        assert!(moves > 0);
        assert!(p.max_block_weight() <= lmax, "{:?}", p.block_weights);
        assert!(p.validate(&g).is_ok());
    }

    #[test]
    fn rebalance_noop_when_balanced() {
        let g = karate_club();
        let blocks: Vec<u32> = (0..34u32).map(|v| v % 2).collect();
        let mut p = Partition::from_blocks(&g, 2, blocks);
        let moves = rebalance(&g, &mut p, 18).unwrap();
        assert_eq!(moves, 0);
    }

    #[test]
    fn rebalance_reports_impossible() {
        // One node of weight 10, lmax 5: impossible.
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 1);
        b.set_node_weight(0, 10);
        let g = b.build();
        let mut p = Partition::from_blocks(&g, 2, vec![0, 1]);
        assert!(rebalance(&g, &mut p, 5).is_err());
    }

    #[test]
    fn rebalance_prefers_low_damage_moves() {
        // Path a-b-c-d-e; block0={a,b,c,d}, block1={e}; lmax=3.
        // Moving d (boundary) costs nothing extra; moving a would cut 1.
        let mut bld = GraphBuilder::new(5);
        for i in 1..5u32 {
            bld.add_edge(i - 1, i, 1);
        }
        let g = bld.build();
        let mut p = Partition::from_blocks(&g, 2, vec![0, 0, 0, 0, 1]);
        rebalance(&g, &mut p, 3).unwrap();
        assert!(p.max_block_weight() <= 3);
        // d moved to block 1 (cut stays 1)
        assert_eq!(p.blocks, vec![0, 0, 0, 1, 1]);
    }
}
