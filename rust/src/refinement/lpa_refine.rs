//! SCLaP as local search (§3.1 last paragraph): the same size-constrained
//! label propagation engine, run in refinement mode with `W = L_max`.
//! This is the "Fast" refinement of the paper's configurations — much
//! cheaper than FM, surprisingly effective on complex networks, but poor
//! at *re*-balancing (the paper observes exactly that in §5.1, CFastV vs
//! CFastV/B — reproduced in `benches/ablations.rs`).

use crate::clustering::label_propagation::{size_constrained_lpa_ws, LpaConfig};
use crate::clustering::parallel_lpa::{synchronous_round, RoundScratch, SyncMode};
use crate::graph::csr::{Graph, Weight};
use crate::obs::trace;
use crate::partitioning::partition::Partition;
use crate::partitioning::workspace::VcycleWorkspace;
use crate::util::exec::ExecutionCtx;
use crate::util::rng::Rng;

/// Refine `p` in place with SCLaP (active-nodes rounds, §B.2).
/// Returns (cut_before, cut_after).
pub fn lpa_refine(
    g: &Graph,
    p: &mut Partition,
    lmax: Weight,
    iterations: usize,
    rng: &mut Rng,
) -> (Weight, Weight) {
    lpa_refine_ws(g, p, lmax, iterations, None, rng)
}

/// [`lpa_refine`] with LPA round scratch leased from a workspace when
/// one is supplied — bit-identical output either way.
pub fn lpa_refine_ws(
    g: &Graph,
    p: &mut Partition,
    lmax: Weight,
    iterations: usize,
    ws: Option<&VcycleWorkspace>,
    rng: &mut Rng,
) -> (Weight, Weight) {
    let before = crate::partitioning::metrics::cut_value(g, &p.blocks);
    let config = LpaConfig::refinement(iterations);
    let (clustering, _) = size_constrained_lpa_ws(
        g,
        lmax,
        &config,
        Some(p.blocks.clone()),
        None,
        ws,
        rng,
    );
    // Refinement mode never merges blocks out of existence, but the
    // densification may have renamed labels; restore original block ids
    // by majority vote per dense cluster (each dense cluster is exactly
    // one original block since moves only relabel nodes between blocks).
    // Simpler and exact: map each dense label to the original block of
    // any node holding it *before* moves is wrong — instead carry the
    // actual label values: refinement labels ARE block ids before
    // densification. Re-derive from the clustering labels directly.
    let new_blocks = undense_blocks(&clustering.labels, &p.blocks, p.k);
    *p = Partition::from_blocks(g, p.k, new_blocks);
    let after = crate::partitioning::metrics::cut_value(g, &p.blocks);
    // Per-pass refinement gain (both cuts are computed regardless, so
    // this costs nothing beyond the inert-counter check).
    trace::counter(
        "lpa_refine_gain",
        &[("before", before as i64), ("after", after as i64)],
    );
    // Note: `after > before` is legitimate when the overloaded-block
    // rule fires — the paper trades cut for balance there ("at the cost
    // of the number of edges cut", §3.1) — and the repair may be only
    // partial if no eligible target exists yet.
    (before, after)
}

/// Pool-parallel SCLaP refinement: the same size-constrained local
/// search, but with *synchronous* rounds on the shared
/// [`ExecutionCtx`] pool (snapshot-score in fixed chunks, reconcile
/// sequentially in descending-gain order — `clustering::parallel_lpa`
/// semantics, so the overloaded-block rule applies and blocks are never
/// emptied).
///
/// Because refinement labels *are* block ids, no densification or
/// undensing is needed. Output is bit-identical for every pool size
/// given the same `rng` stream (enforced in `rust/tests/properties.rs`);
/// it generally differs from the sequential asynchronous [`lpa_refine`],
/// which visits nodes in degree order with live updates.
pub fn parallel_lpa_refine(
    g: &Graph,
    p: &mut Partition,
    lmax: Weight,
    iterations: usize,
    ctx: &ExecutionCtx,
    rng: &mut Rng,
) -> (Weight, Weight) {
    let before = crate::partitioning::metrics::cut_value(g, &p.blocks);
    let pool = ctx.pool();
    let k = p.k;
    let n = g.n();
    let mut labels = p.blocks.clone();
    // Block tables are round scratch (labels escape into the partition,
    // the tables do not) — leased, so warm V-cycles stop allocating here.
    let arena = ctx.workspace().caller();
    let mut cluster_weight = arena.lease::<Vec<Weight>>(k);
    cluster_weight.extend_from_slice(&p.block_weights);
    let mut cluster_count = arena.lease::<Vec<u32>>(k);
    cluster_count.resize(k, 0);
    for &b in &labels {
        cluster_count[b as usize] += 1;
    }

    let mut rounds = 0usize;
    let mut converged = false;
    for round in 0..iterations {
        crate::util::cancel::checkpoint();
        let round_seed = rng.next_u64();
        let applied = synchronous_round(
            g,
            &mut labels,
            &mut cluster_weight,
            Some(&mut cluster_count),
            lmax,
            SyncMode::Refinement,
            pool,
            RoundScratch::Workspace(ctx.workspace()),
            round_seed,
        );
        rounds = round + 1;
        trace::counter(
            "lpa_refine_round",
            &[("round", round as i64), ("moved", applied as i64)],
        );
        if (applied as f64) < 0.05 * n as f64 {
            converged = true;
            break;
        }
    }
    let reason = if converged {
        crate::obs::quality::STOP_CONVERGED
    } else {
        crate::obs::quality::STOP_MAX_ITERATIONS
    };
    trace::counter(
        "lpa_refine_done",
        &[("rounds", rounds as i64), ("reason", reason)],
    );

    *p = Partition::from_blocks(g, k, labels);
    let after = crate::partitioning::metrics::cut_value(g, &p.blocks);
    trace::counter(
        "lpa_refine_gain",
        &[("before", before as i64), ("after", after as i64)],
    );
    (before, after)
}

/// The LPA engine densifies labels; map dense cluster ids back to block
/// ids `0..k`. Every dense cluster corresponds to exactly one original
/// block (clusters in refinement mode are blocks), so a single
/// co-occurrence vote per cluster suffices — but after moves a node's
/// dense label may pair with several original blocks. The *dense label*
/// is what identifies the block: two nodes share a final block iff they
/// share a dense label. We assign each dense label the id of the block
/// whose members dominate it (stable, keeps ids aligned for V-cycles).
fn undense_blocks(dense: &[u32], original: &[u32], k: usize) -> Vec<u32> {
    let nd = dense.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    // vote[dense][orig] counts — k is small, dense count = k in practice
    let mut votes = vec![0u64; nd * k];
    for v in 0..dense.len() {
        votes[dense[v] as usize * k + original[v] as usize] += 1;
    }
    let mut assignment = vec![0u32; nd];
    let mut taken = vec![false; k];
    // Greedy maximum-vote assignment (nd ≤ k always holds here).
    let mut order: Vec<usize> = (0..nd).collect();
    order.sort_by_key(|&d| std::cmp::Reverse(*votes[d * k..(d + 1) * k].iter().max().unwrap()));
    for &d in &order {
        let mut best = None;
        let mut best_votes = 0u64;
        for b in 0..k {
            if !taken[b] && votes[d * k + b] >= best_votes {
                best = Some(b);
                best_votes = votes[d * k + b];
            }
        }
        let b = best.expect("more dense clusters than blocks");
        taken[b] = true;
        assignment[d] = b as u32;
    }
    dense.iter().map(|&d| assignment[d as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::karate::karate_club;
    use crate::partitioning::metrics::cut_value;

    #[test]
    fn refine_improves_random_partition() {
        let g = karate_club();
        let mut rng = Rng::new(1);
        let blocks: Vec<u32> = (0..g.n() as u32).map(|_| rng.below(2) as u32).collect();
        let mut p = Partition::from_blocks(&g, 2, blocks);
        let lmax = 20;
        let (before, after) = lpa_refine(&g, &mut p, lmax, 10, &mut rng);
        assert!(after <= before);
        assert_eq!(after, cut_value(&g, &p.blocks));
        assert_eq!(p.k, 2);
        assert!(p.validate(&g).is_ok());
    }

    #[test]
    fn refine_keeps_block_count() {
        let g = karate_club();
        let mut rng = Rng::new(2);
        let blocks: Vec<u32> = (0..g.n() as u32).map(|v| v % 4).collect();
        let mut p = Partition::from_blocks(&g, 4, blocks);
        lpa_refine(&g, &mut p, 12, 10, &mut rng);
        assert_eq!(p.nonempty_blocks(), 4);
        assert!(p.max_block_weight() <= 12);
    }

    #[test]
    fn undense_identity() {
        let orig = vec![0u32, 0, 1, 1, 2, 2];
        let out = undense_blocks(&[0, 0, 1, 1, 2, 2], &orig, 3);
        assert_eq!(out, orig);
    }

    #[test]
    fn undense_renamed() {
        // dense labels permuted relative to original blocks
        let orig = vec![2u32, 2, 0, 0, 1, 1];
        let dense = vec![0u32, 0, 1, 1, 2, 2];
        let out = undense_blocks(&dense, &orig, 3);
        assert_eq!(out, orig);
    }

    #[test]
    fn undense_after_moves_majority() {
        // block 0 = {0,1,2}, block 1 = {3}; node 3 joined dense cluster 0
        // after a move — wait, moves change dense labels not originals.
        // dense: {0,1,2,3} all in cluster 0? Then k=2 but nd=1 < k is
        // impossible in refinement (blocks never emptied); use nd=k case:
        let orig = vec![0u32, 0, 1, 1];
        let dense = vec![0u32, 0, 0, 1]; // node 2 moved from block 1 to 0
        let out = undense_blocks(&dense, &orig, 2);
        assert_eq!(out, vec![0, 0, 0, 1]);
    }

    #[test]
    fn parallel_refine_respects_bound_and_blocks() {
        let g = karate_club();
        for threads in [1usize, 2, 4] {
            let ctx = ExecutionCtx::new(threads);
            let mut rng = Rng::new(6);
            let blocks: Vec<u32> = (0..g.n() as u32).map(|v| v % 4).collect();
            let mut p = Partition::from_blocks(&g, 4, blocks);
            parallel_lpa_refine(&g, &mut p, 12, 10, &ctx, &mut rng);
            assert!(p.max_block_weight() <= 12, "threads={threads}");
            assert_eq!(p.nonempty_blocks(), 4);
            assert!(p.validate(&g).is_ok());
        }
    }

    #[test]
    fn parallel_refine_thread_invariant() {
        let mut rng = Rng::new(7);
        let g = crate::generators::barabasi_albert(1500, 3, &mut rng);
        let blocks: Vec<u32> = (0..g.n() as u32).map(|v| v % 3).collect();
        let run = |threads: usize| {
            let ctx = ExecutionCtx::new(threads);
            let mut p = Partition::from_blocks(&g, 3, blocks.clone());
            parallel_lpa_refine(&g, &mut p, 520, 8, &ctx, &mut Rng::new(11));
            p.blocks
        };
        let reference = run(1);
        for threads in [2usize, 4] {
            assert_eq!(reference, run(threads), "threads={threads}");
        }
    }

    #[test]
    fn weighted_graph_refinement() {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1, 10);
        b.add_edge(1, 2, 10);
        b.add_edge(3, 4, 10);
        b.add_edge(4, 5, 10);
        b.add_edge(2, 3, 1);
        let g = b.build();
        // split across the weak edge is optimal; start from a bad split
        // (cut 30). LPA refinement is order-dependent and can stall in a
        // local optimum when U leaves little slack — the paper pairs it
        // with FM for exactly this reason — so assert improvement, not
        // optimality.
        let mut p = Partition::from_blocks(&g, 2, vec![0, 0, 1, 1, 0, 1]);
        let mut rng = Rng::new(3);
        let (before, after) = lpa_refine(&g, &mut p, 4, 10, &mut rng);
        assert_eq!(before, 30);
        assert!(after < before, "after={after}");
        assert!(p.max_block_weight() <= 4);
    }
}
