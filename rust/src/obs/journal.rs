//! Durable ops journal: one JSON line per request lifecycle event,
//! written behind `serve --journal FILE`.
//!
//! The journal is the *durable* complement to the in-memory
//! [`MetricsRegistry`](super::MetricsRegistry): counters answer "how
//! many so far", the journal answers "what happened, when" across
//! restarts. Events are admitted / started / completed / cancelled /
//! busy / cache_hit / error / shutdown — emitted by the net layer and
//! the scheduler, never by the partitioning pipeline, so journaling
//! can never change a result byte (the same invariant tracing pins in
//! `rust/tests/observability.rs`).
//!
//! # Line format
//!
//! Each line is a self-contained JSON object with a fixed field
//! prefix, e.g.:
//!
//! ```text
//! {"seq":3,"ts_ms":1754550000123,"event":"completed","id":"t1","seconds":0.42}
//! ```
//!
//! `seq` is a process-monotonic sequence number (reconciliation key
//! for `scripts/journal_replay.py`); `ts_ms` is wall-clock Unix
//! milliseconds — fine here because journal lines are operator
//! telemetry, never part of a deterministic response. Caller-supplied
//! strings are JSON-escaped; floats render with `{:.6}`.
//!
//! # Rotation
//!
//! With `max_bytes > 0`, a line that would push the current file past
//! the limit first rotates `FILE` → `FILE.1` (replacing any previous
//! `FILE.1`) and starts a fresh `FILE` — bounded disk use with one
//! generation of history. Every line is flushed on write: a crashed
//! process loses at most the line being written.

use crate::util::json::escape_json;
use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// Where the journal writes and when it rotates.
#[derive(Debug, Clone)]
pub struct JournalConfig {
    pub path: PathBuf,
    /// Rotate when a write would push the file past this size;
    /// `0` disables rotation.
    pub max_bytes: u64,
}

impl JournalConfig {
    /// A journal at `path` with the default 16 MiB rotation threshold.
    pub fn new<P: Into<PathBuf>>(path: P) -> JournalConfig {
        JournalConfig {
            path: path.into(),
            max_bytes: 16 << 20,
        }
    }
}

/// One typed field value of a journal line.
#[derive(Debug, Clone, Copy)]
pub enum FieldValue<'a> {
    Str(&'a str),
    Int(i64),
    Float(f64),
    Bool(bool),
}

struct JournalInner {
    writer: BufWriter<File>,
    written: u64,
    seq: u64,
}

/// The durable event sink. Shared via `Arc` between the accept loop,
/// per-connection threads, and the scheduler callback; a poisoned lock
/// is recovered (a panicking connection thread must not silence the
/// journal for everyone else).
pub struct Journal {
    config: JournalConfig,
    inner: Mutex<JournalInner>,
}

impl Journal {
    /// Open (append) the journal at `config.path`.
    pub fn open(config: JournalConfig) -> io::Result<Journal> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&config.path)?;
        let written = file.metadata()?.len();
        Ok(Journal {
            config,
            inner: Mutex::new(JournalInner {
                writer: BufWriter::new(file),
                written,
                seq: 0,
            }),
        })
    }

    /// The journal's file path.
    pub fn path(&self) -> &Path {
        &self.config.path
    }

    /// Append one event line (see the module docs for the format) and
    /// flush it. I/O errors are swallowed: telemetry must never take
    /// the service down.
    pub fn record(&self, event: &str, fields: &[(&str, FieldValue<'_>)]) {
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let mut line = format!(
            "{{\"seq\":{},\"ts_ms\":{ts_ms},\"event\":\"{}\"",
            inner.seq,
            escape_json(event)
        );
        inner.seq += 1;
        for (key, value) in fields {
            line.push_str(&format!(",\"{}\":", escape_json(key)));
            match value {
                FieldValue::Str(s) => {
                    line.push('"');
                    line.push_str(&escape_json(s));
                    line.push('"');
                }
                FieldValue::Int(v) => line.push_str(&v.to_string()),
                FieldValue::Float(v) => line.push_str(&format!("{v:.6}")),
                FieldValue::Bool(v) => line.push_str(if *v { "true" } else { "false" }),
            }
        }
        line.push_str("}\n");
        let len = line.len() as u64;
        if self.config.max_bytes > 0
            && inner.written > 0
            && inner.written + len > self.config.max_bytes
        {
            self.rotate(&mut inner);
        }
        if inner.writer.write_all(line.as_bytes()).is_ok() {
            let _ = inner.writer.flush();
            inner.written += len;
        }
    }

    /// `FILE` → `FILE.1`, fresh `FILE`. On any failure the journal
    /// keeps writing to the old file (bounded-disk is best-effort).
    fn rotate(&self, inner: &mut JournalInner) {
        let _ = inner.writer.flush();
        let mut rotated = self.config.path.as_os_str().to_owned();
        rotated.push(".1");
        if std::fs::rename(&self.config.path, PathBuf::from(&rotated)).is_err() {
            return;
        }
        match OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.config.path)
        {
            Ok(file) => {
                inner.writer = BufWriter::new(file);
                inner.written = 0;
            }
            Err(_) => {
                // Keep the old handle (now FILE.1) rather than lose
                // events entirely.
            }
        }
    }

    /// Flush buffered lines (called on shutdown; each record already
    /// flushes, so this is belt-and-braces).
    pub fn flush(&self) {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let _ = inner.writer.flush();
    }
}

impl std::fmt::Debug for Journal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Journal")
            .field("path", &self.config.path)
            .field("max_bytes", &self.config.max_bytes)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{parse_json, Json};

    fn temp_journal(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sclap-journal-{}-{tag}.jsonl", std::process::id()))
    }

    #[test]
    fn lines_are_valid_json_with_monotonic_seq() {
        let path = temp_journal("basic");
        std::fs::remove_file(&path).ok();
        let journal = Journal::open(JournalConfig::new(&path)).unwrap();
        journal.record("admitted", &[("id", FieldValue::Str("t1"))]);
        journal.record(
            "completed",
            &[
                ("id", FieldValue::Str("t1")),
                ("seconds", FieldValue::Float(0.25)),
                ("cached", FieldValue::Bool(false)),
                ("cut", FieldValue::Int(42)),
            ],
        );
        journal.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let json = parse_json(line).expect("journal line parses");
            assert_eq!(json.get("seq").and_then(Json::as_i64), Some(i as i64));
            assert!(json.get("ts_ms").and_then(Json::as_i64).unwrap() > 0);
        }
        let done = parse_json(lines[1]).unwrap();
        assert_eq!(done.get("event").and_then(Json::as_str), Some("completed"));
        assert_eq!(done.get("cut").and_then(Json::as_i64), Some(42));
        assert_eq!(done.get("cached").and_then(Json::as_bool), Some(false));
        assert_eq!(done.get("seconds").and_then(Json::as_f64), Some(0.25));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn hostile_strings_are_escaped() {
        let path = temp_journal("escape");
        std::fs::remove_file(&path).ok();
        let journal = Journal::open(JournalConfig::new(&path)).unwrap();
        let hostile = "a\"b\\c\nd\te";
        journal.record("error", &[("id", FieldValue::Str(hostile))]);
        let text = std::fs::read_to_string(&path).unwrap();
        let json = parse_json(text.lines().next().unwrap()).expect("escaped line parses");
        assert_eq!(json.get("id").and_then(Json::as_str), Some(hostile));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rotation_keeps_one_generation() {
        let path = temp_journal("rotate");
        let rotated = PathBuf::from(format!("{}.1", path.display()));
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&rotated).ok();
        let journal = Journal::open(JournalConfig {
            path: path.clone(),
            max_bytes: 200,
        })
        .unwrap();
        for i in 0..20 {
            journal.record("admitted", &[("i", FieldValue::Int(i))]);
        }
        assert!(rotated.exists(), "rotation must produce FILE.1");
        let head = std::fs::metadata(&path).unwrap().len();
        assert!(head <= 200, "head file stays under the threshold, got {head}");
        // Every surviving line still parses, and seqs stay monotonic
        // across the rotation boundary.
        let mut seqs = Vec::new();
        for file in [&rotated, &path] {
            for line in std::fs::read_to_string(file).unwrap().lines() {
                seqs.push(parse_json(line).unwrap().get("seq").and_then(Json::as_i64).unwrap());
            }
        }
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "seqs monotonic: {seqs:?}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&rotated).ok();
    }

    #[test]
    fn reopen_appends_after_existing_content() {
        let path = temp_journal("reopen");
        std::fs::remove_file(&path).ok();
        {
            let journal = Journal::open(JournalConfig::new(&path)).unwrap();
            journal.record("admitted", &[]);
        }
        {
            let journal = Journal::open(JournalConfig::new(&path)).unwrap();
            journal.record("shutdown", &[]);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "append, not truncate");
        std::fs::remove_file(&path).ok();
    }
}
