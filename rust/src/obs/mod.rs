//! Observability: deterministic tracing ([`trace`]) and the unified
//! metrics registry ([`metrics`]).
//!
//! The two pieces split along the *when* axis. **Tracing** answers
//! "what did this run do, in order": hierarchical spans and counter
//! events on per-repetition logical tracks, merged into one
//! deterministic stream and exported as Chrome `trace_event` JSON
//! (`partition --trace FILE`, `serve --trace FILE`). **Metrics**
//! answer "how much, so far": typed counters/gauges/histograms plus
//! the per-phase wall-clock table, snapshotted on demand by `serve
//! --timing`, benches, and the wire `!stats` command.
//!
//! Both hang off [`ExecutionCtx`](crate::util::exec::ExecutionCtx):
//! every context owns a [`MetricsRegistry`] (so all layers built on
//! the context — queue, cache, net server — share one instrument
//! space) and optionally carries a [`Tracer`]. The crate-wide
//! invariant: **observability never changes results.** Tracing on or
//! off, the partition bytes and every deterministic wire field are
//! identical (`rust/tests/observability.rs`); with no tracer attached
//! the instrumentation points cost one thread-local `Option` check and
//! take no locks.

pub mod metrics;
pub mod trace;

pub use metrics::{
    bucket_index, bucket_upper_bound, Counter, Gauge, Histogram, MetricsRegistry, PhaseStat,
    HISTOGRAM_BINS,
};
pub use trace::{
    counter, span, tracing_active, EventKind, SpanGuard, TraceEvent, Tracer, TrackScope,
};
