//! Observability: deterministic tracing ([`trace`]) and the unified
//! metrics registry ([`metrics`]).
//!
//! The two pieces split along the *when* axis. **Tracing** answers
//! "what did this run do, in order": hierarchical spans and counter
//! events on per-repetition logical tracks, merged into one
//! deterministic stream and exported as Chrome `trace_event` JSON
//! (`partition --trace FILE`, `serve --trace FILE`). **Metrics**
//! answer "how much, so far": typed counters/gauges/histograms plus
//! the per-phase wall-clock table, snapshotted on demand by `serve
//! --timing`, benches, and the wire `!stats` command.
//!
//! Two quality/ops layers build on those primitives. **Quality
//! explainability** ([`quality`]): `explain=true` on a request makes
//! the scheduler collect that request's trace lanes into a
//! deterministic, worker-count-invariant [`QualityReport`] — per-level
//! coarsening lineage, LPA convergence telemetry, refinement gains —
//! appended to the response JSON. **Ops telemetry**: the durable
//! lifecycle [`Journal`] behind `serve --journal FILE` and the
//! Prometheus text exposition behind the wire `!metrics` command
//! ([`MetricsRegistry::render_prometheus`]).
//!
//! Both hang off [`ExecutionCtx`](crate::util::exec::ExecutionCtx):
//! every context owns a [`MetricsRegistry`] (so all layers built on
//! the context — queue, cache, net server — share one instrument
//! space) and optionally carries a [`Tracer`]. The crate-wide
//! invariant: **observability never changes results.** Tracing on or
//! off, the partition bytes and every deterministic wire field are
//! identical (`rust/tests/observability.rs`); with no tracer attached
//! the instrumentation points cost one thread-local `Option` check and
//! take no locks.

pub mod journal;
pub mod metrics;
pub mod quality;
pub mod trace;

pub use journal::{Journal, JournalConfig};
pub use metrics::{
    bucket_index, bucket_upper_bound, escape_label_value, Counter, Gauge, Histogram,
    MetricsRegistry, PhaseStat, RollingWindow, WindowSnapshot, HISTOGRAM_BINS,
};
pub use quality::QualityReport;
pub use trace::{
    counter, span, tracing_active, EventKind, SpanGuard, TraceEvent, Tracer, TrackScope,
};
