//! The unified metrics registry: typed counters, gauges, and
//! log-bucketed histograms behind one handle.
//!
//! Before this existed every layer kept private tallies — the net cache
//! its `CacheStats` under the map lock, the arena its `ArenaStats`
//! atomics, `ExecutionCtx` a flat phase-timing table — and `serve
//! --timing` / bench reports each hand-picked fields from whichever
//! struct they could reach. The [`MetricsRegistry`] absorbs all of
//! them: instruments are registered once by `&'static str` name,
//! updated lock-free (plain atomics), and snapshotted deterministically
//! (sorted by name) for the wire `!stats` command and for tests.
//!
//! The registry is **instantiable, not a process global**: every
//! [`ExecutionCtx`](crate::util::exec::ExecutionCtx) owns one
//! (`Arc`-shared with the queue, cache, and server built on that
//! context), so tests and embedded services get isolated counter
//! spaces for free.
//!
//! # Instruments
//!
//! - [`Counter`] — monotonically increasing `u64` (events, rejections,
//!   cache hits).
//! - [`Gauge`] — last-write-wins `i64` (queue depth, uptime).
//! - [`Histogram`] — fixed-bin log₂ histogram of `u64` samples: bucket
//!   0 holds exactly the value 0 and bucket `i ≥ 1` holds
//!   `2^(i-1) ≤ v < 2^i`, so 65 bins cover the full `u64` range with
//!   no configuration and no allocation per sample.
//!
//! Lookup takes the registry lock; updates touch only the instrument's
//! atomics. Hot paths therefore resolve their instrument handle once
//! (`Arc<Counter>`) and increment it lock-free forever after.
//!
//! # Cancellation counters
//!
//! The cooperative-cancellation layer (`util::cancel`,
//! `queue::scheduler`) reports through this registry:
//! `requests_cancelled` counts every request reaped with a cancelled
//! reply, one of `cancel_reason_timeout` / `cancel_reason_disconnect` /
//! `cancel_reason_race_lost` / `cancel_reason_abandoned` (fixed names —
//! counter names must be `&'static str`, see
//! [`CancelReason::counter_name`](crate::util::cancel::CancelReason::counter_name))
//! records why, and `race_losers_cancelled` counts ensemble-race
//! configs whose remaining repetitions were cancelled after the
//! decision wave. All are visible over the wire via `!stats`.
//!
//! # Phase table
//!
//! The phase-timing sink that used to live inside `ExecutionCtx` moved
//! here, keyed by `(&'static str, Option<u32>)` — name **plus an
//! optional level index**. Drivers that reuse one phase name across
//! hierarchy levels (`external_coarsening` per out-of-core level,
//! `uncoarsening` per V-cycle level) record with
//! [`record_phase`](MetricsRegistry::record_phase)`(name, Some(level),
//! secs)` and no longer collapse into one bucket;
//! [`phase_stats`](MetricsRegistry::phase_stats) still aggregates
//! across levels for the old flat view.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Number of histogram bins: bucket 0 (the value 0) plus one power-of-
/// two bucket per bit of `u64`.
pub const HISTOGRAM_BINS: usize = 65;

/// Log₂ bucket index of a sample: 0 for 0, else `i` with
/// `2^(i-1) ≤ v < 2^i` (i.e. `64 - v.leading_zeros()`).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i` (`0` for bucket 0, else
/// `2^i − 1`); the boundaries [`bucket_index`] sorts against.
#[inline]
pub fn bucket_upper_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bin log₂ histogram (module docs). All updates are relaxed
/// atomics; `count`/`sum`/bucket totals are therefore each exact, and
/// mutually consistent whenever the histogram is quiescent (the only
/// time snapshots are compared in tests).
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HISTOGRAM_BINS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Histogram {
    #[inline]
    pub fn observe(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// `(bucket_index, count)` for every non-empty bucket, ascending.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then_some((i, c))
            })
            .collect()
    }

    /// Estimate the `q`-quantile (`0.0 ≤ q ≤ 1.0`) from the log₂
    /// buckets, with **upper-bound semantics**: the result is
    /// [`bucket_upper_bound`] of the bucket containing the rank-⌈q·n⌉
    /// sample, i.e. an inclusive upper bound on the true quantile that
    /// is exact only when every sample in that bucket equals the bound.
    /// The error is bounded by the bucket width (< 2× the true value
    /// for nonzero samples). `None` when the histogram is empty.
    ///
    /// `quantile(0.0)` is the upper bound of the first non-empty
    /// bucket; `quantile(1.0)` of the last.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= rank {
                return Some(bucket_upper_bound(i));
            }
        }
        // Unreachable for a quiescent histogram (cum ends at count);
        // racing observers can leave buckets behind count momentarily.
        Some(bucket_upper_bound(HISTOGRAM_BINS - 1))
    }
}

/// Summary of a [`RollingWindow`] at one instant: how many samples the
/// window currently holds, the implied rate, and exact (not bucketed)
/// latency quantiles over those samples.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WindowSnapshot {
    /// Samples inside the window.
    pub count: u64,
    /// `count / window`, scaled by 1000 (milli-requests per second) so
    /// sub-1/s rates stay visible as integer gauges.
    pub rps_milli: u64,
    /// Exact median of the windowed values (0 when empty).
    pub p50: u64,
    /// Exact 99th percentile of the windowed values (0 when empty).
    pub p99: u64,
}

/// A sliding time window over `(Instant, u64)` samples — the rolling
/// req/s and latency view behind the `net_window_*` gauges, which the
/// cumulative [`Histogram`]s cannot provide (they never forget).
///
/// Unlike the lock-free instruments this takes a mutex per update; it
/// is fed once per completed network request, far off any hot path.
/// Sample count is bounded ([`RollingWindow::MAX_SAMPLES`]); beyond the
/// bound the oldest samples fall off early, biasing a flooded window
/// toward recent traffic — acceptable for an ops gauge.
///
/// The `*_at` methods take an explicit `now` so tests inject time
/// instead of sleeping.
#[derive(Debug)]
pub struct RollingWindow {
    window: Duration,
    samples: Mutex<VecDeque<(Instant, u64)>>,
}

impl RollingWindow {
    /// Hard bound on retained samples.
    pub const MAX_SAMPLES: usize = 4096;

    /// A window covering the trailing `window` of wall time.
    pub fn new(window: Duration) -> RollingWindow {
        RollingWindow {
            window: window.max(Duration::from_millis(1)),
            samples: Mutex::new(VecDeque::new()),
        }
    }

    /// Record `value` (e.g. a request latency in µs) now.
    pub fn record(&self, value: u64) {
        self.record_at(Instant::now(), value);
    }

    /// [`RollingWindow::record`] with an injected clock.
    pub fn record_at(&self, now: Instant, value: u64) {
        let mut samples = self.lock();
        Self::prune(&mut samples, now, self.window);
        if samples.len() >= Self::MAX_SAMPLES {
            samples.pop_front();
        }
        samples.push_back((now, value));
    }

    /// Snapshot the window as of now.
    pub fn snapshot(&self) -> WindowSnapshot {
        self.snapshot_at(Instant::now())
    }

    /// [`RollingWindow::snapshot`] with an injected clock.
    pub fn snapshot_at(&self, now: Instant) -> WindowSnapshot {
        let mut samples = self.lock();
        Self::prune(&mut samples, now, self.window);
        let count = samples.len() as u64;
        if count == 0 {
            return WindowSnapshot::default();
        }
        let mut values: Vec<u64> = samples.iter().map(|&(_, v)| v).collect();
        drop(samples);
        values.sort_unstable();
        let quantile = |q: f64| {
            let rank = ((q * count as f64).ceil() as usize).clamp(1, values.len());
            values[rank - 1]
        };
        let window_ms = self.window.as_millis().max(1) as u64;
        WindowSnapshot {
            count,
            rps_milli: count.saturating_mul(1_000_000) / window_ms,
            p50: quantile(0.5),
            p99: quantile(0.99),
        }
    }

    fn prune(samples: &mut VecDeque<(Instant, u64)>, now: Instant, window: Duration) {
        while let Some(&(t, _)) = samples.front() {
            // `duration_since` saturates to zero for t > now (clock
            // skew between threads), which keeps such samples.
            if now.duration_since(t) > window {
                samples.pop_front();
            } else {
                break;
            }
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, VecDeque<(Instant, u64)>> {
        self.samples.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// Escape a Prometheus label value: backslash, double quote, and
/// newline, per the text-format spec. Everything else passes through
/// verbatim (UTF-8 label values are legal).
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Aggregate wall-clock of one named phase (the type
/// `util::exec::PhaseStat` re-exports).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseStat {
    pub calls: usize,
    pub seconds: f64,
}

#[derive(Default)]
struct Instruments {
    counters: BTreeMap<&'static str, Arc<Counter>>,
    gauges: BTreeMap<&'static str, Arc<Gauge>>,
    histograms: BTreeMap<&'static str, Arc<Histogram>>,
    phases: BTreeMap<(&'static str, Option<u32>), PhaseStat>,
}

/// The typed instrument registry (module docs). Cheap to share via
/// `Arc`; one per [`ExecutionCtx`](crate::util::exec::ExecutionCtx).
pub struct MetricsRegistry {
    start: Instant,
    inner: Mutex<Instruments>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry {
            start: Instant::now(),
            inner: Mutex::new(Instruments::default()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Instruments> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Seconds since the registry (≈ its owning service) was created —
    /// the uptime the wire `!ping` / `!stats` responses report.
    pub fn uptime_seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Get-or-register the named counter. Lookup locks the registry;
    /// hold the returned handle to update lock-free.
    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        self.lock().counters.entry(name).or_default().clone()
    }

    /// Get-or-register the named gauge.
    pub fn gauge(&self, name: &'static str) -> Arc<Gauge> {
        self.lock().gauges.entry(name).or_default().clone()
    }

    /// Get-or-register the named histogram.
    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        self.lock().histograms.entry(name).or_default().clone()
    }

    /// Accumulate `seconds` of wall-clock into phase `name`, optionally
    /// attributed to one hierarchy `level` (module docs).
    pub fn record_phase(&self, name: &'static str, level: Option<u32>, seconds: f64) {
        let mut inner = self.lock();
        let entry = inner.phases.entry((name, level)).or_default();
        entry.calls += 1;
        entry.seconds += seconds;
    }

    /// Flat phase view: stats aggregated across levels, sorted by phase
    /// name — the shape `ExecutionCtx::phase_stats` has always returned.
    pub fn phase_stats(&self) -> Vec<(&'static str, PhaseStat)> {
        let inner = self.lock();
        let mut flat: BTreeMap<&'static str, PhaseStat> = BTreeMap::new();
        for (&(name, _level), stat) in &inner.phases {
            let e = flat.entry(name).or_default();
            e.calls += stat.calls;
            e.seconds += stat.seconds;
        }
        flat.into_iter().collect()
    }

    /// Per-level phase view: `(name, level)` keys verbatim, sorted by
    /// name then level (levelless entries first).
    pub fn phase_stats_by_level(&self) -> Vec<((&'static str, Option<u32>), PhaseStat)> {
        let inner = self.lock();
        inner.phases.iter().map(|(k, v)| (*k, *v)).collect()
    }

    /// Render the whole registry as the inner fields of one JSON object
    /// (no surrounding braces). **The ordering is a contract** (pinned
    /// by a unit test) so journal/metrics diffs are stable across runs:
    ///
    /// - sections in fixed order: `"counters":{…},"gauges":{…},
    ///   "histograms":{…},"phases":[…]`;
    /// - within `counters`/`gauges`/`histograms`, keys in sorted
    ///   (byte-order) name order;
    /// - each histogram renders `{"count":…,"sum":…,"p50":…,"p99":…,
    ///   "buckets":[[i,c],…]}` — `p50`/`p99` are
    ///   [`Histogram::quantile`] upper bounds (`0` when empty), buckets
    ///   are the non-empty `[bucket_index, count]` pairs ascending;
    /// - `phases` entries sorted by `(name, level)` with levelless
    ///   entries first, each `{"name":…,"level":…,"calls":…,
    ///   "seconds":…}` (seconds to 6 decimal places — the one
    ///   nondeterministic value).
    ///
    /// Deterministic for a quiescent registry, so tests can compare
    /// snapshots byte-for-byte.
    pub fn render_json_fields(&self) -> String {
        let inner = self.lock();
        let mut out = String::new();
        out.push_str("\"counters\":{");
        for (i, (name, c)) in inner.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{name}\":{}", c.get()));
        }
        out.push_str("},\"gauges\":{");
        for (i, (name, g)) in inner.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{name}\":{}", g.get()));
        }
        out.push_str("},\"histograms\":{");
        for (i, (name, h)) in inner.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let buckets: Vec<String> = h
                .nonzero_buckets()
                .iter()
                .map(|(b, c)| format!("[{b},{c}]"))
                .collect();
            out.push_str(&format!(
                "\"{name}\":{{\"count\":{},\"sum\":{},\"p50\":{},\"p99\":{},\"buckets\":[{}]}}",
                h.count(),
                h.sum(),
                h.quantile(0.5).unwrap_or(0),
                h.quantile(0.99).unwrap_or(0),
                buckets.join(",")
            ));
        }
        out.push_str("},\"phases\":[");
        for (i, (&(name, level), stat)) in inner.phases.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let level = match level {
                None => "null".to_string(),
                Some(l) => l.to_string(),
            };
            out.push_str(&format!(
                "{{\"name\":\"{name}\",\"level\":{level},\"calls\":{},\"seconds\":{:.6}}}",
                stat.calls, stat.seconds
            ));
        }
        out.push(']');
        out
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (the wire `!metrics` payload). Layout, in order:
    ///
    /// - every counter as `sclap_<name>_total` (`# TYPE … counter`),
    ///   sorted by name;
    /// - every gauge as `sclap_<name>` (`# TYPE … gauge`), sorted;
    /// - every histogram as `sclap_<name>` (`# TYPE … histogram`):
    ///   cumulative `_bucket{le="…"}` series over the non-empty log₂
    ///   buckets ([`bucket_upper_bound`] boundaries) plus the mandatory
    ///   `le="+Inf"` bucket, then `_sum` and `_count`, then derived
    ///   `sclap_<name>_p50` / `sclap_<name>_p99` helper gauges
    ///   ([`Histogram::quantile`] upper bounds; omitted while empty);
    /// - the phase table as `sclap_phase_calls_total` /
    ///   `sclap_phase_seconds_total` labeled
    ///   `{phase="…",level="…"}` (level `""` for levelless entries),
    ///   label values escaped via [`escape_label_value`].
    ///
    /// Instrument names are `&'static str` idents (`[a-z0-9_]`), which
    /// is exactly the legal Prometheus name alphabet — only label
    /// *values* need escaping. Ordering is deterministic for a
    /// quiescent registry, like [`render_json_fields`]
    /// (`MetricsRegistry::render_json_fields`).
    pub fn render_prometheus(&self) -> String {
        let inner = self.lock();
        let mut out = String::new();
        for (name, c) in &inner.counters {
            out.push_str(&format!(
                "# TYPE sclap_{name}_total counter\nsclap_{name}_total {}\n",
                c.get()
            ));
        }
        for (name, g) in &inner.gauges {
            out.push_str(&format!(
                "# TYPE sclap_{name} gauge\nsclap_{name} {}\n",
                g.get()
            ));
        }
        for (name, h) in &inner.histograms {
            out.push_str(&format!("# TYPE sclap_{name} histogram\n"));
            let mut cum = 0u64;
            for (i, c) in h.nonzero_buckets() {
                cum += c;
                out.push_str(&format!(
                    "sclap_{name}_bucket{{le=\"{}\"}} {cum}\n",
                    bucket_upper_bound(i)
                ));
            }
            out.push_str(&format!("sclap_{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
            out.push_str(&format!("sclap_{name}_sum {}\n", h.sum()));
            out.push_str(&format!("sclap_{name}_count {}\n", h.count()));
            if let (Some(p50), Some(p99)) = (h.quantile(0.5), h.quantile(0.99)) {
                out.push_str(&format!(
                    "# TYPE sclap_{name}_p50 gauge\nsclap_{name}_p50 {p50}\n\
                     # TYPE sclap_{name}_p99 gauge\nsclap_{name}_p99 {p99}\n"
                ));
            }
        }
        if !inner.phases.is_empty() {
            out.push_str("# TYPE sclap_phase_calls_total counter\n");
            out.push_str("# TYPE sclap_phase_seconds_total counter\n");
            for (&(name, level), stat) in &inner.phases {
                let level = level.map(|l| l.to_string()).unwrap_or_default();
                let labels = format!(
                    "{{phase=\"{}\",level=\"{}\"}}",
                    escape_label_value(name),
                    escape_label_value(&level)
                );
                out.push_str(&format!(
                    "sclap_phase_calls_total{labels} {}\nsclap_phase_seconds_total{labels} {:.6}\n",
                    stat.calls, stat.seconds
                ));
            }
        }
        out
    }
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.lock();
        f.debug_struct("MetricsRegistry")
            .field("counters", &inner.counters.len())
            .field("gauges", &inner.gauges.len())
            .field("histograms", &inner.histograms.len())
            .field("phases", &inner.phases.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        // Bucket 0 is exactly the value 0.
        assert_eq!(bucket_index(0), 0);
        // Bucket i holds 2^(i-1) ≤ v < 2^i: check both edges of every
        // bucket that has them.
        for i in 1..=63usize {
            let lo = 1u64 << (i - 1);
            let hi = (1u64 << i) - 1;
            assert_eq!(bucket_index(lo), i, "low edge of bucket {i}");
            assert_eq!(bucket_index(hi), i, "high edge of bucket {i}");
        }
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_index(1u64 << 63), 64);
        // Upper bounds are consistent with the index function.
        assert_eq!(bucket_upper_bound(0), 0);
        assert_eq!(bucket_upper_bound(1), 1);
        assert_eq!(bucket_upper_bound(4), 15);
        assert_eq!(bucket_upper_bound(64), u64::MAX);
        for i in 0..64usize {
            assert_eq!(bucket_index(bucket_upper_bound(i)), i);
        }
    }

    #[test]
    fn histogram_observes_into_the_right_bins() {
        let h = Histogram::default();
        for v in [0u64, 1, 2, 3, 4, 1024, u64::MAX] {
            h.observe(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 0u64.wrapping_add(1 + 2 + 3 + 4 + 1024).wrapping_add(u64::MAX));
        assert_eq!(
            h.nonzero_buckets(),
            vec![(0, 1), (1, 1), (2, 2), (3, 1), (11, 1), (64, 1)]
        );
    }

    #[test]
    fn registry_handles_are_shared() {
        let r = MetricsRegistry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        b.add(2);
        assert_eq!(r.counter("x").get(), 3);
        r.gauge("depth").set(5);
        assert_eq!(r.gauge("depth").get(), 5);
    }

    #[test]
    fn phase_table_keeps_levels_apart_and_flat_view_aggregates() {
        let r = MetricsRegistry::new();
        r.record_phase("uncoarsening", Some(0), 1.0);
        r.record_phase("uncoarsening", Some(1), 2.0);
        r.record_phase("uncoarsening", Some(1), 3.0);
        r.record_phase("coarsening", None, 4.0);
        let by_level = r.phase_stats_by_level();
        assert_eq!(by_level.len(), 3);
        assert_eq!(by_level[1].0, ("uncoarsening", Some(0)));
        assert_eq!(by_level[2].0, ("uncoarsening", Some(1)));
        assert_eq!(by_level[2].1.calls, 2);
        assert!((by_level[2].1.seconds - 5.0).abs() < 1e-12);
        let flat = r.phase_stats();
        assert_eq!(flat.len(), 2);
        assert_eq!(flat[1].0, "uncoarsening");
        assert_eq!(flat[1].1.calls, 3);
        assert!((flat[1].1.seconds - 6.0).abs() < 1e-12);
    }

    #[test]
    fn json_fields_render_deterministically() {
        let r = MetricsRegistry::new();
        r.counter("b").add(2);
        r.counter("a").inc();
        r.gauge("g").set(-7);
        r.histogram("h").observe(3);
        r.record_phase("p", Some(2), 0.5);
        let s = format!("{{{}}}", r.render_json_fields());
        assert_eq!(
            s,
            "{\"counters\":{\"a\":1,\"b\":2},\"gauges\":{\"g\":-7},\
             \"histograms\":{\"h\":{\"count\":1,\"sum\":3,\"p50\":3,\"p99\":3,\"buckets\":[[2,1]]}},\
             \"phases\":[{\"name\":\"p\",\"level\":2,\"calls\":1,\"seconds\":0.500000}]}"
        );
        // And it parses as JSON.
        crate::util::json::parse_json(&s).expect("valid json");
    }

    #[test]
    fn quantiles_report_bucket_upper_bounds() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), None, "empty histogram has no quantiles");
        // Samples 1..=100: bucket i holds 2^(i-1)..2^i, so the median
        // sample (rank 50) lands in bucket 6 (32..=63) whose upper
        // bound is 63, and rank 99 in bucket 7 (64..=100 observed).
        for v in 1..=100u64 {
            h.observe(v);
        }
        assert_eq!(h.quantile(0.5), Some(63));
        assert_eq!(h.quantile(0.99), Some(127));
        assert_eq!(h.quantile(1.0), Some(127));
        // q=0 pins to the first non-empty bucket's bound.
        assert_eq!(h.quantile(0.0), Some(1));
        // Exact at bucket boundaries when the bucket is a single value:
        // all-zero samples sit in bucket 0, upper bound 0.
        let z = Histogram::default();
        z.observe(0);
        z.observe(0);
        assert_eq!(z.quantile(0.5), Some(0));
        assert_eq!(z.quantile(0.99), Some(0));
        // A single sample answers every quantile with its bucket bound.
        let one = Histogram::default();
        one.observe(1000); // bucket 10 (512..=1023)
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(one.quantile(q), Some(1023), "q={q}");
        }
        // Out-of-range q clamps rather than panicking.
        assert_eq!(one.quantile(-3.0), Some(1023));
        assert_eq!(one.quantile(7.0), Some(1023));
    }

    #[test]
    fn rolling_window_forgets_old_samples() {
        let w = RollingWindow::new(Duration::from_secs(10));
        let t0 = Instant::now();
        w.record_at(t0, 100);
        w.record_at(t0 + Duration::from_secs(1), 200);
        w.record_at(t0 + Duration::from_secs(2), 400);
        // All three inside the window: count 3, exact quantiles.
        let snap = w.snapshot_at(t0 + Duration::from_secs(2));
        assert_eq!(snap.count, 3);
        assert_eq!(snap.p50, 200);
        assert_eq!(snap.p99, 400);
        // 3 samples over 10 s = 0.3 req/s = 300 milli-rps.
        assert_eq!(snap.rps_milli, 300);
        // 11 s after t0 the first sample has aged out.
        let snap = w.snapshot_at(t0 + Duration::from_secs(11));
        assert_eq!(snap.count, 2);
        assert_eq!(snap.p50, 200);
        // And far in the future the window is empty again.
        assert_eq!(
            w.snapshot_at(t0 + Duration::from_secs(60)),
            WindowSnapshot::default()
        );
    }

    #[test]
    fn rolling_window_bounds_memory() {
        let w = RollingWindow::new(Duration::from_secs(3600));
        let t0 = Instant::now();
        for i in 0..(RollingWindow::MAX_SAMPLES as u64 + 100) {
            w.record_at(t0 + Duration::from_millis(i), i);
        }
        let snap = w.snapshot_at(t0 + Duration::from_secs(1));
        assert_eq!(snap.count, RollingWindow::MAX_SAMPLES as u64);
        // Oldest samples were dropped, so the minimum retained value is
        // the 100th.
        assert!(snap.p50 >= 100);
    }

    #[test]
    fn label_escaping_handles_hostile_values() {
        assert_eq!(escape_label_value("plain"), "plain");
        assert_eq!(escape_label_value("a\"b"), "a\\\"b");
        assert_eq!(escape_label_value("a\\b"), "a\\\\b");
        assert_eq!(escape_label_value("a\nb"), "a\\nb");
        assert_eq!(
            escape_label_value("\\\"\n"),
            "\\\\\\\"\\n",
            "each hostile char escapes independently"
        );
        // UTF-8 passes through.
        assert_eq!(escape_label_value("émoji🦀"), "émoji🦀");
    }

    #[test]
    fn prometheus_rendering_is_wellformed_and_cumulative() {
        let r = MetricsRegistry::new();
        r.counter("reqs").add(3);
        r.gauge("depth").set(-2);
        let h = r.histogram("lat");
        for v in [0u64, 1, 5, 5, 300] {
            h.observe(v);
        }
        r.record_phase("coarsening", Some(1), 0.25);
        r.record_phase("initial", None, 0.5);
        let text = r.render_prometheus();
        assert_eq!(
            text,
            "# TYPE sclap_reqs_total counter\n\
             sclap_reqs_total 3\n\
             # TYPE sclap_depth gauge\n\
             sclap_depth -2\n\
             # TYPE sclap_lat histogram\n\
             sclap_lat_bucket{le=\"0\"} 1\n\
             sclap_lat_bucket{le=\"1\"} 2\n\
             sclap_lat_bucket{le=\"7\"} 4\n\
             sclap_lat_bucket{le=\"511\"} 5\n\
             sclap_lat_bucket{le=\"+Inf\"} 5\n\
             sclap_lat_sum 311\n\
             sclap_lat_count 5\n\
             # TYPE sclap_lat_p50 gauge\n\
             sclap_lat_p50 7\n\
             # TYPE sclap_lat_p99 gauge\n\
             sclap_lat_p99 511\n\
             # TYPE sclap_phase_calls_total counter\n\
             # TYPE sclap_phase_seconds_total counter\n\
             sclap_phase_calls_total{phase=\"coarsening\",level=\"1\"} 1\n\
             sclap_phase_seconds_total{phase=\"coarsening\",level=\"1\"} 0.250000\n\
             sclap_phase_calls_total{phase=\"initial\",level=\"\"} 1\n\
             sclap_phase_seconds_total{phase=\"initial\",level=\"\"} 0.500000\n"
        );
        // Bucket series are cumulative (monotone non-decreasing).
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "{line}");
            last = v;
        }
    }
}
