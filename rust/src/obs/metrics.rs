//! The unified metrics registry: typed counters, gauges, and
//! log-bucketed histograms behind one handle.
//!
//! Before this existed every layer kept private tallies — the net cache
//! its `CacheStats` under the map lock, the arena its `ArenaStats`
//! atomics, `ExecutionCtx` a flat phase-timing table — and `serve
//! --timing` / bench reports each hand-picked fields from whichever
//! struct they could reach. The [`MetricsRegistry`] absorbs all of
//! them: instruments are registered once by `&'static str` name,
//! updated lock-free (plain atomics), and snapshotted deterministically
//! (sorted by name) for the wire `!stats` command and for tests.
//!
//! The registry is **instantiable, not a process global**: every
//! [`ExecutionCtx`](crate::util::exec::ExecutionCtx) owns one
//! (`Arc`-shared with the queue, cache, and server built on that
//! context), so tests and embedded services get isolated counter
//! spaces for free.
//!
//! # Instruments
//!
//! - [`Counter`] — monotonically increasing `u64` (events, rejections,
//!   cache hits).
//! - [`Gauge`] — last-write-wins `i64` (queue depth, uptime).
//! - [`Histogram`] — fixed-bin log₂ histogram of `u64` samples: bucket
//!   0 holds exactly the value 0 and bucket `i ≥ 1` holds
//!   `2^(i-1) ≤ v < 2^i`, so 65 bins cover the full `u64` range with
//!   no configuration and no allocation per sample.
//!
//! Lookup takes the registry lock; updates touch only the instrument's
//! atomics. Hot paths therefore resolve their instrument handle once
//! (`Arc<Counter>`) and increment it lock-free forever after.
//!
//! # Cancellation counters
//!
//! The cooperative-cancellation layer (`util::cancel`,
//! `queue::scheduler`) reports through this registry:
//! `requests_cancelled` counts every request reaped with a cancelled
//! reply, one of `cancel_reason_timeout` / `cancel_reason_disconnect` /
//! `cancel_reason_race_lost` / `cancel_reason_abandoned` (fixed names —
//! counter names must be `&'static str`, see
//! [`CancelReason::counter_name`](crate::util::cancel::CancelReason::counter_name))
//! records why, and `race_losers_cancelled` counts ensemble-race
//! configs whose remaining repetitions were cancelled after the
//! decision wave. All are visible over the wire via `!stats`.
//!
//! # Phase table
//!
//! The phase-timing sink that used to live inside `ExecutionCtx` moved
//! here, keyed by `(&'static str, Option<u32>)` — name **plus an
//! optional level index**. Drivers that reuse one phase name across
//! hierarchy levels (`external_coarsening` per out-of-core level,
//! `uncoarsening` per V-cycle level) record with
//! [`record_phase`](MetricsRegistry::record_phase)`(name, Some(level),
//! secs)` and no longer collapse into one bucket;
//! [`phase_stats`](MetricsRegistry::phase_stats) still aggregates
//! across levels for the old flat view.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Number of histogram bins: bucket 0 (the value 0) plus one power-of-
/// two bucket per bit of `u64`.
pub const HISTOGRAM_BINS: usize = 65;

/// Log₂ bucket index of a sample: 0 for 0, else `i` with
/// `2^(i-1) ≤ v < 2^i` (i.e. `64 - v.leading_zeros()`).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i` (`0` for bucket 0, else
/// `2^i − 1`); the boundaries [`bucket_index`] sorts against.
#[inline]
pub fn bucket_upper_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bin log₂ histogram (module docs). All updates are relaxed
/// atomics; `count`/`sum`/bucket totals are therefore each exact, and
/// mutually consistent whenever the histogram is quiescent (the only
/// time snapshots are compared in tests).
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; HISTOGRAM_BINS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Histogram {
    #[inline]
    pub fn observe(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// `(bucket_index, count)` for every non-empty bucket, ascending.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then_some((i, c))
            })
            .collect()
    }
}

/// Aggregate wall-clock of one named phase (the type
/// `util::exec::PhaseStat` re-exports).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseStat {
    pub calls: usize,
    pub seconds: f64,
}

#[derive(Default)]
struct Instruments {
    counters: BTreeMap<&'static str, Arc<Counter>>,
    gauges: BTreeMap<&'static str, Arc<Gauge>>,
    histograms: BTreeMap<&'static str, Arc<Histogram>>,
    phases: BTreeMap<(&'static str, Option<u32>), PhaseStat>,
}

/// The typed instrument registry (module docs). Cheap to share via
/// `Arc`; one per [`ExecutionCtx`](crate::util::exec::ExecutionCtx).
pub struct MetricsRegistry {
    start: Instant,
    inner: Mutex<Instruments>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry {
            start: Instant::now(),
            inner: Mutex::new(Instruments::default()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Instruments> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Seconds since the registry (≈ its owning service) was created —
    /// the uptime the wire `!ping` / `!stats` responses report.
    pub fn uptime_seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Get-or-register the named counter. Lookup locks the registry;
    /// hold the returned handle to update lock-free.
    pub fn counter(&self, name: &'static str) -> Arc<Counter> {
        self.lock().counters.entry(name).or_default().clone()
    }

    /// Get-or-register the named gauge.
    pub fn gauge(&self, name: &'static str) -> Arc<Gauge> {
        self.lock().gauges.entry(name).or_default().clone()
    }

    /// Get-or-register the named histogram.
    pub fn histogram(&self, name: &'static str) -> Arc<Histogram> {
        self.lock().histograms.entry(name).or_default().clone()
    }

    /// Accumulate `seconds` of wall-clock into phase `name`, optionally
    /// attributed to one hierarchy `level` (module docs).
    pub fn record_phase(&self, name: &'static str, level: Option<u32>, seconds: f64) {
        let mut inner = self.lock();
        let entry = inner.phases.entry((name, level)).or_default();
        entry.calls += 1;
        entry.seconds += seconds;
    }

    /// Flat phase view: stats aggregated across levels, sorted by phase
    /// name — the shape `ExecutionCtx::phase_stats` has always returned.
    pub fn phase_stats(&self) -> Vec<(&'static str, PhaseStat)> {
        let inner = self.lock();
        let mut flat: BTreeMap<&'static str, PhaseStat> = BTreeMap::new();
        for (&(name, _level), stat) in &inner.phases {
            let e = flat.entry(name).or_default();
            e.calls += stat.calls;
            e.seconds += stat.seconds;
        }
        flat.into_iter().collect()
    }

    /// Per-level phase view: `(name, level)` keys verbatim, sorted by
    /// name then level (levelless entries first).
    pub fn phase_stats_by_level(&self) -> Vec<((&'static str, Option<u32>), PhaseStat)> {
        let inner = self.lock();
        inner.phases.iter().map(|(k, v)| (*k, *v)).collect()
    }

    /// Render the whole registry as the inner fields of one JSON object
    /// (no surrounding braces): `"counters":{...},"gauges":{...},
    /// "histograms":{...},"phases":[...]`. Key order is sorted name
    /// order — deterministic for a quiescent registry, so tests can
    /// compare snapshots byte-for-byte.
    pub fn render_json_fields(&self) -> String {
        let inner = self.lock();
        let mut out = String::new();
        out.push_str("\"counters\":{");
        for (i, (name, c)) in inner.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{name}\":{}", c.get()));
        }
        out.push_str("},\"gauges\":{");
        for (i, (name, g)) in inner.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{name}\":{}", g.get()));
        }
        out.push_str("},\"histograms\":{");
        for (i, (name, h)) in inner.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let buckets: Vec<String> = h
                .nonzero_buckets()
                .iter()
                .map(|(b, c)| format!("[{b},{c}]"))
                .collect();
            out.push_str(&format!(
                "\"{name}\":{{\"count\":{},\"sum\":{},\"buckets\":[{}]}}",
                h.count(),
                h.sum(),
                buckets.join(",")
            ));
        }
        out.push_str("},\"phases\":[");
        for (i, (&(name, level), stat)) in inner.phases.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let level = match level {
                None => "null".to_string(),
                Some(l) => l.to_string(),
            };
            out.push_str(&format!(
                "{{\"name\":\"{name}\",\"level\":{level},\"calls\":{},\"seconds\":{:.6}}}",
                stat.calls, stat.seconds
            ));
        }
        out.push(']');
        out
    }
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.lock();
        f.debug_struct("MetricsRegistry")
            .field("counters", &inner.counters.len())
            .field("gauges", &inner.gauges.len())
            .field("histograms", &inner.histograms.len())
            .field("phases", &inner.phases.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        // Bucket 0 is exactly the value 0.
        assert_eq!(bucket_index(0), 0);
        // Bucket i holds 2^(i-1) ≤ v < 2^i: check both edges of every
        // bucket that has them.
        for i in 1..=63usize {
            let lo = 1u64 << (i - 1);
            let hi = (1u64 << i) - 1;
            assert_eq!(bucket_index(lo), i, "low edge of bucket {i}");
            assert_eq!(bucket_index(hi), i, "high edge of bucket {i}");
        }
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_index(1u64 << 63), 64);
        // Upper bounds are consistent with the index function.
        assert_eq!(bucket_upper_bound(0), 0);
        assert_eq!(bucket_upper_bound(1), 1);
        assert_eq!(bucket_upper_bound(4), 15);
        assert_eq!(bucket_upper_bound(64), u64::MAX);
        for i in 0..64usize {
            assert_eq!(bucket_index(bucket_upper_bound(i)), i);
        }
    }

    #[test]
    fn histogram_observes_into_the_right_bins() {
        let h = Histogram::default();
        for v in [0u64, 1, 2, 3, 4, 1024, u64::MAX] {
            h.observe(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 0u64.wrapping_add(1 + 2 + 3 + 4 + 1024).wrapping_add(u64::MAX));
        assert_eq!(
            h.nonzero_buckets(),
            vec![(0, 1), (1, 1), (2, 2), (3, 1), (11, 1), (64, 1)]
        );
    }

    #[test]
    fn registry_handles_are_shared() {
        let r = MetricsRegistry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        b.add(2);
        assert_eq!(r.counter("x").get(), 3);
        r.gauge("depth").set(5);
        assert_eq!(r.gauge("depth").get(), 5);
    }

    #[test]
    fn phase_table_keeps_levels_apart_and_flat_view_aggregates() {
        let r = MetricsRegistry::new();
        r.record_phase("uncoarsening", Some(0), 1.0);
        r.record_phase("uncoarsening", Some(1), 2.0);
        r.record_phase("uncoarsening", Some(1), 3.0);
        r.record_phase("coarsening", None, 4.0);
        let by_level = r.phase_stats_by_level();
        assert_eq!(by_level.len(), 3);
        assert_eq!(by_level[1].0, ("uncoarsening", Some(0)));
        assert_eq!(by_level[2].0, ("uncoarsening", Some(1)));
        assert_eq!(by_level[2].1.calls, 2);
        assert!((by_level[2].1.seconds - 5.0).abs() < 1e-12);
        let flat = r.phase_stats();
        assert_eq!(flat.len(), 2);
        assert_eq!(flat[1].0, "uncoarsening");
        assert_eq!(flat[1].1.calls, 3);
        assert!((flat[1].1.seconds - 6.0).abs() < 1e-12);
    }

    #[test]
    fn json_fields_render_deterministically() {
        let r = MetricsRegistry::new();
        r.counter("b").add(2);
        r.counter("a").inc();
        r.gauge("g").set(-7);
        r.histogram("h").observe(3);
        r.record_phase("p", Some(2), 0.5);
        let s = format!("{{{}}}", r.render_json_fields());
        assert_eq!(
            s,
            "{\"counters\":{\"a\":1,\"b\":2},\"gauges\":{\"g\":-7},\
             \"histograms\":{\"h\":{\"count\":1,\"sum\":3,\"buckets\":[[2,1]]}},\
             \"phases\":[{\"name\":\"p\",\"level\":2,\"calls\":1,\"seconds\":0.500000}]}"
        );
        // And it parses as JSON.
        crate::util::json::parse_json(&s).expect("valid json");
    }
}
