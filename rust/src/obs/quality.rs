//! Quality explainability: deterministic per-request reports built
//! from trace lanes (`queue::spec` key `explain=true`).
//!
//! The pipeline already narrates itself through [`trace`] spans and
//! counters — coarsening lineage, LPA rounds, FM passes, per-level
//! cuts. This module turns one repetition's *lane* (the `(track,
//! instance)` slice of a [`Tracer`]) into a structured
//! [`QualityReport`] and renders it as JSON with a fixed field order.
//!
//! # Determinism
//!
//! The report consumes only the logical content of events — names,
//! integer args, and the per-lane `seq` order — never timestamps.
//! Lane coordinates are pure functions of the request (`track =
//! track_of(seed)`, `instance` = racer index), and the pool masks
//! multi-task jobs ([`trace::mask`], `util::pool` contract rule 5), so
//! the same request produces a byte-identical report for any worker
//! count, backend, or shard layout. `rust/tests/observability.rs`
//! pins exactly that.
//!
//! # Section attribution
//!
//! Events are attributed to report sections by the innermost open span
//! at emission time: `coarsening` → the cycle's coarsening section,
//! `initial` → the root-bisection section (deeper splits run as
//! multi-task pool jobs and are masked), `refine_level` → that level's
//! refinement section, `uncoarsening` outside any `refine_level` → the
//! feasibility-repair section, and the `external_*` spans → the
//! out-of-core driver's sections.

use super::trace::{EventKind, TraceEvent, Tracer};
use crate::util::json::escape_json;

/// LPA stop reason: the round budget ran out (`max_iterations` in the
/// paper's §3.1 loop).
pub const STOP_MAX_ITERATIONS: i64 = 0;
/// LPA stop reason: the moved fraction fell under the convergence
/// threshold before the budget ran out.
pub const STOP_CONVERGED: i64 = 1;
/// LPA stop reason: the active-nodes queue drained (§B.2) — nothing
/// left to visit, strictly stronger than threshold convergence.
pub const STOP_EXHAUSTED: i64 = 2;

/// Human-readable name of a `STOP_*` code (`"unknown"` for values the
/// vocabulary does not define — forward compatibility, not an error).
pub fn stop_reason_name(code: i64) -> &'static str {
    match code {
        STOP_MAX_ITERATIONS => "max_iterations",
        STOP_CONVERGED => "converged",
        STOP_EXHAUSTED => "exhausted",
        _ => "unknown",
    }
}

/// One closed LPA engine run: the per-round moved counts, the round
/// total, and the stop reason, tagged with the engine variant
/// (`lpa`, `parallel_lpa`, `async_lpa`, `external_lpa`, `lpa_refine`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LpaRun {
    pub variant: &'static str,
    pub rounds: i64,
    pub stop: i64,
    pub moved: Vec<i64>,
}

/// One closed FM run: pass count, cut trajectory endpoints, applied
/// moves, and the per-pass best cuts (`fm_pass` trail).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FmRun {
    pub passes: i64,
    pub initial_cut: i64,
    pub final_cut: i64,
    pub moves: i64,
    pub pass_cuts: Vec<i64>,
}

/// One cut-before/cut-after refinement gain (`lpa_refine_gain`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Gain {
    pub before: i64,
    pub after: i64,
}

/// The telemetry attributed to one pipeline section.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Section {
    pub lpa: Vec<LpaRun>,
    pub fm: Vec<FmRun>,
    pub gains: Vec<Gain>,
}

impl Section {
    fn is_empty(&self) -> bool {
        self.lpa.is_empty() && self.fm.is_empty() && self.gains.is_empty()
    }
}

/// One coarsening contraction (`coarsen_level`): the graph after
/// contraction `level + 1` (level 0 = first contraction of the input).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelLineage {
    pub level: i64,
    pub n: i64,
    pub m: i64,
}

/// One refined hierarchy level (`refine_level` span) and what ran
/// inside it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RefineLevel {
    pub level: i64,
    pub n: i64,
    pub section: Section,
}

/// Post-refinement quality of one level (`level_quality`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelQuality {
    pub level: i64,
    pub cut: i64,
    pub imbalance_milli: i64,
}

/// One V-cycle of the in-memory pipeline.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CycleReport {
    pub cycle: i64,
    /// Hierarchy depth (`hierarchy.levels`).
    pub levels: i64,
    pub coarsest_n: i64,
    pub coarsest_m: i64,
    pub lineage: Vec<LevelLineage>,
    pub coarsening: Section,
    pub initial: Section,
    pub refine: Vec<RefineLevel>,
    /// Feasibility repair on the input graph (inside `uncoarsening`,
    /// outside any `refine_level`).
    pub repair: Section,
    pub quality: Vec<LevelQuality>,
    /// This cycle's cut on the input graph (`cycle_cut`).
    pub cut: i64,
}

/// The out-of-core driver's sections (absent when the run never left
/// the in-memory pipeline — including the store fast path, which emits
/// no external events at all, keeping backends stream-identical).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ExternalReport {
    /// `external_level` counters: (level, coarse_n, coarse_m).
    pub levels: Vec<(i64, i64, i64)>,
    pub coarsening: Section,
    pub refinement: Section,
    pub cut: i64,
    pub external_levels: i64,
}

impl ExternalReport {
    fn is_empty(&self) -> bool {
        self.levels.is_empty()
            && self.coarsening.is_empty()
            && self.refinement.is_empty()
            && self.cut == 0
            && self.external_levels == 0
    }
}

/// Everything one repetition's lane says about its run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RepReport {
    pub seed: u64,
    /// Dimensions of the graph handed to the in-memory pipeline
    /// (`input_graph`). For out-of-core runs this is the contracted
    /// graph the inner pipeline partitioned; the store-level lineage
    /// lives in [`ExternalReport::levels`].
    pub input_n: i64,
    pub input_m: i64,
    pub cycles: Vec<CycleReport>,
    pub external: Option<ExternalReport>,
}

/// A full per-request report: one [`RepReport`] per aggregate-
/// contributing repetition, in `(seed, instance)` order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QualityReport {
    pub reps: Vec<RepReport>,
}

/// In-flight LPA run state while walking a lane.
#[derive(Default)]
struct PendingLpa {
    moved: Vec<i64>,
}

/// In-flight FM run state while walking a lane.
#[derive(Default)]
struct PendingFm {
    pass_cuts: Vec<i64>,
}

fn arg(e: &TraceEvent, name: &str) -> i64 {
    e.args()
        .iter()
        .find(|(k, _)| *k == name)
        .map(|(_, v)| *v)
        .unwrap_or(0)
}

/// `("lpa_round", "lpa")`-style mapping: the engine variant of a round
/// or done counter, or `None` for unrelated counters.
fn lpa_variant(name: &str) -> Option<&'static str> {
    match name {
        "lpa_round" | "lpa_done" => Some("lpa"),
        "parallel_lpa_round" | "parallel_lpa_done" => Some("parallel_lpa"),
        "async_lpa_round" | "async_lpa_done" => Some("async_lpa"),
        "external_lpa_round" | "external_lpa_done" => Some("external_lpa"),
        "lpa_refine_round" | "lpa_refine_done" => Some("lpa_refine"),
        _ => None,
    }
}

impl RepReport {
    /// Build one repetition's report from its lane events (already in
    /// `seq` order — [`Tracer::lane_events`]).
    pub fn from_events(seed: u64, events: &[TraceEvent]) -> RepReport {
        let mut rep = RepReport {
            seed,
            ..RepReport::default()
        };
        let mut input_seen = false;
        // The innermost-open-span stack; `refine_level` entries double
        // as the index into the current cycle's refine list.
        let mut stack: Vec<&'static str> = Vec::new();
        let mut pending_lpa: Vec<(&'static str, PendingLpa)> = Vec::new();
        let mut pending_fm = PendingFm::default();
        // Events outside every known section (vocabulary growth) are
        // attributed here and dropped.
        let mut floating = Section::default();
        for e in events {
            match e.kind {
                EventKind::Begin => {
                    stack.push(e.name);
                    match e.name {
                        "vcycle" => rep.cycles.push(CycleReport {
                            cycle: arg(e, "cycle"),
                            ..CycleReport::default()
                        }),
                        "refine_level" => {
                            if let Some(c) = rep.cycles.last_mut() {
                                c.refine.push(RefineLevel {
                                    level: arg(e, "level"),
                                    n: arg(e, "n"),
                                    section: Section::default(),
                                });
                            }
                        }
                        "external_coarsen_level" | "external_refinement" => {
                            rep.external.get_or_insert_with(ExternalReport::default);
                        }
                        _ => {}
                    }
                }
                EventKind::End => {
                    // Pop to the matching Begin; tolerate (don't crash
                    // on) unbalanced streams from overflowing lanes.
                    while let Some(top) = stack.pop() {
                        if top == e.name {
                            break;
                        }
                    }
                }
                EventKind::Counter => {
                    if let Some(variant) = lpa_variant(e.name) {
                        if e.name.ends_with("_done") {
                            let moved = match pending_lpa
                                .iter()
                                .position(|(v, _)| *v == variant)
                            {
                                Some(i) => pending_lpa.remove(i).1.moved,
                                None => Vec::new(),
                            };
                            let run = LpaRun {
                                variant,
                                rounds: arg(e, "rounds"),
                                stop: arg(e, "reason"),
                                moved,
                            };
                            section_mut(&stack, &mut rep, &mut floating)
                                .lpa
                                .push(run);
                        } else {
                            let slot = match pending_lpa
                                .iter()
                                .position(|(v, _)| *v == variant)
                            {
                                Some(i) => &mut pending_lpa[i].1,
                                None => {
                                    pending_lpa.push((variant, PendingLpa::default()));
                                    &mut pending_lpa.last_mut().unwrap().1
                                }
                            };
                            slot.moved.push(arg(e, "moved"));
                        }
                        continue;
                    }
                    match e.name {
                        "input_graph" => {
                            // First wins: for out-of-core runs only the
                            // inner pipeline events this, so there is
                            // exactly one either way.
                            if !input_seen {
                                rep.input_n = arg(e, "n");
                                rep.input_m = arg(e, "m");
                                input_seen = true;
                            }
                        }
                        "hierarchy" => {
                            if let Some(c) = rep.cycles.last_mut() {
                                c.levels = arg(e, "levels");
                                c.coarsest_n = arg(e, "coarsest_n");
                                c.coarsest_m = arg(e, "coarsest_m");
                            }
                        }
                        "coarsen_level" => {
                            if let Some(c) = rep.cycles.last_mut() {
                                c.lineage.push(LevelLineage {
                                    level: arg(e, "level"),
                                    n: arg(e, "n"),
                                    m: arg(e, "m"),
                                });
                            }
                        }
                        "level_quality" => {
                            if let Some(c) = rep.cycles.last_mut() {
                                c.quality.push(LevelQuality {
                                    level: arg(e, "level"),
                                    cut: arg(e, "cut"),
                                    imbalance_milli: arg(e, "imbalance_milli"),
                                });
                            }
                        }
                        "cycle_cut" => {
                            if let Some(c) = rep.cycles.last_mut() {
                                c.cut = arg(e, "cut");
                            }
                        }
                        "fm_pass" => pending_fm.pass_cuts.push(arg(e, "cut")),
                        "fm_done" => {
                            let run = FmRun {
                                passes: arg(e, "passes"),
                                initial_cut: arg(e, "initial_cut"),
                                final_cut: arg(e, "final_cut"),
                                moves: arg(e, "moves"),
                                pass_cuts: std::mem::take(&mut pending_fm.pass_cuts),
                            };
                            section_mut(&stack, &mut rep, &mut floating)
                                .fm
                                .push(run);
                        }
                        "lpa_refine_gain" => {
                            section_mut(&stack, &mut rep, &mut floating)
                                .gains
                                .push(Gain {
                                    before: arg(e, "before"),
                                    after: arg(e, "after"),
                                });
                        }
                        "external_level" => {
                            let ext =
                                rep.external.get_or_insert_with(ExternalReport::default);
                            ext.levels.push((
                                arg(e, "level"),
                                arg(e, "coarse_n"),
                                arg(e, "coarse_m"),
                            ));
                        }
                        "external_result" => {
                            let ext =
                                rep.external.get_or_insert_with(ExternalReport::default);
                            ext.cut = arg(e, "cut");
                            ext.external_levels = arg(e, "external_levels");
                        }
                        _ => {}
                    }
                }
            }
        }
        // A fast-path store run never emits external events; drop the
        // empty shell if section attribution lazily created one.
        if rep.external.as_ref().is_some_and(ExternalReport::is_empty) {
            rep.external = None;
        }
        rep
    }
}

/// The section the innermost open span attributes telemetry to. The
/// borrow is resolved fresh per event, so the stack walk stays simple.
fn section_mut<'a>(
    stack: &[&'static str],
    rep: &'a mut RepReport,
    floating: &'a mut Section,
) -> &'a mut Section {
    for name in stack.iter().rev() {
        match *name {
            "refine_level" => {
                if let Some(c) = rep.cycles.last_mut() {
                    if let Some(r) = c.refine.last_mut() {
                        return &mut r.section;
                    }
                }
            }
            "initial" => {
                if let Some(c) = rep.cycles.last_mut() {
                    return &mut c.initial;
                }
            }
            "coarsening" => {
                if let Some(c) = rep.cycles.last_mut() {
                    return &mut c.coarsening;
                }
            }
            "uncoarsening" => {
                if let Some(c) = rep.cycles.last_mut() {
                    return &mut c.repair;
                }
            }
            "external_coarsen_level" => {
                return &mut rep
                    .external
                    .get_or_insert_with(ExternalReport::default)
                    .coarsening;
            }
            "external_refinement" => {
                return &mut rep
                    .external
                    .get_or_insert_with(ExternalReport::default)
                    .refinement;
            }
            _ => {}
        }
    }
    floating
}

impl QualityReport {
    /// Build the report for the aggregate-contributing lanes of
    /// `tracer`: one `(seed, instance)` pair per repetition, where
    /// `instance` is the deterministic lane the scheduler pinned with
    /// [`Tracer::enter_lane`] (0 for plain units, the racer index for
    /// config races). Reps are ordered by `(seed, instance)`.
    pub fn from_lanes(tracer: &Tracer, lanes: &[(u64, u32)]) -> QualityReport {
        let mut lanes: Vec<(u64, u32)> = lanes.to_vec();
        lanes.sort_unstable();
        lanes.dedup();
        let reps = lanes
            .iter()
            .map(|&(seed, instance)| {
                let events = tracer.lane_events(Tracer::track_of(seed), instance);
                RepReport::from_events(seed, &events)
            })
            .collect();
        QualityReport { reps }
    }

    /// Render as JSON with a fixed field order — the explain payload
    /// appended to response lines. Byte-deterministic: every value is
    /// an integer, an integer-derived `{:.4}` ratio, or a fixed string.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"reps\":[");
        for (i, rep) in self.reps.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            render_rep(&mut out, rep);
        }
        out.push_str("]}");
        out
    }
}

fn render_rep(out: &mut String, rep: &RepReport) {
    out.push_str(&format!(
        "{{\"seed\":{},\"input\":{{\"n\":{},\"m\":{}}},\"cycles\":[",
        rep.seed, rep.input_n, rep.input_m
    ));
    for (i, c) in rep.cycles.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        render_cycle(out, c, rep.input_n);
    }
    out.push(']');
    if let Some(ext) = &rep.external {
        out.push_str(",\"external\":");
        render_external(out, ext);
    }
    out.push('}');
}

fn render_cycle(out: &mut String, c: &CycleReport, input_n: i64) {
    out.push_str(&format!(
        "{{\"cycle\":{},\"levels\":{},\"coarsest\":{{\"n\":{},\"m\":{}}},\"lineage\":[",
        c.cycle, c.levels, c.coarsest_n, c.coarsest_m
    ));
    let mut prev_n = input_n;
    for (i, l) in c.lineage.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        // Shrink factor of this contraction (finer n / coarser n) —
        // deterministic: IEEE division of two integers, fixed format.
        let shrink = if l.n > 0 { prev_n as f64 / l.n as f64 } else { 0.0 };
        out.push_str(&format!(
            "{{\"level\":{},\"n\":{},\"m\":{},\"shrink\":{:.4}}}",
            l.level, l.n, l.m, shrink
        ));
        prev_n = l.n;
    }
    out.push_str("],\"coarsening\":");
    render_section(out, &c.coarsening);
    out.push_str(",\"initial\":");
    render_section(out, &c.initial);
    out.push_str(",\"refine\":[");
    for (i, r) in c.refine.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("{{\"level\":{},\"n\":{},\"section\":", r.level, r.n));
        render_section(out, &r.section);
        out.push('}');
    }
    out.push_str("],\"repair\":");
    render_section(out, &c.repair);
    out.push_str(",\"quality\":[");
    for (i, q) in c.quality.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"level\":{},\"cut\":{},\"imbalance_milli\":{}}}",
            q.level, q.cut, q.imbalance_milli
        ));
    }
    out.push_str(&format!("],\"cut\":{}}}", c.cut));
}

fn render_section(out: &mut String, s: &Section) {
    out.push_str("{\"lpa\":[");
    for (i, run) in s.lpa.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"variant\":\"{}\",\"rounds\":{},\"stop\":\"{}\",\"moved\":[{}]}}",
            escape_json(run.variant),
            run.rounds,
            stop_reason_name(run.stop),
            join_i64(&run.moved)
        ));
    }
    out.push_str("],\"fm\":[");
    for (i, run) in s.fm.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"passes\":{},\"initial_cut\":{},\"final_cut\":{},\"moves\":{},\"pass_cuts\":[{}]}}",
            run.passes,
            run.initial_cut,
            run.final_cut,
            run.moves,
            join_i64(&run.pass_cuts)
        ));
    }
    out.push_str("],\"gains\":[");
    for (i, g) in s.gains.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("{{\"before\":{},\"after\":{}}}", g.before, g.after));
    }
    out.push_str("]}");
}

fn render_external(out: &mut String, ext: &ExternalReport) {
    out.push_str("{\"levels\":[");
    for (i, (level, n, m)) in ext.levels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("{{\"level\":{level},\"n\":{n},\"m\":{m}}}"));
    }
    out.push_str("],\"coarsening\":");
    render_section(out, &ext.coarsening);
    out.push_str(",\"refinement\":");
    render_section(out, &ext.refinement);
    out.push_str(&format!(
        ",\"cut\":{},\"external_levels\":{}}}",
        ext.cut, ext.external_levels
    ));
}

fn join_i64(values: &[i64]) -> String {
    values
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{counter, span};
    use crate::util::json::parse_json;
    use std::sync::Arc;

    #[test]
    fn stop_reasons_name_the_vocabulary() {
        assert_eq!(stop_reason_name(STOP_MAX_ITERATIONS), "max_iterations");
        assert_eq!(stop_reason_name(STOP_CONVERGED), "converged");
        assert_eq!(stop_reason_name(STOP_EXHAUSTED), "exhausted");
        assert_eq!(stop_reason_name(99), "unknown");
    }

    /// Emit a synthetic in-memory pipeline lane, mirroring the real
    /// emission order in `partitioning::multilevel`.
    fn synthetic_lane(tracer: &Arc<Tracer>, seed: u64, instance: u32) {
        let _lane = tracer.enter_lane(Tracer::track_of(seed), instance);
        counter("input_graph", &[("n", 100), ("m", 400)]);
        let vcycle = span("vcycle", &[("cycle", 0)]);
        {
            let coarsening = span("coarsening", &[("cycle", 0)]);
            counter("lpa_round", &[("round", 1), ("moved", 60)]);
            counter("lpa_round", &[("round", 2), ("moved", 3)]);
            counter("lpa_done", &[("rounds", 2), ("reason", STOP_CONVERGED)]);
            drop(coarsening);
        }
        counter(
            "hierarchy",
            &[("cycle", 0), ("levels", 2), ("coarsest_n", 25), ("coarsest_m", 80)],
        );
        counter("coarsen_level", &[("level", 0), ("n", 50), ("m", 160)]);
        counter("coarsen_level", &[("level", 1), ("n", 25), ("m", 80)]);
        {
            let initial = span("initial", &[("cycle", 0)]);
            counter("fm_pass", &[("pass", 1), ("kept_moves", 4), ("cut", 30)]);
            counter(
                "fm_done",
                &[("passes", 1), ("initial_cut", 35), ("final_cut", 30), ("moves", 4)],
            );
            drop(initial);
        }
        {
            let uncoarsening = span("uncoarsening", &[("cycle", 0)]);
            {
                let rl = span("refine_level", &[("level", 2), ("n", 25)]);
                counter("lpa_refine_round", &[("round", 0), ("moved", 5)]);
                counter(
                    "lpa_refine_done",
                    &[("rounds", 1), ("reason", STOP_CONVERGED)],
                );
                counter("lpa_refine_gain", &[("before", 30), ("after", 28)]);
                counter("fm_pass", &[("pass", 1), ("kept_moves", 2), ("cut", 27)]);
                counter(
                    "fm_done",
                    &[("passes", 1), ("initial_cut", 28), ("final_cut", 27), ("moves", 2)],
                );
                drop(rl);
            }
            counter("level_quality", &[("level", 2), ("cut", 27), ("imbalance_milli", 12)]);
            // Feasibility repair: a gain outside any refine_level span.
            counter("lpa_refine_gain", &[("before", 27), ("after", 27)]);
            drop(uncoarsening);
        }
        counter("cycle_cut", &[("cycle", 0), ("cut", 27)]);
        drop(vcycle);
    }

    #[test]
    fn builder_attributes_sections_by_innermost_span() {
        let tracer = Arc::new(Tracer::new());
        synthetic_lane(&tracer, 7, 0);
        let report = QualityReport::from_lanes(&tracer, &[(7, 0)]);
        assert_eq!(report.reps.len(), 1);
        let rep = &report.reps[0];
        assert_eq!((rep.input_n, rep.input_m), (100, 400));
        assert!(rep.external.is_none(), "no external events, no section");
        assert_eq!(rep.cycles.len(), 1);
        let c = &rep.cycles[0];
        assert_eq!((c.levels, c.coarsest_n, c.coarsest_m, c.cut), (2, 25, 80, 27));
        assert_eq!(c.lineage.len(), 2);
        assert_eq!(c.coarsening.lpa.len(), 1);
        assert_eq!(c.coarsening.lpa[0].variant, "lpa");
        assert_eq!(c.coarsening.lpa[0].moved, vec![60, 3]);
        assert_eq!(c.coarsening.lpa[0].stop, STOP_CONVERGED);
        assert_eq!(c.initial.fm.len(), 1);
        assert_eq!(c.initial.fm[0].pass_cuts, vec![30]);
        assert_eq!(c.refine.len(), 1);
        let r = &c.refine[0];
        assert_eq!((r.level, r.n), (2, 25));
        assert_eq!(r.section.lpa[0].variant, "lpa_refine");
        assert_eq!(r.section.gains, vec![Gain { before: 30, after: 28 }]);
        assert_eq!(r.section.fm[0].final_cut, 27);
        // The repair gain landed outside the refine_level span.
        assert_eq!(c.repair.gains, vec![Gain { before: 27, after: 27 }]);
        assert_eq!(c.quality.len(), 1);
        assert_eq!(c.quality[0].imbalance_milli, 12);
    }

    #[test]
    fn report_json_is_pinned_and_parses() {
        let tracer = Arc::new(Tracer::new());
        synthetic_lane(&tracer, 7, 0);
        let json = QualityReport::from_lanes(&tracer, &[(7, 0)]).to_json();
        // Byte-pinned: the explain payload's field order and number
        // formatting are part of the wire contract.
        assert_eq!(
            json,
            concat!(
                "{\"reps\":[{\"seed\":7,\"input\":{\"n\":100,\"m\":400},\"cycles\":[",
                "{\"cycle\":0,\"levels\":2,\"coarsest\":{\"n\":25,\"m\":80},",
                "\"lineage\":[{\"level\":0,\"n\":50,\"m\":160,\"shrink\":2.0000},",
                "{\"level\":1,\"n\":25,\"m\":80,\"shrink\":2.0000}],",
                "\"coarsening\":{\"lpa\":[{\"variant\":\"lpa\",\"rounds\":2,",
                "\"stop\":\"converged\",\"moved\":[60,3]}],\"fm\":[],\"gains\":[]},",
                "\"initial\":{\"lpa\":[],\"fm\":[{\"passes\":1,\"initial_cut\":35,",
                "\"final_cut\":30,\"moves\":4,\"pass_cuts\":[30]}],\"gains\":[]},",
                "\"refine\":[{\"level\":2,\"n\":25,\"section\":{\"lpa\":[",
                "{\"variant\":\"lpa_refine\",\"rounds\":1,\"stop\":\"converged\",",
                "\"moved\":[5]}],\"fm\":[{\"passes\":1,\"initial_cut\":28,",
                "\"final_cut\":27,\"moves\":2,\"pass_cuts\":[27]}],",
                "\"gains\":[{\"before\":30,\"after\":28}]}}],",
                "\"repair\":{\"lpa\":[],\"fm\":[],\"gains\":[{\"before\":27,\"after\":27}]},",
                "\"quality\":[{\"level\":2,\"cut\":27,\"imbalance_milli\":12}],",
                "\"cut\":27}]}]}"
            )
        );
        parse_json(&json).expect("explain payload is valid JSON");
    }

    #[test]
    fn external_events_populate_the_external_section() {
        let tracer = Arc::new(Tracer::new());
        {
            let _lane = tracer.enter_lane(Tracer::track_of(3), 0);
            {
                let s = span("external_coarsen_level", &[("level", 0)]);
                counter("external_lpa_round", &[("round", 1), ("moved", 40)]);
                counter(
                    "external_lpa_done",
                    &[("rounds", 1), ("reason", STOP_MAX_ITERATIONS)],
                );
                drop(s);
            }
            counter("external_level", &[("level", 0), ("coarse_n", 50), ("coarse_m", 200)]);
            counter("input_graph", &[("n", 50), ("m", 200)]);
            {
                let s = span("external_refinement", &[]);
                counter("lpa_refine_gain", &[("before", 90), ("after", 80)]);
                drop(s);
            }
            counter("external_result", &[("cut", 80), ("external_levels", 1)]);
        }
        let report = QualityReport::from_lanes(&tracer, &[(3, 0)]);
        let ext = report.reps[0].external.as_ref().expect("external section");
        assert_eq!(ext.levels, vec![(0, 50, 200)]);
        assert_eq!(ext.coarsening.lpa[0].variant, "external_lpa");
        assert_eq!(ext.coarsening.lpa[0].stop, STOP_MAX_ITERATIONS);
        assert_eq!(ext.refinement.gains, vec![Gain { before: 90, after: 80 }]);
        assert_eq!((ext.cut, ext.external_levels), (80, 1));
        assert_eq!(report.reps[0].input_n, 50);
        parse_json(&report.to_json()).expect("external payload is valid JSON");
    }

    #[test]
    fn empty_lane_renders_an_empty_rep() {
        let tracer = Arc::new(Tracer::new());
        let report = QualityReport::from_lanes(&tracer, &[(1, 0)]);
        assert_eq!(report.reps.len(), 1);
        assert!(report.reps[0].cycles.is_empty());
        assert_eq!(
            report.to_json(),
            "{\"reps\":[{\"seed\":1,\"input\":{\"n\":0,\"m\":0},\"cycles\":[]}]}"
        );
    }

    #[test]
    fn lanes_are_ordered_and_deduplicated() {
        let tracer = Arc::new(Tracer::new());
        synthetic_lane(&tracer, 9, 1);
        synthetic_lane(&tracer, 2, 0);
        let report = QualityReport::from_lanes(&tracer, &[(9, 1), (2, 0), (9, 1)]);
        let seeds: Vec<u64> = report.reps.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, vec![2, 9], "reps sort by (seed, instance), deduped");
    }
}
