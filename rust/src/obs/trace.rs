//! Deterministic hierarchical tracing: spans, counter events, and the
//! Chrome `trace_event` exporter behind `partition --trace` / `serve
//! --trace`.
//!
//! # Design: logical tracks, ambient emission
//!
//! The determinism contract (same seed + config ⇒ byte-identical
//! partition for any thread count) extends to the trace's *logical*
//! content. A global append log would interleave concurrent
//! repetitions nondeterministically, so events are organized into
//! **tracks** — one per repetition, with an id derived from the
//! repetition seed (`splitmix64(seed)` truncated to 31 bits), never
//! from the executing worker. A driver *enters* its track
//! ([`Tracer::enter`]) at the top of a repetition; the scope parks the
//! track state in thread-local storage, and every instrumentation
//! point in the phases below it ([`span`], [`counter`]) emits into the
//! ambient track with **no context plumbing and no locks** — one TLS
//! `Option` check when tracing is off, one `Vec` push when it is on.
//! Pool workers running nested parallel chunks have no ambient track
//! and emit nothing, which is exactly what keeps the stream
//! worker-count-invariant: emission happens only at deterministic
//! control points on the thread that owns the repetition.
//!
//! Within a track every event carries a sequence number, so the merged
//! stream sorted by `(track, instance, seq)` is deterministic up to
//! timestamps; [`Tracer::logical_stream`] renders exactly that
//! ts-free view for tests.
//!
//! # Buffers: arena-style reuse, fixed capacity
//!
//! Track buffers are fixed-capacity `Vec<TraceEvent>`s recycled
//! through the tracer's shelf exactly like workspace leases
//! (`util::arena` semantics: cleared but capacitated), so steady-state
//! tracing allocates nothing after the first repetition per
//! concurrency slot. (A `Lease` proper borrows its arena, which would
//! make a tracer stored on `ExecutionCtx` self-referential — hence the
//! tracer owns its shelf.) When a track buffer is full, **newest
//! events are dropped and counted**, with one invariant: a span's End
//! is emitted iff its Begin was recorded, so the exported trace always
//! has balanced B/E pairs per lane.
//!
//! # Trace-file schema (`--trace FILE`)
//!
//! The export is Chrome `trace_event` JSON ("JSON object format"),
//! openable in Perfetto / `chrome://tracing`:
//!
//! ```text
//! {"traceEvents":[E0,E1,...],"displayTimeUnit":"ms","otherData":{...}}
//!
//! Ei (metadata)  {"name":"process_name","ph":"M","pid":1,"tid":0,
//!                 "args":{"name":"sclap"}}
//! Ei (span)      {"name":NAME,"ph":"B"|"E","ts":MICROS,"pid":1,
//!                 "tid":TID,"args":{K:V,...}}
//! Ei (counter)   {"name":NAME,"ph":"C","ts":MICROS,"pid":1,
//!                 "tid":TID,"args":{K:V,...}}
//! ```
//!
//! - `TID = track + (instance << 32)`: the low 31 bits identify the
//!   logical track (repetition seed), the high bits disambiguate
//!   re-entries of the same track so every lane has monotone
//!   timestamps and balanced B/E pairs.
//! - `ts` is microseconds since the tracer was created; events of one
//!   lane appear in emission (= seq) order, so per-lane `ts` is
//!   non-decreasing. `scripts/trace_validate.py` checks the schema,
//!   per-lane monotonicity, and B/E balance in CI.
//! - span/counter names are static strings (`vcycle`, `coarsening`,
//!   `uncoarsen_level`, `lpa_round`, ...); args carry the structured
//!   payload (level index, round, moved nodes, cut, imbalance).
//! - Cancellation instrumentation uses the same ambient API:
//!   `request_cancelled` (args: `reason` — the numeric `CancelReason`
//!   code) when the scheduler reaps a cancelled request, and
//!   `race_decided` (args: `winner`, `losers`) when an ensemble race
//!   picks its winner. Like every ambient emission they record only
//!   when the emitting thread has an entered track; a token that
//!   never fires emits nothing — the zero-impact invariant extends to
//!   the trace stream.

use crate::util::rng::splitmix64;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::{self, Write};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Maximum args on one event (level, round, moved, cut… — widest user
/// takes 4).
pub const MAX_ARGS: usize = 4;

/// Default per-track event-buffer capacity. Deep hierarchies emit a
/// few hundred events per V-cycle; 1<<16 leaves headroom while keeping
/// a shelved buffer under 4 MiB.
pub const DEFAULT_TRACK_CAPACITY: usize = 1 << 16;

/// Event flavor, mapping 1:1 onto Chrome `ph` values B/E/C.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Begin,
    End,
    Counter,
}

impl EventKind {
    fn ph(self) -> char {
        match self {
            EventKind::Begin => 'B',
            EventKind::End => 'E',
            EventKind::Counter => 'C',
        }
    }
}

/// One recorded event. `Copy` and fixed-size so track buffers recycle
/// without touching the allocator.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub track: u32,
    pub instance: u32,
    pub seq: u32,
    pub ts_us: u64,
    pub kind: EventKind,
    pub name: &'static str,
    pub args: [(&'static str, i64); MAX_ARGS],
    pub nargs: u8,
}

impl TraceEvent {
    /// The Chrome lane id: track in the low bits, re-entry instance in
    /// the high bits (module docs).
    pub fn tid(&self) -> u64 {
        self.track as u64 | ((self.instance as u64) << 32)
    }

    pub fn args(&self) -> &[(&'static str, i64)] {
        &self.args[..self.nargs as usize]
    }
}

#[derive(Default)]
struct TracerInner {
    events: Vec<TraceEvent>,
    shelf: Vec<Vec<TraceEvent>>,
    /// Next instance number per track id (how many times each track
    /// has been entered).
    instances: BTreeMap<u32, u32>,
    dropped: u64,
}

/// The trace sink: hands out track scopes, collects their buffers,
/// exports Chrome JSON. Shared via `Arc` on the `ExecutionCtx`.
pub struct Tracer {
    epoch: Instant,
    capacity: usize,
    inner: Mutex<TracerInner>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_TRACK_CAPACITY)
    }

    /// Tracer whose track buffers hold at most `capacity` events each
    /// (overflow drops newest, keeping B/E balanced — module docs).
    pub fn with_capacity(capacity: usize) -> Self {
        Tracer {
            epoch: Instant::now(),
            capacity: capacity.max(2),
            inner: Mutex::new(TracerInner::default()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TracerInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The logical track id of a repetition seed: `splitmix64(seed)`
    /// truncated to 31 bits (a positive Chrome tid component).
    pub fn track_of(seed: u64) -> u32 {
        (splitmix64(seed) & 0x7fff_ffff) as u32
    }

    /// Enter the track for `seed` on the current thread; every
    /// [`span`]/[`counter`] until the returned scope drops lands on
    /// this track. Re-entrant enters (a nested driver on the same
    /// thread, e.g. the in-memory pipeline inside the out-of-core
    /// driver) are inert: events keep attaching to the outer track.
    pub fn enter(self: &Arc<Self>, seed: u64) -> TrackScope {
        let already_active = ACTIVE.with(|a| a.borrow().is_some());
        if already_active {
            return TrackScope { entered: false };
        }
        let track = Self::track_of(seed);
        let (instance, buf) = {
            let mut inner = self.lock();
            let slot = inner.instances.entry(track).or_insert(0);
            let instance = *slot;
            *slot += 1;
            let buf = inner.shelf.pop().unwrap_or_default();
            (instance, buf)
        };
        ACTIVE.with(|a| {
            *a.borrow_mut() = Some(TrackState {
                tracer: self.clone(),
                epoch: self.epoch,
                capacity: self.capacity,
                track,
                instance,
                seq: 0,
                dropped: 0,
                buf,
            });
        });
        TrackScope { entered: true }
    }

    /// Enter a track at **explicit** coordinates, bypassing the
    /// arrival-order instance counter of [`enter`](Self::enter).
    ///
    /// This is the collection primitive behind per-request quality
    /// reports (`obs::quality`): the scheduler owns a dedicated tracer
    /// per `explain=true` request and wraps each repetition in a lane
    /// whose coordinates are pure functions of the request —
    /// `track = track_of(seed)`, `instance` = the racer index (0 for
    /// plain repetitions). Arrival order — which thread happened to
    /// pick the unit up first — never influences lane identity, so the
    /// merged `(track, instance, seq)` stream is byte-identical for
    /// any worker count. Like [`enter`](Self::enter) it is inert when
    /// the thread already has an active track.
    pub fn enter_lane(self: &Arc<Self>, track: u32, instance: u32) -> TrackScope {
        let already_active = ACTIVE.with(|a| a.borrow().is_some());
        if already_active {
            return TrackScope { entered: false };
        }
        let buf = {
            let mut inner = self.lock();
            let slot = inner.instances.entry(track).or_insert(0);
            *slot = (*slot).max(instance + 1);
            inner.shelf.pop().unwrap_or_default()
        };
        ACTIVE.with(|a| {
            *a.borrow_mut() = Some(TrackState {
                tracer: self.clone(),
                epoch: self.epoch,
                capacity: self.capacity,
                track,
                instance,
                seq: 0,
                dropped: 0,
                buf,
            });
        });
        TrackScope { entered: true }
    }

    /// The events of one lane, in seq order — the per-repetition slice
    /// of [`events`](Self::events) that `obs::quality` consumes.
    pub fn lane_events(&self, track: u32, instance: u32) -> Vec<TraceEvent> {
        let inner = self.lock();
        let mut events: Vec<TraceEvent> = inner
            .events
            .iter()
            .filter(|e| e.track == track && e.instance == instance)
            .copied()
            .collect();
        events.sort_by_key(|e| e.seq);
        events
    }

    /// All recorded events, merged and sorted by `(track, instance,
    /// seq)` — the deterministic logical order (timestamps ride along).
    pub fn events(&self) -> Vec<TraceEvent> {
        let inner = self.lock();
        let mut events = inner.events.clone();
        events.sort_by_key(|e| (e.track, e.instance, e.seq));
        events
    }

    /// Events dropped to capacity overflow across all tracks so far.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }

    /// The ts-free rendering of [`events`](Self::events): one line per
    /// event, `track/instance seq kind name k=v ...`. Two runs of the
    /// same workload are line-identical for any worker count.
    pub fn logical_stream(&self) -> Vec<String> {
        self.events()
            .iter()
            .map(|e| {
                let mut line = format!(
                    "{:08x}/{} {} {} {}",
                    e.track,
                    e.instance,
                    e.seq,
                    e.kind.ph(),
                    e.name
                );
                for (k, v) in e.args() {
                    line.push_str(&format!(" {k}={v}"));
                }
                line
            })
            .collect()
    }

    /// Write the Chrome `trace_event` JSON export (module docs).
    pub fn write_chrome_trace<W: Write>(&self, w: &mut W) -> io::Result<()> {
        let events = self.events();
        let dropped = self.dropped();
        write!(
            w,
            "{{\"traceEvents\":[{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\
             \"tid\":0,\"args\":{{\"name\":\"sclap\"}}}}"
        )?;
        for e in &events {
            write!(
                w,
                ",{{\"name\":\"{}\",\"ph\":\"{}\",\"ts\":{},\"pid\":1,\"tid\":{}",
                e.name,
                e.kind.ph(),
                e.ts_us,
                e.tid()
            )?;
            let args = e.args();
            if !args.is_empty() || e.kind == EventKind::Counter {
                write!(w, ",\"args\":{{")?;
                for (i, (k, v)) in args.iter().enumerate() {
                    if i > 0 {
                        write!(w, ",")?;
                    }
                    write!(w, "\"{k}\":{v}")?;
                }
                write!(w, "}}")?;
            }
            write!(w, "}}")?;
        }
        write!(
            w,
            "],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"events\":{},\"dropped\":{}}}}}",
            events.len(),
            dropped
        )
    }

    /// [`write_chrome_trace`](Self::write_chrome_trace) to a file path.
    pub fn write_chrome_trace_file(&self, path: &std::path::Path) -> io::Result<()> {
        let mut f = io::BufWriter::new(std::fs::File::create(path)?);
        self.write_chrome_trace(&mut f)?;
        f.flush()
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.lock();
        f.debug_struct("Tracer")
            .field("events", &inner.events.len())
            .field("dropped", &inner.dropped)
            .finish()
    }
}

struct TrackState {
    tracer: Arc<Tracer>,
    epoch: Instant,
    capacity: usize,
    track: u32,
    instance: u32,
    seq: u32,
    dropped: u64,
    buf: Vec<TraceEvent>,
}

impl TrackState {
    fn ts_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn pack(args: &[(&'static str, i64)]) -> ([(&'static str, i64); MAX_ARGS], u8) {
        let n = args.len().min(MAX_ARGS);
        let mut packed = [("", 0i64); MAX_ARGS];
        packed[..n].copy_from_slice(&args[..n]);
        (packed, n as u8)
    }

    /// Record one event; `force` bypasses the capacity check (Ends of
    /// recorded Begins — keeps B/E balanced at overflow).
    fn emit(
        &mut self,
        kind: EventKind,
        name: &'static str,
        args: &[(&'static str, i64)],
        force: bool,
    ) -> bool {
        if !force && self.buf.len() >= self.capacity {
            self.dropped += 1;
            // seq still advances: the sequence numbering is part of the
            // deterministic logical schedule, dropped or not.
            self.seq = self.seq.wrapping_add(1);
            return false;
        }
        let (packed, nargs) = Self::pack(args);
        self.buf.push(TraceEvent {
            track: self.track,
            instance: self.instance,
            seq: self.seq,
            ts_us: self.ts_us(),
            kind,
            name,
            args: packed,
            nargs,
        });
        self.seq = self.seq.wrapping_add(1);
        true
    }
}

thread_local! {
    static ACTIVE: RefCell<Option<TrackState>> = const { RefCell::new(None) };
}

/// RAII guard for one entered track ([`Tracer::enter`]). Dropping it
/// drains the thread's buffer into the tracer and shelves the buffer
/// for reuse.
#[must_use = "the track closes when this scope drops"]
pub struct TrackScope {
    entered: bool,
}

impl Drop for TrackScope {
    fn drop(&mut self) {
        if !self.entered {
            return;
        }
        let state = ACTIVE.with(|a| a.borrow_mut().take());
        if let Some(mut state) = state {
            let mut inner = state.tracer.lock();
            inner.events.extend_from_slice(&state.buf);
            inner.dropped += state.dropped;
            state.buf.clear();
            inner.shelf.push(std::mem::take(&mut state.buf));
        }
    }
}

/// RAII guard for one masked region ([`mask`]): the thread's ambient
/// track is parked for the guard's lifetime and restored on drop
/// (including unwinds — the guard sits on the masking frame's stack).
pub struct MaskGuard {
    saved: Option<TrackState>,
}

impl Drop for MaskGuard {
    fn drop(&mut self) {
        if self.saved.is_none() {
            return;
        }
        ACTIVE.with(|a| {
            let mut borrow = a.borrow_mut();
            debug_assert!(
                borrow.is_none(),
                "a masked region leaked an active track"
            );
            *borrow = self.saved.take();
        });
    }
}

/// Park the thread's ambient track until the returned guard drops:
/// [`span`]/[`counter`] become inert and [`Tracer::enter`] starts a
/// *fresh* track instead of nesting inertly.
///
/// This is the pool's invariance primitive (`util::pool`): tasks of a
/// multi-task job are masked on **every** execution path — claimed by
/// a background worker (no ambient track anyway), claimed by the
/// calling thread participating as worker 0, or run inline under
/// `threads = 1` / re-entrant submission. Which thread happens to claim
/// a task therefore never decides whether its events exist, which is
/// what keeps the merged logical stream worker-count-invariant.
pub fn mask() -> MaskGuard {
    MaskGuard {
        saved: ACTIVE.with(|a| a.borrow_mut().take()),
    }
}

/// RAII span guard: [`span`] emits the Begin, dropping the guard emits
/// the matching End. Inert (a no-op on drop) when no track is active
/// or the Begin was dropped to overflow.
#[must_use = "a span ends when this guard drops"]
pub struct SpanGuard {
    name: &'static str,
    recorded: bool,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.recorded {
            return;
        }
        ACTIVE.with(|a| {
            if let Some(state) = a.borrow_mut().as_mut() {
                state.emit(EventKind::End, self.name, &[], true);
            }
        });
    }
}

/// Open a span on the ambient track (one TLS check; a no-op guard when
/// tracing is off). Args beyond [`MAX_ARGS`] are truncated.
pub fn span(name: &'static str, args: &[(&'static str, i64)]) -> SpanGuard {
    ACTIVE.with(|a| {
        let mut borrow = a.borrow_mut();
        match borrow.as_mut() {
            None => SpanGuard {
                name,
                recorded: false,
            },
            Some(state) => {
                let recorded = state.emit(EventKind::Begin, name, args, false);
                SpanGuard { name, recorded }
            }
        }
    })
}

/// Emit a counter event on the ambient track (one TLS check when
/// tracing is off).
pub fn counter(name: &'static str, args: &[(&'static str, i64)]) {
    ACTIVE.with(|a| {
        if let Some(state) = a.borrow_mut().as_mut() {
            state.emit(EventKind::Counter, name, args, false);
        }
    });
}

/// Whether the current thread has an active track — instrumentation
/// that must *compute* a payload (a cut, an imbalance) gates on this
/// so the disabled path never pays for values nobody records.
pub fn tracing_active() -> bool {
    ACTIVE.with(|a| a.borrow().is_some())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_and_balance() {
        let t = Arc::new(Tracer::new());
        {
            let _scope = t.enter(7);
            let _outer = span("vcycle", &[("cycle", 0)]);
            {
                let _inner = span("coarsening", &[("level", 1)]);
                counter("lpa_round", &[("round", 3), ("moved", 42)]);
            }
        }
        let events = t.events();
        assert_eq!(events.len(), 5);
        let kinds: Vec<EventKind> = events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::Begin,
                EventKind::Begin,
                EventKind::Counter,
                EventKind::End,
                EventKind::End
            ]
        );
        assert_eq!(events[3].name, "coarsening");
        assert_eq!(events[4].name, "vcycle");
        assert_eq!(events[2].args(), &[("round", 3), ("moved", 42)]);
        // seq is contiguous and ts non-decreasing within the lane.
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.seq as usize, i);
        }
        assert!(events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
    }

    #[test]
    fn emission_without_a_track_is_inert() {
        let _g = span("nobody", &[("x", 1)]);
        counter("nothing", &[]);
        assert!(!tracing_active());
    }

    #[test]
    fn nested_enter_is_inert_and_buffers_recycle() {
        let t = Arc::new(Tracer::new());
        {
            let _outer = t.enter(1);
            let _inner = t.enter(2); // same thread: inert
            counter("c", &[]);
        }
        {
            let _again = t.enter(1); // second instance of track 1
            counter("c", &[]);
        }
        let events = t.events();
        assert_eq!(events.len(), 2);
        // Both events landed on track_of(1); the nested enter created
        // no second track.
        assert!(events.iter().all(|e| e.track == Tracer::track_of(1)));
        assert_eq!(events[0].instance, 0);
        assert_eq!(events[1].instance, 1);
        assert_ne!(events[0].tid(), events[1].tid());
        // The second scope reused the shelved buffer.
        assert_eq!(t.lock().shelf.len(), 1);
    }

    #[test]
    fn overflow_drops_newest_but_balances_ends() {
        let t = Arc::new(Tracer::with_capacity(3));
        {
            let _scope = t.enter(9);
            let _a = span("a", &[]); // recorded (1)
            let _b = span("b", &[]); // recorded (2)
            counter("x", &[]); // recorded (3) — buffer full
            counter("y", &[]); // dropped
            let _c = span("c", &[]); // Begin dropped → End suppressed
        } // Ends of a and b force-emitted past capacity
        let events = t.events();
        assert_eq!(t.dropped(), 2);
        let names: Vec<&str> = events.iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["a", "b", "x", "b", "a"]);
        let mut depth = 0i64;
        for e in &events {
            match e.kind {
                EventKind::Begin => depth += 1,
                EventKind::End => depth -= 1,
                EventKind::Counter => {}
            }
            assert!(depth >= 0);
        }
        assert_eq!(depth, 0);
    }

    #[test]
    fn enter_lane_pins_coordinates_and_nests_inert() {
        let t = Arc::new(Tracer::new());
        {
            // Explicit coordinates land verbatim, regardless of entry
            // order (instance 2 before instance 0).
            let _lane = t.enter_lane(0xabc, 2);
            let _inner = t.enter_lane(0xdef, 0); // same thread: inert
            counter("c", &[("v", 1)]);
        }
        {
            let _lane = t.enter_lane(0xabc, 0);
            counter("c", &[("v", 2)]);
        }
        let events = t.events();
        assert_eq!(events.len(), 2);
        // Sorted order is (track, instance, seq): instance 0 first.
        assert_eq!(events[0].instance, 0);
        assert_eq!(events[0].args(), &[("v", 2)]);
        assert_eq!(events[1].instance, 2);
        assert_eq!(events[1].args(), &[("v", 1)]);
        assert!(events.iter().all(|e| e.track == 0xabc));
        // Lane extraction slices exactly one lane, in seq order.
        let lane = t.lane_events(0xabc, 2);
        assert_eq!(lane.len(), 1);
        assert_eq!(lane[0].args(), &[("v", 1)]);
        assert!(t.lane_events(0xabc, 1).is_empty());
        // A later arrival-order enter() of the same track does not
        // collide with the explicit instances.
        {
            let _scope = t.enter_lane(Tracer::track_of(7), 1);
        }
        {
            let _scope = t.enter(7);
            counter("c", &[("v", 3)]);
        }
        let lane = t.lane_events(Tracer::track_of(7), 2);
        assert_eq!(lane.len(), 1, "enter() allocates past pinned lanes");
    }

    #[test]
    fn chrome_export_is_valid_json() {
        let t = Arc::new(Tracer::new());
        {
            let _scope = t.enter(3);
            let _s = span("vcycle", &[("cycle", 0)]);
            counter("cut", &[("level", 2), ("cut", 123)]);
        }
        let mut out = Vec::new();
        t.write_chrome_trace(&mut out).unwrap();
        let s = String::from_utf8(out).unwrap();
        let parsed = crate::util::json::parse_json(&s).expect("valid trace json");
        let events = parsed
            .get("traceEvents")
            .and_then(|v| v.as_array())
            .expect("traceEvents array");
        // metadata + B + C + E
        assert_eq!(events.len(), 4);
    }

    #[test]
    fn logical_stream_ignores_time() {
        let t = Arc::new(Tracer::new());
        {
            let _scope = t.enter(5);
            let _s = span("phase", &[("level", 1)]);
        }
        assert_eq!(
            t.logical_stream(),
            vec![
                format!("{:08x}/0 0 B phase level=1", Tracer::track_of(5)),
                format!("{:08x}/0 1 E phase", Tracer::track_of(5)),
            ]
        );
    }
}
