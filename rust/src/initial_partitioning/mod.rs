//! Initial partitioning of the coarsest graph: greedy graph growing and
//! multilevel recursive bisection (matching- or cluster-based, the `C…`
//! vs `U…` configuration families of §5.1).

pub mod greedy_growing;
pub mod recursive_bisection;

pub use greedy_growing::{greedy_bisection, grow_from, round_robin};
pub use recursive_bisection::{
    multilevel_bisect, recursive_bisection, InitialPartitionConfig,
};
