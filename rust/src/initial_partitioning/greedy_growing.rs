//! Greedy graph growing — the classic initial bisection heuristic
//! (Karypis & Kumar): grow one block outward from a seed node, always
//! absorbing the frontier node with the best gain, until the block
//! reaches its target weight. Several seeds are tried; the best result
//! (after optional 2-way FM polish) wins.

use crate::graph::csr::{Graph, NodeId, Weight};
use crate::partitioning::metrics::cut_value;
use crate::partitioning::partition::Partition;
use crate::util::bucket_queue::BucketQueue;
use crate::util::fast_reset::FastResetArray;
use crate::util::rng::Rng;

/// Grow block 1 from `seed` until its weight reaches `target`.
/// Returns the block array (0 = rest, 1 = grown side).
pub fn grow_from(g: &Graph, seed: NodeId, target: Weight) -> Vec<u32> {
    let n = g.n();
    let mut blocks = vec![0u32; n];
    let mut grown_weight: Weight = 0;
    let max_gain = (g.max_degree() as i64 + 1).max(8);
    let mut queue = BucketQueue::new(n, max_gain);
    let mut conn: FastResetArray<i64> = FastResetArray::new(2);

    let gain_of = |v: NodeId, blocks: &[u32], conn: &mut FastResetArray<i64>| -> i64 {
        conn.clear();
        let adj = g.adjacent(v);
        let ws = g.adjacent_weights(v);
        let mut inside = 0i64;
        let mut outside = 0i64;
        for i in 0..adj.len() {
            if blocks[adj[i] as usize] == 1 {
                inside += ws[i];
            } else {
                outside += ws[i];
            }
        }
        inside - outside
    };

    queue.push(seed as usize, 0);
    while grown_weight < target {
        let Some((vu, _)) = queue.pop_max() else { break };
        let v = vu as NodeId;
        if blocks[vu] == 1 {
            continue;
        }
        blocks[vu] = 1;
        grown_weight += g.node_weight(v);
        for &u in g.adjacent(v) {
            let uu = u as usize;
            if blocks[uu] == 0 {
                let gain = gain_of(u, &blocks, &mut conn);
                queue.update(uu, gain);
            }
        }
    }

    // Disconnected graphs: frontier may empty before the target — top up
    // with arbitrary unassigned nodes (keeps the bisection feasible).
    if grown_weight < target {
        for v in g.nodes() {
            if grown_weight >= target {
                break;
            }
            if blocks[v as usize] == 0 {
                blocks[v as usize] = 1;
                grown_weight += g.node_weight(v);
            }
        }
    }
    blocks
}

/// Best-of-`tries` greedy-growing bisection with target weight for the
/// grown side. Returns the best block array by cut.
pub fn greedy_bisection(
    g: &Graph,
    target: Weight,
    tries: usize,
    rng: &mut Rng,
) -> Vec<u32> {
    assert!(g.n() > 0);
    let mut best: Option<(Weight, Vec<u32>)> = None;
    for _ in 0..tries.max(1) {
        let seed = rng.below(g.n()) as NodeId;
        let blocks = grow_from(g, seed, target);
        let cut = cut_value(g, &blocks);
        if best.as_ref().map(|(bc, _)| cut < *bc).unwrap_or(true) {
            best = Some((cut, blocks));
        }
    }
    best.unwrap().1
}

/// Round-robin fallback for degenerate cases (n < k): block i gets every
/// k-th node.
pub fn round_robin(g: &Graph, k: usize) -> Partition {
    let blocks: Vec<u32> = (0..g.n()).map(|v| (v % k) as u32).collect();
    Partition::from_blocks(g, k, blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::karate::karate_club;

    fn two_cliques() -> Graph {
        let mut b = GraphBuilder::new(8);
        for base in [0u32, 4] {
            for i in 0..4 {
                for j in (i + 1)..4 {
                    b.add_edge(base + i, base + j, 1);
                }
            }
        }
        b.add_edge(3, 4, 1);
        b.build()
    }

    #[test]
    fn grows_to_target() {
        let g = karate_club();
        let blocks = grow_from(&g, 0, 17);
        let w: Weight = blocks.iter().filter(|&&b| b == 1).count() as Weight;
        assert!(w >= 17);
        assert!(w <= 18); // one node overshoot at most
    }

    #[test]
    fn finds_clique_cut() {
        let g = two_cliques();
        let mut rng = Rng::new(1);
        let blocks = greedy_bisection(&g, 4, 4, &mut rng);
        assert_eq!(cut_value(&g, &blocks), 1);
    }

    #[test]
    fn grown_side_is_connected_when_possible() {
        let g = two_cliques();
        let blocks = grow_from(&g, 0, 4);
        // growing from node 0 with target 4 should absorb exactly clique 1
        assert_eq!(&blocks[0..4], &[1, 1, 1, 1]);
        assert_eq!(&blocks[4..8], &[0, 0, 0, 0]);
    }

    #[test]
    fn disconnected_top_up() {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1, 1);
        b.add_edge(2, 3, 1);
        b.add_edge(4, 5, 1);
        let g = b.build();
        let blocks = grow_from(&g, 0, 4);
        let grown = blocks.iter().filter(|&&x| x == 1).count();
        assert!(grown >= 4);
    }

    #[test]
    fn round_robin_covers_all_blocks() {
        let g = karate_club();
        let p = round_robin(&g, 5);
        assert_eq!(p.nonempty_blocks(), 5);
        assert!(p.validate(&g).is_ok());
    }
}
