//! Multilevel recursive bisection — KaHIP's initial partitioning (§3.1:
//! "KaHIP uses a multilevel recursive bisection algorithm to create an
//! initial partitioning").
//!
//! To split into k blocks: bisect with proportional target weights
//! (⌈k/2⌉ : ⌊k/2⌋), recurse on the induced subgraphs. Each bisection is
//! itself a small multilevel run: coarsen (matching for the `C…`
//! configurations, cluster contraction for `U…`), greedy-grow + 2-way FM
//! on the coarsest graph, FM-refine while uncoarsening.

use crate::coarsening::hierarchy::{coarsen, CoarseningParams, CoarseningScheme};
use crate::graph::csr::{Graph, NodeId, Weight};
use crate::graph::subgraph::induced_subgraph;
use crate::initial_partitioning::greedy_growing::{greedy_bisection, round_robin};
use crate::partitioning::partition::Partition;
use crate::refinement::fm::{kway_fm_bounded, FmConfig};
use crate::util::rng::Rng;

/// Initial partitioning configuration.
#[derive(Debug, Clone)]
pub struct InitialPartitionConfig {
    /// Coarsening scheme inside each bisection (C = matching, U = LPA).
    pub scheme: CoarseningScheme,
    /// Imbalance allowance ε for the bisection targets.
    pub epsilon: f64,
    /// Greedy-growing attempts per bisection.
    pub tries: usize,
    pub fm: FmConfig,
}

impl InitialPartitionConfig {
    pub fn matching_based(epsilon: f64) -> Self {
        InitialPartitionConfig {
            scheme: CoarseningScheme::Matching { two_hop: true },
            epsilon,
            tries: 4,
            fm: FmConfig::eco(),
        }
    }

    pub fn cluster_based(epsilon: f64) -> Self {
        use crate::clustering::label_propagation::{LpaConfig, NodeOrdering};
        InitialPartitionConfig {
            scheme: CoarseningScheme::ClusterLpa {
                lpa: LpaConfig::clustering(10, NodeOrdering::Degree),
                size_factor: 18.0,
                ensemble: None,
            },
            epsilon,
            tries: 4,
            fm: FmConfig::eco(),
        }
    }
}

/// Partition `g` into `k` blocks by multilevel recursive bisection.
pub fn recursive_bisection(
    g: &Graph,
    k: usize,
    config: &InitialPartitionConfig,
    rng: &mut Rng,
) -> Partition {
    assert!(k >= 1);
    if k == 1 {
        return Partition::from_blocks(g, 1, vec![0; g.n()]);
    }
    if g.n() <= k {
        return round_robin(g, k);
    }
    let mut blocks = vec![0u32; g.n()];
    let all: Vec<NodeId> = g.nodes().collect();
    split(g, &all, k, 0, config, &mut blocks, rng);
    Partition::from_blocks(g, k, blocks)
}

/// Recursively bisect the subgraph induced by `nodes` into `k` blocks
/// with ids starting at `first_block`.
fn split(
    root: &Graph,
    nodes: &[NodeId],
    k: usize,
    first_block: u32,
    config: &InitialPartitionConfig,
    out: &mut [u32],
    rng: &mut Rng,
) {
    if k == 1 {
        for &v in nodes {
            out[v as usize] = first_block;
        }
        return;
    }
    // Degenerate branch: fewer nodes than target blocks (possible when k
    // is close to n — e.g. karate with k=32). Round-robin so every block
    // id in [first_block, first_block+k) is used where possible.
    if nodes.len() <= k {
        for (i, &v) in nodes.iter().enumerate() {
            out[v as usize] = first_block + (i % k) as u32;
        }
        return;
    }
    let (sub, old_of) = induced_subgraph(root, nodes);
    let k1 = k.div_ceil(2);
    let k2 = k - k1;
    let target1 = (sub.total_node_weight() as f64 * k1 as f64 / k as f64).round() as Weight;
    let side1 = multilevel_bisect(&sub, target1, config, rng);

    let mut left: Vec<NodeId> = Vec::new();
    let mut right: Vec<NodeId> = Vec::new();
    for (i, &old) in old_of.iter().enumerate() {
        if side1[i] == 1 {
            left.push(old);
        } else {
            right.push(old);
        }
    }
    // Degenerate guard: greedy growing can swallow everything on tiny
    // or star-shaped graphs — force non-empty sides.
    if left.is_empty() || right.is_empty() {
        let mut both: Vec<NodeId> = nodes.to_vec();
        rng.shuffle(&mut both);
        let cut_at = (both.len() * k1 / k).max(1).min(both.len() - 1);
        left = both[..cut_at].to_vec();
        right = both[cut_at..].to_vec();
    }
    split(root, &left, k1, first_block, config, out, rng);
    split(root, &right, k2, first_block + k1 as u32, config, out, rng);
}

/// One multilevel bisection: returns a 0/1 array over `g`'s nodes where
/// side 1 has weight ≈ `target1`.
pub fn multilevel_bisect(
    g: &Graph,
    target1: Weight,
    config: &InitialPartitionConfig,
    rng: &mut Rng,
) -> Vec<u32> {
    let total = g.total_node_weight();
    let target0 = total - target1;
    // Per-side bounds with ε slack + heaviest node allowance.
    let slack = |t: Weight| {
        ((1.0 + config.epsilon) * t as f64).ceil() as Weight + g.max_node_weight()
    };
    let bounds = [slack(target0), slack(target1)];

    // Mini-multilevel: coarsen for 2 blocks.
    let mut params = CoarseningParams::new(2, config.epsilon, config.scheme.clone());
    params.max_levels = 32;
    let h = coarsen(g, &params, None, rng);
    let coarsest = h.coarsest(g);

    // Initial bisection on the coarsest graph.
    let blocks = greedy_bisection(coarsest, target1, config.tries, rng);
    let mut p = Partition::from_blocks(coarsest, 2, blocks);
    kway_fm_bounded(coarsest, &mut p, &bounds, &config.fm, rng);

    // Uncoarsen with FM at every level.
    let mut blocks = p.blocks;
    for i in (0..h.levels.len()).rev() {
        let finer: &Graph = if i == 0 { g } else { &h.levels[i - 1].graph };
        let map = &h.levels[i].map;
        blocks = crate::coarsening::contract::project_partition(map, &blocks);
        let mut p = Partition::from_blocks(finer, 2, blocks);
        kway_fm_bounded(finer, &mut p, &bounds, &config.fm, rng);
        blocks = p.blocks;
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::karate::karate_club;
    use crate::partitioning::metrics::{cut_value, evaluate};

    #[test]
    fn bisection_of_karate_is_decent() {
        let g = karate_club();
        let mut rng = Rng::new(1);
        let config = InitialPartitionConfig::matching_based(0.03);
        let p = recursive_bisection(&g, 2, &config, &mut rng);
        assert!(p.validate(&g).is_ok());
        let m = evaluate(&g, &p, 0.03);
        // ground-truth fission cuts 10; a decent bisection lands ≤ 14
        assert!(m.cut <= 14, "cut = {}", m.cut);
        assert!(m.feasible, "weights {:?}", p.block_weights);
    }

    #[test]
    fn kway_produces_k_blocks() {
        let mut rng = Rng::new(2);
        let g = generators::barabasi_albert(500, 3, &mut rng);
        for k in [2usize, 3, 4, 8] {
            let config = InitialPartitionConfig::matching_based(0.03);
            let p = recursive_bisection(&g, k, &config, &mut Rng::new(k as u64));
            assert_eq!(p.k, k);
            assert_eq!(p.nonempty_blocks(), k, "k={k}");
            assert!(p.validate(&g).is_ok());
        }
    }

    #[test]
    fn cluster_based_variant_works() {
        let mut rng = Rng::new(3);
        let g = generators::rmat(10, 4000, 0.57, 0.19, 0.19, &mut rng);
        let g = crate::graph::subgraph::largest_component(&g);
        let config = InitialPartitionConfig::cluster_based(0.03);
        let p = recursive_bisection(&g, 4, &config, &mut Rng::new(4));
        assert_eq!(p.nonempty_blocks(), 4);
        let m = evaluate(&g, &p, 0.03);
        assert!(m.cut < g.total_edge_weight(), "cut should be nontrivial");
    }

    #[test]
    fn k_one_is_trivial() {
        let g = karate_club();
        let config = InitialPartitionConfig::matching_based(0.03);
        let p = recursive_bisection(&g, 1, &config, &mut Rng::new(5));
        assert_eq!(p.k, 1);
        assert_eq!(cut_value(&g, &p.blocks), 0);
    }

    #[test]
    fn tiny_graph_round_robins() {
        let g = karate_club();
        let config = InitialPartitionConfig::matching_based(0.03);
        let p = recursive_bisection(&g, 34, &config, &mut Rng::new(6));
        assert_eq!(p.nonempty_blocks(), 34);
    }

    #[test]
    fn balance_within_bounds_odd_k() {
        let mut rng = Rng::new(7);
        let g = generators::watts_strogatz(900, 4, 0.1, &mut rng);
        let config = InitialPartitionConfig::matching_based(0.05);
        let p = recursive_bisection(&g, 5, &config, &mut Rng::new(8));
        let m = evaluate(&g, &p, 0.05);
        // recursive bisection compounds slack; allow generous margin but
        // catch gross imbalance
        assert!(m.imbalance < 0.25, "imbalance {}", m.imbalance);
    }
}
