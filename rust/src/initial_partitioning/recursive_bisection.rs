//! Multilevel recursive bisection — KaHIP's initial partitioning (§3.1:
//! "KaHIP uses a multilevel recursive bisection algorithm to create an
//! initial partitioning"), **parallel across independent splits** on
//! the shared [`ExecutionCtx`] pool.
//!
//! To split into k blocks: bisect with proportional target weights
//! (⌈k/2⌉ : ⌊k/2⌋), recurse on the induced subgraphs. Each bisection is
//! itself a small multilevel run: coarsen (matching for the `C…`
//! configurations, cluster contraction for `U…`), greedy-grow + 2-way FM
//! on the coarsest graph, FM-refine while uncoarsening.
//!
//! # Parallelism and determinism
//!
//! The two halves of every split are independent (disjoint node sets),
//! so the recursion is processed as a **breadth-first frontier of split
//! tasks** fanned out on the shared pool: all splits of one depth run
//! concurrently (up to k/2-way parallelism at the leaves), and each
//! task draws from an RNG stream derived from its **split path** — the
//! root is path 1, the halves of path `p` are `2p` and `2p + 1` — via
//! [`exec::derive_seed`](crate::util::exec::derive_seed). A task's
//! output is therefore a pure function of (graph, config, base seed,
//! path): the executing thread, the pool size, and the completion order
//! of sibling splits are all unobservable, and `threads ∈ {1, 2, 4}`
//! produce byte-identical partitions (`rust/tests/recursive_bisection.rs`
//! and, end-to-end, `rust/tests/determinism.rs`).

use crate::coarsening::hierarchy::{coarsen, CoarseningParams, CoarseningScheme};
use crate::graph::csr::{Graph, NodeId, Weight};
use crate::graph::subgraph::induced_subgraph;
use crate::initial_partitioning::greedy_growing::{greedy_bisection, round_robin};
use crate::partitioning::partition::Partition;
use crate::refinement::fm::{kway_fm_bounded, FmConfig};
use crate::util::exec::{derive_seed, ExecutionCtx};
use crate::util::rng::Rng;

/// Initial partitioning configuration.
#[derive(Debug, Clone)]
pub struct InitialPartitionConfig {
    /// Coarsening scheme inside each bisection (C = matching, U = LPA).
    pub scheme: CoarseningScheme,
    /// Imbalance allowance ε for the bisection targets.
    pub epsilon: f64,
    /// Greedy-growing attempts per bisection.
    pub tries: usize,
    pub fm: FmConfig,
}

impl InitialPartitionConfig {
    pub fn matching_based(epsilon: f64) -> Self {
        InitialPartitionConfig {
            scheme: CoarseningScheme::Matching { two_hop: true },
            epsilon,
            tries: 4,
            fm: FmConfig::eco(),
        }
    }

    pub fn cluster_based(epsilon: f64) -> Self {
        use crate::clustering::label_propagation::{LpaConfig, NodeOrdering};
        InitialPartitionConfig {
            scheme: CoarseningScheme::ClusterLpa {
                lpa: LpaConfig::clustering(10, NodeOrdering::Degree),
                size_factor: 18.0,
                ensemble: None,
            },
            epsilon,
            tries: 4,
            fm: FmConfig::eco(),
        }
    }
}

/// One pending split: bisect the subgraph induced by `nodes` into `k`
/// blocks with ids starting at `first_block`. `path` identifies the
/// split's position in the recursion tree (root 1; children 2p, 2p+1)
/// and seeds its RNG stream.
struct SplitTask {
    nodes: Vec<NodeId>,
    k: usize,
    first_block: u32,
    path: u64,
}

/// What one processed split produced: either final block assignments
/// (a leaf) or the two child splits.
enum SplitOutcome {
    Assign(Vec<(NodeId, u32)>),
    Children(SplitTask, SplitTask),
}

/// Partition `g` into `k` blocks by multilevel recursive bisection,
/// fanning the independent splits of each depth out on `ctx`'s pool.
/// Consumes exactly one draw from `rng` (the base seed of the per-path
/// streams), so the caller's stream advances identically for every
/// thread count.
pub fn recursive_bisection(
    g: &Graph,
    k: usize,
    config: &InitialPartitionConfig,
    ctx: &ExecutionCtx,
    rng: &mut Rng,
) -> Partition {
    assert!(k >= 1);
    let base_seed = rng.next_u64();
    if k == 1 {
        return Partition::from_blocks(g, 1, vec![0; g.n()]);
    }
    if g.n() <= k {
        return round_robin(g, k);
    }
    let mut blocks = vec![0u32; g.n()];
    let mut frontier = vec![SplitTask {
        nodes: g.nodes().collect(),
        k,
        first_block: 0,
        path: 1,
    }];
    while !frontier.is_empty() {
        // All tasks in the frontier are independent (disjoint node
        // sets); results come back in task order, so the schedule is
        // deterministic for any pool size.
        let outcomes: Vec<SplitOutcome> =
            ctx.pool().map_indexed(frontier.len(), |_worker, i| {
                let task = &frontier[i];
                let mut branch_rng = Rng::new(derive_seed(base_seed, task.path));
                split_once(g, task, config, &mut branch_rng)
            });
        let mut next = Vec::new();
        for outcome in outcomes {
            match outcome {
                SplitOutcome::Assign(pairs) => {
                    for (v, b) in pairs {
                        blocks[v as usize] = b;
                    }
                }
                SplitOutcome::Children(left, right) => {
                    next.push(left);
                    next.push(right);
                }
            }
        }
        frontier = next;
    }
    Partition::from_blocks(g, k, blocks)
}

/// Process one split task: either terminate (k = 1 or a degenerate tiny
/// branch) or bisect and emit the two child tasks.
fn split_once(
    root: &Graph,
    task: &SplitTask,
    config: &InitialPartitionConfig,
    rng: &mut Rng,
) -> SplitOutcome {
    let (nodes, k, first_block) = (&task.nodes, task.k, task.first_block);
    if k == 1 {
        return SplitOutcome::Assign(nodes.iter().map(|&v| (v, first_block)).collect());
    }
    // Degenerate branch: fewer nodes than target blocks (possible when k
    // is close to n — e.g. karate with k=32). Round-robin so every block
    // id in [first_block, first_block+k) is used where possible.
    if nodes.len() <= k {
        return SplitOutcome::Assign(
            nodes
                .iter()
                .enumerate()
                .map(|(i, &v)| (v, first_block + (i % k) as u32))
                .collect(),
        );
    }
    let (sub, old_of) = induced_subgraph(root, nodes);
    let k1 = k.div_ceil(2);
    let k2 = k - k1;
    let target1 = (sub.total_node_weight() as f64 * k1 as f64 / k as f64).round() as Weight;
    let side1 = multilevel_bisect(&sub, target1, config, rng);

    let mut left: Vec<NodeId> = Vec::new();
    let mut right: Vec<NodeId> = Vec::new();
    for (i, &old) in old_of.iter().enumerate() {
        if side1[i] == 1 {
            left.push(old);
        } else {
            right.push(old);
        }
    }
    // Degenerate guard: greedy growing can swallow everything on tiny
    // or star-shaped graphs — force non-empty sides.
    if left.is_empty() || right.is_empty() {
        let mut both: Vec<NodeId> = nodes.clone();
        rng.shuffle(&mut both);
        let cut_at = (both.len() * k1 / k).max(1).min(both.len() - 1);
        right = both.split_off(cut_at);
        left = both;
    }
    SplitOutcome::Children(
        SplitTask {
            nodes: left,
            k: k1,
            first_block,
            path: task.path * 2,
        },
        SplitTask {
            nodes: right,
            k: k2,
            first_block: first_block + k1 as u32,
            path: task.path * 2 + 1,
        },
    )
}

/// One multilevel bisection: returns a 0/1 array over `g`'s nodes where
/// side 1 has weight ≈ `target1`. Runs sequentially — it executes
/// *inside* a split task on the shared pool, and any nested pool use
/// goes inline there (util::pool re-entrancy).
pub fn multilevel_bisect(
    g: &Graph,
    target1: Weight,
    config: &InitialPartitionConfig,
    rng: &mut Rng,
) -> Vec<u32> {
    let total = g.total_node_weight();
    let target0 = total - target1;
    // Per-side bounds with ε slack + heaviest node allowance.
    let slack = |t: Weight| {
        ((1.0 + config.epsilon) * t as f64).ceil() as Weight + g.max_node_weight()
    };
    let bounds = [slack(target0), slack(target1)];

    // Mini-multilevel: coarsen for 2 blocks.
    let mut params = CoarseningParams::new(2, config.epsilon, config.scheme.clone());
    params.max_levels = 32;
    let h = coarsen(g, &params, None, rng);
    let coarsest = h.coarsest(g);

    // Initial bisection on the coarsest graph.
    let blocks = greedy_bisection(coarsest, target1, config.tries, rng);
    let mut p = Partition::from_blocks(coarsest, 2, blocks);
    kway_fm_bounded(coarsest, &mut p, &bounds, &config.fm, rng);

    // Uncoarsen with FM at every level.
    let mut blocks = p.blocks;
    for i in (0..h.levels.len()).rev() {
        let finer: &Graph = if i == 0 { g } else { &h.levels[i - 1].graph };
        let map = &h.levels[i].map;
        blocks = crate::coarsening::contract::project_partition(map, &blocks);
        let mut p = Partition::from_blocks(finer, 2, blocks);
        kway_fm_bounded(finer, &mut p, &bounds, &config.fm, rng);
        blocks = p.blocks;
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::karate::karate_club;
    use crate::partitioning::metrics::{cut_value, evaluate};

    fn seq() -> ExecutionCtx {
        ExecutionCtx::sequential()
    }

    #[test]
    fn bisection_of_karate_is_decent() {
        let g = karate_club();
        let mut rng = Rng::new(1);
        let config = InitialPartitionConfig::matching_based(0.03);
        let p = recursive_bisection(&g, 2, &config, &seq(), &mut rng);
        assert!(p.validate(&g).is_ok());
        let m = evaluate(&g, &p, 0.03);
        // ground-truth fission cuts 10; a decent bisection lands ≤ 14
        assert!(m.cut <= 14, "cut = {}", m.cut);
        assert!(m.feasible, "weights {:?}", p.block_weights);
    }

    #[test]
    fn kway_produces_k_blocks() {
        let mut rng = Rng::new(2);
        let g = generators::barabasi_albert(500, 3, &mut rng);
        for k in [2usize, 3, 4, 8] {
            let config = InitialPartitionConfig::matching_based(0.03);
            let p = recursive_bisection(&g, k, &config, &seq(), &mut Rng::new(k as u64));
            assert_eq!(p.k, k);
            assert_eq!(p.nonempty_blocks(), k, "k={k}");
            assert!(p.validate(&g).is_ok());
        }
    }

    #[test]
    fn cluster_based_variant_works() {
        let mut rng = Rng::new(3);
        let g = generators::rmat(10, 4000, 0.57, 0.19, 0.19, &mut rng);
        let g = crate::graph::subgraph::largest_component(&g);
        let config = InitialPartitionConfig::cluster_based(0.03);
        let p = recursive_bisection(&g, 4, &config, &seq(), &mut Rng::new(4));
        assert_eq!(p.nonempty_blocks(), 4);
        let m = evaluate(&g, &p, 0.03);
        assert!(m.cut < g.total_edge_weight(), "cut should be nontrivial");
    }

    #[test]
    fn k_one_is_trivial() {
        let g = karate_club();
        let config = InitialPartitionConfig::matching_based(0.03);
        let p = recursive_bisection(&g, 1, &config, &seq(), &mut Rng::new(5));
        assert_eq!(p.k, 1);
        assert_eq!(cut_value(&g, &p.blocks), 0);
    }

    #[test]
    fn tiny_graph_round_robins() {
        let g = karate_club();
        let config = InitialPartitionConfig::matching_based(0.03);
        let p = recursive_bisection(&g, 34, &config, &seq(), &mut Rng::new(6));
        assert_eq!(p.nonempty_blocks(), 34);
    }

    #[test]
    fn balance_within_bounds_odd_k() {
        let mut rng = Rng::new(7);
        let g = generators::watts_strogatz(900, 4, 0.1, &mut rng);
        let config = InitialPartitionConfig::matching_based(0.05);
        let p = recursive_bisection(&g, 5, &config, &seq(), &mut Rng::new(8));
        let m = evaluate(&g, &p, 0.05);
        // recursive bisection compounds slack; allow generous margin but
        // catch gross imbalance
        assert!(m.imbalance < 0.25, "imbalance {}", m.imbalance);
    }

    #[test]
    fn fan_out_matches_sequential() {
        // The tentpole invariant at the engine level: the frontier fans
        // out on the pool, but path-derived streams make the result a
        // pure function of (graph, config, seed).
        let mut rng = Rng::new(9);
        let g = generators::barabasi_albert(800, 4, &mut rng);
        let config = InitialPartitionConfig::matching_based(0.03);
        let run = |threads: usize| {
            let ctx = ExecutionCtx::new(threads);
            recursive_bisection(&g, 8, &config, &ctx, &mut Rng::new(10)).blocks
        };
        let reference = run(1);
        for threads in [2usize, 4] {
            assert_eq!(reference, run(threads), "threads={threads} diverged");
        }
    }

    #[test]
    fn consumes_exactly_one_rng_draw() {
        // The caller's stream must advance identically regardless of the
        // recursion shape (that is what keeps the surrounding pipeline
        // thread-invariant).
        let g = karate_club();
        let config = InitialPartitionConfig::matching_based(0.03);
        let mut a = Rng::new(21);
        let _ = recursive_bisection(&g, 2, &config, &seq(), &mut a);
        let mut b = Rng::new(21);
        let _ = recursive_bisection(&g, 8, &config, &seq(), &mut b);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
