//! Partition quality metrics (§2.1): total cut, balance/imbalance, and
//! the auxiliary statistics the evaluation tables report.

use super::partition::Partition;
use crate::graph::csr::{Graph, Weight};

/// Quality summary of a partition.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionMetrics {
    pub k: usize,
    /// Total weight of cut edges — the objective.
    pub cut: Weight,
    /// max block weight / ceil(total/k) − 1 (0 = perfectly balanced).
    pub imbalance: f64,
    pub max_block_weight: Weight,
    pub min_block_weight: Weight,
    /// Number of boundary nodes.
    pub boundary_nodes: usize,
    /// Whether every block obeys `L_max` for the given ε.
    pub feasible: bool,
}

/// Total weight of edges crossing blocks.
pub fn cut_value(g: &Graph, blocks: &[u32]) -> Weight {
    let mut cut = 0;
    for v in g.nodes() {
        let bv = blocks[v as usize];
        let adj = g.adjacent(v);
        let ws = g.adjacent_weights(v);
        for i in 0..adj.len() {
            if blocks[adj[i] as usize] != bv {
                cut += ws[i];
            }
        }
    }
    cut / 2
}

/// Count nodes with at least one neighbor in another block.
pub fn boundary_nodes(g: &Graph, blocks: &[u32]) -> usize {
    g.nodes()
        .filter(|&v| {
            let bv = blocks[v as usize];
            g.adjacent(v).iter().any(|&u| blocks[u as usize] != bv)
        })
        .count()
}

/// Compute all metrics for a partition under imbalance parameter ε.
pub fn evaluate(g: &Graph, p: &Partition, epsilon: f64) -> PartitionMetrics {
    let avg = (g.total_node_weight() as f64 / p.k as f64).ceil();
    let lmax = crate::coarsening::hierarchy::l_max(
        g.total_node_weight(),
        p.k,
        epsilon,
        g.max_node_weight(),
    );
    let max_w = p.max_block_weight();
    PartitionMetrics {
        k: p.k,
        cut: cut_value(g, &p.blocks),
        imbalance: if avg > 0.0 {
            max_w as f64 / avg - 1.0
        } else {
            0.0
        },
        max_block_weight: max_w,
        min_block_weight: p.min_block_weight(),
        boundary_nodes: boundary_nodes(g, &p.blocks),
        feasible: max_w <= lmax,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn square() -> Graph {
        GraphBuilder::new(4)
            .edge(0, 1)
            .edge(1, 2)
            .edge(2, 3)
            .edge(3, 0)
            .build()
    }

    #[test]
    fn cut_of_square_halves() {
        let g = square();
        assert_eq!(cut_value(&g, &[0, 0, 1, 1]), 2);
        assert_eq!(cut_value(&g, &[0, 1, 0, 1]), 4);
        assert_eq!(cut_value(&g, &[0, 0, 0, 0]), 0);
    }

    #[test]
    fn cut_respects_weights() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 7);
        let g = b.build();
        assert_eq!(cut_value(&g, &[0, 1]), 7);
    }

    #[test]
    fn boundary_count() {
        let g = square();
        assert_eq!(boundary_nodes(&g, &[0, 0, 1, 1]), 4);
        assert_eq!(boundary_nodes(&g, &[0, 0, 0, 0]), 0);
    }

    #[test]
    fn evaluate_balanced() {
        let g = square();
        let p = Partition::from_blocks(&g, 2, vec![0, 0, 1, 1]);
        let m = evaluate(&g, &p, 0.03);
        assert_eq!(m.cut, 2);
        assert!(m.imbalance.abs() < 1e-9);
        assert!(m.feasible);
        assert_eq!(m.boundary_nodes, 4);
    }

    #[test]
    fn evaluate_imbalanced() {
        let g = square();
        let p = Partition::from_blocks(&g, 2, vec![0, 0, 0, 1]);
        let m = evaluate(&g, &p, 0.03);
        assert!((m.imbalance - 0.5).abs() < 1e-9);
        // L_max = ceil(1.03*4/2)+1 = 4 wait: (1.03*4/2).ceil()=3, +1=4 ⇒ 3 ≤ 4 feasible
        assert!(m.feasible);
    }
}
