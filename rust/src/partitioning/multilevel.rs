//! The multilevel driver: coarsen → initial partition → uncoarsen+refine,
//! iterated as V-cycles (§4, §B.1), with the coarse-level imbalance
//! schedule (§4: ε̂_ℓ = δ/(q−ℓ+1), first V-cycle only).

use crate::clustering::label_propagation::LpaConfig;
use crate::coarsening::contract::project_partition;
use crate::coarsening::hierarchy::{
    coarsen, l_max, CoarseningParams, CoarseningScheme, Hierarchy,
};
use crate::graph::csr::{Graph, Weight};
use crate::initial_partitioning::recursive_bisection::{
    recursive_bisection, InitialPartitionConfig,
};
use crate::obs::trace;
use crate::partitioning::config::{InitialKind, PartitionConfig, RefinementKind, SchemeKind};
use crate::partitioning::metrics::{cut_value, evaluate, PartitionMetrics};
use crate::partitioning::partition::Partition;
use crate::refinement::balance::rebalance;
use crate::refinement::fm::kway_fm_ws;
use crate::refinement::lpa_refine::{lpa_refine_ws, parallel_lpa_refine};
use crate::util::cancel;
use crate::util::exec::ExecutionCtx;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use std::sync::{Arc, OnceLock};

/// Outcome of a partitioning run, with the statistics the paper's
/// evaluation tables report.
#[derive(Debug, Clone)]
pub struct PartitionResult {
    pub partition: Partition,
    pub metrics: PartitionMetrics,
    /// Wall-clock seconds total.
    pub seconds: f64,
    /// Seconds spent per phase (coarsening, initial, uncoarsening).
    pub coarsening_seconds: f64,
    pub initial_seconds: f64,
    pub uncoarsening_seconds: f64,
    /// Hierarchy depth of the first V-cycle.
    pub levels: usize,
    /// Node/edge counts of the first-cycle coarsest graph.
    pub coarsest_n: usize,
    pub coarsest_m: usize,
    /// Cut of the initial partition projected to the input graph —
    /// the paper reports this for the huge instances (§5.2).
    pub initial_cut: Weight,
    /// Shrink factor of the first contraction (n_input / n_level0).
    pub first_shrink: f64,
}

/// Emit the per-level `level_quality` trace counter (cut + imbalance
/// after refining one hierarchy level). The cut is an O(m) scan, so
/// the whole payload computation gates on an active track — with
/// tracing off this is a single TLS check, and with it on the extra
/// scan affects wall-clock only, never results.
fn level_quality_counter(g: &Graph, k: usize, p: &Partition, level: usize) {
    if !trace::tracing_active() {
        return;
    }
    let cut = cut_value(g, &p.blocks);
    let avg = (g.total_node_weight() as f64 / k as f64).ceil();
    let imbalance_milli = if avg > 0.0 {
        ((p.max_block_weight() as f64 / avg - 1.0) * 1000.0).round() as i64
    } else {
        0
    };
    trace::counter(
        "level_quality",
        &[
            ("level", level as i64),
            ("cut", cut as i64),
            ("imbalance_milli", imbalance_milli),
        ],
    );
}

/// Arc-count threshold below which the driver runs on an inline
/// sequential [`ExecutionCtx`] instead of the configured one: on tiny
/// inputs the dispatch overhead outweighs the work, and the sequential
/// and parallel paths are bit-identical anyway (the gate changes
/// wall-clock, never output — a 1-thread pool spawns no OS threads).
const POOL_MIN_ARCS: usize = 1 << 16;

/// The multilevel partitioner (the system's main entry point).
pub struct MultilevelPartitioner {
    pub config: PartitionConfig,
    /// The shared execution context: injected by the coordinator via
    /// [`MultilevelPartitioner::with_ctx`] (one process pool through
    /// every phase), or lazily created from `config.threads` on first
    /// use.
    ctx: OnceLock<Arc<ExecutionCtx>>,
    /// Inline sequential context for inputs below [`POOL_MIN_ARCS`]
    /// (never spawns threads; identical results by the pool contract).
    seq_ctx: OnceLock<Arc<ExecutionCtx>>,
}

impl std::fmt::Debug for MultilevelPartitioner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MultilevelPartitioner")
            .field("config", &self.config)
            .finish()
    }
}

impl Clone for MultilevelPartitioner {
    fn clone(&self) -> Self {
        // Runtime state: an injected shared context is kept (handoff
        // semantics survive cloning); a lazily-created one is re-created
        // lazily. Results are thread-count-invariant either way.
        match self.ctx.get() {
            Some(ctx) => MultilevelPartitioner::with_ctx(self.config.clone(), ctx.clone()),
            None => MultilevelPartitioner::new(self.config.clone()),
        }
    }
}

impl MultilevelPartitioner {
    pub fn new(config: PartitionConfig) -> Self {
        MultilevelPartitioner {
            config,
            ctx: OnceLock::new(),
            seq_ctx: OnceLock::new(),
        }
    }

    /// Partitioner running on a shared [`ExecutionCtx`] — the
    /// coordinator handoff path. The context's pool is used for every
    /// parallel phase; `config.threads` is ignored (the context owner
    /// already decided the process-wide cap).
    pub fn with_ctx(config: PartitionConfig, ctx: Arc<ExecutionCtx>) -> Self {
        let cell = OnceLock::new();
        let _ = cell.set(ctx);
        MultilevelPartitioner {
            config,
            ctx: cell,
            seq_ctx: OnceLock::new(),
        }
    }

    /// The shared execution context, created on first use from
    /// `config.threads` (0 = available parallelism) unless injected.
    pub fn ctx(&self) -> &Arc<ExecutionCtx> {
        self.ctx
            .get_or_init(|| Arc::new(ExecutionCtx::new(self.config.threads)))
    }

    fn seq_ctx(&self) -> &Arc<ExecutionCtx> {
        self.seq_ctx
            .get_or_init(|| Arc::new(ExecutionCtx::sequential()))
    }

    /// The context a run on `input` executes with. An already-available
    /// context (injected by the coordinator, or created by an earlier
    /// run) is always used — its pool exists, so there is nothing to
    /// save. Otherwise the configured context is created only when the
    /// input is big enough to amortize pool dispatch; small inputs get
    /// the inline sequential one (no thread spawn). Pure wall-clock
    /// choice — both produce byte-identical results (util::pool
    /// contract).
    fn ctx_for(&self, input: &Graph) -> &Arc<ExecutionCtx> {
        if let Some(existing) = self.ctx.get() {
            return existing;
        }
        // The opt-in parallel engines get the configured pool regardless
        // of input size (the caller asked for them); otherwise only
        // inputs past the gate are worth the dispatch.
        let wants_pool = input.arc_count() >= POOL_MIN_ARCS
            || self.config.parallel_refinement
            || self.config.parallel_coarsening;
        if wants_pool && self.config.threads != 1 {
            self.ctx()
        } else {
            self.seq_ctx()
        }
    }

    fn coarsening_scheme(&self) -> CoarseningScheme {
        match self.config.scheme {
            SchemeKind::ClusterLpa => CoarseningScheme::ClusterLpa {
                lpa: LpaConfig {
                    max_iterations: self.config.lpa_iterations,
                    ordering: self.config.ordering,
                    active_nodes: self.config.active_nodes_coarsening,
                    convergence_fraction: 0.05,
                    mode: crate::clustering::label_propagation::LpaMode::Clustering,
                },
                size_factor: self.config.size_factor,
                ensemble: self.config.ensemble_count(),
            },
            SchemeKind::Matching => CoarseningScheme::Matching { two_hop: true },
        }
    }

    fn initial_config(&self) -> InitialPartitionConfig {
        let mut ip = match self.config.initial {
            InitialKind::MatchingRb => InitialPartitionConfig::matching_based(self.config.epsilon),
            InitialKind::ClusterRb => InitialPartitionConfig::cluster_based(self.config.epsilon),
        };
        if matches!(self.config.refinement, RefinementKind::Strong) {
            ip.tries = 8;
        }
        ip
    }

    /// The SCLaP refinement stage: sequential asynchronous engine by
    /// default, synchronous pool rounds when `parallel_refinement` is
    /// set. Both are deterministic; the choice selects an *algorithm*,
    /// never a schedule (thread count does not affect either).
    fn lpa_stage(
        &self,
        ctx: &ExecutionCtx,
        g: &Graph,
        p: &mut Partition,
        lmax: Weight,
        rng: &mut Rng,
    ) {
        if self.config.parallel_refinement {
            parallel_lpa_refine(g, p, lmax, self.config.lpa_iterations, ctx, rng);
        } else {
            lpa_refine_ws(g, p, lmax, self.config.lpa_iterations, Some(ctx.workspace()), rng);
        }
    }

    /// Refine `p` on `g` under bound `lmax` according to the config.
    fn refine(
        &self,
        ctx: &ExecutionCtx,
        g: &Graph,
        p: &mut Partition,
        lmax: Weight,
        rng: &mut Rng,
    ) {
        let ws = Some(ctx.workspace());
        match self.config.refinement {
            RefinementKind::Lpa => {
                self.lpa_stage(ctx, g, p, lmax, rng);
            }
            RefinementKind::Eco => {
                self.lpa_stage(ctx, g, p, lmax, rng);
                kway_fm_ws(g, p, lmax, &self.config.fm, ws, rng);
            }
            RefinementKind::Strong => {
                self.lpa_stage(ctx, g, p, lmax, rng);
                kway_fm_ws(g, p, lmax, &self.config.fm, ws, rng);
                // KaFFPa's "more-localized" pairwise search (§2.2): only
                // affordable on the smaller levels of the hierarchy.
                if g.n() <= 50_000 {
                    crate::refinement::quotient::quotient_pair_refine(
                        g, p, lmax, &self.config.fm, 2, rng,
                    );
                }
            }
            RefinementKind::Greedy => {
                kway_fm_ws(g, p, lmax, &self.config.fm, ws, rng);
            }
        }
    }

    /// Partition `input` with the configured algorithm and `seed`.
    pub fn partition(&self, input: &Graph, seed: u64) -> PartitionResult {
        let cfg = &self.config;
        let k = cfg.k;
        assert!(k >= 1);
        let total_timer = Timer::start();
        let mut rng = Rng::new(seed);

        let final_lmax = l_max(
            input.total_node_weight(),
            k,
            cfg.epsilon,
            input.max_node_weight(),
        );

        // The one execution context for every phase of this run —
        // the configured shared pool for big inputs, an inline
        // sequential context (no thread spawn) for small ones; results
        // are identical either way.
        let ctx: &Arc<ExecutionCtx> = self.ctx_for(input);

        // Tracing: this repetition's logical track, derived from the
        // seed (inert when no tracer is attached, or when an outer
        // driver — e.g. the out-of-core path — already entered one on
        // this thread). Tracing never changes results.
        let _track = ctx.tracer().map(|t| t.enter(seed));
        // Input dimensions, evented once per repetition: the quality
        // report's level-0 contraction ratio needs them, and nothing
        // else in the stream records the uncoarsened graph.
        trace::counter(
            "input_graph",
            &[("n", input.n() as i64), ("m", input.m() as i64)],
        );

        let mut best_blocks: Option<Vec<u32>> = None;
        let mut best_cut: Weight = Weight::MAX;
        let mut coarsening_seconds = 0.0;
        let mut initial_seconds = 0.0;
        let mut uncoarsening_seconds = 0.0;
        let mut levels_first = 0usize;
        let mut coarsest_n = input.n();
        let mut coarsest_m = input.m();
        let mut initial_cut: Weight = 0;
        let mut first_shrink = 1.0f64;

        for cycle in 0..cfg.vcycles.max(1) {
            // Cancellation checkpoint per V-cycle (and per refine level
            // below): a fired ambient token exits here with the typed
            // `Cancelled` payload; an unfired one changes nothing.
            cancel::checkpoint();
            let vcycle_span = trace::span("vcycle", &[("cycle", cycle as i64)]);
            // ---- Coarsening ----
            let t = Timer::start();
            let coarsen_span = trace::span("coarsening", &[("cycle", cycle as i64)]);
            let mut params =
                CoarseningParams::new(k, cfg.epsilon, self.coarsening_scheme());
            if cfg.deep_coarsening {
                params.min_shrink = 0.999;
            }
            params.ctx = Some(ctx.clone());
            params.parallel_lpa = cfg.parallel_coarsening;
            let respect = best_blocks.clone();
            let h: Hierarchy = coarsen(input, &params, respect.as_deref(), &mut rng);
            drop(coarsen_span);
            let secs = t.elapsed_s();
            coarsening_seconds += secs;
            ctx.record("coarsening", secs);
            let q = h.levels.len();
            let coarsest = h.coarsest(input);
            trace::counter(
                "hierarchy",
                &[
                    ("cycle", cycle as i64),
                    ("levels", q as i64),
                    ("coarsest_n", coarsest.n() as i64),
                    ("coarsest_m", coarsest.m() as i64),
                ],
            );
            // Per-level coarsening lineage (nodes/edges after each
            // contraction) — the quality report derives contraction
            // ratios from consecutive entries. Level i here is the
            // graph after contraction i+1 (level 0 = first contraction
            // of the input).
            for (i, level) in h.levels.iter().enumerate() {
                trace::counter(
                    "coarsen_level",
                    &[
                        ("level", i as i64),
                        ("n", level.graph.n() as i64),
                        ("m", level.graph.m() as i64),
                    ],
                );
            }
            if cycle == 0 {
                levels_first = q;
                coarsest_n = coarsest.n();
                coarsest_m = coarsest.m();
                first_shrink = input.n() as f64
                    / h.levels.first().map(|l| l.graph.n()).unwrap_or(input.n()) as f64;
            }

            // ---- Initial partitioning ----
            let t = Timer::start();
            let initial_span = trace::span("initial", &[("cycle", cycle as i64)]);
            let mut blocks = match &h.coarsest_partition {
                Some(projected) => projected.clone(),
                None => {
                    let ip = recursive_bisection(
                        coarsest,
                        k,
                        &self.initial_config(),
                        ctx,
                        &mut rng,
                    );
                    ip.blocks
                }
            };
            if cycle == 0 {
                // Paper §5.2 reports the initial partition's quality on
                // the input graph: project through all levels.
                let mut proj = blocks.clone();
                for i in (0..h.levels.len()).rev() {
                    proj = project_partition(&h.levels[i].map, &proj);
                }
                initial_cut = cut_value(input, &proj);
            }
            drop(initial_span);
            let secs = t.elapsed_s();
            initial_seconds += secs;
            ctx.record("initial", secs);

            // ---- Uncoarsening with refinement ----
            let t = Timer::start();
            let uncoarsen_span = trace::span("uncoarsening", &[("cycle", cycle as i64)]);
            // Imbalance schedule (§4): extra ε̂ on coarse levels, first
            // cycle only, decreasing to 0 at the finest level.
            let delta = if cycle == 0 { cfg.coarse_imbalance } else { 0.0 };
            // Refine the coarsest level (level index q → ε̂ = δ).
            {
                let level_timer = Timer::start();
                let level_span = trace::span(
                    "refine_level",
                    &[("level", q as i64), ("n", coarsest.n() as i64)],
                );
                let eps_here = cfg.epsilon + if q > 0 { delta } else { 0.0 };
                let lmax_here = l_max(
                    input.total_node_weight(),
                    k,
                    eps_here,
                    coarsest.max_node_weight(),
                );
                let mut p = Partition::from_blocks(coarsest, k, blocks);
                self.refine(ctx, coarsest, &mut p, lmax_here, &mut rng);
                drop(level_span);
                level_quality_counter(coarsest, k, &p, q);
                blocks = p.blocks;
                ctx.record_level("refine_level", q as u32, level_timer.elapsed_s());
            }
            for i in (0..h.levels.len()).rev() {
                cancel::checkpoint();
                let finer: &Graph = if i == 0 { input } else { &h.levels[i - 1].graph };
                blocks = project_partition(&h.levels[i].map, &blocks);
                // Level i of `levels` is graph G_{i+2} in paper numbering
                // (G_1 = input). For the finer graph at index i-1 (or the
                // input), the remaining coarse distance is i.
                let eps_hat = if i > 0 {
                    delta / (q - i + 1) as f64
                } else {
                    0.0 // finest level: no extra imbalance
                };
                let lmax_here = l_max(
                    input.total_node_weight(),
                    k,
                    cfg.epsilon + eps_hat,
                    finer.max_node_weight(),
                );
                let level_timer = Timer::start();
                let level_span = trace::span(
                    "refine_level",
                    &[("level", i as i64), ("n", finer.n() as i64)],
                );
                let mut p = Partition::from_blocks(finer, k, blocks);
                self.refine(ctx, finer, &mut p, lmax_here, &mut rng);
                drop(level_span);
                level_quality_counter(finer, k, &p, i);
                blocks = p.blocks;
                ctx.record_level("refine_level", i as u32, level_timer.elapsed_s());
            }

            // Final feasibility repair on the input graph.
            let mut p = Partition::from_blocks(input, k, blocks);
            if !cfg.tolerate_imbalance && p.max_block_weight() > final_lmax {
                let _ = rebalance(input, &mut p, final_lmax);
                // Rebalancing may open improvement: one more cheap pass.
                self.refine(ctx, input, &mut p, final_lmax, &mut rng);
                if p.max_block_weight() > final_lmax {
                    let _ = rebalance(input, &mut p, final_lmax);
                }
            }
            drop(uncoarsen_span);
            let secs = t.elapsed_s();
            uncoarsening_seconds += secs;
            ctx.record("uncoarsening", secs);

            let cut = cut_value(input, &p.blocks);
            trace::counter("cycle_cut", &[("cycle", cycle as i64), ("cut", cut as i64)]);
            drop(vcycle_span);
            if cut < best_cut || best_blocks.is_none() {
                best_cut = cut;
                best_blocks = Some(p.blocks);
            }
        }

        let partition = Partition::from_blocks(input, k, best_blocks.unwrap());
        let metrics = evaluate(input, &partition, cfg.epsilon);
        PartitionResult {
            partition,
            metrics,
            seconds: total_timer.elapsed_s(),
            coarsening_seconds,
            initial_seconds,
            uncoarsening_seconds,
            levels: levels_first,
            coarsest_n,
            coarsest_m,
            initial_cut,
            first_shrink,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::graph::karate_club;
    use crate::partitioning::config::Preset;

    fn check_result(g: &Graph, r: &PartitionResult, k: usize, eps: f64) {
        assert_eq!(r.partition.k, k);
        assert!(r.partition.validate(g).is_ok());
        assert_eq!(r.metrics.cut, cut_value(g, &r.partition.blocks));
        let lmax = l_max(g.total_node_weight(), k, eps, g.max_node_weight());
        assert!(
            r.partition.max_block_weight() <= lmax,
            "imbalanced: {:?} lmax={lmax}",
            r.partition.block_weights
        );
    }

    #[test]
    fn karate_bisection_all_fast_presets() {
        let g = karate_club();
        for preset in [Preset::CFast, Preset::UFast, Preset::CEco, Preset::KMetisLike] {
            let cfg = PartitionConfig::preset(preset, 2);
            let r = MultilevelPartitioner::new(cfg).partition(&g, 1);
            check_result(&g, &r, 2, 0.03);
            assert!(
                r.metrics.cut <= 15,
                "{}: cut = {}",
                preset.name(),
                r.metrics.cut
            );
        }
    }

    #[test]
    fn ba_graph_k8() {
        let mut rng = Rng::new(1);
        let g = generators::barabasi_albert(3000, 4, &mut rng);
        let cfg = PartitionConfig::preset(Preset::UFast, 8);
        let r = MultilevelPartitioner::new(cfg).partition(&g, 2);
        check_result(&g, &r, 8, 0.03);
        assert_eq!(r.partition.nonempty_blocks(), 8);
        assert!(r.metrics.cut > 0);
        assert!(r.levels >= 1);
        assert!(r.first_shrink > 1.5, "shrink {}", r.first_shrink);
    }

    #[test]
    fn vcycles_never_worse_than_first() {
        let mut rng = Rng::new(2);
        let g = crate::graph::subgraph::largest_component(&generators::rmat(
            11, 8000, 0.57, 0.19, 0.19, &mut rng,
        ));
        let base = PartitionConfig::preset(Preset::CFast, 4);
        let mut with_v = base.clone();
        with_v.vcycles = 3;
        let r1 = MultilevelPartitioner::new(base).partition(&g, 3);
        let r3 = MultilevelPartitioner::new(with_v).partition(&g, 3);
        // Same seed ⇒ first cycle identical; V-cycles keep the best.
        assert!(r3.metrics.cut <= r1.metrics.cut);
        check_result(&g, &r3, 4, 0.03);
    }

    #[test]
    fn strong_beats_or_ties_fast() {
        let mut rng = Rng::new(4);
        let g = generators::watts_strogatz(1200, 5, 0.1, &mut rng);
        let fast = MultilevelPartitioner::new(PartitionConfig::preset(Preset::CFast, 4))
            .partition(&g, 5);
        let strong = MultilevelPartitioner::new(PartitionConfig::preset(Preset::CStrong, 4))
            .partition(&g, 5);
        check_result(&g, &fast, 4, 0.03);
        check_result(&g, &strong, 4, 0.03);
        assert!(
            strong.metrics.cut as f64 <= fast.metrics.cut as f64 * 1.1,
            "strong {} vs fast {}",
            strong.metrics.cut,
            fast.metrics.cut
        );
    }

    #[test]
    fn scotch_like_may_be_imbalanced_but_runs() {
        let mut rng = Rng::new(6);
        let g = generators::barabasi_albert(1000, 3, &mut rng);
        let cfg = PartitionConfig::preset(Preset::ScotchLike, 4);
        let r = MultilevelPartitioner::new(cfg).partition(&g, 7);
        assert!(r.partition.validate(&g).is_ok());
        assert_eq!(r.partition.nonempty_blocks(), 4);
    }

    #[test]
    fn k_one_trivial() {
        let g = karate_club();
        let cfg = PartitionConfig::preset(Preset::CFast, 1);
        let r = MultilevelPartitioner::new(cfg).partition(&g, 8);
        assert_eq!(r.metrics.cut, 0);
    }

    #[test]
    fn deterministic_per_seed() {
        let g = karate_club();
        let cfg = PartitionConfig::preset(Preset::CEco, 4);
        let a = MultilevelPartitioner::new(cfg.clone()).partition(&g, 42);
        let b = MultilevelPartitioner::new(cfg).partition(&g, 42);
        assert_eq!(a.partition.blocks, b.partition.blocks);
    }

    #[test]
    fn parallel_coarsening_is_valid_and_thread_invariant() {
        let mut rng = Rng::new(14);
        let g = generators::barabasi_albert(2500, 4, &mut rng);
        let run = |threads: usize| {
            let mut cfg = PartitionConfig::preset(Preset::CFast, 4);
            cfg.parallel_coarsening = true;
            cfg.threads = threads;
            MultilevelPartitioner::new(cfg).partition(&g, 17)
        };
        let reference = run(1);
        check_result(&g, &reference, 4, 0.03);
        for threads in [2usize, 4] {
            let r = run(threads);
            assert_eq!(
                reference.partition.blocks, r.partition.blocks,
                "threads={threads} diverged"
            );
        }
    }

    #[test]
    fn injected_ctx_is_used_and_records_phases() {
        let mut rng = Rng::new(15);
        let g = generators::barabasi_albert(2000, 4, &mut rng);
        let ctx = Arc::new(ExecutionCtx::new(2));
        let cfg = PartitionConfig::preset(Preset::CFast, 4);
        let shared = MultilevelPartitioner::with_ctx(cfg.clone(), ctx.clone());
        let a = shared.partition(&g, 23);
        let b = MultilevelPartitioner::new(cfg).partition(&g, 23);
        // Handoff never changes results (thread-count invariance).
        assert_eq!(a.partition.blocks, b.partition.blocks);
        // The stats sink saw every phase of the run.
        let phases: Vec<&str> = ctx.phase_stats().iter().map(|(n, _)| *n).collect();
        for expected in ["coarsening", "initial", "uncoarsening"] {
            assert!(phases.contains(&expected), "missing phase {expected}");
        }
    }

    #[test]
    fn parallel_refinement_is_valid_and_thread_invariant() {
        let mut rng = Rng::new(9);
        let g = generators::barabasi_albert(2500, 4, &mut rng);
        let run = |threads: usize| {
            let mut cfg = PartitionConfig::preset(Preset::UFast, 4);
            cfg.parallel_refinement = true;
            cfg.threads = threads;
            MultilevelPartitioner::new(cfg).partition(&g, 13)
        };
        let reference = run(1);
        check_result(&g, &reference, 4, 0.03);
        for threads in [2usize, 4] {
            let r = run(threads);
            assert_eq!(
                reference.partition.blocks, r.partition.blocks,
                "threads={threads} diverged"
            );
        }
    }
}
