//! Partitioner configurations — the paper's §5.1 configuration ladder
//! (CEcoR … UStrong) plus the in-repo competitor baselines (DESIGN.md §3).
//!
//! Naming (paper): `C` = matching-based initial partitioning, `U` =
//! cluster-based initial partitioning; `Fast`/`Eco`/`Strong` = the
//! refinement ladder; suffix letters: `R` random ordering, `V` V-cycles,
//! `B` extra imbalance on coarse levels, `E` ensemble clusterings, `A`
//! active nodes during coarsening.

use crate::clustering::label_propagation::NodeOrdering;
use crate::refinement::fm::FmConfig;

/// Coarsening algorithm choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchemeKind {
    /// The paper's cluster contraction (SCLaP).
    ClusterLpa,
    /// Matching baseline (KaFFPa / Metis style).
    Matching,
}

/// Initial partitioning family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitialKind {
    /// `C…`: recursive bisection with matching-based mini-multilevels.
    MatchingRb,
    /// `U…`: recursive bisection with cluster-based mini-multilevels.
    ClusterRb,
}

/// Refinement ladder.
#[derive(Debug, Clone, PartialEq)]
pub enum RefinementKind {
    /// `Fast`: SCLaP as local search only (§3.1).
    Lpa,
    /// `Eco`: SCLaP + cheap boundary FM.
    Eco,
    /// `Strong`: SCLaP + deep FM with long hill climbs.
    Strong,
    /// kMetis-like greedy: positive-gain boundary pass only.
    Greedy,
}

/// Full parameterization of one partitioner run.
#[derive(Debug, Clone)]
pub struct PartitionConfig {
    pub k: usize,
    /// Imbalance ε (paper default 0.03).
    pub epsilon: f64,
    /// LP iterations ℓ during coarsening (paper: 10; 3 for huge graphs).
    pub lpa_iterations: usize,
    /// Cluster-size factor f (paper: 18).
    pub size_factor: f64,
    pub ordering: NodeOrdering,
    /// `A`: active-nodes rounds during coarsening.
    pub active_nodes_coarsening: bool,
    /// `E`: ensemble clusterings for coarsening (size by `k`, §5).
    pub ensemble: bool,
    /// `V`: number of multilevel iterations (1 = plain, paper V = 3).
    pub vcycles: usize,
    /// `B`: extra imbalance δ distributed over coarse levels (0 = off).
    pub coarse_imbalance: f64,
    pub scheme: SchemeKind,
    pub initial: InitialKind,
    pub refinement: RefinementKind,
    /// FM knobs when refinement uses FM.
    pub fm: FmConfig,
    /// Scotch-like behavior: tolerate infeasible final balance.
    pub tolerate_imbalance: bool,
    /// hMetis-like behavior: coarsen far deeper before IP.
    pub deep_coarsening: bool,
    /// Worker threads for the pool-parallel phases (`0` = available
    /// parallelism, `1` = fully sequential). First-class knob: CLI
    /// `--threads`, env `SCLAP_THREADS`, or set directly. The logical
    /// schedule is thread-count-invariant — same seed + same config ⇒
    /// byte-identical partition for every value (the `util::pool`
    /// determinism contract, enforced by `rust/tests/determinism.rs`).
    pub threads: usize,
    /// Use the synchronous-round pool engine
    /// (`refinement::parallel_lpa_refine`) for the SCLaP refinement
    /// stage instead of the sequential asynchronous engine. Off by
    /// default: the sequential engine is the paper-faithful reference;
    /// both are deterministic, but they are *different algorithms* and
    /// produce different (comparable-quality) cuts.
    pub parallel_refinement: bool,
    /// Use the coloring-based parallel *asynchronous* LPA
    /// (`clustering::async_lpa`, after arXiv 1404.4797) for the
    /// non-ensemble coarsening cluster steps instead of the sequential
    /// engine. Off by default for the same reason as
    /// `parallel_refinement`: a different (equally deterministic)
    /// algorithm, selected by configuration, never by thread count —
    /// the thread-count-invariance contract holds for both values.
    pub parallel_coarsening: bool,
    /// RAM budget (bytes) for holding a graph's CSR in memory. `None`
    /// (default) = unlimited, fully in-memory pipeline. When an input's
    /// [`Graph::memory_bytes`](crate::graph::csr::Graph::memory_bytes)
    /// exceeds the budget, `partitioning::external::partition_store`
    /// builds level 0 of the hierarchy out-of-core (semi-external SCLaP
    /// + streaming contraction over `graph::store` shards) and switches
    /// to the in-memory pipeline once the contracted graph fits. Knobs:
    /// CLI `--memory-budget` (bytes, `k`/`m`/`g` suffixes accepted),
    /// env `SCLAP_MEMORY_BUDGET`. The budget selects an *algorithm*;
    /// the storage backend and shard count never change results
    /// (`rust/tests/sharded_store.rs`).
    pub memory_budget_bytes: Option<u64>,
}

/// Default thread count: `SCLAP_THREADS` if set and parseable, else 0
/// (auto = available parallelism).
fn threads_from_env() -> usize {
    parse_threads(std::env::var("SCLAP_THREADS").ok().as_deref())
}

/// Pure parsing core of [`threads_from_env`] (unit-testable without
/// mutating process-global env state): unset or unparseable ⇒ 0 (auto).
fn parse_threads(value: Option<&str>) -> usize {
    value.and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// Default memory budget: `SCLAP_MEMORY_BUDGET` if set and parseable,
/// else `None` (unlimited).
fn memory_budget_from_env() -> Option<u64> {
    parse_memory_budget(std::env::var("SCLAP_MEMORY_BUDGET").ok().as_deref())
}

/// Parse a memory budget: plain bytes or with a `k`/`m`/`g` binary
/// suffix (case-insensitive). Unset, unparseable, or `0` ⇒ `None`
/// (unlimited). Shared by the env default and the CLI flag.
pub fn parse_memory_budget(value: Option<&str>) -> Option<u64> {
    let v = value?.trim().to_ascii_lowercase();
    if v.is_empty() {
        return None;
    }
    let (digits, mult) = if let Some(d) = v.strip_suffix('k') {
        (d, 1u64 << 10)
    } else if let Some(d) = v.strip_suffix('m') {
        (d, 1u64 << 20)
    } else if let Some(d) = v.strip_suffix('g') {
        (d, 1u64 << 30)
    } else {
        (v.as_str(), 1u64)
    };
    digits
        .trim()
        .parse::<u64>()
        .ok()
        .map(|x| x.saturating_mul(mult))
        .filter(|&x| x > 0)
}

/// The configuration keys [`PartitionConfig::apply_option`]
/// understands — shared between the `partition` CLI flags and the
/// `serve` request-spec lines so the two front ends can never drift.
pub const CONFIG_OPTION_KEYS: &[&str] = &[
    "epsilon",
    "lpa-iterations",
    "threads",
    "parallel-coarsening",
    "parallel-refinement",
    "memory-budget",
];

/// Parse a boolean option value (`true`/`1`/`yes` vs `false`/`0`/`no`,
/// case-insensitive).
fn parse_bool_option(key: &str, value: &str) -> Result<bool, String> {
    match value.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        _ => Err(format!("--{key}: bad boolean {value:?} (true/false)")),
    }
}

/// Named presets: the paper's configurations and the baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Preset {
    CFastR,
    CFast,
    CFastV,
    CFastVB,
    CFastVBE,
    CFastVBEA,
    CEcoR,
    CEco,
    CEcoV,
    CEcoVB,
    CEcoVBE,
    CEcoVBEA,
    CStrong,
    UFast,
    UFastV,
    UEcoVB,
    UStrong,
    /// Matching-based baseline ≈ KaFFPaEco.
    KaffpaEco,
    /// Matching-based baseline ≈ KaFFPaStrong.
    KaffpaStrong,
    /// Fast matching-based competitor ≈ kMetis 5.1 (2-hop matching).
    KMetisLike,
    /// ≈ Scotch: matching + RB, imbalance tolerated.
    ScotchLike,
    /// ≈ hMetis: deep slow coarsening + heavy FM.
    HMetisLike,
}

impl Preset {
    pub const ALL: [Preset; 22] = [
        Preset::CEcoR,
        Preset::CEco,
        Preset::CEcoV,
        Preset::CEcoVB,
        Preset::CEcoVBE,
        Preset::CEcoVBEA,
        Preset::CFastR,
        Preset::CFast,
        Preset::CFastV,
        Preset::CFastVB,
        Preset::CFastVBE,
        Preset::CFastVBEA,
        Preset::UFast,
        Preset::UFastV,
        Preset::UEcoVB,
        Preset::CStrong,
        Preset::UStrong,
        Preset::KaffpaEco,
        Preset::KaffpaStrong,
        Preset::ScotchLike,
        Preset::KMetisLike,
        Preset::HMetisLike,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Preset::CFastR => "CFastR",
            Preset::CFast => "CFast",
            Preset::CFastV => "CFastV",
            Preset::CFastVB => "CFastV/B",
            Preset::CFastVBE => "CFastV/B/E",
            Preset::CFastVBEA => "CFastV/B/E/A",
            Preset::CEcoR => "CEcoR",
            Preset::CEco => "CEco",
            Preset::CEcoV => "CEcoV",
            Preset::CEcoVB => "CEcoV/B",
            Preset::CEcoVBE => "CEcoV/B/E",
            Preset::CEcoVBEA => "CEcoV/B/E/A",
            Preset::CStrong => "CStrong",
            Preset::UFast => "UFast",
            Preset::UFastV => "UFastV",
            Preset::UEcoVB => "UEcoV/B",
            Preset::UStrong => "UStrong",
            Preset::KaffpaEco => "KaFFPaEco",
            Preset::KaffpaStrong => "KaFFPaStrong",
            Preset::KMetisLike => "kMetis-like",
            Preset::ScotchLike => "Scotch-like",
            Preset::HMetisLike => "hMetis-like",
        }
    }

    pub fn from_name(name: &str) -> Option<Preset> {
        Preset::ALL.iter().copied().find(|p| {
            p.name().eq_ignore_ascii_case(name)
                || p.name().replace('/', "").eq_ignore_ascii_case(name)
        })
    }
}

impl PartitionConfig {
    /// Shared defaults (paper §5 tuned parameters).
    fn base(k: usize) -> Self {
        PartitionConfig {
            k,
            epsilon: 0.03,
            lpa_iterations: 10,
            size_factor: 18.0,
            ordering: NodeOrdering::Degree,
            active_nodes_coarsening: false,
            ensemble: false,
            vcycles: 1,
            coarse_imbalance: 0.0,
            scheme: SchemeKind::ClusterLpa,
            initial: InitialKind::MatchingRb,
            refinement: RefinementKind::Eco,
            fm: FmConfig::eco(),
            tolerate_imbalance: false,
            deep_coarsening: false,
            threads: threads_from_env(),
            parallel_refinement: false,
            parallel_coarsening: false,
            memory_budget_bytes: memory_budget_from_env(),
        }
    }

    /// Materialize a named preset for `k` blocks.
    pub fn preset(preset: Preset, k: usize) -> Self {
        use Preset::*;
        let mut c = Self::base(k);
        match preset {
            CEcoR => {
                c.ordering = NodeOrdering::Random;
            }
            CEco => {}
            CEcoV => {
                c.vcycles = 3;
            }
            CEcoVB => {
                c.vcycles = 3;
                c.coarse_imbalance = 0.03;
            }
            CEcoVBE => {
                c.vcycles = 3;
                c.coarse_imbalance = 0.03;
                c.ensemble = true;
            }
            CEcoVBEA => {
                c.vcycles = 3;
                c.coarse_imbalance = 0.03;
                c.ensemble = true;
                c.active_nodes_coarsening = true;
            }
            CFastR => {
                c.ordering = NodeOrdering::Random;
                c.refinement = RefinementKind::Lpa;
            }
            CFast => {
                c.refinement = RefinementKind::Lpa;
            }
            CFastV => {
                c.refinement = RefinementKind::Lpa;
                c.vcycles = 3;
            }
            CFastVB => {
                c.refinement = RefinementKind::Lpa;
                c.vcycles = 3;
                c.coarse_imbalance = 0.03;
            }
            CFastVBE => {
                c.refinement = RefinementKind::Lpa;
                c.vcycles = 3;
                c.coarse_imbalance = 0.03;
                c.ensemble = true;
            }
            CFastVBEA => {
                c.refinement = RefinementKind::Lpa;
                c.vcycles = 3;
                c.coarse_imbalance = 0.03;
                c.ensemble = true;
                c.active_nodes_coarsening = true;
            }
            CStrong => {
                c.coarse_imbalance = 0.03;
                c.ensemble = true;
                c.refinement = RefinementKind::Strong;
                c.fm = FmConfig::strong();
            }
            UFast => {
                c.refinement = RefinementKind::Lpa;
                c.initial = InitialKind::ClusterRb;
            }
            UFastV => {
                c.refinement = RefinementKind::Lpa;
                c.initial = InitialKind::ClusterRb;
                c.vcycles = 3;
            }
            UEcoVB => {
                c.initial = InitialKind::ClusterRb;
                c.vcycles = 3;
                c.coarse_imbalance = 0.03;
            }
            UStrong => {
                c.coarse_imbalance = 0.03;
                c.ensemble = true;
                c.refinement = RefinementKind::Strong;
                c.fm = FmConfig::strong();
                c.initial = InitialKind::ClusterRb;
            }
            KaffpaEco => {
                c.scheme = SchemeKind::Matching;
                c.refinement = RefinementKind::Eco;
            }
            KaffpaStrong => {
                c.scheme = SchemeKind::Matching;
                c.refinement = RefinementKind::Strong;
                c.fm = FmConfig::strong();
                c.vcycles = 3;
            }
            KMetisLike => {
                c.scheme = SchemeKind::Matching;
                c.refinement = RefinementKind::Greedy;
                c.fm = FmConfig {
                    max_passes: 2,
                    max_negative_moves: 0,
                    seed_fraction: 1.0,
                };
            }
            ScotchLike => {
                c.scheme = SchemeKind::Matching;
                c.refinement = RefinementKind::Greedy;
                c.tolerate_imbalance = true;
                c.fm = FmConfig {
                    max_passes: 2,
                    max_negative_moves: 0,
                    seed_fraction: 1.0,
                };
            }
            HMetisLike => {
                c.scheme = SchemeKind::Matching;
                c.refinement = RefinementKind::Strong;
                c.fm = FmConfig {
                    max_passes: 16,
                    max_negative_moves: 2000,
                    seed_fraction: 1.0,
                };
                c.deep_coarsening = true;
            }
        }
        c
    }

    /// Ensemble size per the paper (§5): 18 / 7 / 3 depending on k.
    pub fn ensemble_count(&self) -> Option<usize> {
        self.ensemble
            .then(|| crate::clustering::ensemble::ensemble_size_for_k(self.k))
    }

    /// Apply one `key=value` configuration option (see
    /// [`CONFIG_OPTION_KEYS`]). The single code path behind both the
    /// `partition` CLI flags and the `serve` request-spec lines;
    /// unknown keys and malformed values error instead of being
    /// silently ignored.
    pub fn apply_option(&mut self, key: &str, value: &str) -> Result<(), String> {
        match key {
            "epsilon" => {
                self.epsilon = value
                    .parse()
                    .map_err(|_| format!("--epsilon: bad float {value:?}"))?;
            }
            "lpa-iterations" => {
                self.lpa_iterations = value
                    .parse()
                    .map_err(|_| format!("--lpa-iterations: bad integer {value:?}"))?;
            }
            "threads" => {
                self.threads = value
                    .parse()
                    .map_err(|_| format!("--threads: bad integer {value:?}"))?;
            }
            "parallel-coarsening" => {
                self.parallel_coarsening = parse_bool_option(key, value)?;
            }
            "parallel-refinement" => {
                self.parallel_refinement = parse_bool_option(key, value)?;
            }
            "memory-budget" => {
                self.memory_budget_bytes = parse_memory_budget(Some(value));
                if self.memory_budget_bytes.is_none() && value.trim() != "0" {
                    return Err(format!(
                        "--memory-budget: bad value {value:?} (bytes, or k/m/g suffix)"
                    ));
                }
            }
            other => {
                return Err(format!("unknown configuration option {other:?}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_materialize() {
        for p in Preset::ALL {
            let c = PartitionConfig::preset(p, 8);
            assert_eq!(c.k, 8);
            assert!(c.epsilon > 0.0);
            assert!(c.vcycles >= 1);
        }
    }

    #[test]
    fn preset_roundtrip_names() {
        for p in Preset::ALL {
            assert_eq!(Preset::from_name(p.name()), Some(p), "{}", p.name());
        }
        assert_eq!(Preset::from_name("ufast"), Some(Preset::UFast));
        assert_eq!(Preset::from_name("CEcoVB"), Some(Preset::CEcoVB));
        assert!(Preset::from_name("bogus").is_none());
    }

    #[test]
    fn letter_semantics() {
        let c = PartitionConfig::preset(Preset::CEcoVBEA, 4);
        assert_eq!(c.vcycles, 3);
        assert!(c.coarse_imbalance > 0.0);
        assert!(c.ensemble);
        assert!(c.active_nodes_coarsening);
        assert_eq!(c.ordering, NodeOrdering::Degree);
        let r = PartitionConfig::preset(Preset::CEcoR, 4);
        assert_eq!(r.ordering, NodeOrdering::Random);
        let u = PartitionConfig::preset(Preset::UStrong, 4);
        assert_eq!(u.initial, InitialKind::ClusterRb);
    }

    #[test]
    fn ensemble_counts() {
        let mut c = PartitionConfig::preset(Preset::CEcoVBE, 8);
        assert_eq!(c.ensemble_count(), Some(18));
        c.k = 16;
        assert_eq!(c.ensemble_count(), Some(7));
        c.k = 64;
        assert_eq!(c.ensemble_count(), Some(3));
        let plain = PartitionConfig::preset(Preset::CEco, 8);
        assert_eq!(plain.ensemble_count(), None);
    }

    #[test]
    fn thread_knob_defaults() {
        // The parallel engines are opt-in everywhere.
        for p in Preset::ALL {
            let c = PartitionConfig::preset(p, 4);
            assert!(!c.parallel_refinement);
            assert!(!c.parallel_coarsening);
        }
        // SCLAP_THREADS parsing (pure core — no env mutation in tests):
        // unset/garbage/empty fall back to 0 = auto, numbers are taken
        // as-is.
        assert_eq!(parse_threads(None), 0);
        assert_eq!(parse_threads(Some("")), 0);
        assert_eq!(parse_threads(Some("garbage")), 0);
        assert_eq!(parse_threads(Some("-2")), 0);
        assert_eq!(parse_threads(Some("0")), 0);
        assert_eq!(parse_threads(Some("1")), 1);
        assert_eq!(parse_threads(Some("8")), 8);
    }

    #[test]
    fn memory_budget_parsing() {
        // Pure core — no env mutation in tests. Unset/garbage/zero ⇒
        // None (unlimited); binary suffixes accepted.
        assert_eq!(parse_memory_budget(None), None);
        assert_eq!(parse_memory_budget(Some("")), None);
        assert_eq!(parse_memory_budget(Some("garbage")), None);
        assert_eq!(parse_memory_budget(Some("0")), None);
        assert_eq!(parse_memory_budget(Some("-3")), None);
        assert_eq!(parse_memory_budget(Some("1")), Some(1));
        assert_eq!(parse_memory_budget(Some("4096")), Some(4096));
        assert_eq!(parse_memory_budget(Some("2k")), Some(2048));
        assert_eq!(parse_memory_budget(Some("3M")), Some(3 << 20));
        assert_eq!(parse_memory_budget(Some("1G")), Some(1 << 30));
        assert_eq!(parse_memory_budget(Some(" 8 ")), Some(8));
    }

    #[test]
    fn apply_option_covers_every_advertised_key() {
        let mut c = PartitionConfig::preset(Preset::CFast, 4);
        for key in CONFIG_OPTION_KEYS {
            let value = match *key {
                "epsilon" => "0.05",
                "lpa-iterations" => "7",
                "threads" => "3",
                "memory-budget" => "2k",
                _ => "true",
            };
            c.apply_option(key, value)
                .unwrap_or_else(|e| panic!("--{key}: {e}"));
        }
        assert!((c.epsilon - 0.05).abs() < 1e-12);
        assert_eq!(c.lpa_iterations, 7);
        assert_eq!(c.threads, 3);
        assert!(c.parallel_coarsening);
        assert!(c.parallel_refinement);
        assert_eq!(c.memory_budget_bytes, Some(2048));
    }

    #[test]
    fn apply_option_rejects_bad_input() {
        let mut c = PartitionConfig::preset(Preset::CFast, 4);
        assert!(c.apply_option("epsilon", "lots").is_err());
        assert!(c.apply_option("parallel-coarsening", "maybe").is_err());
        assert!(c.apply_option("memory-budget", "1q").is_err());
        assert!(c.apply_option("memory-bugdet", "1g").is_err()); // typo'd key
        // explicit opt-outs parse
        c.apply_option("parallel-coarsening", "false").unwrap();
        assert!(!c.parallel_coarsening);
        c.apply_option("memory-budget", "0").unwrap();
        assert_eq!(c.memory_budget_bytes, None);
    }

    #[test]
    fn baselines_use_matching() {
        for p in [
            Preset::KaffpaEco,
            Preset::KaffpaStrong,
            Preset::KMetisLike,
            Preset::ScotchLike,
            Preset::HMetisLike,
        ] {
            assert_eq!(PartitionConfig::preset(p, 4).scheme, SchemeKind::Matching);
        }
    }
}
