//! Out-of-core partitioning driver: the memory-budget switch between
//! the fully in-memory multilevel pipeline and the semi-external path.
//!
//! [`partition_store`] is the entry point for inputs behind a
//! [`GraphStore`]. With no budget (or a budget the input's CSR
//! footprint fits), the store is materialized and the ordinary
//! [`MultilevelPartitioner`] runs — byte-identical to partitioning the
//! graph directly. When the input **exceeds**
//! `PartitionConfig::memory_budget_bytes`, the driver runs the paper's
//! semi-external recipe (arXiv 1404.4887) end to end:
//!
//! 1. **out-of-core coarsening** — semi-external SCLaP
//!    ([`external_sclap`]) + streaming contraction
//!    ([`contract_store_with_ctx`])
//!    build level 0 (and, if the contracted graph still exceeds the
//!    budget, further levels through an in-memory store view) with at
//!    most one shard of adjacency resident;
//! 2. **in-memory multilevel** — once the contracted graph fits the
//!    budget (or clustering stalls), the ordinary pipeline partitions
//!    it with a seed drawn from the same deterministic RNG stream;
//! 3. **projection + semi-external refinement** — blocks project back
//!    through the level maps, then one semi-external SCLaP refinement
//!    pass (overloaded-block rule, blocks never emptied) runs over the
//!    input store, and the final metrics are computed in one more
//!    streaming pass.
//!
//! # Budget semantics
//!
//! `memory_budget_bytes` **steers the algorithm** (which levels are
//! built out-of-core, and when the pipeline may materialize); it is
//! not a hard RSS cap: every contracted level is an in-memory [`Graph`]
//! by construction, so an unsatisfiable budget (e.g. the
//! `--memory-budget 1` forcing idiom used by tests and CI) coarsens
//! externally as far as clustering can shrink, warns, and hands the
//! smallest reachable graph to the in-memory pipeline. The one hard
//! refusal: an input that is *not* in memory and cannot be shrunk at
//! all (level-0 stall) errors instead of being silently materialized.
//!
//! # Determinism
//!
//! The budget selects the *algorithm*; storage is an execution detail.
//! For a fixed config (including the budget) the result is a pure
//! function of (graph, seed): byte-identical for any shard count, any
//! thread count, for `InMemoryStore` vs `ShardedStore` backends, and
//! for either on-disk shard encoding (`SCLAPS1` raw u64 vs `SCLAPS2`
//! delta+varint — a `ShardedStore` decodes to the same logical CSR
//! stream regardless of format, see `graph::store`). So "the in-memory
//! run" of the external path is the reference the CI out-of-core smoke
//! job compares every shard-streamed run — both formats plus a
//! `shard recompress` re-encode — against
//! (`rust/tests/sharded_store.rs`, `.github/workflows/ci.yml`).

use crate::clustering::external_lpa::{dense_from_labels, external_sclap};
use crate::clustering::label_propagation::{LpaConfig, LpaMode, NodeOrdering};
use crate::coarsening::contract::{contract_store_with_ctx, project_partition, Contraction};
use crate::coarsening::hierarchy::l_max;
use crate::graph::csr::{Graph, Weight};
use crate::graph::store::{streaming_cut, GraphStore, InMemoryStore};
use crate::obs::trace;
use crate::partitioning::config::PartitionConfig;
use crate::partitioning::multilevel::MultilevelPartitioner;
use crate::util::exec::ExecutionCtx;
use crate::util::rng::Rng;
use crate::util::timer::Timer;
use std::io;
use std::sync::Arc;

/// Shrink-stall guard: stop external coarsening when a level keeps more
/// than this fraction of its nodes (mirrors `CoarseningParams`'s
/// default `min_shrink`).
const EXTERNAL_MIN_SHRINK: f64 = 0.98;

/// Hard cap on out-of-core contraction levels (far above anything a
/// shrinking hierarchy can reach; loop-safety only).
const EXTERNAL_MAX_LEVELS: usize = 64;

/// Outcome of an out-of-core (or budget-satisfied in-memory) run.
#[derive(Debug, Clone)]
pub struct OutOfCoreResult {
    /// Block id per input node.
    pub blocks: Vec<u32>,
    /// Cut on the input graph (streamed for the external path).
    pub cut: Weight,
    pub max_block_weight: Weight,
    pub min_block_weight: Weight,
    /// max block weight / ceil(total/k) − 1.
    pub imbalance: f64,
    /// Whether every block obeys `L_max` for the configured ε.
    pub feasible: bool,
    /// Out-of-core contraction levels executed (0 = the input fit the
    /// budget and the ordinary in-memory pipeline ran).
    pub external_levels: usize,
    /// Size of the graph handed to the in-memory pipeline.
    pub handoff_n: usize,
    pub handoff_m: usize,
    /// Total wall-clock seconds, and the share spent in the external
    /// phases (streaming coarsening + refinement).
    pub seconds: f64,
    pub external_seconds: f64,
}

/// Partition a stored graph under the configured memory budget (module
/// docs). Creates a fresh [`ExecutionCtx`] from `config.threads`; the
/// coordinator path ([`partition_store_with_ctx`]) shares one instead.
pub fn partition_store(
    store: &dyn GraphStore,
    config: &PartitionConfig,
    seed: u64,
) -> io::Result<OutOfCoreResult> {
    let ctx = Arc::new(ExecutionCtx::new(config.threads));
    partition_store_with_ctx(store, config, seed, &ctx)
}

/// [`partition_store`] on a shared execution context (one pool through
/// every phase — the `ExecutionCtx` handoff).
pub fn partition_store_with_ctx(
    store: &dyn GraphStore,
    config: &PartitionConfig,
    seed: u64,
    ctx: &Arc<ExecutionCtx>,
) -> io::Result<OutOfCoreResult> {
    let k = config.k;
    assert!(k >= 1);
    let total_timer = Timer::start();

    // This repetition's logical trace track (inert without a tracer).
    // The in-memory pipeline below re-enters on the same thread, which
    // is a no-op: all spans land on this track.
    let _track = ctx.tracer().map(|t| t.enter(seed));

    let fits = match config.memory_budget_bytes {
        None => true,
        Some(budget) => store.memory_bytes() <= budget,
    };
    if fits {
        // In-memory fast path: run the ordinary pipeline. An in-memory
        // backend hands out its graph directly (no copy — a clone here
        // would double peak memory exactly when a budget was asked
        // for); a sharded store streams its segments together once.
        let owned;
        let g: &Graph = match store.as_graph() {
            Some(g) => g,
            None => {
                owned = store.to_graph()?;
                &owned
            }
        };
        let r = MultilevelPartitioner::with_ctx(config.clone(), ctx.clone()).partition(g, seed);
        return Ok(OutOfCoreResult {
            blocks: r.partition.blocks,
            cut: r.metrics.cut,
            max_block_weight: r.metrics.max_block_weight,
            min_block_weight: r.metrics.min_block_weight,
            imbalance: r.metrics.imbalance,
            feasible: r.metrics.feasible,
            external_levels: 0,
            handoff_n: g.n(),
            handoff_m: g.m(),
            seconds: total_timer.elapsed_s(),
            external_seconds: 0.0,
        });
    }
    let budget = config.memory_budget_bytes.expect("checked above");

    let mut rng = Rng::new(seed);
    let ext_timer = Timer::start();

    // ---- 1. out-of-core coarsening --------------------------------
    // Level 0 streams the input store; if the contracted graph still
    // exceeds the budget, further levels stream it through an
    // in-memory store view until it fits or clustering stalls.
    let mut maps: Vec<Vec<u32>> = Vec::new();
    let mut current: Option<Graph> = None;
    while maps.len() < EXTERNAL_MAX_LEVELS {
        // An external coarsening level streams every shard once — the
        // natural cancellation checkpoint for the out-of-core path.
        crate::util::cancel::checkpoint();
        let level = maps.len();
        let level_timer = Timer::start();
        let level_span = trace::span("external_coarsen_level", &[("level", level as i64)]);
        let step = {
            let holder;
            let level_store: &dyn GraphStore = match &current {
                None => store,
                Some(g) => {
                    holder = InMemoryStore::new(g);
                    &holder
                }
            };
            external_coarsen_once(level_store, config, ctx, &mut rng)?
        };
        drop(level_span);
        ctx.record_level("external_coarsen_level", level as u32, level_timer.elapsed_s());
        match step {
            None => break, // stalled: no useful shrink left
            Some(Contraction { coarse, map }) => {
                trace::counter(
                    "external_level",
                    &[
                        ("level", level as i64),
                        ("coarse_n", coarse.n() as i64),
                        ("coarse_m", coarse.m() as i64),
                    ],
                );
                maps.push(map);
                let done = coarse.memory_bytes() <= budget;
                current = Some(coarse);
                if done {
                    break;
                }
            }
        }
    }
    // The budget steers the algorithm; it is not a hard RSS cap — a
    // contracted level is materialized in RAM by construction, and a
    // tiny budget (the `--memory-budget 1` forcing idiom) is
    // intentionally never satisfiable. When coarsening stalls above
    // the budget we hand off the smallest graph reached, loudly.
    if let Some(g) = &current {
        if g.memory_bytes() > budget {
            eprintln!(
                "sclap out-of-core: coarsening stalled at n={} ({} bytes, budget {budget}); \
                 handing the smallest reachable graph to the in-memory pipeline",
                g.n(),
                g.memory_bytes()
            );
        }
    }
    let external_levels = maps.len();
    let coarsen_seconds = ext_timer.elapsed_s();
    ctx.record("external_coarsening", coarsen_seconds);

    // ---- 2. in-memory multilevel on the contracted graph ----------
    let inner_seed = rng.next_u64();
    let (inner_blocks, handoff_n, handoff_m) = {
        // A stall before any shrink means the budget is unsatisfiable
        // for this instance. An in-memory backend can still proceed on
        // its borrowed graph (it evidently fits in RAM); a genuinely
        // out-of-core input must NOT be silently materialized — that
        // is exactly the OOM the budget was meant to prevent.
        let g: &Graph = match &current {
            Some(g) => g,
            None => store.as_graph().ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!(
                        "memory budget ({budget} bytes) unsatisfiable: level-0 clustering \
                         stalled at n={} ({} bytes) on an out-of-core input",
                        store.n(),
                        store.memory_bytes()
                    ),
                )
            })?,
        };
        let r = MultilevelPartitioner::with_ctx(config.clone(), ctx.clone())
            .partition(g, inner_seed);
        (r.partition.blocks, g.n(), g.m())
    };

    // ---- 3. project back + semi-external refinement ---------------
    let mut blocks = inner_blocks;
    for map in maps.iter().rev() {
        blocks = project_partition(map, &blocks);
    }
    let final_lmax = l_max(
        store.total_node_weight(),
        k,
        config.epsilon,
        store.max_node_weight(),
    );
    let refine_timer = Timer::start();
    if external_levels > 0 && k > 1 {
        crate::util::cancel::checkpoint();
        let refine_span = trace::span("external_refinement", &[]);
        let refine_cfg = LpaConfig {
            max_iterations: config.lpa_iterations,
            ordering: NodeOrdering::Degree, // streaming engine: natural order
            active_nodes: false,
            convergence_fraction: 0.05,
            mode: LpaMode::Refinement,
        };
        let (refined, _) =
            external_sclap(store, final_lmax, &refine_cfg, Some(blocks), ctx, &mut rng)?;
        blocks = refined;
        drop(refine_span);
    }
    let refine_seconds = refine_timer.elapsed_s();
    ctx.record("external_refinement", refine_seconds);
    // Only the streamed phases — the phase-2 in-memory multilevel is
    // deliberately excluded.
    let external_seconds = coarsen_seconds + refine_seconds;

    // ---- metrics (one more streaming pass) ------------------------
    let cut = streaming_cut(store, &blocks)?;
    let mut block_weights = vec![0 as Weight; k];
    for (v, &b) in blocks.iter().enumerate() {
        block_weights[b as usize] += store.node_weights()[v];
    }
    let max_w = block_weights.iter().copied().max().unwrap_or(0);
    let min_w = block_weights.iter().copied().min().unwrap_or(0);
    let avg = (store.total_node_weight() as f64 / k as f64).ceil();
    trace::counter(
        "external_result",
        &[("cut", cut as i64), ("external_levels", external_levels as i64)],
    );
    Ok(OutOfCoreResult {
        blocks,
        cut,
        max_block_weight: max_w,
        min_block_weight: min_w,
        imbalance: if avg > 0.0 { max_w as f64 / avg - 1.0 } else { 0.0 },
        feasible: max_w <= final_lmax,
        external_levels,
        handoff_n,
        handoff_m,
        seconds: total_timer.elapsed_s(),
        external_seconds,
    })
}

/// One semi-external coarsening step: SCLaP clustering under the
/// paper's size bound `U = max(max_v c(v), L_max/(f·k))`, then
/// streaming contraction. `None` when clustering stalled (shrink below
/// [`EXTERNAL_MIN_SHRINK`]).
fn external_coarsen_once(
    store: &dyn GraphStore,
    config: &PartitionConfig,
    ctx: &ExecutionCtx,
    rng: &mut Rng,
) -> io::Result<Option<Contraction>> {
    let n = store.n();
    if n == 0 {
        return Ok(None);
    }
    let lmax = l_max(
        store.total_node_weight(),
        config.k,
        config.epsilon,
        store.max_node_weight(),
    );
    let w = (lmax as f64 / (config.size_factor * config.k as f64)).floor() as Weight;
    let upper = w.max(store.max_node_weight()).max(1);
    let lpa = LpaConfig {
        max_iterations: config.lpa_iterations,
        ordering: NodeOrdering::Degree, // streaming engine: natural order
        active_nodes: false,
        convergence_fraction: 0.05,
        mode: LpaMode::Clustering,
    };
    let (labels, _rounds) = external_sclap(store, upper, &lpa, None, ctx, rng)?;
    let clustering = dense_from_labels(store.node_weights(), labels);
    if clustering.num_clusters as f64 > EXTERNAL_MIN_SHRINK * n as f64 {
        return Ok(None);
    }
    Ok(Some(contract_store_with_ctx(store, &clustering, Some(ctx))?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators;
    use crate::partitioning::config::Preset;

    fn lfr() -> Graph {
        let mut rng = Rng::new(4);
        generators::lfr::lfr_like(1200, 6.0, 0.15, &mut rng).0
    }

    #[test]
    fn unlimited_budget_equals_plain_pipeline() {
        let g = lfr();
        let mut cfg = PartitionConfig::preset(Preset::CFast, 4);
        cfg.memory_budget_bytes = None;
        let store = InMemoryStore::new(&g);
        let via_store = partition_store(&store, &cfg, 7).unwrap();
        let direct = MultilevelPartitioner::new(cfg.clone()).partition(&g, 7);
        assert_eq!(via_store.blocks, direct.partition.blocks);
        assert_eq!(via_store.cut, direct.metrics.cut);
        assert_eq!(via_store.external_levels, 0);
        // A budget the graph fits takes the same path.
        cfg.memory_budget_bytes = Some(g.memory_bytes());
        let roomy = partition_store(&store, &cfg, 7).unwrap();
        assert_eq!(roomy.blocks, direct.partition.blocks);
    }

    #[test]
    fn tiny_budget_forces_external_levels() {
        let g = lfr();
        let mut cfg = PartitionConfig::preset(Preset::CFast, 4);
        cfg.memory_budget_bytes = Some(1);
        let store = InMemoryStore::with_shards(&g, 3);
        let r = partition_store(&store, &cfg, 9).unwrap();
        assert!(r.external_levels >= 1, "external path not taken");
        assert!(r.handoff_n < g.n(), "no out-of-core shrink happened");
        assert_eq!(r.blocks.len(), g.n());
        assert_eq!(r.cut, crate::partitioning::metrics::cut_value(&g, &r.blocks));
        assert!(r.blocks.iter().all(|&b| (b as usize) < 4));
        // All four blocks populated and the cut is non-trivial.
        for b in 0..4u32 {
            assert!(r.blocks.iter().any(|&x| x == b), "block {b} empty");
        }
        assert!(r.cut > 0);
        assert!(r.external_seconds <= r.seconds);
    }

    #[test]
    fn external_result_reports_balance_honestly() {
        let g = lfr();
        let mut cfg = PartitionConfig::preset(Preset::CFast, 2);
        cfg.memory_budget_bytes = Some(1);
        let store = InMemoryStore::new(&g);
        let r = partition_store(&store, &cfg, 3).unwrap();
        let mut weights = vec![0i64; 2];
        for (v, &b) in r.blocks.iter().enumerate() {
            weights[b as usize] += g.node_weight(v as u32);
        }
        assert_eq!(r.max_block_weight, *weights.iter().max().unwrap());
        assert_eq!(r.min_block_weight, *weights.iter().min().unwrap());
        let lmax = l_max(g.total_node_weight(), 2, cfg.epsilon, g.max_node_weight());
        assert_eq!(r.feasible, r.max_block_weight <= lmax);
    }
}
