//! `VcycleWorkspace` — the multilevel pipeline's reusable scratch pool.
//!
//! One workspace lives inside every [`ExecutionCtx`]
//! (`crate::util::exec`), so all phases that share a pool also share
//! retired scratch buffers: a V-cycle's level `l+1` re-leases what
//! level `l` just returned, the next repetition the batching service
//! fans out re-leases what the previous one used, and a warm `serve`
//! request runs with (near-)zero new heap allocations.
//!
//! # Layout and locking
//!
//! The workspace holds one [`Arena`] *shard* per pool worker
//! ([`worker`](VcycleWorkspace::worker) maps a worker index to its
//! shard; the caller thread is worker 0). Each shard has its own
//! mutex, so in the steady state — every pool job leasing from its own
//! worker's shard — leases are uncontended. A plain worker-indexed
//! array *without* locks would be unsound: the pool runs nested jobs
//! inline under worker index 0, so two top-level jobs that both
//! re-enter the pool execute "worker 0" code on different OS threads
//! concurrently. The per-shard mutex makes that collision merely a
//! moment of contention instead of a data race.
//!
//! # Lease lifecycle
//!
//! `ws.worker(w).lease::<Vec<u32>>(n)` pops the largest shelved buffer
//! of that type (or allocates fresh on a cold start), re-dimensions it
//! for `n`, and hands it out **cleared**; dropping the lease clears it
//! again and shelves it. See `util::arena` for the `Reusable`
//! contract.
//!
//! # Why reuse cannot affect determinism
//!
//! A lease is observationally identical to a fresh allocation — same
//! length/emptiness, same contents (none) — differing only in
//! *capacity*, which no algorithm observes. Which shard a buffer comes
//! from follows the deterministic task decomposition (worker indices
//! name schedule positions, not threads), and even a "wrong"-shard
//! lease under re-entrant collision yields the same cleared buffer.
//! `tests/determinism.rs` pins the end-to-end guarantee: byte-identical
//! partitions across threads, shards, backends, and formats — workspace
//! on or off the hot path.

use crate::util::arena::{Arena, ArenaStats, LeaseStatsSnapshot};
use std::sync::Arc;

/// Per-worker arena shards plus a shared lease-stats sink. Cheap to
/// create (empty shelves); buffers accrete on first use.
#[derive(Debug)]
pub struct VcycleWorkspace {
    shards: Vec<Arena>,
    stats: Arc<ArenaStats>,
}

impl VcycleWorkspace {
    /// Workspace with one arena shard per pool worker (at least one —
    /// shard 0 serves sequential callers).
    pub fn new(workers: usize) -> Self {
        let stats = Arc::new(ArenaStats::default());
        let shards = (0..workers.max(1))
            .map(|_| Arena::new(stats.clone()))
            .collect();
        VcycleWorkspace { shards, stats }
    }

    /// The arena shard for pool worker `worker` (wraps, so any index is
    /// safe — nested jobs always land on a valid shard).
    #[inline]
    pub fn worker(&self, worker: usize) -> &Arena {
        &self.shards[worker % self.shards.len()]
    }

    /// The caller thread's shard (worker 0) — the one sequential code
    /// leases from.
    #[inline]
    pub fn caller(&self) -> &Arena {
        &self.shards[0]
    }

    /// Number of arena shards (== pool workers).
    #[inline]
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Snapshot of the shared lease stats: `leases_created`,
    /// `fresh_allocations` (what the steady state drives to zero), and
    /// current/peak outstanding lease bytes — the high-water mark is
    /// the pipeline's peak-scratch-RSS proxy reported by
    /// `serve --timing` and the `vcycle_e2e` bench.
    pub fn stats(&self) -> LeaseStatsSnapshot {
        self.stats.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_share_one_stats_sink() {
        let ws = VcycleWorkspace::new(3);
        assert_eq!(ws.shards(), 3);
        {
            let _a: crate::util::Lease<'_, Vec<u32>> = ws.worker(0).lease(8);
            let _b: crate::util::Lease<'_, Vec<u32>> = ws.worker(2).lease(8);
        }
        let s = ws.stats();
        assert_eq!(s.leases_created, 2);
        assert_eq!(s.fresh_allocations, 2);
        assert_eq!(s.current_lease_bytes, 0);
        assert!(s.peak_lease_bytes >= 2 * 8 * 4);
    }

    #[test]
    fn worker_index_wraps() {
        let ws = VcycleWorkspace::new(2);
        assert!(std::ptr::eq(ws.worker(0), ws.worker(4)));
        assert!(std::ptr::eq(ws.worker(1), ws.worker(5)));
        assert!(std::ptr::eq(ws.caller(), ws.worker(0)));
    }

    #[test]
    fn zero_workers_still_yields_a_shard() {
        let ws = VcycleWorkspace::new(0);
        assert_eq!(ws.shards(), 1);
        let v: crate::util::Lease<'_, Vec<u8>> = ws.caller().lease(4);
        assert!(v.is_empty());
    }

    #[test]
    fn steady_state_stops_allocating() {
        let ws = VcycleWorkspace::new(1);
        for _ in 0..10 {
            let mut v: crate::util::Lease<'_, Vec<u64>> = ws.caller().lease(64);
            v.extend(0..64);
        }
        let s = ws.stats();
        assert_eq!(s.leases_created, 10);
        assert_eq!(s.fresh_allocations, 1, "warm leases reuse the shelf");
    }
}
