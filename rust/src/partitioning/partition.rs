//! The partition type: a block assignment `V → {0..k-1}` with cached
//! block weights and the paper's balance bookkeeping (§2.1).

use crate::graph::csr::{Graph, NodeId, Weight};
use std::io::{self, BufRead, Write};

/// A k-way partition of a graph's nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub k: usize,
    /// Block id per node.
    pub blocks: Vec<u32>,
    /// Cached total node weight per block.
    pub block_weights: Vec<Weight>,
}

impl Partition {
    /// Build from a block array (weights computed from the graph).
    pub fn from_blocks(g: &Graph, k: usize, blocks: Vec<u32>) -> Self {
        assert_eq!(blocks.len(), g.n());
        let mut block_weights = vec![0 as Weight; k];
        for v in g.nodes() {
            let b = blocks[v as usize] as usize;
            assert!(b < k, "block id {b} out of range (k={k})");
            block_weights[b] += g.node_weight(v);
        }
        Partition {
            k,
            blocks,
            block_weights,
        }
    }

    /// All nodes in block 0 (the trivial 1-extendable start).
    pub fn singleton(g: &Graph, k: usize) -> Self {
        Partition::from_blocks(g, k, vec![0; g.n()])
    }

    #[inline]
    pub fn block_of(&self, v: NodeId) -> u32 {
        self.blocks[v as usize]
    }

    /// Move `v` to `target`, maintaining cached weights.
    #[inline]
    pub fn move_node(&mut self, g: &Graph, v: NodeId, target: u32) {
        let from = self.blocks[v as usize];
        if from == target {
            return;
        }
        let w = g.node_weight(v);
        self.block_weights[from as usize] -= w;
        self.block_weights[target as usize] += w;
        self.blocks[v as usize] = target;
    }

    /// Heaviest block weight.
    pub fn max_block_weight(&self) -> Weight {
        self.block_weights.iter().copied().max().unwrap_or(0)
    }

    /// Lightest block weight.
    pub fn min_block_weight(&self) -> Weight {
        self.block_weights.iter().copied().min().unwrap_or(0)
    }

    /// Number of non-empty blocks.
    pub fn nonempty_blocks(&self) -> usize {
        self.block_weights.iter().filter(|&&w| w > 0).count()
    }

    /// Structural validation against a graph.
    pub fn validate(&self, g: &Graph) -> Result<(), String> {
        if self.blocks.len() != g.n() {
            return Err("length mismatch".into());
        }
        if self.block_weights.len() != self.k {
            return Err("weights length mismatch".into());
        }
        let mut weights = vec![0 as Weight; self.k];
        for v in g.nodes() {
            let b = self.blocks[v as usize] as usize;
            if b >= self.k {
                return Err(format!("node {v} in out-of-range block {b}"));
            }
            weights[b] += g.node_weight(v);
        }
        if weights != self.block_weights {
            return Err("cached block weights stale".into());
        }
        Ok(())
    }
}

/// Write the METIS-compatible partition format: one block id per line,
/// line i = block of node i.
pub fn write_partition<W: Write>(p: &Partition, out: &mut W) -> io::Result<()> {
    for &b in &p.blocks {
        writeln!(out, "{b}")?;
    }
    Ok(())
}

/// Read a METIS-style partition file for graph `g`. `k` is inferred as
/// 1 + max block id unless `k_hint` is larger.
pub fn read_partition<R: BufRead>(
    g: &Graph,
    reader: R,
    k_hint: Option<usize>,
) -> io::Result<Partition> {
    let mut blocks = Vec::with_capacity(g.n());
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') || t.starts_with('#') {
            continue;
        }
        let b: u32 = t
            .parse()
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad block id"))?;
        blocks.push(b);
    }
    if blocks.len() != g.n() {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("partition has {} entries for {} nodes", blocks.len(), g.n()),
        ));
    }
    let k = blocks
        .iter()
        .map(|&b| b as usize + 1)
        .max()
        .unwrap_or(1)
        .max(k_hint.unwrap_or(1));
    Ok(Partition::from_blocks(g, k, blocks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;

    fn square() -> Graph {
        GraphBuilder::new(4)
            .edge(0, 1)
            .edge(1, 2)
            .edge(2, 3)
            .edge(3, 0)
            .build()
    }

    #[test]
    fn from_blocks_computes_weights() {
        let g = square();
        let p = Partition::from_blocks(&g, 2, vec![0, 0, 1, 1]);
        assert_eq!(p.block_weights, vec![2, 2]);
        assert!(p.validate(&g).is_ok());
    }

    #[test]
    fn move_node_updates_weights() {
        let g = square();
        let mut p = Partition::from_blocks(&g, 2, vec![0, 0, 1, 1]);
        p.move_node(&g, 0, 1);
        assert_eq!(p.block_weights, vec![1, 3]);
        assert_eq!(p.block_of(0), 1);
        assert!(p.validate(&g).is_ok());
        // self-move is a no-op
        p.move_node(&g, 0, 1);
        assert_eq!(p.block_weights, vec![1, 3]);
    }

    #[test]
    fn min_max_and_nonempty() {
        let g = square();
        let p = Partition::from_blocks(&g, 3, vec![0, 0, 0, 1]);
        assert_eq!(p.max_block_weight(), 3);
        assert_eq!(p.min_block_weight(), 0);
        assert_eq!(p.nonempty_blocks(), 2);
    }

    #[test]
    fn partition_file_roundtrip() {
        let g = square();
        let p = Partition::from_blocks(&g, 3, vec![0, 2, 1, 2]);
        let mut buf = Vec::new();
        write_partition(&p, &mut buf).unwrap();
        let p2 = read_partition(&g, std::io::Cursor::new(buf), None).unwrap();
        assert_eq!(p, p2);
    }

    #[test]
    fn partition_file_length_mismatch_rejected() {
        let g = square();
        let r = read_partition(&g, std::io::Cursor::new("0
1
"), None);
        assert!(r.is_err());
    }

    #[test]
    fn partition_file_k_hint() {
        let g = square();
        let p2 = read_partition(&g, std::io::Cursor::new("0
0
1
1
"), Some(5)).unwrap();
        assert_eq!(p2.k, 5);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_block_panics() {
        let g = square();
        let _ = Partition::from_blocks(&g, 2, vec![0, 0, 1, 2]);
    }
}
