//! The partitioning system: partition type, quality metrics, named
//! configurations (paper §5.1 + baselines), the multilevel driver, the
//! out-of-core driver ([`external`]) for inputs beyond the memory
//! budget, and the reusable scratch pool ([`workspace`]) every phase
//! leases from.
//!
//! # Workspace lifecycle
//!
//! The [`workspace::VcycleWorkspace`] rides inside the shared
//! `ExecutionCtx`, so its lifetime is the context's: one per process
//! pool, warm across V-cycle levels, repetitions, and service
//! requests. Phases lease scratch (`ws.worker(w).lease::<T>(n)`),
//! getting cleared-but-capacitated buffers that shelve themselves on
//! drop. Leases hand back **capacity, never contents**, which is why
//! reuse cannot perturb the determinism contract — see the
//! [`workspace`] module docs for the full argument and the per-worker
//! sharding that keeps steady-state leases lock-uncontended.

pub mod config;
pub mod external;
pub mod metrics;
pub mod multilevel;
pub mod partition;
pub mod workspace;

pub use config::{PartitionConfig, Preset};
pub use external::{partition_store, OutOfCoreResult};
pub use metrics::{cut_value, evaluate, PartitionMetrics};
pub use multilevel::{MultilevelPartitioner, PartitionResult};
pub use partition::Partition;
pub use workspace::VcycleWorkspace;
