//! The partitioning system: partition type, quality metrics, named
//! configurations (paper §5.1 + baselines) and the multilevel driver.

pub mod config;
pub mod metrics;
pub mod multilevel;
pub mod partition;

pub use config::{PartitionConfig, Preset};
pub use metrics::{cut_value, evaluate, PartitionMetrics};
pub use multilevel::{MultilevelPartitioner, PartitionResult};
pub use partition::Partition;
