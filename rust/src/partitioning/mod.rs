//! The partitioning system: partition type, quality metrics, named
//! configurations (paper §5.1 + baselines), the multilevel driver, and
//! the out-of-core driver ([`external`]) for inputs beyond the memory
//! budget.

pub mod config;
pub mod external;
pub mod metrics;
pub mod multilevel;
pub mod partition;

pub use config::{PartitionConfig, Preset};
pub use external::{partition_store, OutOfCoreResult};
pub use metrics::{cut_value, evaluate, PartitionMetrics};
pub use multilevel::{MultilevelPartitioner, PartitionResult};
pub use partition::Partition;
