//! sclap — the command-line front end of the partitioning system.
//!
//! Subcommands:
//!   partition  — partition a graph (file or named instance)
//!   serve      — batching service: stdin/file requests, or a TCP
//!                server (--listen) with a content-addressed cache
//!   client     — submit request lines to a serve --listen server
//!   report     — run a preset×instance matrix through the service
//!                path and emit paper-style geomean cut/time tables
//!   generate   — write a synthetic instance to a file
//!   stats      — print instance statistics (Table-1 style)
//!   offload    — demo the PJRT dense-LPA offload on a small graph
//!   presets    — list the available configuration presets
//!
//! Examples:
//!   sclap partition --instance tiny-rmat --k 8 --preset UFast --reps 10
//!   sclap partition --graph my.graph --k 16 --preset UStrong --output part.txt
//!   sclap serve --requests jobs.txt --workers 8 --max-pending 32
//!   sclap serve --listen 127.0.0.1:7643 --workers 8 --cache 128
//!   sclap client --connect 127.0.0.1:7643 --requests jobs.txt
//!   sclap generate --kind rmat --scale 18 --edges 2000000 --out web.bin
//!   sclap stats --instance uk2002-sim

use sclap::bail;
use sclap::bench::harness::{fmt as fmt_num, geomean_row};
use sclap::coordinator::cli::Args;
use sclap::coordinator::net::{parse_response, NetClient, NetServer, NetServerConfig};
use sclap::coordinator::queue::spec::{
    parse_request_line, render_cancelled_line, render_error_line, render_result_line_full,
    write_partition_file, RequestSpec,
};
use sclap::coordinator::queue::{BatchService, EventHook, ServiceConfig};
use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::generators;
use sclap::graph::csr::Graph;
use sclap::graph::store::{
    convert_metis_to_shards_as, recompress_store, write_sharded_as, GraphStore, InMemoryStore,
    ShardFormat, ShardedStore,
};
use sclap::obs::journal::{FieldValue, Journal, JournalConfig};
use sclap::obs::trace::Tracer;
use sclap::partitioning::config::{PartitionConfig, Preset, CONFIG_OPTION_KEYS};
use sclap::partitioning::external::OutOfCoreResult;
use sclap::util::error::{Context, Result};
use sclap::util::rng::Rng;
use std::io::BufRead;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "partition" => cmd_partition(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "report" => cmd_report(args),
        "evaluate" => cmd_evaluate(args),
        "generate" => cmd_generate(args),
        "shard" => cmd_shard(args),
        "stats" => cmd_stats(args),
        "offload" => cmd_offload(args),
        "presets" => cmd_presets(),
        "" | "help" | "--help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `sclap help`)"),
    }
}

fn print_usage() {
    println!(
        "sclap — size-constrained label-propagation graph partitioning\n\
         \n\
         USAGE: sclap <command> [--options]\n\
         \n\
         COMMANDS:\n\
           partition --graph FILE | --instance NAME | --shards DIR\n\
                     --k K [--preset P] [--reps N] [--seed S]\n\
                     [--workers W] [--threads T] [--epsilon E]\n\
                     [--output FILE] [--memory-budget BYTES]\n\
                     [--parallel-coarsening] [--parallel-refinement]\n\
           serve     [--requests FILE|-] [--workers W]\n\
                     [--max-pending N] [--timing]\n\
                     [--journal FILE]\n\
                     [--listen ADDR [--cache N]]\n\
           client    --connect ADDR [--requests FILE|-]\n\
                     [--timeout SECS] [--quiet] [--stats]\n\
           report    [--instances A,B,..] [--presets P1,P2,..]\n\
                     [--k K] [--reps N] [--seed S]\n\
                     [--workers W] [--out FILE]\n\
           generate  --kind rmat|ba|ws|er|grid|lfr --out FILE\n\
                     [--scale S] [--n N] [--edges M] [--seed S]\n\
                     [--avg-degree D] [--mu MU]\n\
           shard     --graph FILE | --instance NAME --out DIR\n\
                     [--shards S] [--format v1|v2]\n\
           shard     recompress --in DIR --out DIR\n\
                     [--shards S] [--format v1|v2]\n\
           evaluate  --graph FILE | --instance NAME --partition FILE\n\
                     [--epsilon E]\n\
           stats     --graph FILE | --instance NAME\n\
           offload   --instance NAME [--upper U] [--rounds R]\n\
           presets\n\
         \n\
         --shards DIR: read topology from a shard directory (see the\n\
           `shard` command) instead of one graph file.\n\
         \n\
         serve: the batching service front end. Reads one request per\n\
           line (key=value tokens: id=, graph=/instance=/shards=, k=,\n\
           preset=, seeds=1,2,3 or reps=N seed=S, output=,\n\
           timeout_ms=MS (cancel when the deadline passes),\n\
           race=P1,P2 (run the presets as an ensemble race: best cut\n\
           wins, losers are cancelled), plus any config key such as\n\
           memory-budget=) from --requests FILE or\n\
           stdin, batches repetitions from all requests onto one\n\
           worker pool (a 1-seed request is never starved behind a\n\
           10-seed request), and writes one JSON result line per\n\
           request to stdout in input order. The bounded queue\n\
           (--max-pending) pushes back on the input stream. Without\n\
           --timing the output is byte-identical for any --workers\n\
           value and any request interleaving.\n\
         serve --listen ADDR: the same service as a TCP server (one\n\
           request line in, one JSON line out, pipelined out of\n\
           order; blank lines and # comments accepted; !ping, !stats,\n\
           !metrics (Prometheus text block) and\n\
           !shutdown control commands). A full queue answers\n\
           {{\"status\":\"busy\"}} instead of blocking the connection,\n\
           and a content-addressed result cache (--cache N entries,\n\
           0 disables) serves repeated requests without\n\
           recomputation — responses gain \"cached\":true and are\n\
           otherwise byte-identical to an offline run.\n\
         serve --journal FILE: durable ops telemetry — one JSON line\n\
           per request lifecycle event (admitted / started /\n\
           completed / cancelled / busy / cache_hit / error /\n\
           shutdown) appended to FILE with size-based rotation\n\
           (FILE -> FILE.1). Journaling never changes a result byte;\n\
           scripts/journal_replay.py reconciles a journal against\n\
           the !stats counters.\n\
         report: run a preset x instance matrix through the batching\n\
           service path and emit the paper-style result tables: one\n\
           JSON document ({{k, reps, presets, instances, cells,\n\
           geomeans}}) on stdout (or --out FILE) with per-cell\n\
           avg/best cut and time plus per-preset cross-instance\n\
           geomeans (zero cells excluded with a count), and a human\n\
           geomean table on stderr. scripts/make_tables.py formats\n\
           the JSON against the paper's reported numbers. Defaults\n\
           are the quick CI matrix (tiny instances, CFast/CEco/\n\
           UFast, k=4, 3 reps).\n\
         client: submit spec lines to a serve --listen server and\n\
           stream the JSON result lines to stdout (responses are\n\
           validated structurally; summary on stderr). An explicit\n\
           --timeout SECS is an end-to-end deadline: it bounds the\n\
           connect retry and is attached to every request line as\n\
           timeout_ms=, so the server cancels overdue work and\n\
           answers {{\"status\":\"cancelled\"}}. The default bounds\n\
           only the connect retry.\n\
         client --stats: ops snapshot instead of requests — fetch\n\
           !stats (one JSON line) and !metrics (a Prometheus text\n\
           block framed by `# sclap metrics` / `# EOF`) from the\n\
           server, print both to stdout, and exit.\n\
         --memory-budget BYTES (k/m/g suffixes; env\n\
           SCLAP_MEMORY_BUDGET): RAM budget for holding a CSR. Inputs\n\
           beyond it are partitioned out-of-core: semi-external SCLaP\n\
           level-0 coarsening streamed shard by shard, in-memory\n\
           multilevel once the contraction fits, semi-external LPA\n\
           refinement on the way back up. Same seed + config gives the\n\
           identical partition for any shard count and storage backend.\n\
         \n\
         --workers W: the one process pool (0 = all cores). Repetitions\n\
           fan out across it and every phase inside a repetition shares\n\
           it (ExecutionCtx handoff), so W caps total worker threads.\n\
         --threads T: caps the shared pool when --workers is absent\n\
           (0 = auto, 1 = fully sequential; also via SCLAP_THREADS).\n\
           Results are byte-identical for every T and W — same seed,\n\
           same partition.\n\
         --parallel-coarsening: coloring-based parallel asynchronous\n\
           LPA for coarsening (arXiv 1404.4797 engine).\n\
         --parallel-refinement: synchronous-round pool engine for the\n\
           SCLaP refinement stage.\n"
    );
}

/// Install a tracer on the shared execution context when `--trace FILE`
/// was given. Tracing never changes results (the observability
/// invariant); the returned pair is handed to [`write_trace`] after the
/// run so the file is written exactly once, when all spans have
/// drained.
fn install_tracer(
    args: &Args,
    ctx: &sclap::util::exec::ExecutionCtx,
) -> Option<(Arc<Tracer>, String)> {
    args.get("trace").map(|path| {
        let tracer = Arc::new(Tracer::new());
        ctx.set_tracer(tracer.clone());
        (tracer, path.to_string())
    })
}

fn write_trace(trace: Option<(Arc<Tracer>, String)>) -> Result<()> {
    if let Some((tracer, path)) = trace {
        let events = tracer.events().len();
        tracer
            .write_chrome_trace_file(Path::new(&path))
            .with_context(|| format!("writing trace {path}"))?;
        println!("wrote trace to {path} ({events} events)");
    }
    Ok(())
}

fn load_graph(args: &Args) -> Result<Graph> {
    if let Some(name) = args.get("instance") {
        let spec = generators::instances::by_name(name)
            .with_context(|| format!("unknown instance {name:?} (see DESIGN.md §3)"))?;
        return Ok(spec.build());
    }
    if let Some(path) = args.get("graph") {
        return sclap::graph::io::load_path(Path::new(path))
            .with_context(|| format!("loading {path}"));
    }
    bail!("need --graph FILE or --instance NAME");
}

fn cmd_partition(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 2)?;
    let preset_name = args.get_or("preset", "UFast");
    let preset = Preset::from_name(preset_name)
        .with_context(|| format!("unknown preset {preset_name:?} (see `sclap presets`)"))?;
    let mut config = PartitionConfig::preset(preset, k);
    // One shared option path for `partition` flags and `serve` request
    // specs: `PartitionConfig::apply_option`.
    for key in CONFIG_OPTION_KEYS {
        if let Some(v) = args.get(key) {
            config.apply_option(key, v)?;
        }
    }
    let reps = args.get_usize("reps", 1)?;
    let seed = args.get_u64("seed", 1)?;
    let workers = args.get_usize("workers", 0)?;

    // Store-backed paths: shard-directory input, or any input under a
    // memory budget (the out-of-core driver decides in-memory vs
    // semi-external — identically for either storage backend).
    if let Some(dir) = args.get("shards") {
        let store = ShardedStore::open(Path::new(dir))
            .with_context(|| format!("opening shard directory {dir}"))?;
        return run_partition_store(args, &store, &config, reps, seed, workers);
    }
    let graph = Arc::new(load_graph(args)?);
    if config.memory_budget_bytes.is_some() {
        let store = InMemoryStore::new(&graph);
        return run_partition_store(args, &store, &config, reps, seed, workers);
    }

    println!(
        "partitioning n={} m={} into k={k} with {} (ε={}, {reps} reps)",
        graph.n(),
        graph.m(),
        preset.name(),
        config.epsilon
    );
    // Size the one process pool: explicit --workers wins; otherwise an
    // explicit --threads / SCLAP_THREADS caps it (so `--threads 1` still
    // means a fully sequential run, as before the ExecutionCtx refactor);
    // else auto. Every phase of every repetition shares this pool.
    let pool_threads = if workers != 0 { workers } else { config.threads };
    let coordinator = Coordinator::new(pool_threads);
    let trace = install_tracer(args, coordinator.ctx());
    let seeds: Vec<u64> = default_seeds(reps).iter().map(|s| s + seed - 1).collect();
    let agg = coordinator.partition_repeated(graph.clone(), &config, &seeds);
    write_trace(trace)?;

    println!("avg cut    : {:.1}", agg.avg_cut);
    println!("best cut   : {}", agg.best_cut);
    println!("avg time   : {:.3}s", agg.avg_seconds);
    println!("infeasible : {}/{}", agg.infeasible_runs, reps);
    let best = &agg.runs[agg
        .runs
        .iter()
        .enumerate()
        .min_by_key(|(_, r)| r.cut)
        .map(|(i, _)| i)
        .unwrap()];
    println!(
        "hierarchy  : {} levels, coarsest n={}, initial cut={}",
        best.levels, best.coarsest_n, best.initial_cut
    );

    if let Some(out) = args.get("output") {
        write_partition_file(out, &agg.best_blocks).with_context(|| format!("writing {out}"))?;
        println!("wrote best partition to {out}");
    }
    Ok(())
}

/// The store-backed `partition` path (shard directories and
/// memory-budgeted runs): repetitions on the coordinator's shared
/// context, best-cut aggregation, same output conventions.
fn run_partition_store(
    args: &Args,
    store: &dyn GraphStore,
    config: &PartitionConfig,
    reps: usize,
    seed: u64,
    workers: usize,
) -> Result<()> {
    let budget = config
        .memory_budget_bytes
        .map(|b| b.to_string())
        .unwrap_or_else(|| "unlimited".into());
    println!(
        "partitioning n={} m={} into k={} ({} shard(s), memory budget {budget}, {reps} reps)",
        store.n(),
        store.m(),
        config.k,
        store.num_shards(),
    );
    let pool_threads = if workers != 0 { workers } else { config.threads };
    let coordinator = Coordinator::new(pool_threads);
    let trace = install_tracer(args, coordinator.ctx());
    let reps = reps.max(1);
    // Repetitions fan out across the coordinator pool (like the normal
    // path's partition_repeated); each job's nested phases re-enter
    // the same pool inline, and results are collected in seed order.
    let outcomes: Vec<std::io::Result<OutOfCoreResult>> = coordinator
        .ctx()
        .pool()
        .map_indexed(reps, |_worker, i| {
            coordinator.partition_store(store, config, seed + i as u64)
        });
    write_trace(trace)?;
    let mut best: Option<OutOfCoreResult> = None;
    let mut cut_sum = 0.0;
    let mut secs_sum = 0.0;
    let mut infeasible = 0usize;
    for outcome in outcomes {
        let r = outcome.context("out-of-core partition")?;
        cut_sum += r.cut as f64;
        secs_sum += r.seconds;
        if !r.feasible {
            infeasible += 1;
        }
        if best.as_ref().map(|b| r.cut < b.cut).unwrap_or(true) {
            best = Some(r);
        }
    }
    let best = best.expect("at least one repetition");
    println!("avg cut    : {:.1}", cut_sum / reps as f64);
    println!("best cut   : {}", best.cut);
    println!("avg time   : {:.3}s", secs_sum / reps as f64);
    println!("infeasible : {infeasible}/{reps}");
    println!(
        "out-of-core: {} external level(s), handed off n={} m={} ({:.3}s external)",
        best.external_levels, best.handoff_n, best.handoff_m, best.external_seconds
    );
    if let Some(out) = args.get("output") {
        write_partition_file(out, &best.blocks).with_context(|| format!("writing {out}"))?;
        println!("wrote best partition to {out}");
    }
    Ok(())
}

/// `serve`: the batching service front end. Reads newline-delimited
/// request specs (`coordinator::queue::spec`) from `--requests FILE`
/// or stdin, submits them to a [`BatchService`] (bounded queue:
/// `--max-pending`, blocking submits apply backpressure to the input
/// stream), and writes **one JSON result line per request to stdout in
/// input order**. Result lines carry only deterministic fields unless
/// `--timing` is set, so the output is byte-identical for any
/// `--workers` value and any scheduling interleaving; diagnostics go
/// to stderr.
fn cmd_serve(args: &Args) -> Result<()> {
    let workers = args.get_usize("workers", 0)?;
    let max_pending = args.get_usize("max-pending", 16)?;
    if max_pending == 0 {
        bail!("--max-pending must be at least 1");
    }
    let timing = args.flag("timing");
    if let Some(listen) = args.get("listen") {
        if args.get("requests").is_some() {
            bail!("--requests reads a spec stream (stdin mode); --listen serves TCP clients — use one or the other");
        }
        let cache_entries = args.get_usize("cache", 64)?;
        let server = NetServer::bind(
            listen,
            NetServerConfig {
                workers,
                max_pending,
                cache_entries,
                timing,
                trace: args.get("trace").map(std::path::PathBuf::from),
                journal: args.get("journal").map(JournalConfig::new),
            },
        )
        .with_context(|| format!("binding {listen}"))?;
        eprintln!(
            "sclap serve: listening on {} (workers={workers}, max-pending={max_pending}, cache={cache_entries})",
            server.local_addr()
        );
        server.run().context("running the server")?;
        eprintln!("sclap serve: drained and shut down");
        return Ok(());
    }
    if args.get("cache").is_some() {
        bail!("--cache applies to --listen mode (stdin serve computes every request)");
    }
    let requests_path = args.get_or("requests", "-");
    let input: Box<dyn BufRead> = if requests_path == "-" {
        Box::new(std::io::BufReader::new(std::io::stdin()))
    } else {
        let file = std::fs::File::open(requests_path)
            .with_context(|| format!("opening {requests_path}"))?;
        Box::new(std::io::BufReader::new(file))
    };

    // `--journal FILE` works in stdin mode too: this front end records
    // admitted/completed/cancelled/error lines itself, and the
    // scheduler's `started` events arrive via the lifecycle hook —
    // the same durable trail a `--listen` server leaves.
    let journal: Option<Arc<Journal>> = match args.get("journal") {
        Some(path) => Some(Arc::new(
            Journal::open(JournalConfig::new(path))
                .with_context(|| format!("opening journal {path}"))?,
        )),
        None => None,
    };
    let on_event: Option<EventHook> = journal.as_ref().map(|journal| {
        let journal = journal.clone();
        Arc::new(move |event: &str, id: &str| {
            journal.record(event, &[("id", FieldValue::Str(id))]);
        }) as EventHook
    });
    let service = BatchService::with_ctx_and_hook(
        ServiceConfig {
            workers,
            max_pending,
        },
        Arc::new(sclap::util::exec::ExecutionCtx::new(workers)),
        on_event,
    );
    let trace = install_tracer(args, service.ctx());
    // Requests naming the same graph file / instance share one loaded
    // copy — the batching win the queue exists for (the same catalog
    // type the TCP server shares across connections).
    let catalog = sclap::coordinator::net::GraphCatalog::new();

    /// One input line's fate, kept in input order.
    enum Entry {
        /// Rejected before submission (parse or load failure).
        Failed { id: String, message: String },
        /// Submitted; the ticket resolves to the result.
        Submitted {
            ticket: sclap::coordinator::queue::Ticket,
            spec: RequestSpec,
        },
    }

    let mut entries: Vec<Entry> = Vec::new();
    for (idx, line) in input.lines().enumerate() {
        let line = line.with_context(|| format!("reading {requests_path}"))?;
        let default_id = format!("req{}", idx + 1);
        let spec = match parse_request_line(&line, &default_id) {
            Ok(None) => continue,
            Ok(Some(spec)) => spec,
            Err(message) => {
                entries.push(Entry::Failed {
                    id: default_id,
                    message: format!("line {}: {message}", idx + 1),
                });
                continue;
            }
        };
        match catalog.materialize(&spec) {
            Ok(request) => {
                // Blocking submit: the bounded queue pushes back on how
                // fast we consume the input stream.
                match service.submit(request) {
                    Ok(ticket) => {
                        if let Some(journal) = &journal {
                            journal.record("admitted", &[("id", FieldValue::Str(&spec.id))]);
                        }
                        entries.push(Entry::Submitted { ticket, spec });
                    }
                    Err(e) => entries.push(Entry::Failed {
                        id: spec.id,
                        message: e.to_string(),
                    }),
                }
            }
            Err(message) => entries.push(Entry::Failed {
                id: spec.id,
                message,
            }),
        }
    }

    let total = entries.len();
    let mut failed = 0usize;
    for entry in entries {
        match entry {
            Entry::Failed { id, message } => {
                failed += 1;
                if let Some(journal) = &journal {
                    journal.record(
                        "error",
                        &[
                            ("id", FieldValue::Str(&id)),
                            ("message", FieldValue::Str(&message)),
                        ],
                    );
                }
                println!("{}", render_error_line(&id, &message));
            }
            Entry::Submitted { ticket, spec } => match ticket.wait() {
                Ok(agg) => {
                    // A failing output= write fails THIS request's line
                    // only — per-request fault isolation extends to the
                    // output stage; the stream keeps flowing.
                    let write_err = spec.output.as_ref().and_then(|out| {
                        match write_partition_file(out, &agg.best_blocks) {
                            Ok(()) => {
                                eprintln!("{}: wrote best partition to {out}", spec.id);
                                None
                            }
                            Err(e) => Some(format!("writing {out}: {e}")),
                        }
                    });
                    match write_err {
                        None => {
                            if let Some(journal) = &journal {
                                journal.record(
                                    "completed",
                                    &[
                                        ("id", FieldValue::Str(&spec.id)),
                                        ("seconds", FieldValue::Float(agg.avg_seconds)),
                                        ("cut", FieldValue::Int(agg.best_cut)),
                                    ],
                                );
                            }
                            let lease = service.ctx().workspace().stats();
                            println!(
                                "{}",
                                render_result_line_full(
                                    &spec.id,
                                    &agg,
                                    timing,
                                    false,
                                    Some((lease.leases_created, lease.peak_lease_bytes)),
                                )
                            );
                        }
                        Some(message) => {
                            failed += 1;
                            if let Some(journal) = &journal {
                                journal.record(
                                    "error",
                                    &[
                                        ("id", FieldValue::Str(&spec.id)),
                                        ("message", FieldValue::Str(&message)),
                                    ],
                                );
                            }
                            println!("{}", render_error_line(&spec.id, &message));
                        }
                    }
                }
                Err(e) => {
                    failed += 1;
                    // Cancellation (a `timeout_ms=` deadline firing) is
                    // a structured outcome with its own status line.
                    match e.cancelled {
                        Some(reason) => {
                            if let Some(journal) = &journal {
                                journal.record(
                                    "cancelled",
                                    &[
                                        ("id", FieldValue::Str(&e.id)),
                                        ("reason", FieldValue::Str(reason.as_str())),
                                    ],
                                );
                            }
                            println!("{}", render_cancelled_line(&e.id, reason));
                        }
                        None => {
                            if let Some(journal) = &journal {
                                journal.record(
                                    "error",
                                    &[
                                        ("id", FieldValue::Str(&e.id)),
                                        ("message", FieldValue::Str(&e.message)),
                                    ],
                                );
                            }
                            println!("{}", render_error_line(&e.id, &e.message));
                        }
                    }
                }
            },
        }
    }
    service.shutdown();
    if let Some(journal) = &journal {
        journal.record("shutdown", &[]);
        journal.flush();
    }
    // Shutdown drained every accepted request, so all span buffers have
    // flushed — the trace is complete.
    write_trace(trace)?;
    eprintln!("served {total} request(s), {failed} failed");
    Ok(())
}

/// `client`: submit request lines to a `serve --listen` server and
/// stream its JSON result lines to stdout (in completion order —
/// responses carry ids). A sender thread pipelines the input while
/// this thread drains responses; every line is validated structurally
/// ([`parse_response`]) before being relayed, and a mismatch between
/// lines sent and responses received is an error.
///
/// An **explicit** `--timeout SECS` is an end-to-end deadline: it
/// bounds the connect retry AND is attached to every request line as
/// `timeout_ms=` (lines already carrying one keep theirs), so the
/// server cancels work that outlives it and answers
/// `{"status":"cancelled","reason":"timeout"}`. Without the flag the
/// default (10s) bounds only the connect retry — established
/// connections wait as long as the partitions take.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("connect").context("need --connect ADDR")?;
    let timeout = args.get_f64("timeout", 10.0)?;
    let explicit_timeout = args.get("timeout").is_some();
    let quiet = args.flag("quiet");
    if args.flag("stats") {
        if args.get("requests").is_some() {
            bail!("--stats fetches the ops snapshot; it does not take --requests");
        }
        return cmd_client_stats(addr, timeout, quiet);
    }
    let requests_path = args.get_or("requests", "-");
    let input: Box<dyn BufRead> = if requests_path == "-" {
        Box::new(std::io::BufReader::new(std::io::stdin()))
    } else {
        let file = std::fs::File::open(requests_path)
            .with_context(|| format!("opening {requests_path}"))?;
        Box::new(std::io::BufReader::new(file))
    };
    let lines: Vec<String> = input
        .lines()
        .collect::<std::io::Result<_>>()
        .with_context(|| format!("reading {requests_path}"))?;
    // An explicit --timeout becomes a per-request `timeout_ms=` key on
    // every spec line that does not already carry one (blank lines,
    // comments, and ! control commands pass through untouched). The
    // deadline is armed at server-side submission, so queue wait
    // counts — this is an end-to-end bound, not a transport knob.
    let lines: Vec<String> = if explicit_timeout {
        let ms = ((timeout.max(0.0) * 1000.0).ceil() as u64).max(1);
        lines
            .into_iter()
            .map(|line| {
                let t = line.trim();
                if t.is_empty()
                    || t.starts_with('#')
                    || t.starts_with('!')
                    || t.contains("timeout_ms=")
                {
                    line
                } else {
                    format!("{line} timeout_ms={ms}")
                }
            })
            .collect()
    } else {
        lines
    };
    // Every non-blank, non-comment line — request spec, malformed
    // garbage, or ! control — elicits exactly one response line.
    let expected = lines
        .iter()
        .filter(|l| {
            let t = l.trim();
            !t.is_empty() && !t.starts_with('#')
        })
        .count();

    let client = NetClient::connect_retry(addr, Duration::from_secs_f64(timeout.max(0.0)))
        .with_context(|| format!("connecting to {addr}"))?;
    let (mut sender, mut receiver) = client.split();
    let sender_thread = std::thread::spawn(move || -> std::result::Result<(), String> {
        for line in &lines {
            sender
                .send_line(line)
                .map_err(|e| format!("sending request: {e}"))?;
        }
        let _ = sender.finish();
        Ok(())
    });

    let mut received = 0usize;
    let mut by_status: std::collections::BTreeMap<String, usize> = Default::default();
    let mut invalid = 0usize;
    while let Some(line) = receiver
        .recv_line()
        .with_context(|| format!("reading from {addr}"))?
    {
        // A `!metrics` reply is a multi-line Prometheus text block
        // framed by `# sclap metrics` … `# EOF`; the whole block
        // counts as ONE response in the sent/received reconciliation.
        if line == "# sclap metrics" {
            println!("{line}");
            let mut terminated = false;
            while let Some(metric_line) = receiver
                .recv_line()
                .with_context(|| format!("reading from {addr}"))?
            {
                println!("{metric_line}");
                if metric_line == "# EOF" {
                    terminated = true;
                    break;
                }
            }
            if !terminated {
                bail!("metrics block cut short (no `# EOF` terminator)");
            }
            *by_status.entry("metrics".to_string()).or_default() += 1;
            received += 1;
            continue;
        }
        match parse_response(&line) {
            Ok(response) => *by_status.entry(response.status).or_default() += 1,
            Err(message) => {
                invalid += 1;
                eprintln!("sclap client: invalid response line: {message}");
            }
        }
        println!("{line}");
        received += 1;
    }
    sender_thread
        .join()
        .map_err(|_| "sender thread panicked".to_string())??;
    if !quiet {
        let summary: Vec<String> = by_status
            .iter()
            .map(|(status, count)| format!("{status}={count}"))
            .collect();
        eprintln!(
            "sclap client: sent {expected} line(s), received {received} response(s) [{}]",
            summary.join(" ")
        );
    }
    if invalid > 0 {
        bail!("{invalid} response line(s) failed structural validation");
    }
    // `!shutdown` drains the server: it may close before unrelated
    // responses exist, but OUR responses are always delivered first —
    // anything short means the transport failed mid-stream.
    if received != expected {
        bail!("expected {expected} response(s), received {received} (connection cut short?)");
    }
    Ok(())
}

/// `client --stats`: the ops-snapshot path. Fetches `!stats` (one
/// JSON line, validated structurally like any response) and
/// `!metrics` (the Prometheus text block framed by `# sclap metrics`
/// / `# EOF`), prints both to stdout, and exits — the same
/// sent/received reconciliation the request path has, applied to the
/// two control commands.
fn cmd_client_stats(addr: &str, timeout: f64, quiet: bool) -> Result<()> {
    let mut client = NetClient::connect_retry(addr, Duration::from_secs_f64(timeout.max(0.0)))
        .with_context(|| format!("connecting to {addr}"))?;
    let stats_line = client
        .request("!stats")
        .with_context(|| format!("fetching !stats from {addr}"))?;
    let stats = parse_response(&stats_line).map_err(|e| format!("invalid !stats response: {e}"))?;
    if stats.status != "stats" {
        bail!("expected a stats response, got status {:?}", stats.status);
    }
    println!("{stats_line}");
    client
        .send_line("!metrics")
        .with_context(|| format!("sending !metrics to {addr}"))?;
    let first = client
        .recv_line()
        .with_context(|| format!("reading from {addr}"))?
        .context("connection closed before the metrics block")?;
    if first != "# sclap metrics" {
        bail!("expected a `# sclap metrics` block, got {first:?}");
    }
    println!("{first}");
    let mut metric_lines = 0usize;
    loop {
        let line = client
            .recv_line()
            .with_context(|| format!("reading from {addr}"))?
            .context("metrics block cut short (no `# EOF` terminator)")?;
        println!("{line}");
        if line == "# EOF" {
            break;
        }
        metric_lines += 1;
    }
    if !quiet {
        eprintln!("sclap client: fetched !stats and !metrics ({metric_lines} metric line(s))");
    }
    Ok(())
}

/// `["a","b"]` with JSON escaping — the `report` document's string
/// arrays.
fn json_str_array(items: &[String]) -> String {
    let quoted: Vec<String> = items
        .iter()
        .map(|s| format!("\"{}\"", sclap::util::json::escape_json(s)))
        .collect();
    format!("[{}]", quoted.join(","))
}

/// `report`: the paper-style result tables, produced through the
/// **service path** — every cell of the preset × instance matrix is a
/// real request (spec line → parse → materialize → bounded queue →
/// scheduler), so the numbers measure exactly the code the wire
/// serves. Emits one JSON document
/// (`{k, reps, seed, presets, instances, cells, geomeans}`) on stdout
/// (or `--out FILE`) for `scripts/make_tables.py` to format against
/// the paper's reported numbers, plus a human geomean table on
/// stderr. Cut fields are deterministic (same seed ⇒ same table);
/// the seconds fields are wall-clock. Defaults form the quick CI
/// matrix: the tiny suite × CFast/CEco/UFast at k=4 with 3 reps.
fn cmd_report(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 4)?;
    if k < 2 {
        bail!("--k must be at least 2");
    }
    let reps = args.get_usize("reps", 3)?.max(1);
    let seed = args.get_u64("seed", 1)?;
    let workers = args.get_usize("workers", 0)?;
    let presets: Vec<String> = args
        .get_or("presets", "CFast,CEco,UFast")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if presets.is_empty() {
        bail!("--presets needs at least one preset name");
    }
    for p in &presets {
        Preset::from_name(p)
            .with_context(|| format!("unknown preset {p:?} (see `sclap presets`)"))?;
    }
    let instances: Vec<String> = args
        .get_or("instances", "karate,tiny-rmat,tiny-ba,tiny-ws,tiny-grid")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    if instances.is_empty() {
        bail!("--instances needs at least one instance name");
    }
    for name in &instances {
        generators::instances::by_name(name)
            .with_context(|| format!("unknown instance {name:?} (see DESIGN.md §3)"))?;
    }
    let seeds: Vec<String> = default_seeds(reps)
        .iter()
        .map(|s| (s + seed - 1).to_string())
        .collect();
    let seeds_str = seeds.join(",");

    // Submit the whole matrix up front (the queue is sized to hold
    // it), then wait in matrix order: the scheduler interleaves
    // repetitions from all cells across one worker pool — the batching
    // behavior the service path exists for.
    let cells_total = presets.len() * instances.len();
    let service = BatchService::new(ServiceConfig {
        workers,
        max_pending: cells_total,
    });
    let catalog = sclap::coordinator::net::GraphCatalog::new();
    let mut tickets = Vec::with_capacity(cells_total);
    for preset in &presets {
        for instance in &instances {
            let line = format!(
                "id={preset}/{instance} instance={instance} k={k} preset={preset} seeds={seeds_str}"
            );
            let spec = parse_request_line(&line, "report")
                .map_err(|e| format!("building cell {preset}/{instance}: {e}"))?
                .expect("a non-empty spec line");
            let request = catalog
                .materialize(&spec)
                .map_err(|e| format!("loading {instance}: {e}"))?;
            let ticket = service
                .submit(request)
                .map_err(|e| format!("submitting {preset}/{instance}: {e}"))?;
            tickets.push((preset.clone(), instance.clone(), ticket));
        }
    }

    struct Cell {
        preset: String,
        instance: String,
        avg_cut: f64,
        best_cut: i64,
        seconds: f64,
        infeasible: usize,
        reps: usize,
    }
    let mut cells: Vec<Cell> = Vec::with_capacity(cells_total);
    for (preset, instance, ticket) in tickets {
        let agg = ticket
            .wait()
            .map_err(|e| format!("cell {preset}/{instance}: {}", e.message))?;
        cells.push(Cell {
            preset,
            instance,
            avg_cut: agg.avg_cut,
            best_cut: agg.best_cut,
            seconds: agg.avg_seconds,
            infeasible: agg.infeasible_runs,
            reps: agg.runs.len(),
        });
    }
    service.shutdown();

    // Per-preset cross-instance geomeans — the paper's aggregation,
    // with zero cells excluded-and-counted (never epsilon-clamped).
    let geomeans: Vec<(String, sclap::bench::harness::GeomeanRow)> = presets
        .iter()
        .map(|preset| {
            let row: Vec<(f64, f64, f64)> = cells
                .iter()
                .filter(|c| &c.preset == preset)
                .map(|c| (c.avg_cut, c.best_cut as f64, c.seconds))
                .collect();
            (preset.clone(), geomean_row(&row))
        })
        .collect();

    eprintln!(
        "report: geomeans over {} instance(s), k={k}, {reps} rep(s) ('*N' = N zero cells excluded):",
        instances.len()
    );
    eprintln!(
        "{:>14}  {:>10}  {:>10}  {:>10}",
        "preset", "avg cut", "best cut", "seconds"
    );
    for (preset, g) in &geomeans {
        eprintln!(
            "{preset:>14}  {:>10}  {:>10}  {:>10}",
            format!("{}{}", fmt_num(g.avg_cut), g.zero_marker()),
            format!("{}{}", fmt_num(g.best_cut), g.zero_marker()),
            format!("{:.3}{}", g.seconds, g.time_marker()),
        );
    }

    let cell_objs: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "{{\"preset\":\"{}\",\"instance\":\"{}\",\"avg_cut\":{:.6},\"best_cut\":{},\"seconds\":{:.6},\"infeasible\":{},\"reps\":{}}}",
                sclap::util::json::escape_json(&c.preset),
                sclap::util::json::escape_json(&c.instance),
                c.avg_cut,
                c.best_cut,
                c.seconds,
                c.infeasible,
                c.reps,
            )
        })
        .collect();
    let geo_objs: Vec<String> = geomeans
        .iter()
        .map(|(preset, g)| {
            format!(
                "{{\"preset\":\"{}\",\"avg_cut\":{:.6},\"best_cut\":{:.6},\"seconds\":{:.6},\"zero_cut_cells\":{},\"zero_time_cells\":{}}}",
                sclap::util::json::escape_json(preset),
                g.avg_cut,
                g.best_cut,
                g.seconds,
                g.zero_cut_cells,
                g.zero_time_cells,
            )
        })
        .collect();
    let doc = format!(
        "{{\"k\":{k},\"reps\":{reps},\"seed\":{seed},\"presets\":{},\"instances\":{},\"cells\":[{}],\"geomeans\":[{}]}}",
        json_str_array(&presets),
        json_str_array(&instances),
        cell_objs.join(","),
        geo_objs.join(","),
    );
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, format!("{doc}\n")).with_context(|| format!("writing {path}"))?;
            eprintln!("wrote report to {path}");
        }
        None => println!("{doc}"),
    }
    Ok(())
}

/// Parse `--format` (default: v2, the compressed format — the CLI
/// writes the better format unless asked otherwise; the library
/// default stays v1 for back-compat).
fn parse_shard_format(args: &Args) -> Result<ShardFormat> {
    match args.get("format") {
        None => Ok(ShardFormat::V2),
        Some(s) => ShardFormat::parse(s)
            .ok_or_else(|| format!("unknown shard format {s:?} (expected v1 or v2)").into()),
    }
}

/// `shard`: convert a graph to an on-disk shard directory. METIS inputs
/// stream through `convert_metis_to_shards_as` (bounded memory — never
/// the whole graph); other formats load and re-shard. The `recompress`
/// verb rewrites an existing directory (format and/or shard count)
/// streaming one shard at a time.
fn cmd_shard(args: &Args) -> Result<()> {
    if args.positional.first().map(String::as_str) == Some("recompress") {
        return cmd_shard_recompress(args);
    }
    let out = args.get("out").context("need --out DIR")?;
    let shards = args.get_usize("shards", 4)?;
    if shards == 0 {
        bail!("--shards must be at least 1");
    }
    let format = parse_shard_format(args)?;
    let store = if let Some(path) = args.get("graph") {
        let p = Path::new(path);
        let ext = p.extension().and_then(|e| e.to_str()).unwrap_or("");
        match ext {
            "bin" | "el" | "edges" | "txt" => {
                let g = sclap::graph::io::load_path(p)
                    .with_context(|| format!("loading {path}"))?;
                write_sharded_as(&g, Path::new(out), shards, format)?
            }
            // METIS and anything else METIS-shaped: streaming.
            _ => {
                let file = std::fs::File::open(p).with_context(|| format!("opening {path}"))?;
                convert_metis_to_shards_as(
                    std::io::BufReader::new(file),
                    Path::new(out),
                    shards,
                    format,
                )
                .with_context(|| format!("converting {path}"))?
            }
        }
    } else if args.get("instance").is_some() {
        let g = load_graph(args)?;
        write_sharded_as(&g, Path::new(out), shards, format)?
    } else {
        bail!("need --graph FILE or --instance NAME");
    };
    println!(
        "wrote {} {} shard(s), n={} m={} ({} bytes on disk) to {out}",
        store.num_shards(),
        store.format().name(),
        store.n(),
        store.m(),
        store.disk_bytes().unwrap_or(0),
    );
    Ok(())
}

/// `shard recompress --in DIR --out DIR [--shards S] [--format v1|v2]`.
fn cmd_shard_recompress(args: &Args) -> Result<()> {
    let src = args.get("in").context("need --in DIR (source shard directory)")?;
    let out = args.get("out").context("need --out DIR")?;
    let shards = if args.get("shards").is_some() {
        let s = args.get_usize("shards", 0)?;
        if s == 0 {
            bail!("--shards must be at least 1");
        }
        Some(s)
    } else {
        None
    };
    let format = parse_shard_format(args)?;
    let store = recompress_store(Path::new(src), Path::new(out), shards, format)
        .with_context(|| format!("recompressing {src}"))?;
    println!(
        "recompressed {src} -> {out}: {} {} shard(s), n={} m={} ({} bytes on disk)",
        store.num_shards(),
        store.format().name(),
        store.n(),
        store.m(),
        store.disk_bytes().unwrap_or(0),
    );
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let kind = args.get_or("kind", "rmat");
    let seed = args.get_u64("seed", 1)?;
    let mut rng = Rng::new(seed);
    let graph = match kind {
        "rmat" => {
            let scale = args.get_usize("scale", 16)? as u32;
            let m = args.get_usize("edges", 1 << (scale + 3))?;
            generators::rmat(scale, m, 0.57, 0.19, 0.19, &mut rng)
        }
        "ba" => {
            let n = args.get_usize("n", 100_000)?;
            let attach = args.get_usize("attach", 4)?;
            generators::barabasi_albert(n, attach, &mut rng)
        }
        "ws" => {
            let n = args.get_usize("n", 100_000)?;
            let k = args.get_usize("ring", 4)?;
            let beta = args.get_f64("beta", 0.1)?;
            generators::watts_strogatz(n, k, beta, &mut rng)
        }
        "er" => {
            let n = args.get_usize("n", 100_000)?;
            let m = args.get_usize("edges", 4 * n)?;
            generators::erdos_renyi(n, m, &mut rng)
        }
        "grid" => {
            let rows = args.get_usize("rows", 300)?;
            let cols = args.get_usize("cols", 300)?;
            generators::grid2d(rows, cols)
        }
        "lfr" => {
            // Community-structured scale-free — the stand-in for the
            // paper's web/social crawls; what the CI out-of-core smoke
            // partitions.
            let n = args.get_usize("n", 50_000)?;
            let avg_degree = args.get_f64("avg-degree", 8.0)?;
            let mu = args.get_f64("mu", 0.2)?;
            generators::lfr::lfr_like(n, avg_degree, mu, &mut rng).0
        }
        other => bail!("unknown generator kind {other:?}"),
    };
    let out = args.get("out").context("need --out FILE")?;
    sclap::graph::io::save_path(&graph, Path::new(out))?;
    println!("wrote n={} m={} to {out}", graph.n(), graph.m());
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let graph = load_graph(args)?;
    let part_path = args.get("partition").context("need --partition FILE")?;
    let file = std::fs::File::open(part_path).with_context(|| format!("opening {part_path}"))?;
    let p = sclap::partitioning::partition::read_partition(
        &graph,
        std::io::BufReader::new(file),
        None,
    )?;
    let epsilon = args.get_f64("epsilon", 0.03)?;
    let m = sclap::partitioning::metrics::evaluate(&graph, &p, epsilon);
    println!("k             : {}", m.k);
    println!("cut           : {}", m.cut);
    println!("imbalance     : {:.4}", m.imbalance);
    println!("feasible(ε={epsilon}): {}", m.feasible);
    println!("boundary nodes: {}", m.boundary_nodes);
    println!("block weights : min {} max {}", m.min_block_weight, m.max_block_weight);
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let graph = load_graph(args)?;
    let mut rng = Rng::new(42);
    let s = sclap::graph::stats::compute_stats(&graph, &mut rng);
    println!("n                : {}", s.n);
    println!("m                : {}", s.m);
    println!("degree (min/avg/max): {}/{:.2}/{}", s.min_degree, s.avg_degree, s.max_degree);
    println!("components       : {}", s.components);
    println!("degree gini      : {:.3}", s.degree_gini);
    println!("approx diameter  : {}", s.approx_diameter);
    println!("clustering coeff : {:.3}", s.clustering_coeff);
    Ok(())
}

fn cmd_offload(args: &Args) -> Result<()> {
    let graph = load_graph(args)?;
    let mut runtime = sclap::runtime::pjrt::Runtime::from_env()
        .context("PJRT runtime (vendor the `xla` crate, enable the `pjrt` feature per Cargo.toml, then run `make artifacts`)")?;
    println!("runtime: {runtime:?}");
    let upper = args.get_u64("upper", (graph.total_node_weight() as u64 / 8).max(2))? as i64;
    let rounds = args.get_usize("rounds", 10)?;
    let result = sclap::runtime::dense_lpa::offload_sclap(&graph, upper, rounds, &mut runtime)?;
    match result {
        None => bail!(
            "graph too large for the available artifacts (n={} > max {})",
            graph.n(),
            runtime.max_n()
        ),
        Some((clustering, stats)) => {
            println!(
                "offloaded clustering: {} clusters, cut {}, bound {} respected: {}",
                clustering.num_clusters,
                clustering.cut(&graph),
                upper,
                clustering.respects_bound(upper)
            );
            println!(
                "rounds={} proposals={} applied={} artifact=N{}",
                stats.rounds, stats.proposals, stats.applied, stats.artifact_n
            );
        }
    }
    Ok(())
}

fn cmd_presets() -> Result<()> {
    println!("available presets (paper §5.1 + baselines):");
    for p in Preset::ALL {
        let c = PartitionConfig::preset(p, 8);
        println!(
            "  {:<14} scheme={:?} initial={:?} refinement={:?} V={} B={} E={} A={}",
            p.name(),
            c.scheme,
            c.initial,
            c.refinement,
            c.vcycles,
            c.coarse_imbalance > 0.0,
            c.ensemble,
            c.active_nodes_coarsening,
        );
    }
    Ok(())
}
