//! sclap — the command-line front end of the partitioning system.
//!
//! Subcommands:
//!   partition  — partition a graph (file or named instance)
//!   generate   — write a synthetic instance to a file
//!   stats      — print instance statistics (Table-1 style)
//!   offload    — demo the PJRT dense-LPA offload on a small graph
//!   presets    — list the available configuration presets
//!
//! Examples:
//!   sclap partition --instance tiny-rmat --k 8 --preset UFast --reps 10
//!   sclap partition --graph my.graph --k 16 --preset UStrong --output part.txt
//!   sclap generate --kind rmat --scale 18 --edges 2000000 --out web.bin
//!   sclap stats --instance uk2002-sim

use sclap::bail;
use sclap::coordinator::cli::Args;
use sclap::coordinator::service::{default_seeds, Coordinator};
use sclap::generators;
use sclap::graph::csr::Graph;
use sclap::partitioning::config::{PartitionConfig, Preset};
use sclap::util::error::{Context, Result};
use sclap::util::rng::Rng;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "partition" => cmd_partition(args),
        "evaluate" => cmd_evaluate(args),
        "generate" => cmd_generate(args),
        "stats" => cmd_stats(args),
        "offload" => cmd_offload(args),
        "presets" => cmd_presets(),
        "" | "help" | "--help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `sclap help`)"),
    }
}

fn print_usage() {
    println!(
        "sclap — size-constrained label-propagation graph partitioning\n\
         \n\
         USAGE: sclap <command> [--options]\n\
         \n\
         COMMANDS:\n\
           partition --graph FILE | --instance NAME  --k K [--preset P]\n\
                     [--reps N] [--seed S] [--workers W] [--threads T]\n\
                     [--epsilon E] [--output FILE]\n\
                     [--parallel-coarsening] [--parallel-refinement]\n\
           generate  --kind rmat|ba|ws|er|grid --out FILE [--scale S]\n\
                     [--n N] [--edges M] [--seed S]\n\
           evaluate  --graph FILE | --instance NAME --partition FILE\n\
                     [--epsilon E]\n\
           stats     --graph FILE | --instance NAME\n\
           offload   --instance NAME [--upper U] [--rounds R]\n\
           presets\n\
         \n\
         --workers W: the one process pool (0 = all cores). Repetitions\n\
           fan out across it and every phase inside a repetition shares\n\
           it (ExecutionCtx handoff), so W caps total worker threads.\n\
         --threads T: caps the shared pool when --workers is absent\n\
           (0 = auto, 1 = fully sequential; also via SCLAP_THREADS).\n\
           Results are byte-identical for every T and W — same seed,\n\
           same partition.\n\
         --parallel-coarsening: coloring-based parallel asynchronous\n\
           LPA for coarsening (arXiv 1404.4797 engine).\n\
         --parallel-refinement: synchronous-round pool engine for the\n\
           SCLaP refinement stage.\n"
    );
}

fn load_graph(args: &Args) -> Result<Graph> {
    if let Some(name) = args.get("instance") {
        let spec = generators::instances::by_name(name)
            .with_context(|| format!("unknown instance {name:?} (see DESIGN.md §3)"))?;
        return Ok(spec.build());
    }
    if let Some(path) = args.get("graph") {
        return sclap::graph::io::load_path(Path::new(path))
            .with_context(|| format!("loading {path}"));
    }
    bail!("need --graph FILE or --instance NAME");
}

fn cmd_partition(args: &Args) -> Result<()> {
    let graph = Arc::new(load_graph(args)?);
    let k = args.get_usize("k", 2)?;
    let preset_name = args.get_or("preset", "UFast");
    let preset = Preset::from_name(preset_name)
        .with_context(|| format!("unknown preset {preset_name:?} (see `sclap presets`)"))?;
    let mut config = PartitionConfig::preset(preset, k);
    config.epsilon = args.get_f64("epsilon", 0.03)?;
    if let Some(l) = args.get("lpa-iterations") {
        config.lpa_iterations = l.parse().context("--lpa-iterations")?;
    }
    config.threads = args.get_usize("threads", config.threads)?;
    config.parallel_coarsening |= args.flag("parallel-coarsening");
    config.parallel_refinement |= args.flag("parallel-refinement");
    let reps = args.get_usize("reps", 1)?;
    let seed = args.get_u64("seed", 1)?;
    let workers = args.get_usize("workers", 0)?;

    println!(
        "partitioning n={} m={} into k={k} with {} (ε={}, {reps} reps)",
        graph.n(),
        graph.m(),
        preset.name(),
        config.epsilon
    );
    // Size the one process pool: explicit --workers wins; otherwise an
    // explicit --threads / SCLAP_THREADS caps it (so `--threads 1` still
    // means a fully sequential run, as before the ExecutionCtx refactor);
    // else auto. Every phase of every repetition shares this pool.
    let pool_threads = if workers != 0 { workers } else { config.threads };
    let coordinator = Coordinator::new(pool_threads);
    let seeds: Vec<u64> = default_seeds(reps).iter().map(|s| s + seed - 1).collect();
    let agg = coordinator.partition_repeated(graph.clone(), &config, &seeds);

    println!("avg cut    : {:.1}", agg.avg_cut);
    println!("best cut   : {}", agg.best_cut);
    println!("avg time   : {:.3}s", agg.avg_seconds);
    println!("infeasible : {}/{}", agg.infeasible_runs, reps);
    let best = &agg.runs[agg
        .runs
        .iter()
        .enumerate()
        .min_by_key(|(_, r)| r.cut)
        .map(|(i, _)| i)
        .unwrap()];
    println!(
        "hierarchy  : {} levels, coarsest n={}, initial cut={}",
        best.levels, best.coarsest_n, best.initial_cut
    );

    if let Some(out) = args.get("output") {
        let mut text = String::new();
        for b in &agg.best_blocks {
            text.push_str(&b.to_string());
            text.push('\n');
        }
        std::fs::write(out, text).with_context(|| format!("writing {out}"))?;
        println!("wrote best partition to {out}");
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let kind = args.get_or("kind", "rmat");
    let seed = args.get_u64("seed", 1)?;
    let mut rng = Rng::new(seed);
    let graph = match kind {
        "rmat" => {
            let scale = args.get_usize("scale", 16)? as u32;
            let m = args.get_usize("edges", 1 << (scale + 3))?;
            generators::rmat(scale, m, 0.57, 0.19, 0.19, &mut rng)
        }
        "ba" => {
            let n = args.get_usize("n", 100_000)?;
            let attach = args.get_usize("attach", 4)?;
            generators::barabasi_albert(n, attach, &mut rng)
        }
        "ws" => {
            let n = args.get_usize("n", 100_000)?;
            let k = args.get_usize("ring", 4)?;
            let beta = args.get_f64("beta", 0.1)?;
            generators::watts_strogatz(n, k, beta, &mut rng)
        }
        "er" => {
            let n = args.get_usize("n", 100_000)?;
            let m = args.get_usize("edges", 4 * n)?;
            generators::erdos_renyi(n, m, &mut rng)
        }
        "grid" => {
            let rows = args.get_usize("rows", 300)?;
            let cols = args.get_usize("cols", 300)?;
            generators::grid2d(rows, cols)
        }
        other => bail!("unknown generator kind {other:?}"),
    };
    let out = args.get("out").context("need --out FILE")?;
    sclap::graph::io::save_path(&graph, Path::new(out))?;
    println!("wrote n={} m={} to {out}", graph.n(), graph.m());
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let graph = load_graph(args)?;
    let part_path = args.get("partition").context("need --partition FILE")?;
    let file = std::fs::File::open(part_path).with_context(|| format!("opening {part_path}"))?;
    let p = sclap::partitioning::partition::read_partition(
        &graph,
        std::io::BufReader::new(file),
        None,
    )?;
    let epsilon = args.get_f64("epsilon", 0.03)?;
    let m = sclap::partitioning::metrics::evaluate(&graph, &p, epsilon);
    println!("k             : {}", m.k);
    println!("cut           : {}", m.cut);
    println!("imbalance     : {:.4}", m.imbalance);
    println!("feasible(ε={epsilon}): {}", m.feasible);
    println!("boundary nodes: {}", m.boundary_nodes);
    println!("block weights : min {} max {}", m.min_block_weight, m.max_block_weight);
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let graph = load_graph(args)?;
    let mut rng = Rng::new(42);
    let s = sclap::graph::stats::compute_stats(&graph, &mut rng);
    println!("n                : {}", s.n);
    println!("m                : {}", s.m);
    println!("degree (min/avg/max): {}/{:.2}/{}", s.min_degree, s.avg_degree, s.max_degree);
    println!("components       : {}", s.components);
    println!("degree gini      : {:.3}", s.degree_gini);
    println!("approx diameter  : {}", s.approx_diameter);
    println!("clustering coeff : {:.3}", s.clustering_coeff);
    Ok(())
}

fn cmd_offload(args: &Args) -> Result<()> {
    let graph = load_graph(args)?;
    let mut runtime = sclap::runtime::pjrt::Runtime::from_env()
        .context("PJRT runtime (vendor the `xla` crate, enable the `pjrt` feature per Cargo.toml, then run `make artifacts`)")?;
    println!("runtime: {runtime:?}");
    let upper = args.get_u64("upper", (graph.total_node_weight() as u64 / 8).max(2))? as i64;
    let rounds = args.get_usize("rounds", 10)?;
    let result = sclap::runtime::dense_lpa::offload_sclap(&graph, upper, rounds, &mut runtime)?;
    match result {
        None => bail!(
            "graph too large for the available artifacts (n={} > max {})",
            graph.n(),
            runtime.max_n()
        ),
        Some((clustering, stats)) => {
            println!(
                "offloaded clustering: {} clusters, cut {}, bound {} respected: {}",
                clustering.num_clusters,
                clustering.cut(&graph),
                upper,
                clustering.respects_bound(upper)
            );
            println!(
                "rounds={} proposals={} applied={} artifact=N{}",
                stats.rounds, stats.proposals, stats.applied, stats.artifact_n
            );
        }
    }
    Ok(())
}

fn cmd_presets() -> Result<()> {
    println!("available presets (paper §5.1 + baselines):");
    for p in Preset::ALL {
        let c = PartitionConfig::preset(p, 8);
        println!(
            "  {:<14} scheme={:?} initial={:?} refinement={:?} V={} B={} E={} A={}",
            p.name(),
            c.scheme,
            c.initial,
            c.refinement,
            c.vcycles,
            c.coarse_imbalance > 0.0,
            c.ensemble,
            c.active_nodes_coarsening,
        );
    }
    Ok(())
}
