//! Network service layer: a zero-dependency TCP front end
//! (`std::net::TcpListener` + threads) over the batching queue
//! ([`crate::coordinator::queue`]), with a content-addressed partition
//! cache ([`cache`]) in front of the scheduler.
//!
//! Many machines, many clients, one deterministic service: `sclap
//! serve --listen ADDR` stands up a [`NetServer`]; `sclap client
//! --connect ADDR` (or a [`NetClient`], or `nc`) submits request lines
//! and streams result lines back. Every connection feeds the same
//! bounded queue, the same shared worker pool, and the same result
//! cache — repeated requests, the defining trait of heavy traffic,
//! cost zero recomputation.
//!
//! # Wire protocol
//!
//! **Framing** — UTF-8 lines, `\n`-terminated, both directions. No
//! binary headers, no length prefixes: the protocol is `nc`-debuggable
//! by design.
//!
//! **Requests** — each client line is one of:
//!
//! - a *request spec* in the [`queue::spec`](crate::coordinator::queue::spec)
//!   grammar — whitespace-separated `key=value` tokens, exactly the
//!   lines `sclap serve` reads from stdin:
//!   `id=r1 graph=/data/web.graph k=8 preset=CFast seeds=1,2,3`
//!   (`instance=NAME` and `shards=DIR` select the other topology
//!   sources; any [`CONFIG_OPTION_KEYS`](crate::partitioning::config::CONFIG_OPTION_KEYS)
//!   key rides along; `output=PATH` writes the best partition
//!   server-side). The `id` is echoed in the response — clients that
//!   pipeline pick their own unique ids; lines without `id=` get a
//!   per-connection default `c<conn>-req<line>`. Two keys drive the
//!   cancellation layer: `timeout_ms=MS` arms an end-to-end deadline
//!   at submission (queue wait counts; overdue work is cancelled
//!   cooperatively and answers `status=cancelled`), and
//!   `race=P1,P2,…` (two or more preset names) runs the request's
//!   first seed under every named preset as one scheduler wave —
//!   lowest cut wins, ties break on list order, the winner's config
//!   finishes the remaining seeds, and the losing repetitions are
//!   cancelled. The winning aggregate is byte-identical to running
//!   the winner's preset alone. A third key, `explain=true`, asks for
//!   a quality-explainability report: the response gains a trailing
//!   `"explain":{"reps":[…]}` object narrating every repetition's
//!   V-cycles — per-level hierarchy shrink, coarsening/refinement
//!   round counts, FM pass trajectories, per-level cut and imbalance
//!   (schema in [`crate::obs::quality`]). The report is assembled
//!   from the deterministic trace stream, so it is **byte-identical
//!   for any worker count or storage backend** and observation-only:
//!   every field before it matches the unexplained response byte for
//!   byte (`rust/tests/observability.rs`).
//! - a *blank line or `#` comment* — skipped, exactly as on stdin.
//! - a *control command* starting with `!`:
//!   - `!ping` → `{"status":"pong","version":"…","uptime_seconds":…}`
//!     (liveness, the crate version, and seconds since the service
//!     registry was created — the same clock `!stats` reports),
//!   - `!stats` → one JSON line introspecting the live server:
//!     `{"status":"stats","uptime_seconds":…,"connection":N,
//!     "connection_requests":N,"counters":{…},"gauges":{…},
//!     "histograms":{…},"phases":[…]}` — the whole
//!     [`MetricsRegistry`](crate::obs::metrics::MetricsRegistry) of the
//!     service context (cache hits/misses/joins, queue depth and busy
//!     rejections, scheduler waves, arena lease gauges, per-phase
//!     wall-clock), rendered in sorted name order. `connection` /
//!     `connection_requests` identify the asking connection and count
//!     its submitted request lines (control commands excluded).
//!     Histograms render as `{"count":…,"sum":…,"p50":…,"p99":…,
//!     "buckets":[[i,c],…]}` — quantiles are bucket upper bounds
//!     ([`Histogram::quantile`](crate::obs::metrics::Histogram::quantile))
//!     and `buckets` lists the populated log₂ bins
//!     (`obs::metrics::bucket_index`) in index order,
//!   - `!metrics` → the same registry in Prometheus text format,
//!     framed for the line-oriented wire: a `# sclap metrics`
//!     sentinel line opens the block, `# TYPE`/sample lines follow
//!     (counters as `sclap_<name>_total`, histograms with cumulative
//!     `_bucket{le="…"}` series, phase wall-clock as
//!     `sclap_phase_*_total{phase="…",level="…"}` with escaped label
//!     values), and `# EOF` closes it. `scripts/prom_validate.py`
//!     checks the rendering in CI `obs-smoke`,
//!   - `!shutdown` → `{"status":"shutdown"}`, then graceful
//!     drain-then-close of the whole server (below).
//!
//! **Responses** — one JSON object per line, **in completion order,
//! not request order** (responses are pipelined; match them to
//! requests by `id`):
//!
//! - success: the same deterministic rendering as offline `serve`
//!   (`{"id":…,"status":"ok","n":…,"reps":…,"seeds":[…],"cuts":[…],
//!   "avg_cut":…,"best_cut":…,"infeasible_runs":…,
//!   "best_blocks_fnv":"…"}`), plus a trailing `"cached":true` iff the
//!   aggregate came from the result cache. Timing fields appear only
//!   when the server runs with `--timing` (they are the one
//!   nondeterministic rendering).
//! - failure: `{"id":…,"status":"error","error":"…"}` — parse errors,
//!   unknown instances, unopenable shard directories, and failed
//!   repetitions all answer this way; one bad request never affects
//!   the connection or other requests.
//! - backpressure: `{"id":…,"status":"busy"}` when the bounded queue
//!   is at `max_pending` — the server maps `try_submit → Busy` into a
//!   structured refusal instead of blocking the connection; clients
//!   resubmit when ready. (Stdin `serve` blocks instead: a file is
//!   happy to wait, a remote client should decide for itself.)
//! - cancellation: `{"id":…,"status":"cancelled","reason":"…"}` when
//!   the request's cancel token fired before it completed. Reasons:
//!   `timeout` (its `timeout_ms=` deadline passed), `disconnect`
//!   (the client vanished — see below), `race_lost` (an ensemble
//!   race picked another config), `abandoned` (the submitter dropped
//!   the ticket without waiting). A cancelled request frees its
//!   queue slot and arena leases; nothing about it is ever cached,
//!   and every other request's bytes are untouched.
//!
//! **Disconnect-abort** — a vanished client (a failed response write,
//! or a mid-line read *error*; EOF and half-close are normal ends)
//! fires every in-flight request token of that connection with
//! `disconnect`: workers abandon the doomed computations at their
//! next checkpoint instead of finishing results nobody will read.
//! The server stays healthy — subsequent requests from other
//! connections compute byte-identical results (CI `net-smoke`
//! exercises exactly this).
//!
//! **Shutdown** — on `!shutdown` (or [`NetServerHandle::shutdown`])
//! the server stops accepting connections, EOFs every connection's
//! read half (no new requests), lets every admitted request finish,
//! writes the remaining responses, then closes each connection and
//! returns from [`NetServer::run`]. Clients observe: their pending
//! responses, then EOF.
//!
//! # Ops journal
//!
//! `serve --journal FILE` (listen and stdin modes alike) appends one
//! JSON line per request lifecycle event — admitted / started /
//! completed / cancelled / busy / cache_hit / error, plus a final
//! `shutdown` after the drain — with a monotone `seq` and wall-clock
//! `ts_ms`, size-rotated `FILE` → `FILE.1` (format and rotation in
//! [`crate::obs::journal`]). The journal is the durable complement to
//! `!stats`: `scripts/journal_replay.py` replays it and reconciles
//! the event counts against the live counters in CI `obs-smoke`.
//! Like every observability surface here, it never changes a
//! response byte.
//!
//! # Determinism across the wire
//!
//! A request answered by the server is **bit-identical** to the same
//! request run offline (`sclap serve` from a file, or a
//! [`Coordinator`](crate::coordinator::service::Coordinator) call) —
//! for any client count, any interleaving, any worker count, and any
//! cache state. This holds because every layer below is deterministic
//! (repetitions are pure functions of (graph, config, seed)), the
//! response rendering contains only deterministic fields, and the
//! cache returns the byte-identical [`Aggregate`]. The only observable
//! cache effect is the `"cached":true` marker (`rust/tests/net_service.rs`;
//! CI `net-smoke`). Observability rides along without weakening this:
//! `serve --listen --trace FILE` records structured spans of every
//! repetition and writes a Chrome `trace_event` file at shutdown, and
//! `!stats` snapshots the metrics registry — neither changes a single
//! response byte (`rust/tests/observability.rs`).
//!
//! # Cache key
//!
//! An entry is addressed by content, never by name:
//!
//! - [`store_fingerprints`](crate::graph::store::store_fingerprints)
//!   of the topology — a pair of independent 64-bit hashes over the
//!   logical CSR stream, invariant to storage backend and shard
//!   count, streamed without materialization and memoized per live
//!   graph allocation / per shard directory;
//! - [`config_cache_key`] — every algorithmic [`PartitionConfig`]
//!   field, with the `threads` execution knob deliberately excluded
//!   (thread-count invariance makes it unobservable), plus each
//!   racer's config key when the request carries `race=` (a race is
//!   a different computation; `timeout_ms=` is excluded — deadlines
//!   bound waiting, never results);
//! - the sorted seed list.
//!
//! Hits return the cached aggregate; identical in-flight requests are
//! deduplicated single-flight (N concurrent identical requests, one
//! computation). See [`cache`] for the full model.
//!
//! [`PartitionConfig`]: crate::partitioning::config::PartitionConfig
//! [`Aggregate`]: crate::coordinator::service::Aggregate

pub mod cache;
pub mod client;
pub mod server;

pub use cache::{config_cache_key, CacheStats, CachedService, ServeError};
pub use client::{parse_response, NetClient, Response};
pub use server::{GraphCatalog, NetServer, NetServerConfig, NetServerHandle};
