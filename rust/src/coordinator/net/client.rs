//! The TCP client: line-oriented connection plus structural response
//! validation (via [`parse_json`]) so consumers check shape and
//! fields, never raw strings. Used by `sclap client` and the wire
//! tests.

use crate::util::json::{parse_json, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::time::{Duration, Instant};

/// The write half of a connection (usable from a sender thread after
/// [`NetClient::split`]).
pub struct NetSender {
    stream: TcpStream,
}

impl NetSender {
    /// Send one line (request spec, comment, or `!` control command).
    pub fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.stream.write_all(line.as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()
    }

    /// Half-close the write side: the server sees EOF and closes the
    /// connection once the remaining responses have drained.
    pub fn finish(&mut self) -> std::io::Result<()> {
        self.stream.shutdown(Shutdown::Write)
    }
}

/// The read half of a connection.
pub struct NetReceiver {
    reader: BufReader<TcpStream>,
}

impl NetReceiver {
    /// Receive one response line (`None` on server EOF).
    pub fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            return Ok(None);
        }
        while line.ends_with('\n') || line.ends_with('\r') {
            line.pop();
        }
        Ok(Some(line))
    }
}

/// One line-framed connection to a [`NetServer`](super::NetServer).
pub struct NetClient {
    sender: NetSender,
    receiver: NetReceiver,
}

impl NetClient {
    /// Connect once.
    pub fn connect(addr: &str) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(NetClient {
            sender: NetSender { stream },
            receiver: NetReceiver { reader },
        })
    }

    /// Connect, retrying until `timeout` elapses — for scripts that
    /// race a freshly spawned server (the CI smoke job).
    pub fn connect_retry(addr: &str, timeout: Duration) -> std::io::Result<NetClient> {
        let deadline = Instant::now() + timeout;
        loop {
            match Self::connect(addr) {
                Ok(client) => return Ok(client),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }

    /// Send one line (request spec, comment, or `!` control command).
    pub fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.sender.send_line(line)
    }

    /// Half-close the write side: the server sees EOF and will close
    /// the connection after the remaining responses drain.
    pub fn finish_sending(&mut self) -> std::io::Result<()> {
        self.sender.finish()
    }

    /// Receive one response line (`None` on server EOF).
    pub fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        self.receiver.recv_line()
    }

    /// Split into independent send/receive halves, so a sender thread
    /// can stream requests while this thread drains responses —
    /// full-duplex pipelining without a deadlock risk on large
    /// streams.
    pub fn split(self) -> (NetSender, NetReceiver) {
        (self.sender, self.receiver)
    }

    /// Send one line, then block for the next response line. Only
    /// meaningful when no other responses are outstanding (responses
    /// complete out of order).
    pub fn request(&mut self, line: &str) -> std::io::Result<String> {
        self.send_line(line)?;
        match self.recv_line()? {
            Some(response) => Ok(response),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection before responding",
            )),
        }
    }
}

/// A structurally validated response line.
#[derive(Debug, Clone)]
pub struct Response {
    /// The echoed request id (control responses have none).
    pub id: Option<String>,
    /// `ok`, `error`, `busy`, `cancelled`, `pong`, `stats`, or
    /// `shutdown`.
    pub status: String,
    /// Whether the result came from the content-addressed cache.
    pub cached: bool,
    /// The full parsed object, for field-level assertions.
    pub json: Json,
}

impl Response {
    /// `best_blocks_fnv` of an ok response — the partition fingerprint
    /// the determinism tests compare.
    pub fn blocks_fnv(&self) -> Option<&str> {
        self.json.get("best_blocks_fnv").and_then(Json::as_str)
    }

    /// `best_cut` of an ok response.
    pub fn best_cut(&self) -> Option<i64> {
        self.json.get("best_cut").and_then(Json::as_i64)
    }
}

/// Parse and validate one response line against the wire protocol: it
/// must be a JSON object with a string `status`, and each status's
/// required fields must be present with the right types. This is the
/// structural check `sclap client` runs on every line it relays.
pub fn parse_response(line: &str) -> Result<Response, String> {
    let json = parse_json(line).map_err(|e| format!("bad response JSON: {e}"))?;
    if !matches!(json, Json::Obj(_)) {
        return Err("response is not a JSON object".to_string());
    }
    let status = json
        .get("status")
        .and_then(Json::as_str)
        .ok_or("response missing string \"status\"")?
        .to_string();
    let id = json
        .get("id")
        .and_then(Json::as_str)
        .map(|s| s.to_string());
    let cached = json
        .get("cached")
        .and_then(Json::as_bool)
        .unwrap_or(false);
    match status.as_str() {
        "ok" => {
            if id.is_none() {
                return Err("ok response missing \"id\"".to_string());
            }
            for (field, want_num) in [
                ("n", true),
                ("reps", true),
                ("avg_cut", true),
                ("best_cut", true),
                ("infeasible_runs", true),
                ("best_blocks_fnv", false),
            ] {
                let value = json
                    .get(field)
                    .ok_or_else(|| format!("ok response missing \"{field}\""))?;
                let typed = if want_num {
                    value.as_f64().is_some()
                } else {
                    value.as_str().is_some()
                };
                if !typed {
                    return Err(format!("ok response field \"{field}\" has the wrong type"));
                }
            }
            let reps = json.get("reps").and_then(Json::as_i64).unwrap_or(0);
            for list in ["seeds", "cuts"] {
                let items = json
                    .get(list)
                    .and_then(Json::as_array)
                    .ok_or_else(|| format!("ok response missing array \"{list}\""))?;
                if items.len() as i64 != reps {
                    return Err(format!(
                        "ok response \"{list}\" has {} entries for reps={reps}",
                        items.len()
                    ));
                }
                if items.iter().any(|v| v.as_f64().is_none()) {
                    return Err(format!("ok response \"{list}\" has a non-number entry"));
                }
            }
        }
        "error" => {
            json.get("error")
                .and_then(Json::as_str)
                .ok_or("error response missing string \"error\"")?;
        }
        "busy" => {
            if id.is_none() {
                return Err("busy response missing \"id\"".to_string());
            }
        }
        "cancelled" => {
            if id.is_none() {
                return Err("cancelled response missing \"id\"".to_string());
            }
            json.get("reason")
                .and_then(Json::as_str)
                .ok_or("cancelled response missing string \"reason\"")?;
        }
        "pong" | "shutdown" => {}
        "stats" => {
            // The introspection snapshot: the registry sections must be
            // present (objects/arrays render even when empty).
            for field in ["uptime_seconds", "counters", "gauges", "histograms", "phases"] {
                if json.get(field).is_none() {
                    return Err(format!("stats response missing \"{field}\""));
                }
            }
        }
        other => return Err(format!("unknown response status {other:?}")),
    }
    Ok(Response {
        id,
        status,
        cached,
        json,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_ok_lines() {
        let line = "{\"id\":\"a\",\"status\":\"ok\",\"n\":34,\"reps\":2,\"seeds\":[1,2],\
                    \"cuts\":[10,30],\"avg_cut\":20,\"best_cut\":10,\"infeasible_runs\":0,\
                    \"best_blocks_fnv\":\"32d748215c66e845\"}";
        let r = parse_response(line).unwrap();
        assert_eq!(r.status, "ok");
        assert_eq!(r.id.as_deref(), Some("a"));
        assert!(!r.cached);
        assert_eq!(r.blocks_fnv(), Some("32d748215c66e845"));
        assert_eq!(r.best_cut(), Some(10));
        let cached_line = line.replace("}", ",\"cached\":true}");
        assert!(parse_response(&cached_line).unwrap().cached);
    }

    #[test]
    fn validates_stats_lines() {
        let line = "{\"status\":\"stats\",\"uptime_seconds\":1.234,\"connection\":1,\
                    \"connection_requests\":3,\"counters\":{\"cache_hits\":2},\"gauges\":{},\
                    \"histograms\":{},\"phases\":[]}";
        let r = parse_response(line).unwrap();
        assert_eq!(r.status, "stats");
        assert!(parse_response("{\"status\":\"stats\"}").is_err());
    }

    #[test]
    fn validates_control_error_and_busy_lines() {
        assert_eq!(parse_response("{\"status\":\"pong\"}").unwrap().status, "pong");
        assert_eq!(
            parse_response("{\"status\":\"shutdown\"}").unwrap().status,
            "shutdown"
        );
        let e = parse_response("{\"id\":\"x\",\"status\":\"error\",\"error\":\"boom\"}").unwrap();
        assert_eq!(e.status, "error");
        let b = parse_response("{\"id\":\"x\",\"status\":\"busy\"}").unwrap();
        assert_eq!(b.status, "busy");
        assert_eq!(b.id.as_deref(), Some("x"));
        let c =
            parse_response("{\"id\":\"x\",\"status\":\"cancelled\",\"reason\":\"timeout\"}")
                .unwrap();
        assert_eq!(c.status, "cancelled");
        assert_eq!(c.json.get("reason").and_then(Json::as_str), Some("timeout"));
    }

    #[test]
    fn rejects_malformed_responses() {
        for bad in [
            "not json",
            "[1,2]",
            "{}",
            "{\"status\":\"wat\"}",
            "{\"status\":\"busy\"}",
            "{\"status\":\"cancelled\"}",
            "{\"id\":\"x\",\"status\":\"cancelled\"}",
            "{\"id\":\"x\",\"status\":\"error\"}",
            // ok with a missing field
            "{\"id\":\"a\",\"status\":\"ok\",\"n\":34}",
            // ok with mismatched seed count
            "{\"id\":\"a\",\"status\":\"ok\",\"n\":1,\"reps\":2,\"seeds\":[1],\"cuts\":[1,2],\
             \"avg_cut\":1,\"best_cut\":1,\"infeasible_runs\":0,\"best_blocks_fnv\":\"00\"}",
        ] {
            assert!(parse_response(bad).is_err(), "{bad:?} should fail");
        }
    }
}
