//! The TCP server: accept loop, per-connection reader/writer pairs,
//! and the shared graph catalog. See [`super`] for the wire protocol.

use super::cache::{CacheStats, CachedService, ServeError};
use crate::coordinator::queue::spec::{
    parse_request_line, render_busy_line, render_cancelled_line, render_error_line,
    render_result_line_full, write_partition_file, RequestSource, RequestSpec,
};
use crate::coordinator::queue::{EventHook, GraphHandle, RaceEntry, Request, ServiceConfig};
use crate::graph::csr::Graph;
use crate::obs::journal::{FieldValue, Journal, JournalConfig};
use crate::obs::metrics::RollingWindow;
use crate::obs::trace::Tracer;
use crate::util::cancel::{CancelReason, CancelToken};
use crate::util::exec::ExecutionCtx;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Server knobs (superset of [`ServiceConfig`]).
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Worker threads of the one shared pool (0 = available
    /// parallelism).
    pub workers: usize,
    /// Queue bound; beyond it new requests get `busy` responses.
    pub max_pending: usize,
    /// Result-cache capacity in completed aggregates (0 = disabled).
    pub cache_entries: usize,
    /// Emit wall-clock fields in result lines (nondeterministic —
    /// off by default so responses are byte-reproducible).
    pub timing: bool,
    /// Collect a structured trace of every partitioning phase and
    /// write it (Chrome `trace_event` JSON) here when the accept loop
    /// exits. `None` keeps tracing disabled — the zero-cost default.
    /// Tracing never changes responses or partitions (the crate-wide
    /// observability invariant, pinned in `tests/observability.rs`).
    pub trace: Option<PathBuf>,
    /// Durable ops journal (`serve --journal FILE`): one JSON line per
    /// request lifecycle event — admitted / started / completed /
    /// cancelled / busy / cache_hit / error / shutdown — with size-based
    /// rotation (see [`JournalConfig`]). `None` disables journaling.
    /// Like tracing, the journal never changes a response byte.
    pub journal: Option<JournalConfig>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            workers: 0,
            max_pending: 16,
            cache_entries: 64,
            timing: false,
            trace: None,
            journal: None,
        }
    }
}

/// One catalog entry: a per-key single-flight cell. Concurrent
/// requests for the *same* graph block on the cell (one load, shared
/// result); requests for other graphs only touch the catalog map for
/// the brief entry lookup, never for the load I/O itself.
type CatalogCell = Arc<std::sync::OnceLock<Result<Arc<Graph>, String>>>;

/// Graphs materialized for request specs, shared across connections so
/// every request naming the same file/instance reuses one loaded copy
/// (the batching win the service exists for). Shard directories pass
/// through by path — the scheduler opens them per request.
#[derive(Default)]
pub struct GraphCatalog {
    graphs: Mutex<HashMap<String, CatalogCell>>,
}

impl GraphCatalog {
    pub fn new() -> GraphCatalog {
        GraphCatalog::default()
    }

    /// Turn a parsed spec into a submittable [`Request`]: build the
    /// config and load (or reuse) the topology. Loads are per-key
    /// single-flight: N concurrent requests for one graph perform one
    /// load, while loads of different graphs proceed independently.
    pub fn materialize(&self, spec: &RequestSpec) -> Result<Request, String> {
        let config = spec.build_config()?;
        let graph = match &spec.source {
            RequestSource::Shards(dir) => GraphHandle::Shards(PathBuf::from(dir)),
            RequestSource::GraphFile(path) => self.load(&format!("graph:{path}"), || {
                crate::graph::io::load_path(Path::new(path))
                    .map_err(|e| format!("loading {path}: {e}"))
            })?,
            RequestSource::Instance(name) => self.load(&format!("instance:{name}"), || {
                crate::generators::instances::by_name(name)
                    .map(|instance| instance.build())
                    .ok_or_else(|| format!("unknown instance {name:?}"))
            })?,
        };
        let mut request = Request::new(spec.id.clone(), graph, config, spec.seeds.clone());
        // `timeout_ms=` was armed against wall time the moment the
        // request is submitted (inside `submit`), so queue wait counts
        // toward the deadline — the key is an end-to-end bound.
        request.timeout_ms = spec.timeout_ms;
        request.explain = spec.explain;
        request.race = spec
            .racer_configs()?
            .into_iter()
            .map(|(name, config)| RaceEntry { name, config })
            .collect();
        Ok(request)
    }

    fn load<F>(&self, key: &str, build: F) -> Result<GraphHandle, String>
    where
        F: FnOnce() -> Result<Graph, String>,
    {
        let cell = {
            let mut graphs = self.graphs.lock().unwrap_or_else(|p| p.into_inner());
            graphs.entry(key.to_string()).or_default().clone()
        };
        let result = cell.get_or_init(|| build().map(Arc::new)).clone();
        if result.is_err() {
            // Failures are not cached: a later request may find the
            // file. Remove the cell (if it is still ours) so the next
            // attempt loads afresh.
            let mut graphs = self.graphs.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(existing) = graphs.get(key) {
                if Arc::ptr_eq(existing, &cell) {
                    graphs.remove(key);
                }
            }
        }
        result.map(GraphHandle::InMemory)
    }
}

struct ServerShared {
    service: CachedService,
    catalog: GraphCatalog,
    timing: bool,
    shutting_down: AtomicBool,
    /// Read-half clones of every live connection, for drain-then-close:
    /// shutdown EOFs each reader, in-flight work finishes, writers
    /// drain, connections close.
    conns: Mutex<HashMap<usize, TcpStream>>,
    addr: SocketAddr,
    /// Durable lifecycle journal (`--journal`), shared with the
    /// scheduler hook; `None` when journaling is off.
    journal: Option<Arc<Journal>>,
    /// Rolling 60 s request window behind the `net_window_*` gauges.
    window: RollingWindow,
}

impl ServerShared {
    /// Append one journal event (no-op without `--journal`).
    fn journal_event(&self, event: &str, fields: &[(&str, FieldValue<'_>)]) {
        if let Some(journal) = &self.journal {
            journal.record(event, fields);
        }
    }

    /// Refresh the `net_window_*` gauges from the rolling window — at
    /// request completion and at `!stats`/`!metrics` render, so the
    /// exposition always reflects the trailing window. Wall-clock
    /// values, like `uptime_seconds`: never part of a result line.
    fn update_window_gauges(&self) {
        let snap = self.window.snapshot();
        let registry = self.service.service().ctx().metrics();
        registry.gauge("net_window_requests").set(snap.count as i64);
        registry
            .gauge("net_window_rps_milli")
            .set(snap.rps_milli as i64);
        registry.gauge("net_window_p50_micros").set(snap.p50 as i64);
        registry.gauge("net_window_p99_micros").set(snap.p99 as i64);
    }
}

impl ServerShared {
    fn begin_shutdown(&self) {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        // EOF every connection's read half: readers stop accepting new
        // requests; everything already admitted still completes.
        let conns = self.conns.lock().unwrap_or_else(|p| p.into_inner());
        for stream in conns.values() {
            let _ = stream.shutdown(Shutdown::Read);
        }
        drop(conns);
        // Wake the accept loop (it blocks in `accept`).
        let _ = TcpStream::connect(self.addr);
    }
}

/// Control handle onto a running [`NetServer`] — shutdown from another
/// thread, scheduler pause/resume, and cache observability. Cloneable
/// and usable while `run` blocks.
#[derive(Clone)]
pub struct NetServerHandle {
    shared: Arc<ServerShared>,
}

impl NetServerHandle {
    /// The bound listen address (with the real port when bound to 0).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Initiate graceful drain-then-close shutdown (same as a client's
    /// `!shutdown` control command).
    pub fn shutdown(&self) {
        self.shared.begin_shutdown();
    }

    /// Pause the scheduler ([`CachedService::pause`]) — nothing new is
    /// activated; queued and newly admitted work waits.
    pub fn pause(&self) {
        self.shared.service.pause();
    }

    /// Undo [`NetServerHandle::pause`].
    pub fn resume(&self) {
        self.shared.service.resume();
    }

    /// Result-cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.shared.service.stats()
    }
}

/// The batching service behind a TCP listener. Construct with
/// [`NetServer::bind`], then [`NetServer::run`] the accept loop (it
/// blocks until shutdown). See the module docs for the protocol.
pub struct NetServer {
    listener: TcpListener,
    shared: Arc<ServerShared>,
    /// Installed tracer and its output path; the trace file is written
    /// once, after the accept loop has fully drained.
    trace: Option<(PathBuf, Arc<Tracer>)>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7643"`, port 0 for ephemeral) and
    /// stand up the service stack behind it: one [`CachedService`]
    /// (bounded queue + content-addressed cache) shared by every
    /// connection.
    pub fn bind(addr: &str, config: NetServerConfig) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let journal = match config.journal {
            Some(jc) => Some(Arc::new(Journal::open(jc)?)),
            None => None,
        };
        // The scheduler cannot see the journal; its lifecycle hook
        // (today: `started` at activation) writes through the same
        // shared sink as the net-layer events.
        let on_event: Option<EventHook> = journal.as_ref().map(|journal| {
            let journal = journal.clone();
            Arc::new(move |event: &str, id: &str| {
                journal.record(event, &[("id", FieldValue::Str(id))]);
            }) as EventHook
        });
        let ctx = Arc::new(ExecutionCtx::new(config.workers));
        let service = CachedService::with_ctx_and_hook(
            ServiceConfig {
                workers: config.workers,
                max_pending: config.max_pending.max(1),
            },
            ctx,
            config.cache_entries,
            on_event,
        );
        let trace = config.trace.map(|path| {
            let tracer = Arc::new(Tracer::new());
            service.service().ctx().set_tracer(tracer.clone());
            (path, tracer)
        });
        Ok(NetServer {
            listener,
            trace,
            shared: Arc::new(ServerShared {
                service,
                catalog: GraphCatalog::new(),
                timing: config.timing,
                shutting_down: AtomicBool::new(false),
                conns: Mutex::new(HashMap::new()),
                addr: local,
                journal,
                window: RollingWindow::new(Duration::from_secs(60)),
            }),
        })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// A control handle usable while (and after) `run` blocks.
    pub fn handle(&self) -> NetServerHandle {
        NetServerHandle {
            shared: self.shared.clone(),
        }
    }

    /// Run the accept loop until shutdown (a `!shutdown` control line
    /// or [`NetServerHandle::shutdown`]), then drain: every accepted
    /// connection finishes its in-flight requests, receives its
    /// remaining responses, and is closed before this returns.
    pub fn run(self) -> std::io::Result<()> {
        let mut handlers: Vec<JoinHandle<()>> = Vec::new();
        let mut conn_id = 0usize;
        loop {
            let accepted = self.listener.accept();
            if self.shared.shutting_down.load(Ordering::SeqCst) {
                // A late stream (possibly the shutdown wake-up
                // connection) is dropped unserved.
                break;
            }
            let stream = match accepted {
                Ok((stream, _peer)) => stream,
                Err(_) => {
                    // Accept errors (e.g. EMFILE under fd pressure)
                    // tend to persist for a while — back off instead
                    // of busy-spinning the loop at full speed.
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    continue;
                }
            };
            conn_id += 1;
            let shared = self.shared.clone();
            let id = conn_id;
            handlers.push(std::thread::spawn(move || {
                handle_connection(&shared, stream, id);
            }));
            // Reap finished connections so a long-lived server does
            // not accumulate one JoinHandle per connection ever made.
            if handlers.len() >= 64 {
                handlers.retain(|h| !h.is_finished());
            }
        }
        for h in handlers {
            let _ = h.join();
        }
        // Every connection has drained, so every traced repetition has
        // flushed its span buffer: write the trace file now, before the
        // shared service is dropped.
        if let Some((path, tracer)) = &self.trace {
            tracer.write_chrome_trace_file(path)?;
        }
        // Terminal journal line: everything admitted before this point
        // has its completed/cancelled event on disk already.
        if let Some(journal) = &self.shared.journal {
            journal.record("shutdown", &[]);
            journal.flush();
        }
        // Dropping the shared service drains anything still queued.
        Ok(())
    }
}

/// One connection: a reader loop on this thread, a dedicated writer
/// thread, and one short-lived waiter thread per admitted request so
/// responses complete out of order (pipelining). The reader admits
/// requests in line order — that is what makes the `cached` markers of
/// duplicated requests deterministic.
fn handle_connection(shared: &Arc<ServerShared>, stream: TcpStream, conn_id: usize) {
    if let Ok(clone) = stream.try_clone() {
        let mut conns = shared.conns.lock().unwrap_or_else(|p| p.into_inner());
        if shared.shutting_down.load(Ordering::SeqCst) {
            return; // raced with shutdown: refuse
        }
        conns.insert(conn_id, clone);
    } else {
        return;
    }
    shared
        .service
        .service()
        .ctx()
        .metrics()
        .counter("net_connections")
        .inc();
    serve_connection(shared, stream, conn_id);
    let mut conns = shared.conns.lock().unwrap_or_else(|p| p.into_inner());
    conns.remove(&conn_id);
}

fn serve_connection(shared: &Arc<ServerShared>, stream: TcpStream, conn_id: usize) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let (tx, rx) = mpsc::channel::<String>();
    // Cancel tokens of this connection's in-flight requests, keyed by
    // line index. A vanished client — a write failure in the writer
    // loop, or a read *error* (not EOF: half-close and graceful
    // shutdown are normal ends) — fires every live token with
    // `Disconnect`, so workers abandon doomed computations at their
    // next checkpoint instead of finishing results nobody will read.
    let cancels: Arc<Mutex<HashMap<u64, CancelToken>>> = Arc::new(Mutex::new(HashMap::new()));
    let writer = {
        let cancels = cancels.clone();
        std::thread::spawn(move || writer_loop(stream, &rx, &cancels))
    };
    let mut waiters: Vec<JoinHandle<()>> = Vec::new();
    let reader = BufReader::new(read_half);
    // Request lines this connection has submitted (control commands and
    // comments excluded) — reported by `!stats` as `connection_requests`.
    let mut conn_requests = 0u64;
    let mut read_error = false;
    for (idx, line) in reader.lines().enumerate() {
        let line = match line {
            Ok(line) => line,
            Err(_) => {
                read_error = true;
                break;
            }
        };
        let trimmed = line.trim();
        // Blank lines and `#` comments are legal in every spec stream.
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        if let Some(command) = trimmed.strip_prefix('!') {
            match command.trim() {
                "ping" => {
                    let registry = shared.service.service().ctx().metrics();
                    let _ = tx.send(format!(
                        "{{\"status\":\"pong\",\"version\":\"{}\",\"uptime_seconds\":{:.3}}}",
                        env!("CARGO_PKG_VERSION"),
                        registry.uptime_seconds()
                    ));
                }
                "stats" => {
                    // Snapshot the whole registry as one JSON line. The
                    // arena gauges are set here, at snapshot time — the
                    // workspace keeps its own atomics; the registry view
                    // is refreshed on demand rather than double-counted.
                    shared.update_window_gauges();
                    let ctx = shared.service.service().ctx();
                    let registry = ctx.metrics();
                    let lease = ctx.workspace().stats();
                    registry
                        .gauge("arena_leases_created")
                        .set(lease.leases_created as i64);
                    registry
                        .gauge("arena_fresh_allocations")
                        .set(lease.fresh_allocations as i64);
                    registry
                        .gauge("arena_current_lease_bytes")
                        .set(lease.current_lease_bytes as i64);
                    registry
                        .gauge("arena_peak_lease_bytes")
                        .set(lease.peak_lease_bytes as i64);
                    let _ = tx.send(format!(
                        "{{\"status\":\"stats\",\"uptime_seconds\":{:.3},\
                         \"connection\":{conn_id},\
                         \"connection_requests\":{conn_requests},{}}}",
                        registry.uptime_seconds(),
                        registry.render_json_fields()
                    ));
                }
                "metrics" => {
                    // Prometheus text exposition as ONE queued message:
                    // the `# sclap metrics` sentinel opens the block,
                    // `# EOF` closes it, so line-oriented clients can
                    // relay the multi-line body as a single response.
                    // Atomic through the writer channel — never
                    // interleaved with other responses.
                    shared.update_window_gauges();
                    let registry = shared.service.service().ctx().metrics();
                    let _ = tx.send(format!(
                        "# sclap metrics\n{}# EOF",
                        registry.render_prometheus()
                    ));
                }
                "shutdown" => {
                    let _ = tx.send("{\"status\":\"shutdown\"}".to_string());
                    shared.begin_shutdown();
                    // Our own read half was EOF'd too; the loop ends on
                    // the next read. In-flight waiters still resolve.
                }
                other => {
                    let _ = tx.send(format!(
                        "{{\"status\":\"error\",\"error\":\"unknown control command !{}\"}}",
                        crate::util::json::escape_json(other)
                    ));
                }
            }
            continue;
        }
        conn_requests += 1;
        shared
            .service
            .service()
            .ctx()
            .metrics()
            .counter("net_requests")
            .inc();
        let default_id = format!("c{conn_id}-req{}", idx + 1);
        let spec = match parse_request_line(trimmed, &default_id) {
            Ok(Some(spec)) => spec,
            Ok(None) => continue,
            Err(message) => {
                shared.journal_event("error", &[("id", FieldValue::Str(&default_id))]);
                let _ = tx.send(render_error_line(&default_id, &message));
                continue;
            }
        };
        let request = match shared.catalog.materialize(&spec) {
            Ok(request) => request,
            Err(message) => {
                shared.journal_event("error", &[("id", FieldValue::Str(&spec.id))]);
                let _ = tx.send(render_error_line(&spec.id, &message));
                continue;
            }
        };
        // Admission (cache lookup + queue-slot claim) is synchronous,
        // so hit/join/lead outcomes and busy refusals follow line
        // order deterministically; only the wait moves off this
        // thread.
        let cancel = request.cancel.clone();
        let admitted_at = Instant::now();
        let admission = match shared.service.admit(request, false) {
            Ok(admission) => admission,
            Err(ServeError::Busy) => {
                shared.journal_event("busy", &[("id", FieldValue::Str(&spec.id))]);
                let _ = tx.send(render_busy_line(&spec.id));
                continue;
            }
            Err(e) => {
                shared.journal_event("error", &[("id", FieldValue::Str(&spec.id))]);
                let _ = tx.send(render_error_line(&spec.id, &e.to_string()));
                continue;
            }
        };
        shared.journal_event(
            "admitted",
            &[
                ("id", FieldValue::Str(&spec.id)),
                ("connection", FieldValue::Int(conn_id as i64)),
            ],
        );
        let req_key = idx as u64;
        cancels
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(req_key, cancel);
        let shared = shared.clone();
        let tx = tx.clone();
        let cancels = cancels.clone();
        waiters.push(std::thread::spawn(move || {
            let line = match shared.service.complete(admission) {
                Ok((agg, cached)) => {
                    if cached {
                        shared.journal_event("cache_hit", &[("id", FieldValue::Str(&spec.id))]);
                    }
                    let elapsed = admitted_at.elapsed();
                    shared.window.record(elapsed.as_micros() as u64);
                    shared.update_window_gauges();
                    shared.journal_event(
                        "completed",
                        &[
                            ("id", FieldValue::Str(&spec.id)),
                            ("seconds", FieldValue::Float(elapsed.as_secs_f64())),
                            ("cached", FieldValue::Bool(cached)),
                            ("cut", FieldValue::Int(agg.best_cut)),
                        ],
                    );
                    // A failing output= write fails THIS request's line
                    // only — fault isolation extends to the output
                    // stage, exactly like the stdin front end.
                    let write_err = spec.output.as_ref().and_then(|out| {
                        write_partition_file(out, &agg.best_blocks)
                            .err()
                            .map(|e| format!("writing {out}: {e}"))
                    });
                    match write_err {
                        None => {
                            let lease = shared.service.service().ctx().workspace().stats();
                            render_result_line_full(
                                &spec.id,
                                &agg,
                                shared.timing,
                                cached,
                                Some((lease.leases_created, lease.peak_lease_bytes)),
                            )
                        }
                        Some(message) => render_error_line(&spec.id, &message),
                    }
                }
                // A joiner inherits its leader's refusal as `busy` too.
                Err(ServeError::Busy) => {
                    shared.journal_event("busy", &[("id", FieldValue::Str(&spec.id))]);
                    render_busy_line(&spec.id)
                }
                // Cancellation (deadline, disconnect, race loss) is a
                // structured outcome, not an error: its own status.
                Err(ServeError::Failed(e)) => match e.cancelled {
                    Some(reason) => {
                        shared.journal_event(
                            "cancelled",
                            &[
                                ("id", FieldValue::Str(&spec.id)),
                                ("reason", FieldValue::Str(reason.as_str())),
                            ],
                        );
                        render_cancelled_line(&spec.id, reason)
                    }
                    None => {
                        shared.journal_event("error", &[("id", FieldValue::Str(&spec.id))]);
                        render_error_line(&spec.id, &e.message)
                    }
                },
                Err(e) => {
                    shared.journal_event("error", &[("id", FieldValue::Str(&spec.id))]);
                    render_error_line(&spec.id, &e.to_string())
                }
            };
            let _ = tx.send(line);
            cancels
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .remove(&req_key);
        }));
        // Reap finished waiters so a pipelining connection does not
        // accumulate one JoinHandle per request it ever sent.
        if waiters.len() >= 128 {
            waiters.retain(|w| !w.is_finished());
        }
    }
    if read_error {
        // The socket broke mid-line: the client is gone, not merely
        // done sending. Abandon its in-flight work.
        fire_all(&cancels, CancelReason::Disconnect);
    }
    // Drain-then-close: stop feeding the writer only after every
    // admitted request has sent its response.
    drop(tx);
    for w in waiters {
        let _ = w.join();
    }
    let _ = writer.join();
}

/// Fire every live request token of a connection with `reason`.
/// Cooperative: workers observe the verdict at their next checkpoint.
fn fire_all(cancels: &Mutex<HashMap<u64, CancelToken>>, reason: CancelReason) {
    let cancels = cancels.lock().unwrap_or_else(|p| p.into_inner());
    for token in cancels.values() {
        token.fire(reason);
    }
}

/// The write half: one JSON line per completed response, flushed
/// eagerly (clients pipeline and read while sending). On exit the
/// write side is shut down so clients see EOF after the last response.
/// A write failure means the client vanished — every in-flight request
/// of the connection is cancelled with `Disconnect` so workers stop
/// computing results nobody will read.
fn writer_loop(
    stream: TcpStream,
    rx: &mpsc::Receiver<String>,
    cancels: &Mutex<HashMap<u64, CancelToken>>,
) {
    let mut w = BufWriter::new(&stream);
    while let Ok(line) = rx.recv() {
        let ok = w
            .write_all(line.as_bytes())
            .and_then(|()| w.write_all(b"\n"))
            .and_then(|()| w.flush());
        if ok.is_err() {
            // Client gone: cancel in-flight work; remaining waiter
            // sends are simply dropped.
            fire_all(cancels, CancelReason::Disconnect);
            break;
        }
    }
    let _ = w.flush();
    drop(w);
    let _ = stream.shutdown(Shutdown::Write);
}
