//! Content-addressed result cache in front of the batching scheduler.
//!
//! Heavy service traffic repeats itself: the same graph partitioned
//! with the same configuration and seeds, submitted by many clients.
//! [`CachedService`] wraps a [`BatchService`] so such repeats cost zero
//! recomputation:
//!
//! - **Content-addressed keys** — a request is identified by
//!   ([`store_fingerprints`] of its topology, [`config_cache_key`] of
//!   its configuration, its sorted seed list). The fingerprint pair
//!   streams the CSR through the
//!   [`GraphStore`](crate::graph::store::GraphStore) cursor, so
//!   in-memory graphs and on-disk shard directories of the same
//!   topology share cache entries without materialization — the
//!   determinism contract guarantees both backends produce identical
//!   partitions, cuts, and rendered result lines. Fingerprints are
//!   memoized (per live graph allocation; per shard directory,
//!   validated against `meta.bin`'s stamp), so repeated admissions do
//!   not re-stream the CSR. The request `id` and `output=` destination
//!   are labels, never key material; `race` membership and `explain`
//!   ARE key material (each changes the cached artifact), `timeout_ms`
//!   is not.
//! - **Canonical configs** — [`config_cache_key`] renders every
//!   *algorithmic* field of [`PartitionConfig`] and deliberately omits
//!   `threads`: the crate-wide thread-count-invariance contract makes
//!   the pool size unobservable in results, so requests differing only
//!   in `threads` hit the same entry. Seed lists are sorted in the key
//!   because [`Aggregate::from_runs`] orders runs by seed — `seeds=1,2`
//!   and `seeds=2,1` are the same computation.
//! - **Single-flight** — N concurrent identical requests trigger
//!   exactly one computation: the first becomes the *leader* and
//!   submits to the queue; the rest *join* its in-flight slot and wait
//!   on a condvar. A leader's failure (including `Busy` backpressure)
//!   propagates to its joiners and is never cached. Cancellation
//!   fate-shares the same way: a cancelled leader (timeout, client
//!   disconnect, race loss) resolves its joiners with the same
//!   `cancelled` error and drops the entry, so the next identical
//!   request leads a fresh computation instead of inheriting a stale
//!   verdict.
//! - **Bounded LRU** — at most `capacity` completed aggregates stay
//!   resident; the least-recently-used entry is evicted on overflow.
//!   In-flight slots are never evicted. Capacity 0 disables caching
//!   entirely (every request passes straight through).
//!
//! Admission ([`CachedService::admit`]) is synchronous and cheap (a
//! memoized fingerprint lookup, plus one CSR stream the first time a
//! topology is seen) and also claims the queue slot for leaders;
//! completion ([`CachedService::complete`]) blocks until the aggregate
//! exists. The TCP server keeps the two phases apart — its
//! per-connection reader admits requests *in line order* (so a
//! duplicated request deterministically joins or hits its predecessor
//! and busy refusals are reproducible) and hands completion to a
//! waiter thread so responses may finish out of order. A Lead
//! admission dropped without completion fails its slot (instead of
//! wedging the key), so joiners always unblock.

use crate::coordinator::queue::{
    BatchService, EventHook, GraphHandle, Request, RequestError, ServiceConfig, SubmitError,
};
use crate::coordinator::service::Aggregate;
use crate::graph::csr::Graph;
use crate::graph::store::{meta_stamp, store_fingerprints, InMemoryStore, MetaStamp, ShardedStore};
use crate::obs::metrics::Counter;
use crate::partitioning::config::PartitionConfig;
use crate::util::exec::ExecutionCtx;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex, Weak};

/// Why a cached-service request produced no aggregate.
#[derive(Debug, Clone)]
pub enum ServeError {
    /// The bounded queue is at `max_pending` (non-blocking admission).
    Busy,
    /// The service is shutting down.
    ShutDown,
    /// The request itself failed (bad config, unopenable shards, ...).
    Failed(RequestError),
}

impl From<SubmitError> for ServeError {
    fn from(e: SubmitError) -> Self {
        match e {
            SubmitError::Busy => ServeError::Busy,
            SubmitError::ShutDown => ServeError::ShutDown,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Busy => write!(f, "service queue is full"),
            ServeError::ShutDown => write!(f, "service is shutting down"),
            ServeError::Failed(e) => write!(f, "{}", e.message),
        }
    }
}

/// Canonical cache rendering of a [`PartitionConfig`]: every field that
/// can change the computed partition, and **only** those — `threads` is
/// omitted (thread-count invariance makes it unobservable in results),
/// so requests differing only in worker count share cache entries.
/// The exhaustive destructuring (no `..` rest pattern) is deliberate:
/// adding a config field without deciding its cache-key role becomes a
/// compile error instead of a silent stale-result bug.
pub fn config_cache_key(c: &PartitionConfig) -> String {
    let PartitionConfig {
        k,
        epsilon,
        lpa_iterations,
        size_factor,
        ordering,
        active_nodes_coarsening,
        ensemble,
        vcycles,
        coarse_imbalance,
        scheme,
        initial,
        refinement,
        fm,
        tolerate_imbalance,
        deep_coarsening,
        threads: _, // execution knob: unobservable in results
        parallel_refinement,
        parallel_coarsening,
        memory_budget_bytes,
    } = c;
    let crate::refinement::fm::FmConfig {
        max_passes,
        max_negative_moves,
        seed_fraction,
    } = fm;
    format!(
        "k={k} eps={epsilon:?} lpa={lpa_iterations} f={size_factor:?} ord={ordering:?} \
         active={active_nodes_coarsening} ens={ensemble} v={vcycles} cimb={coarse_imbalance:?} \
         scheme={scheme:?} init={initial:?} refine={refinement:?} \
         fm=({max_passes},{max_negative_moves},{seed_fraction:?}) tol={tolerate_imbalance} \
         deep={deep_coarsening} prefine={parallel_refinement} pcoarse={parallel_coarsening} \
         budget={memory_budget_bytes:?}"
    )
}

/// The content address of one request's result. The graph component is
/// the [`store_fingerprints`] **pair** (two independent 64-bit mixers
/// over the CSR stream), so a collision must defeat both at once.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    graph: (u64, u64),
    config: String,
    seeds: Vec<u64>,
}

/// Memo of already-computed topology fingerprints, so repeated
/// requests — the whole point of the cache — do not re-stream the CSR
/// (for shard directories that is a full disk scan) on every
/// admission.
///
/// - In-memory graphs are keyed by allocation address and validated
///   with a [`Weak`] upgrade + [`Arc::ptr_eq`] against the request's
///   handle: an entry is only ever reused while the *original*
///   allocation is still alive, so address reuse after a drop cannot
///   alias (graphs are immutable once built).
/// - Shard directories are keyed by path and validated against
///   `meta.bin`'s [`MetaStamp`] — length, mtime, declared format
///   version, *and* a content hash of the file. Length + mtime alone
///   were not enough: a rewrite landing within mtime granularity at
///   equal length (a `shard recompress`, or same-n regeneration with
///   different node weights) would have validated a stale fingerprint
///   and served a cached result for the wrong graph. Any changed stamp
///   component forces a re-stream.
#[derive(Default)]
struct FingerprintMemo {
    mem: HashMap<usize, (Weak<Graph>, (u64, u64))>,
    shards: HashMap<PathBuf, (MetaStamp, (u64, u64))>,
}

impl FingerprintMemo {
    fn graph_fp(memo: &Mutex<FingerprintMemo>, g: &Arc<Graph>) -> (u64, u64) {
        let key = Arc::as_ptr(g) as usize;
        {
            let m = memo.lock().unwrap_or_else(|p| p.into_inner());
            if let Some((weak, fp)) = m.mem.get(&key) {
                if let Some(live) = weak.upgrade() {
                    if Arc::ptr_eq(&live, g) {
                        return *fp;
                    }
                }
            }
        }
        let fp = store_fingerprints(&InMemoryStore::new(g))
            .expect("in-memory fingerprint cannot fail");
        let mut m = memo.lock().unwrap_or_else(|p| p.into_inner());
        if m.mem.len() >= 256 {
            m.mem.retain(|_, entry| entry.0.strong_count() > 0);
        }
        m.mem.insert(key, (Arc::downgrade(g), fp));
        fp
    }

    fn shard_fp(
        memo: &Mutex<FingerprintMemo>,
        dir: &std::path::Path,
    ) -> std::io::Result<(u64, u64)> {
        let stamp = meta_stamp(dir)?;
        {
            let m = memo.lock().unwrap_or_else(|p| p.into_inner());
            if let Some((seen, fp)) = m.shards.get(dir) {
                if *seen == stamp {
                    return Ok(*fp);
                }
            }
        }
        let store = ShardedStore::open(dir)?;
        let fp = store_fingerprints(&store)?;
        let mut m = memo.lock().unwrap_or_else(|p| p.into_inner());
        m.shards.insert(dir.to_path_buf(), (stamp, fp));
        Ok(fp)
    }
}

enum SlotState {
    Pending,
    Resolved(Result<Arc<Aggregate>, ServeError>),
}

/// One in-flight or completed computation; joiners park on `cond`.
struct Slot {
    state: Mutex<SlotState>,
    cond: Condvar,
}

impl Slot {
    fn pending() -> Arc<Slot> {
        Arc::new(Slot {
            state: Mutex::new(SlotState::Pending),
            cond: Condvar::new(),
        })
    }

    fn resolve(&self, result: Result<Arc<Aggregate>, ServeError>) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        *st = SlotState::Resolved(result);
        self.cond.notify_all();
    }

    fn wait(&self) -> Result<Arc<Aggregate>, ServeError> {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            match &*st {
                SlotState::Resolved(result) => return result.clone(),
                SlotState::Pending => {
                    st = self.cond.wait(st).unwrap_or_else(|p| p.into_inner());
                }
            }
        }
    }
}

struct CacheEntry {
    slot: Arc<Slot>,
    last_used: u64,
}

struct CacheMap {
    entries: HashMap<CacheKey, CacheEntry>,
    /// Logical LRU clock.
    tick: u64,
    stats: CacheStats,
}

/// Cache observability counters (monotonic since service start).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Admissions served from a completed entry.
    pub hits: u64,
    /// Admissions that became computation leaders.
    pub misses: u64,
    /// Admissions that joined an in-flight leader (single-flight dedup).
    pub joined: u64,
    /// Admissions that bypassed the cache (disabled, or topology
    /// unreadable at fingerprint time).
    pub uncached: u64,
    /// Completed entries evicted by the LRU bound.
    pub evictions: u64,
}

/// Cleanup guard carried by a Lead admission: if the admission is
/// dropped before [`CachedService::complete`] resolves its slot (the
/// waiter thread failed to spawn, or the caller panicked between the
/// two phases), the guard fails the slot so joiners unblock and the
/// key is not wedged Pending forever. After a normal completion the
/// slot is already resolved and the guard is a no-op.
struct LeadGuard {
    map: Arc<Mutex<CacheMap>>,
    key: CacheKey,
    slot: Arc<Slot>,
}

impl Drop for LeadGuard {
    fn drop(&mut self) {
        let abandoned = {
            let mut st = self.slot.state.lock().unwrap_or_else(|p| p.into_inner());
            if matches!(*st, SlotState::Pending) {
                *st = SlotState::Resolved(Err(ServeError::Failed(RequestError::new(
                    String::new(),
                    "request abandoned before completion",
                ))));
                self.slot.cond.notify_all();
                true
            } else {
                false
            }
        };
        // Slot lock released before the map lock: the map→slot order
        // used everywhere else is never inverted while both are held.
        if abandoned {
            let mut map = lock_map(&self.map);
            if let Some(entry) = map.entries.get(&self.key) {
                if Arc::ptr_eq(&entry.slot, &self.slot) {
                    map.entries.remove(&self.key);
                }
            }
        }
    }
}

enum AdmissionKind {
    /// Cache disabled or key not computable: submitted straight to the
    /// queue (ticket held).
    Bypass(crate::coordinator::queue::Ticket),
    /// Completed entry: the aggregate is already resident.
    Hit(Arc<Aggregate>),
    /// An identical request is in flight: wait for its result.
    Join(Arc<Slot>),
    /// First of its kind: submitted (ticket held); completion resolves
    /// the slot for the joiners (the guard resolves it on abandonment).
    Lead {
        ticket: crate::coordinator::queue::Ticket,
        guard: LeadGuard,
    },
}

/// A request after cache admission, ready to [`CachedService::complete`].
pub struct Admission {
    kind: AdmissionKind,
}

/// Registry mirrors of [`CacheStats`]: the same monotonic tallies,
/// exported through the context's
/// [`MetricsRegistry`](crate::obs::metrics::MetricsRegistry) so the
/// wire `!stats` command sees them without reaching into the cache.
/// Handles are resolved once at construction and updated lock-free at
/// the same points the struct fields are bumped under the map lock.
struct CacheCounters {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    joined: Arc<Counter>,
    uncached: Arc<Counter>,
    evictions: Arc<Counter>,
}

/// A [`BatchService`] behind a content-addressed single-flight LRU
/// result cache. See the module docs for the model.
pub struct CachedService {
    service: BatchService,
    capacity: usize,
    map: Arc<Mutex<CacheMap>>,
    fp_memo: Mutex<FingerprintMemo>,
    counters: CacheCounters,
}

impl CachedService {
    /// Service owning a fresh pool, caching up to `cache_entries`
    /// completed aggregates (0 = caching disabled).
    pub fn new(config: ServiceConfig, cache_entries: usize) -> Self {
        Self::wrap(BatchService::new(config), cache_entries)
    }

    /// Cached service on an existing shared execution context.
    pub fn with_ctx(config: ServiceConfig, ctx: Arc<ExecutionCtx>, cache_entries: usize) -> Self {
        Self::wrap(BatchService::with_ctx(config, ctx), cache_entries)
    }

    /// [`CachedService::with_ctx`] plus a scheduler lifecycle hook —
    /// how the net server journals `started` events (see [`EventHook`]).
    pub fn with_ctx_and_hook(
        config: ServiceConfig,
        ctx: Arc<ExecutionCtx>,
        cache_entries: usize,
        on_event: Option<EventHook>,
    ) -> Self {
        Self::wrap(
            BatchService::with_ctx_and_hook(config, ctx, on_event),
            cache_entries,
        )
    }

    fn wrap(service: BatchService, cache_entries: usize) -> Self {
        let registry = service.ctx().metrics();
        let counters = CacheCounters {
            hits: registry.counter("cache_hits"),
            misses: registry.counter("cache_misses"),
            joined: registry.counter("cache_joined"),
            uncached: registry.counter("cache_uncached"),
            evictions: registry.counter("cache_evictions"),
        };
        CachedService {
            service,
            capacity: cache_entries,
            map: Arc::new(Mutex::new(CacheMap {
                entries: HashMap::new(),
                tick: 0,
                stats: CacheStats::default(),
            })),
            fp_memo: Mutex::new(FingerprintMemo::default()),
            counters,
        }
    }

    /// The wrapped batching service.
    pub fn service(&self) -> &BatchService {
        &self.service
    }

    /// Total worker count of the shared pool.
    pub fn worker_count(&self) -> usize {
        self.service.worker_count()
    }

    /// Stop activating new requests (see [`BatchService::pause`]) —
    /// also the lever that makes single-flight observable in tests.
    pub fn pause(&self) {
        self.service.pause();
    }

    /// Undo [`CachedService::pause`].
    pub fn resume(&self) {
        self.service.resume();
    }

    /// Current cache counters.
    pub fn stats(&self) -> CacheStats {
        lock_map(&self.map).stats
    }

    /// Number of completed entries currently resident.
    pub fn resident_entries(&self) -> usize {
        let map = lock_map(&self.map);
        map.entries
            .values()
            .filter(|e| {
                let state = e.slot.state.lock().unwrap_or_else(|p| p.into_inner());
                !matches!(*state, SlotState::Pending)
            })
            .count()
    }

    /// Phase 1: compute the content address, register against the
    /// cache, and — for leaders and bypassed requests — claim a queue
    /// slot. Synchronous and deterministic: callers that admit
    /// requests in a fixed order get fixed hit/join/lead outcomes
    /// **and** a fixed queue order, which is what makes both the
    /// `cached` marker and the `busy` backpressure signal reproducible
    /// over the wire. `block` selects blocking vs `Busy`-reporting
    /// submission; joins and hits never consume a queue slot and never
    /// report `Busy`.
    pub fn admit(&self, request: Request, block: bool) -> Result<Admission, ServeError> {
        if self.capacity == 0 {
            lock_map(&self.map).stats.uncached += 1;
            self.counters.uncached.inc();
            let ticket = self.submit(request, block)?;
            return Ok(Admission {
                kind: AdmissionKind::Bypass(ticket),
            });
        }
        let graph = match self.fingerprint(&request) {
            Ok(fp) => fp,
            // Unreadable topology: bypass — the queue fails the request
            // with the real I/O error, and nothing is cached.
            Err(_) => {
                lock_map(&self.map).stats.uncached += 1;
                self.counters.uncached.inc();
                let ticket = self.submit(request, block)?;
                return Ok(Admission {
                    kind: AdmissionKind::Bypass(ticket),
                });
            }
        };
        let mut seeds = request.seeds.clone();
        seeds.sort_unstable();
        // Races ARE key material: a race request's result is the wave
        // winner over its whole config list, which is a different
        // computation from any single config run (and from a race over
        // a different list). Racer *names* are labels — each racer's
        // canonical config key is what is appended. `timeout_ms` is
        // deliberately NOT key material: a deadline bounds how long
        // the caller waits, never what is computed, and a cache hit
        // returns before any deadline could fire.
        let mut config = config_cache_key(&request.config);
        for entry in &request.race {
            config.push_str(" race:");
            config.push_str(&config_cache_key(&entry.config));
        }
        // `explain` IS key material: the cached artifact is the whole
        // aggregate, and an explained aggregate carries the report
        // string a plain one lacks. Sharing an entry across the two
        // would make response bytes depend on which variant computed
        // first — the one thing the cache must never do. (The partition
        // itself is identical either way; only the attachment differs.)
        if request.explain {
            config.push_str(" explain");
        }
        let key = CacheKey {
            graph,
            config,
            seeds,
        };
        let slot = {
            let mut map = lock_map(&self.map);
            map.tick += 1;
            let tick = map.tick;
            if let Some(entry) = map.entries.get_mut(&key) {
                let slot = entry.slot.clone();
                entry.last_used = tick;
                let state = slot.state.lock().unwrap_or_else(|p| p.into_inner());
                match &*state {
                    SlotState::Resolved(Ok(agg)) => {
                        let agg = agg.clone();
                        drop(state);
                        map.stats.hits += 1;
                        self.counters.hits.inc();
                        return Ok(Admission {
                            kind: AdmissionKind::Hit(agg),
                        });
                    }
                    SlotState::Pending => {
                        drop(state);
                        map.stats.joined += 1;
                        self.counters.joined.inc();
                        return Ok(Admission {
                            kind: AdmissionKind::Join(slot),
                        });
                    }
                    // A failed slot between resolution and removal:
                    // treat as absent and lead a fresh computation.
                    SlotState::Resolved(Err(_)) => drop(state),
                }
            }
            let slot = Slot::pending();
            map.stats.misses += 1;
            self.counters.misses.inc();
            map.entries.insert(
                key.clone(),
                CacheEntry {
                    slot: slot.clone(),
                    last_used: tick,
                },
            );
            slot
        };
        match self.submit(request, block) {
            Ok(ticket) => Ok(Admission {
                kind: AdmissionKind::Lead {
                    ticket,
                    guard: LeadGuard {
                        map: self.map.clone(),
                        key,
                        slot,
                    },
                },
            }),
            Err(e) => {
                // The leader could not even enqueue (backpressure or
                // shutdown): joiners inherit the refusal, nothing is
                // cached.
                self.resolve_err(&key, &slot, e.clone());
                Err(e)
            }
        }
    }

    /// The memoized topology fingerprint of a request's graph handle.
    fn fingerprint(&self, request: &Request) -> std::io::Result<(u64, u64)> {
        match &request.graph {
            GraphHandle::InMemory(g) => Ok(FingerprintMemo::graph_fp(&self.fp_memo, g)),
            GraphHandle::Shards(dir) => FingerprintMemo::shard_fp(&self.fp_memo, dir),
        }
    }

    /// Phase 2: produce the aggregate for an admission. Returns the
    /// aggregate and whether it came from the cache (a hit or a
    /// single-flight join — anything that did not cost a computation).
    pub fn complete(&self, admission: Admission) -> Result<(Arc<Aggregate>, bool), ServeError> {
        match admission.kind {
            AdmissionKind::Bypass(ticket) => {
                let agg = ticket.wait().map_err(ServeError::Failed)?;
                Ok((Arc::new(agg), false))
            }
            AdmissionKind::Hit(agg) => Ok((agg, true)),
            AdmissionKind::Join(slot) => slot.wait().map(|agg| (agg, true)),
            AdmissionKind::Lead { ticket, guard } => match ticket.wait() {
                Ok(agg) => {
                    let agg = Arc::new(agg);
                    self.resolve_ok(&guard.key, &guard.slot, agg.clone());
                    Ok((agg, false))
                }
                Err(e) => {
                    let e = ServeError::Failed(e);
                    self.resolve_err(&guard.key, &guard.slot, e.clone());
                    Err(e)
                }
            },
        }
    }

    /// [`admit`](CachedService::admit) + [`complete`](CachedService::complete)
    /// in one call — the API for in-process users (tests, benches, the
    /// stdin front end if it ever wants caching).
    pub fn run(
        &self,
        request: Request,
        block: bool,
    ) -> Result<(Arc<Aggregate>, bool), ServeError> {
        let admission = self.admit(request, block)?;
        self.complete(admission)
    }

    fn submit(
        &self,
        request: Request,
        block: bool,
    ) -> Result<crate::coordinator::queue::Ticket, ServeError> {
        if block {
            self.service.submit(request)
        } else {
            self.service.try_submit(request)
        }
        .map_err(ServeError::from)
    }

    fn resolve_ok(&self, key: &CacheKey, slot: &Arc<Slot>, agg: Arc<Aggregate>) {
        let mut map = lock_map(&self.map);
        slot.resolve(Ok(agg));
        map.tick += 1;
        let tick = map.tick;
        if let Some(entry) = map.entries.get_mut(key) {
            entry.last_used = tick;
        }
        // LRU bound: evict completed entries, never in-flight ones.
        loop {
            let resolved: Vec<(&CacheKey, u64)> = map
                .entries
                .iter()
                .filter(|(_, e)| {
                    !matches!(
                        *e.slot.state.lock().unwrap_or_else(|p| p.into_inner()),
                        SlotState::Pending
                    )
                })
                .map(|(k, e)| (k, e.last_used))
                .collect();
            if resolved.len() <= self.capacity {
                break;
            }
            let victim = resolved
                .iter()
                .min_by_key(|(_, used)| *used)
                .map(|(k, _)| (*k).clone())
                .expect("resolved set is non-empty");
            map.entries.remove(&victim);
            map.stats.evictions += 1;
            self.counters.evictions.inc();
        }
    }

    fn resolve_err(&self, key: &CacheKey, slot: &Arc<Slot>, error: ServeError) {
        let mut map = lock_map(&self.map);
        slot.resolve(Err(error));
        // Failures are never cached: drop the entry (if it is still
        // ours) so the next identical request leads a fresh attempt.
        if let Some(entry) = map.entries.get(key) {
            if Arc::ptr_eq(&entry.slot, slot) {
                map.entries.remove(key);
            }
        }
    }
}

fn lock_map(m: &Mutex<CacheMap>) -> std::sync::MutexGuard<'_, CacheMap> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate_club;
    use crate::partitioning::config::Preset;

    fn karate_request(id: &str, seeds: Vec<u64>) -> Request {
        Request::new(
            id,
            GraphHandle::InMemory(Arc::new(karate_club())),
            PartitionConfig::preset(Preset::CFast, 2),
            seeds,
        )
    }

    #[test]
    fn hit_returns_the_same_aggregate() {
        let svc = CachedService::new(
            ServiceConfig {
                workers: 2,
                max_pending: 4,
            },
            8,
        );
        let (first, cached) = svc.run(karate_request("a", vec![1, 2]), true).unwrap();
        assert!(!cached);
        let (second, cached) = svc.run(karate_request("b", vec![1, 2]), true).unwrap();
        assert!(cached, "identical request must hit");
        assert!(Arc::ptr_eq(&first, &second), "hits share the aggregate");
        let stats = svc.stats();
        assert_eq!((stats.misses, stats.hits), (1, 1));
    }

    #[test]
    fn thread_knob_and_seed_order_do_not_split_entries() {
        let svc = CachedService::new(ServiceConfig::default(), 8);
        let mut req = karate_request("a", vec![2, 1]);
        req.config.threads = 1;
        svc.run(req, true).unwrap();
        let mut req = karate_request("b", vec![1, 2]);
        req.config.threads = 4; // execution knob, not key material
        let (_, cached) = svc.run(req, true).unwrap();
        assert!(cached);
        assert_eq!(svc.stats().misses, 1);
    }

    #[test]
    fn config_key_omits_threads_but_covers_algorithmic_fields() {
        let a = PartitionConfig::preset(Preset::CFast, 4);
        let mut b = a.clone();
        b.threads = 7;
        assert_eq!(config_cache_key(&a), config_cache_key(&b));
        let mut c = a.clone();
        c.epsilon = 0.05;
        assert_ne!(config_cache_key(&a), config_cache_key(&c));
        let mut d = a.clone();
        d.parallel_coarsening = true; // a *different algorithm*
        assert_ne!(config_cache_key(&a), config_cache_key(&d));
        let mut e = a.clone();
        e.memory_budget_bytes = Some(1);
        assert_ne!(config_cache_key(&a), config_cache_key(&e));
    }

    #[test]
    fn race_is_cache_key_material_but_timeout_is_not() {
        use crate::coordinator::queue::RaceEntry;
        let svc = CachedService::new(ServiceConfig::default(), 8);
        svc.run(karate_request("plain", vec![1]), true).unwrap();
        let mut req = karate_request("timed", vec![1]);
        req.timeout_ms = Some(3_600_000); // a deadline never changes the key
        let (_, cached) = svc.run(req, true).unwrap();
        assert!(cached, "timeout_ms must not split cache entries");
        let mut req = karate_request("race", vec![1]);
        req.race = vec![
            RaceEntry {
                name: "CFast".to_string(),
                config: PartitionConfig::preset(Preset::CFast, 2),
            },
            RaceEntry {
                name: "UFast".to_string(),
                config: PartitionConfig::preset(Preset::UFast, 2),
            },
        ];
        let (_, cached) = svc.run(req, true).unwrap();
        assert!(!cached, "a race over configs is a different computation");
        assert_eq!(svc.stats().misses, 2);
    }

    #[test]
    fn explain_is_cache_key_material() {
        let svc = CachedService::new(ServiceConfig::default(), 8);
        svc.run(karate_request("plain", vec![1]), true).unwrap();
        let mut req = karate_request("explained", vec![1]);
        req.explain = true;
        let (agg, cached) = svc.run(req, true).unwrap();
        assert!(!cached, "an explained aggregate is a different artifact");
        assert!(agg.explain.is_some());
        // ...but identical explained requests share their entry.
        let mut req = karate_request("explained-again", vec![1]);
        req.explain = true;
        let (again, cached) = svc.run(req, true).unwrap();
        assert!(cached);
        assert!(Arc::ptr_eq(&agg, &again));
        assert_eq!(svc.stats().misses, 2);
    }

    #[test]
    fn capacity_zero_disables_caching() {
        let svc = CachedService::new(ServiceConfig::default(), 0);
        let (_, cached) = svc.run(karate_request("a", vec![1]), true).unwrap();
        assert!(!cached);
        let (_, cached) = svc.run(karate_request("b", vec![1]), true).unwrap();
        assert!(!cached);
        let stats = svc.stats();
        assert_eq!(stats.uncached, 2);
        assert_eq!(stats.misses + stats.hits + stats.joined, 0);
    }

    #[test]
    fn abandoned_lead_admission_unwedges_its_key_and_joiners() {
        let svc = Arc::new(CachedService::new(ServiceConfig::default(), 8));
        svc.pause(); // the leader cannot complete while we abandon it
        let admission = svc
            .admit(karate_request("dropped", vec![1]), true)
            .unwrap();
        let joiner = {
            let svc = svc.clone();
            std::thread::spawn(move || svc.run(karate_request("joiner", vec![1]), true))
        };
        while svc.stats().joined == 0 {
            std::thread::yield_now();
        }
        // Dropping a Lead admission without completing it (the failure
        // mode of a waiter thread that never spawned) must fail the
        // slot — not wedge the key and its joiners forever.
        drop(admission);
        let err = joiner.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("abandoned"), "{err}");
        svc.resume();
        let (_, cached) = svc.run(karate_request("retry", vec![1]), true).unwrap();
        assert!(!cached, "the key must be free for a fresh computation");
        assert_eq!(svc.stats().misses, 2);
    }

    #[test]
    fn fingerprints_are_memoized_per_graph_allocation() {
        let svc = CachedService::new(ServiceConfig::default(), 8);
        let karate = Arc::new(karate_club());
        let same = |id: &str| {
            Request::new(
                id,
                GraphHandle::InMemory(karate.clone()),
                PartitionConfig::preset(Preset::CFast, 2),
                vec![1],
            )
        };
        svc.run(same("a"), true).unwrap();
        let (_, cached) = svc.run(same("b"), true).unwrap();
        assert!(cached);
        // A different allocation of identical content still hits (the
        // memo validates by liveness, the key by content).
        let other = Arc::new(karate_club());
        let (_, cached) = svc
            .run(
                Request::new(
                    "c",
                    GraphHandle::InMemory(other),
                    PartitionConfig::preset(Preset::CFast, 2),
                    vec![1],
                ),
                true,
            )
            .unwrap();
        assert!(cached, "content addressing is allocation-independent");
    }

    #[test]
    fn failures_are_not_cached() {
        let svc = CachedService::new(ServiceConfig::default(), 8);
        let err = svc
            .run(karate_request("no-seeds", vec![]), true)
            .unwrap_err();
        assert!(matches!(err, ServeError::Failed(_)), "{err}");
        assert_eq!(svc.resident_entries(), 0, "failed entry must be dropped");
        // the same key computes (and fails) again — still a miss
        svc.run(karate_request("again", vec![]), true).unwrap_err();
        assert_eq!(svc.stats().misses, 2);
    }
}
