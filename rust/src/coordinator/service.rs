//! The partitioning service coordinator.
//!
//! The paper's evaluation protocol (§5: "we perform ten repetitions for
//! each configuration of the algorithm and report the arithmetic
//! average of computed cut size, running time and the best cut found")
//! is a first-class L3 feature here: the shared deterministic
//! [`ExecutionCtx`] pool executes repetition jobs in parallel, the
//! coordinator aggregates average/best/geomean and retains the best
//! partition. The bench harness and the CLI both sit on top of this
//! service.
//!
//! Implementation: one shared [`ExecutionCtx`] owns **the** process
//! pool (std threads; tokio is not available offline — DESIGN.md §3).
//! Repetition jobs fan out across that pool, and the same context is
//! handed down into every job's `MultilevelPartitioner`, so nested
//! parallel phases (coarsening LPA, contraction, recursive bisection,
//! refinement) re-enter the same pool and run inline — total live
//! worker threads never exceed the configured cap
//! (`rust/tests/thread_cap.rs`), with no oversubscription guard needed.
//! Each job's outcome is a pure function of (graph, config, seed), and
//! results are collected in seed order, so aggregates are deterministic
//! regardless of worker count or scheduling (invariant 6, DESIGN.md
//! §7). A panicking job is contained by the pool (the worker — and
//! every queued job — survives; the caller re-raises after the batch
//! drains).

use crate::graph::csr::{Graph, Weight};
use crate::partitioning::config::PartitionConfig;
use crate::partitioning::multilevel::{MultilevelPartitioner, PartitionResult};
use crate::util::exec::ExecutionCtx;
use crate::util::timer::Stats;
use std::sync::Arc;

/// One repetition outcome (a trimmed [`PartitionResult`]).
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub seed: u64,
    pub cut: Weight,
    pub seconds: f64,
    pub imbalance: f64,
    pub feasible: bool,
    pub initial_cut: Weight,
    pub levels: usize,
    pub coarsest_n: usize,
    pub blocks: Vec<u32>,
    /// Per-phase wall-clock of this repetition, in pipeline order.
    /// In-memory runs report `coarsening`/`initial`/`uncoarsening`;
    /// out-of-core runs report `external` (streaming phases) plus
    /// `in_memory` (the handed-off pipeline). The names and their
    /// order are deterministic; only the seconds vary.
    pub phase_seconds: Vec<(&'static str, f64)>,
}

impl RunOutcome {
    pub(crate) fn from_result(seed: u64, r: &PartitionResult) -> Self {
        RunOutcome {
            seed,
            cut: r.metrics.cut,
            seconds: r.seconds,
            imbalance: r.metrics.imbalance,
            feasible: r.metrics.feasible,
            initial_cut: r.initial_cut,
            levels: r.levels,
            coarsest_n: r.coarsest_n,
            blocks: r.partition.blocks.clone(),
            phase_seconds: vec![
                ("coarsening", r.coarsening_seconds),
                ("initial", r.initial_seconds),
                ("uncoarsening", r.uncoarsening_seconds),
            ],
        }
    }

    /// RunOutcome view of an out-of-core run (the store-backed service
    /// path). The external driver does not track an initial cut, so
    /// `initial_cut` reports 0; `levels` carries the external level
    /// count and `coarsest_n` the size of the graph handed to the
    /// in-memory pipeline. All fields except `seconds` and
    /// `phase_seconds` are deterministic for a fixed (store, config,
    /// seed).
    pub fn from_out_of_core(
        seed: u64,
        r: &crate::partitioning::external::OutOfCoreResult,
    ) -> Self {
        RunOutcome {
            seed,
            cut: r.cut,
            seconds: r.seconds,
            imbalance: r.imbalance,
            feasible: r.feasible,
            initial_cut: 0,
            levels: r.external_levels,
            coarsest_n: r.handoff_n,
            blocks: r.blocks.clone(),
            phase_seconds: vec![
                ("external", r.external_seconds),
                ("in_memory", (r.seconds - r.external_seconds).max(0.0)),
            ],
        }
    }
}

/// Execute one repetition on the shared context: the single code path
/// behind both [`Coordinator::partition_repeated`] jobs and the
/// batching service's scheduler units
/// ([`crate::coordinator::queue::BatchService`]). Pure function of
/// (graph, config, seed) — the context never influences results.
pub(crate) fn run_repetition(
    ctx: &Arc<ExecutionCtx>,
    graph: &Arc<Graph>,
    config: &PartitionConfig,
    seed: u64,
) -> RunOutcome {
    let partitioner = MultilevelPartitioner::with_ctx(config.clone(), ctx.clone());
    let result = partitioner.partition(graph, seed);
    RunOutcome::from_result(seed, &result)
}

/// Aggregate over the repetitions of one (instance, config, k) cell —
/// exactly the numbers Table 2 / Table 3 report.
#[derive(Debug, Clone)]
pub struct Aggregate {
    pub runs: Vec<RunOutcome>,
    pub avg_cut: f64,
    pub best_cut: Weight,
    pub avg_seconds: f64,
    pub avg_initial_cut: f64,
    pub infeasible_runs: usize,
    /// Blocks of the best run.
    pub best_blocks: Vec<u32>,
    /// Total seconds per phase name, summed across runs in the fixed
    /// per-run phase order (first-seen order over seed-sorted runs —
    /// deterministic names/order, wall-clock values).
    pub phase_seconds: Vec<(&'static str, f64)>,
    /// Pre-rendered [`QualityReport`](crate::obs::QualityReport) JSON,
    /// set by the batching scheduler when the request asked for
    /// `explain=true`; `None` otherwise. Deterministic and
    /// worker-count-invariant, like every non-timing field here.
    pub explain: Option<String>,
}

impl Aggregate {
    pub fn from_runs(mut runs: Vec<RunOutcome>) -> Aggregate {
        assert!(!runs.is_empty());
        runs.sort_by_key(|r| r.seed); // deterministic order
        let mut cut = Stats::new();
        let mut secs = Stats::new();
        let mut init = Stats::new();
        let mut phase_seconds: Vec<(&'static str, f64)> = Vec::new();
        for r in &runs {
            cut.add(r.cut as f64);
            secs.add(r.seconds);
            init.add(r.initial_cut as f64);
            for &(name, s) in &r.phase_seconds {
                match phase_seconds.iter_mut().find(|(n, _)| *n == name) {
                    Some(entry) => entry.1 += s,
                    None => phase_seconds.push((name, s)),
                }
            }
        }
        let best = runs
            .iter()
            .min_by_key(|r| r.cut)
            .expect("non-empty runs");
        Aggregate {
            avg_cut: cut.mean(),
            best_cut: best.cut,
            avg_seconds: secs.mean(),
            avg_initial_cut: init.mean(),
            infeasible_runs: runs.iter().filter(|r| !r.feasible).count(),
            best_blocks: best.blocks.clone(),
            phase_seconds,
            explain: None,
            runs,
        }
    }
}

/// Repetition executor on the shared [`ExecutionCtx`]: the coordinator
/// creates the one process pool and hands it down through every phase.
pub struct Coordinator {
    ctx: Arc<ExecutionCtx>,
}

impl Coordinator {
    /// Context with a pool of `workers` threads (0 ⇒ available
    /// parallelism) — the process-wide worker cap.
    pub fn new(workers: usize) -> Self {
        Coordinator {
            ctx: Arc::new(ExecutionCtx::new(workers)),
        }
    }

    /// Coordinator on an existing shared context.
    pub fn with_ctx(ctx: Arc<ExecutionCtx>) -> Self {
        Coordinator { ctx }
    }

    /// The shared execution context (pool + phase-timing sink).
    pub fn ctx(&self) -> &Arc<ExecutionCtx> {
        &self.ctx
    }

    pub fn worker_count(&self) -> usize {
        self.ctx.threads()
    }

    /// Run the §5 protocol: one repetition per seed, aggregated.
    /// Deterministic for a given (graph, config, seeds) regardless of
    /// the worker count: each job depends only on its seed, and the
    /// results are collected in seed order.
    ///
    /// Every job runs on this coordinator's shared context — repetitions
    /// fan out across the pool, and the jobs' own parallel phases
    /// re-enter it inline (util::pool re-entrancy), so the configured
    /// worker cap bounds the whole batch. `config.threads` is not
    /// consulted here: one pool serves every nesting level. (The old
    /// nested-pool guard — `threads = 0 ⇒ 1` inside jobs, bounded
    /// oversubscription — is gone because there is no nested pool left
    /// to guard.)
    pub fn partition_repeated(
        &self,
        graph: Arc<Graph>,
        config: &PartitionConfig,
        seeds: &[u64],
    ) -> Aggregate {
        assert!(!seeds.is_empty());
        if seeds.len() == 1 {
            // Single repetition: run on the caller so the job's own
            // parallel phases fan out across the shared pool instead of
            // nesting inline behind a one-task job. Identical result
            // (thread-count invariance), better wall-clock.
            let run = run_repetition(&self.ctx, &graph, config, seeds[0]);
            return Aggregate::from_runs(vec![run]);
        }
        let runs: Vec<RunOutcome> = self.ctx.pool().map_indexed(seeds.len(), |_worker, i| {
            let seed = seeds[i];
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_repetition(&self.ctx, &graph, config, seed)
            }));
            match outcome {
                Ok(run) => run,
                Err(payload) => {
                    // Name the failing repetition so the operator can
                    // reproduce it directly, then let the pool's panic
                    // containment report the batch failure. Cooperative
                    // cancellation also travels as a panic payload
                    // (`util::cancel::Cancelled`) — that one is not a
                    // bug, so no stderr noise for it.
                    if payload
                        .downcast_ref::<crate::util::cancel::Cancelled>()
                        .is_none()
                    {
                        eprintln!("sclap coordinator: repetition seed={seed} panicked");
                    }
                    std::panic::resume_unwind(payload)
                }
            }
        });
        Aggregate::from_runs(runs)
    }

    /// Partition a stored (possibly out-of-core) graph on this
    /// coordinator's shared context — the service entry point for
    /// instances behind a `GraphStore` (on-disk shard directories, or
    /// in-memory graphs under a memory budget). Routed through
    /// `partitioning::external::partition_store_with_ctx`, so the
    /// budget switch, streaming coarsening/refinement, and the ordinary
    /// pipeline all share this coordinator's one pool.
    pub fn partition_store(
        &self,
        store: &dyn crate::graph::store::GraphStore,
        config: &PartitionConfig,
        seed: u64,
    ) -> std::io::Result<crate::partitioning::external::OutOfCoreResult> {
        crate::partitioning::external::partition_store_with_ctx(store, config, seed, &self.ctx)
    }

    /// Convenience: a single run.
    pub fn partition_once(
        &self,
        graph: Arc<Graph>,
        config: &PartitionConfig,
        seed: u64,
    ) -> RunOutcome {
        self.partition_repeated(graph, config, &[seed])
            .runs
            .into_iter()
            .next()
            .expect("one run")
    }
}

/// The default seed set for the §5 protocol (10 repetitions).
pub fn default_seeds(n: usize) -> Vec<u64> {
    (1..=n as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate_club;
    use crate::partitioning::config::{PartitionConfig, Preset};

    #[test]
    fn repeated_runs_aggregate() {
        let g = Arc::new(karate_club());
        let coord = Coordinator::new(2);
        let config = PartitionConfig::preset(Preset::CFast, 2);
        let agg = coord.partition_repeated(g.clone(), &config, &default_seeds(5));
        assert_eq!(agg.runs.len(), 5);
        assert!(agg.best_cut as f64 <= agg.avg_cut);
        assert!(agg.avg_seconds > 0.0);
        assert_eq!(agg.best_blocks.len(), g.n());
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let g = Arc::new(karate_club());
        let config = PartitionConfig::preset(Preset::CEco, 4);
        let run = |workers| {
            let coord = Coordinator::new(workers);
            let agg = coord.partition_repeated(g.clone(), &config, &default_seeds(4));
            agg.runs.iter().map(|r| (r.seed, r.cut)).collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn single_run_matches_direct_call() {
        let g = Arc::new(karate_club());
        let coord = Coordinator::new(1);
        let config = PartitionConfig::preset(Preset::CFast, 2);
        let via_service = coord.partition_once(g.clone(), &config, 7);
        let direct = MultilevelPartitioner::new(config).partition(&g, 7);
        assert_eq!(via_service.cut, direct.metrics.cut);
        assert_eq!(via_service.blocks, direct.partition.blocks);
    }

    #[test]
    fn partition_store_routes_through_the_shared_pool() {
        use crate::graph::store::InMemoryStore;
        let g = karate_club();
        let coord = Coordinator::new(2);
        let config = PartitionConfig::preset(Preset::CFast, 2);
        let store = InMemoryStore::new(&g);
        let via_store = coord.partition_store(&store, &config, 7).unwrap();
        let direct = coord.partition_once(Arc::new(g.clone()), &config, 7);
        // No budget: identical to the ordinary pipeline.
        assert_eq!(via_store.blocks, direct.blocks);
        assert_eq!(via_store.cut, direct.cut);
        assert_eq!(via_store.external_levels, 0);
    }

    #[test]
    fn drop_joins_workers() {
        let coord = Coordinator::new(3);
        assert_eq!(coord.worker_count(), 3);
        drop(coord); // must not hang
    }

    #[test]
    fn panicking_job_does_not_poison_the_pool() {
        let g = Arc::new(karate_club());
        let coord = Coordinator::new(2);
        // k = 0 violates the partitioner's precondition and panics
        // inside the job; the batch must report it...
        let mut bad = PartitionConfig::preset(Preset::CFast, 2);
        bad.k = 0;
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            coord.partition_repeated(g.clone(), &bad, &default_seeds(3))
        }));
        assert!(r.is_err(), "bad config must surface as a panic");
        // ...and the coordinator must keep serving later batches.
        let good = PartitionConfig::preset(Preset::CFast, 2);
        let agg = coord.partition_repeated(g.clone(), &good, &default_seeds(3));
        assert_eq!(agg.runs.len(), 3);
    }
}
