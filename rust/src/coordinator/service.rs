//! The partitioning service coordinator.
//!
//! The paper's evaluation protocol (§5: "we perform ten repetitions for
//! each configuration of the algorithm and report the arithmetic
//! average of computed cut size, running time and the best cut found")
//! is a first-class L3 feature here: a worker pool executes repetition
//! jobs in parallel, the coordinator aggregates average/best/geomean and
//! retains the best partition. The bench harness and the CLI both sit
//! on top of this service.
//!
//! Implementation: std threads + mpsc channels (tokio is not available
//! offline — DESIGN.md §3). Jobs are deterministic per seed regardless
//! of worker count or scheduling (invariant 6, DESIGN.md §7).

use crate::graph::csr::{Graph, Weight};
use crate::partitioning::config::PartitionConfig;
use crate::partitioning::multilevel::{MultilevelPartitioner, PartitionResult};
use crate::util::timer::Stats;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One repetition outcome (a trimmed [`PartitionResult`]).
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub seed: u64,
    pub cut: Weight,
    pub seconds: f64,
    pub imbalance: f64,
    pub feasible: bool,
    pub initial_cut: Weight,
    pub levels: usize,
    pub coarsest_n: usize,
    pub blocks: Vec<u32>,
}

impl RunOutcome {
    fn from_result(seed: u64, r: &PartitionResult) -> Self {
        RunOutcome {
            seed,
            cut: r.metrics.cut,
            seconds: r.seconds,
            imbalance: r.metrics.imbalance,
            feasible: r.metrics.feasible,
            initial_cut: r.initial_cut,
            levels: r.levels,
            coarsest_n: r.coarsest_n,
            blocks: r.partition.blocks.clone(),
        }
    }
}

/// Aggregate over the repetitions of one (instance, config, k) cell —
/// exactly the numbers Table 2 / Table 3 report.
#[derive(Debug, Clone)]
pub struct Aggregate {
    pub runs: Vec<RunOutcome>,
    pub avg_cut: f64,
    pub best_cut: Weight,
    pub avg_seconds: f64,
    pub avg_initial_cut: f64,
    pub infeasible_runs: usize,
    /// Blocks of the best run.
    pub best_blocks: Vec<u32>,
}

impl Aggregate {
    pub fn from_runs(mut runs: Vec<RunOutcome>) -> Aggregate {
        assert!(!runs.is_empty());
        runs.sort_by_key(|r| r.seed); // deterministic order
        let mut cut = Stats::new();
        let mut secs = Stats::new();
        let mut init = Stats::new();
        for r in &runs {
            cut.add(r.cut as f64);
            secs.add(r.seconds);
            init.add(r.initial_cut as f64);
        }
        let best = runs
            .iter()
            .min_by_key(|r| r.cut)
            .expect("non-empty runs");
        Aggregate {
            avg_cut: cut.mean(),
            best_cut: best.cut,
            avg_seconds: secs.mean(),
            avg_initial_cut: init.mean(),
            infeasible_runs: runs.iter().filter(|r| !r.feasible).count(),
            best_blocks: best.blocks.clone(),
            runs,
        }
    }
}

/// A work item: one partitioning repetition.
struct Job {
    graph: Arc<Graph>,
    config: PartitionConfig,
    seed: u64,
    reply: Sender<RunOutcome>,
}

/// Long-lived worker pool executing partition jobs.
pub struct Coordinator {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    worker_count: usize,
}

impl Coordinator {
    /// Spawn `workers` threads (0 ⇒ available parallelism).
    pub fn new(workers: usize) -> Self {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
        } else {
            workers
        };
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("sclap-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("rx poisoned");
                            guard.recv()
                        };
                        let Ok(job) = job else { break };
                        // Failure containment: a panicking job must not
                        // take the worker (and every queued job) down.
                        let seed = job.seed;
                        let outcome = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                let partitioner = MultilevelPartitioner::new(job.config);
                                let result = partitioner.partition(&job.graph, seed);
                                RunOutcome::from_result(seed, &result)
                            }),
                        );
                        match outcome {
                            // Receiver may have hung up (caller gave up)
                            // — that's fine, drop the result.
                            Ok(out) => {
                                let _ = job.reply.send(out);
                            }
                            Err(_) => {
                                eprintln!("sclap-worker-{i}: job seed={seed} panicked");
                                // reply sender dropped ⇒ the aggregator's
                                // count check reports the missing run.
                            }
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Coordinator {
            tx: Some(tx),
            workers: handles,
            worker_count: workers,
        }
    }

    pub fn worker_count(&self) -> usize {
        self.worker_count
    }

    /// Run the §5 protocol: one repetition per seed, aggregated.
    pub fn partition_repeated(
        &self,
        graph: Arc<Graph>,
        config: &PartitionConfig,
        seeds: &[u64],
    ) -> Aggregate {
        assert!(!seeds.is_empty());
        let (reply_tx, reply_rx): (Sender<RunOutcome>, Receiver<RunOutcome>) = channel();
        for &seed in seeds {
            self.tx
                .as_ref()
                .expect("coordinator alive")
                .send(Job {
                    graph: graph.clone(),
                    config: config.clone(),
                    seed,
                    reply: reply_tx.clone(),
                })
                .expect("workers alive");
        }
        drop(reply_tx);
        let runs: Vec<RunOutcome> = reply_rx.iter().collect();
        assert_eq!(runs.len(), seeds.len(), "every job must report");
        Aggregate::from_runs(runs)
    }

    /// Convenience: a single run.
    pub fn partition_once(
        &self,
        graph: Arc<Graph>,
        config: &PartitionConfig,
        seed: u64,
    ) -> RunOutcome {
        self.partition_repeated(graph, config, &[seed])
            .runs
            .into_iter()
            .next()
            .expect("one run")
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers drain and exit
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// The default seed set for the §5 protocol (10 repetitions).
pub fn default_seeds(n: usize) -> Vec<u64> {
    (1..=n as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate_club;
    use crate::partitioning::config::{PartitionConfig, Preset};

    #[test]
    fn repeated_runs_aggregate() {
        let g = Arc::new(karate_club());
        let coord = Coordinator::new(2);
        let config = PartitionConfig::preset(Preset::CFast, 2);
        let agg = coord.partition_repeated(g.clone(), &config, &default_seeds(5));
        assert_eq!(agg.runs.len(), 5);
        assert!(agg.best_cut as f64 <= agg.avg_cut);
        assert!(agg.avg_seconds > 0.0);
        assert_eq!(agg.best_blocks.len(), g.n());
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let g = Arc::new(karate_club());
        let config = PartitionConfig::preset(Preset::CEco, 4);
        let run = |workers| {
            let coord = Coordinator::new(workers);
            let agg = coord.partition_repeated(g.clone(), &config, &default_seeds(4));
            agg.runs.iter().map(|r| (r.seed, r.cut)).collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn single_run_matches_direct_call() {
        let g = Arc::new(karate_club());
        let coord = Coordinator::new(1);
        let config = PartitionConfig::preset(Preset::CFast, 2);
        let via_service = coord.partition_once(g.clone(), &config, 7);
        let direct = MultilevelPartitioner::new(config).partition(&g, 7);
        assert_eq!(via_service.cut, direct.metrics.cut);
        assert_eq!(via_service.blocks, direct.partition.blocks);
    }

    #[test]
    fn drop_joins_workers() {
        let coord = Coordinator::new(3);
        assert_eq!(coord.worker_count(), 3);
        drop(coord); // must not hang
    }
}
