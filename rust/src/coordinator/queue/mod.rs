//! The batching service front end on the coordinator: a multi-producer
//! request queue in front of [`Coordinator::partition_repeated`] /
//! [`Coordinator::partition_store`]-shaped work, batching **individual
//! repetitions** from many requests onto the one shared
//! [`ExecutionCtx`] pool.
//!
//! [`Coordinator`]: crate::coordinator::service::Coordinator
//!
//! # Model
//!
//! A [`Request`] is (graph handle, [`PartitionConfig`], seeds, reply
//! channel): the graph handle is either an in-memory [`Arc<Graph>`] or
//! an on-disk shard directory — the semi-external design means both
//! flow through the same queue and the same scheduler. Producers call
//! [`BatchService::submit`] (blocks while the queue is full) or
//! [`BatchService::try_submit`] (returns [`SubmitError::Busy`]) from
//! any number of threads and get back a [`Ticket`] to wait on.
//!
//! A scheduler thread drains the queue and fans out *repetitions*, not
//! whole requests: each scheduling wave interleaves one repetition per
//! active request round-robin until the wave is pool-sized, and the
//! round-robin start rotates every wave, so a 1-seed request submitted
//! next to a 10-seed request rides an early wave instead of queueing
//! behind all ten repetitions — even when the wave is narrower than
//! the active request count (e.g. one worker). Results are reassembled
//! per request in seed order.
//!
//! # Determinism
//!
//! Every repetition is a pure function of (graph, config, seed) — the
//! crate-wide thread-count-invariance contract — so the same request
//! produces an [`Aggregate`] whose deterministic fields (runs, cuts,
//! blocks, aggregates) are byte-identical for **any worker count, any
//! submission order, and any interleaving with other requests**; only
//! the wall-clock `seconds`/`avg_seconds` fields vary
//! (`rust/tests/batch_queue.rs`).
//!
//! # Backpressure and shutdown
//!
//! The queue is bounded by [`ServiceConfig::max_pending`]: `submit`
//! blocks until a slot frees, `try_submit` reports `Busy`. Dropping
//! (or explicitly [`BatchService::shutdown`]-ing) the service is
//! graceful: already-accepted requests are drained to completion and
//! their tickets resolve; new submissions are refused with
//! [`SubmitError::ShutDown`]. A panicking repetition (e.g. an invalid
//! config) fails only its own request — the service, its pool, and
//! every other request keep going.

mod scheduler;
pub mod spec;

use crate::coordinator::service::Aggregate;
use crate::graph::csr::Graph;
use crate::partitioning::config::PartitionConfig;
use crate::util::cancel::{CancelReason, CancelToken};
use crate::util::exec::ExecutionCtx;
use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads of the one shared pool (0 = available
    /// parallelism) — the process-wide cap, exactly like
    /// [`Coordinator::new`](crate::coordinator::service::Coordinator::new).
    pub workers: usize,
    /// Bound on accepted-but-not-yet-scheduled requests; at the bound,
    /// [`BatchService::submit`] blocks and
    /// [`BatchService::try_submit`] returns [`SubmitError::Busy`].
    /// Clamped to at least 1.
    pub max_pending: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 0,
            max_pending: 16,
        }
    }
}

/// Where a request's topology lives. Both kinds flow through the same
/// queue; shard directories are opened by the scheduler on activation.
#[derive(Debug, Clone)]
pub enum GraphHandle {
    /// An in-memory graph, shared with the submitter.
    InMemory(Arc<Graph>),
    /// An on-disk shard directory (see [`crate::graph::store`]);
    /// partitioned through the out-of-core driver under the request
    /// config's memory budget.
    Shards(PathBuf),
}

/// One named configuration competing in a [`Request::race`]: the
/// scheduler runs every entry on the request's first seed, keeps the
/// one with the lowest cut (ties broken by race-list order — never by
/// timing), and cancels the rest.
#[derive(Debug, Clone)]
pub struct RaceEntry {
    /// Display name — for spec-driven requests this is the preset
    /// name. Deliberately *not* echoed in the response: the winning
    /// aggregate renders byte-identically to running that config
    /// alone, and an extra field would break that invariant.
    pub name: String,
    pub config: PartitionConfig,
}

/// One unit of client work: partition `graph` once per seed under
/// `config`, aggregated exactly like
/// [`Coordinator::partition_repeated`](crate::coordinator::service::Coordinator::partition_repeated).
///
/// # Cancellation
///
/// Every request carries a [`CancelToken`]. The scheduler derives a
/// child token per repetition, so firing `cancel` (or arming
/// `timeout_ms`, or dropping the [`Ticket`] unwaited) cancels the
/// whole request: queued repetitions are never dispatched, running
/// ones exit at their next checkpoint, and the ticket resolves to a
/// [`RequestError`] with [`RequestError::cancelled`] set. A token that
/// never fires changes no result byte.
#[derive(Debug)]
pub struct Request {
    /// Client-chosen label, echoed in errors and the `serve` output.
    pub id: String,
    pub graph: GraphHandle,
    pub config: PartitionConfig,
    /// One repetition per seed; must be non-empty.
    pub seeds: Vec<u64>,
    /// End-to-end deadline in milliseconds, armed at submission (queue
    /// wait counts). `None` = no deadline.
    pub timeout_ms: Option<u64>,
    /// Ensemble race: when non-empty (two or more entries), the
    /// scheduler runs each entry's config on `seeds[0]`, picks the
    /// winner (lowest cut, race-order tie-break), completes the
    /// remaining seeds under the winning config only, and cancels the
    /// losers. `config` is the base the entries were derived from; the
    /// winner's config replaces it for the surviving repetitions. The
    /// winning aggregate is byte-identical to running that config
    /// alone.
    pub race: Vec<RaceEntry>,
    /// Cooperative cancellation root for this request (see above).
    pub cancel: CancelToken,
    /// Attach a quality explain report to the aggregate: the scheduler
    /// runs the request under a per-request [`Tracer`] and renders a
    /// [`QualityReport`] into [`Aggregate::explain`]. Observation-only
    /// — the partition bytes are identical with the flag on or off,
    /// for any worker count (`rust/tests/observability.rs`).
    ///
    /// [`Tracer`]: crate::obs::Tracer
    /// [`QualityReport`]: crate::obs::QualityReport
    pub explain: bool,
}

impl Request {
    /// A plain request: no deadline, no race, a fresh (unfired) cancel
    /// token.
    pub fn new(
        id: impl Into<String>,
        graph: GraphHandle,
        config: PartitionConfig,
        seeds: Vec<u64>,
    ) -> Self {
        Request {
            id: id.into(),
            graph,
            config,
            seeds,
            timeout_ms: None,
            race: Vec::new(),
            cancel: CancelToken::new(),
            explain: false,
        }
    }
}

impl Clone for Request {
    /// A clone is a *fresh submission* of the same work, not the same
    /// submission twice: it gets its own unfired token, so cancelling
    /// (or abandoning the ticket of) one never leaks into the other.
    fn clone(&self) -> Self {
        Request {
            id: self.id.clone(),
            graph: self.graph.clone(),
            config: self.config.clone(),
            seeds: self.seeds.clone(),
            timeout_ms: self.timeout_ms,
            race: self.race.clone(),
            cancel: CancelToken::new(),
            explain: self.explain,
        }
    }
}

/// Why a submission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at `max_pending` (only from
    /// [`BatchService::try_submit`]; `submit` blocks instead).
    Busy,
    /// The service is shutting down and accepts no new requests.
    ShutDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => write!(f, "service queue is full"),
            SubmitError::ShutDown => write!(f, "service is shutting down"),
        }
    }
}

/// A request that failed (bad config panicking in the partitioner, an
/// unopenable shard directory, I/O errors on the external path, ...)
/// or was cancelled ([`RequestError::cancelled`] set).
#[derive(Debug, Clone)]
pub struct RequestError {
    pub id: String,
    pub message: String,
    /// `Some(reason)` when the request was cancelled rather than
    /// failed: the wire layer renders `status=cancelled` instead of
    /// `status=error`, and nothing about the request is cached.
    pub cancelled: Option<CancelReason>,
}

impl RequestError {
    /// A plain (non-cancelled) failure.
    pub fn new(id: impl Into<String>, message: impl Into<String>) -> Self {
        RequestError {
            id: id.into(),
            message: message.into(),
            cancelled: None,
        }
    }

    /// A cancellation outcome.
    pub fn cancelled_with(id: impl Into<String>, reason: CancelReason) -> Self {
        RequestError {
            id: id.into(),
            message: format!("cancelled: {reason}"),
            cancelled: Some(reason),
        }
    }
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {:?}: {}", self.id, self.message)
    }
}

pub(crate) type Reply = Result<Aggregate, RequestError>;

/// Lifecycle callback invoked by the scheduler with `(event,
/// request_id)` — today only `"started"`, fired when a request is
/// activated (leaves the pending queue and its first repetitions are
/// eligible to run). The net layer uses it to journal scheduler-side
/// lifecycle transitions it cannot observe itself. Called on the
/// scheduler thread: implementations must be quick and must not call
/// back into the service.
pub type EventHook = Arc<dyn Fn(&str, &str) + Send + Sync>;

/// Handle to one submitted request's eventual result.
///
/// Dropping a ticket **without** calling [`Ticket::wait`] fires the
/// request's cancel token with [`CancelReason::Abandoned`]: nobody can
/// observe the result any more, so still-queued repetitions are
/// cancelled instead of silently computed (the scheduler reaps the
/// request as cancelled and its arena leases return). This is how
/// shutdown drains abandoned work and how the net server aborts the
/// requests of a disconnected client.
#[derive(Debug)]
pub struct Ticket {
    id: String,
    rx: mpsc::Receiver<Reply>,
    cancel: CancelToken,
    armed: bool,
}

impl Ticket {
    /// The request id this ticket belongs to.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// The request's cancel token (fire it to abort the request; safe
    /// to call at any time, before or after completion).
    pub fn cancel_token(&self) -> &CancelToken {
        &self.cancel
    }

    /// Block until the request completes (or fails, or is cancelled).
    /// Requests already accepted are always drained — even across
    /// service shutdown — so this resolves rather than hangs. Calling
    /// `wait` disarms the drop-abandon behaviour: the caller committed
    /// to observing the result.
    pub fn wait(mut self) -> Reply {
        self.armed = false;
        match self.rx.recv() {
            Ok(reply) => reply,
            // Scheduler gone without replying (it panicked — it never
            // drops a live request otherwise): surface, don't hang.
            Err(_) => Err(RequestError::new(
                self.id.clone(),
                "batching service terminated before the request completed",
            )),
        }
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        if self.armed {
            self.cancel.fire(CancelReason::Abandoned);
        }
    }
}

pub(crate) struct QueueState {
    pub(crate) pending: VecDeque<(Request, mpsc::Sender<Reply>)>,
    pub(crate) shutting_down: bool,
    /// While paused the scheduler activates nothing new (in-flight
    /// waves still finish); shutdown overrides pause for draining.
    pub(crate) paused: bool,
}

pub(crate) struct QueueShared {
    pub(crate) state: Mutex<QueueState>,
    /// Producers wait here for a queue slot.
    pub(crate) not_full: Condvar,
    /// The scheduler waits here for work (or shutdown/resume).
    pub(crate) not_empty: Condvar,
    pub(crate) max_pending: usize,
    /// Optional lifecycle hook (see [`EventHook`]).
    pub(crate) on_event: Option<EventHook>,
}

/// Poison-tolerant lock (a panicking repetition is contained inside the
/// scheduler; the queue mutex itself must survive any caller panic).
pub(crate) fn lock(m: &Mutex<QueueState>) -> MutexGuard<'_, QueueState> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// The batching service front end. See the module docs.
pub struct BatchService {
    shared: Arc<QueueShared>,
    ctx: Arc<ExecutionCtx>,
    scheduler: Option<JoinHandle<()>>,
}

impl BatchService {
    /// Service owning a fresh pool of `config.workers` threads.
    pub fn new(config: ServiceConfig) -> Self {
        let workers = config.workers;
        Self::with_ctx(config, Arc::new(ExecutionCtx::new(workers)))
    }

    /// Service on an existing shared execution context (the
    /// coordinator handoff: one process pool through every phase of
    /// every request).
    pub fn with_ctx(config: ServiceConfig, ctx: Arc<ExecutionCtx>) -> Self {
        Self::with_ctx_and_hook(config, ctx, None)
    }

    /// [`BatchService::with_ctx`] plus a scheduler lifecycle hook —
    /// how `serve --journal` records `started` events without the
    /// scheduler knowing about journals.
    pub fn with_ctx_and_hook(
        config: ServiceConfig,
        ctx: Arc<ExecutionCtx>,
        on_event: Option<EventHook>,
    ) -> Self {
        let shared = Arc::new(QueueShared {
            state: Mutex::new(QueueState {
                pending: VecDeque::new(),
                shutting_down: false,
                paused: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            max_pending: config.max_pending.max(1),
            on_event,
        });
        let scheduler = {
            let shared = shared.clone();
            let ctx = ctx.clone();
            std::thread::Builder::new()
                .name("sclap-batch-scheduler".to_string())
                .spawn(move || scheduler::scheduler_loop(&shared, &ctx))
                .expect("spawn batch scheduler")
        };
        BatchService {
            shared,
            ctx,
            scheduler: Some(scheduler),
        }
    }

    /// The shared execution context (pool + phase-timing sink).
    pub fn ctx(&self) -> &Arc<ExecutionCtx> {
        &self.ctx
    }

    /// Total worker count of the shared pool.
    pub fn worker_count(&self) -> usize {
        self.ctx.threads()
    }

    /// Enqueue a request, blocking while the bounded queue is at
    /// [`ServiceConfig::max_pending`].
    pub fn submit(&self, request: Request) -> Result<Ticket, SubmitError> {
        self.submit_inner(request, true)
    }

    /// Enqueue a request without blocking: [`SubmitError::Busy`] when
    /// the bounded queue is full.
    pub fn try_submit(&self, request: Request) -> Result<Ticket, SubmitError> {
        self.submit_inner(request, false)
    }

    fn submit_inner(&self, request: Request, block: bool) -> Result<Ticket, SubmitError> {
        let metrics = self.ctx.metrics();
        let (tx, rx) = mpsc::channel();
        let id = request.id.clone();
        // Arm the deadline before queueing: `timeout_ms` is an
        // end-to-end bound, so time spent waiting for a worker counts
        // against it.
        if let Some(ms) = request.timeout_ms {
            request
                .cancel
                .set_deadline(Instant::now() + Duration::from_millis(ms));
        }
        let cancel = request.cancel.clone();
        let wait_start = std::time::Instant::now();
        let mut waited = false;
        let mut st = lock(&self.shared.state);
        loop {
            if st.shutting_down {
                return Err(SubmitError::ShutDown);
            }
            if st.pending.len() < self.shared.max_pending {
                break;
            }
            if !block {
                metrics.counter("queue_busy_rejections").inc();
                return Err(SubmitError::Busy);
            }
            waited = true;
            st = self
                .shared
                .not_full
                .wait(st)
                .unwrap_or_else(|p| p.into_inner());
        }
        if waited {
            metrics
                .histogram("queue_wait_us")
                .observe(wait_start.elapsed().as_micros() as u64);
        }
        st.pending.push_back((request, tx));
        metrics.counter("queue_submitted").inc();
        metrics.gauge("queue_depth").set(st.pending.len() as i64);
        drop(st);
        self.shared.not_empty.notify_all();
        Ok(Ticket {
            id,
            rx,
            cancel,
            armed: true,
        })
    }

    /// Stop activating new requests (in-flight repetitions finish;
    /// accepted requests stay queued and producers keep hitting the
    /// backpressure bound). For maintenance windows — and for making
    /// backpressure deterministic in tests.
    pub fn pause(&self) {
        lock(&self.shared.state).paused = true;
    }

    /// Undo [`BatchService::pause`].
    pub fn resume(&self) {
        lock(&self.shared.state).paused = false;
        self.shared.not_empty.notify_all();
    }

    /// Graceful shutdown: refuse new submissions, drain every accepted
    /// request (their tickets resolve), then stop the scheduler.
    /// Dropping the service does the same.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for BatchService {
    fn drop(&mut self) {
        {
            let mut st = lock(&self.shared.state);
            st.shutting_down = true;
        }
        // Wake the scheduler (to drain and exit) and any blocked
        // producers (to observe ShutDown).
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        if let Some(handle) = self.scheduler.take() {
            let _ = handle.join();
        }
    }
}

impl std::fmt::Debug for BatchService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BatchService")
            .field("workers", &self.ctx.threads())
            .field("max_pending", &self.shared.max_pending)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::karate_club;
    use crate::partitioning::config::Preset;

    fn karate_request(id: &str, k: usize, seeds: Vec<u64>) -> Request {
        Request::new(
            id,
            GraphHandle::InMemory(Arc::new(karate_club())),
            PartitionConfig::preset(Preset::CFast, k),
            seeds,
        )
    }

    #[test]
    fn one_request_round_trips() {
        let service = BatchService::new(ServiceConfig {
            workers: 2,
            max_pending: 4,
        });
        let t = service.submit(karate_request("r1", 2, vec![1, 2, 3])).unwrap();
        assert_eq!(t.id(), "r1");
        let agg = t.wait().expect("request succeeds");
        assert_eq!(agg.runs.len(), 3);
        let seeds: Vec<u64> = agg.runs.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, vec![1, 2, 3]);
    }

    #[test]
    fn matches_serial_coordinator() {
        let g = Arc::new(karate_club());
        let config = PartitionConfig::preset(Preset::CFast, 2);
        let serial = crate::coordinator::service::Coordinator::new(2).partition_repeated(
            g.clone(),
            &config,
            &[5, 6, 7],
        );
        let service = BatchService::new(ServiceConfig::default());
        let agg = service
            .submit(Request::new(
                "x",
                GraphHandle::InMemory(g),
                config,
                vec![5, 6, 7],
            ))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(agg.best_cut, serial.best_cut);
        assert_eq!(agg.best_blocks, serial.best_blocks);
        for (a, b) in agg.runs.iter().zip(serial.runs.iter()) {
            assert_eq!((a.seed, a.cut, &a.blocks), (b.seed, b.cut, &b.blocks));
        }
    }

    #[test]
    fn empty_seed_list_fails_the_request_not_the_service() {
        let service = BatchService::new(ServiceConfig {
            workers: 1,
            max_pending: 4,
        });
        let bad = service.submit(karate_request("empty", 2, vec![])).unwrap();
        let err = bad.wait().unwrap_err();
        assert!(err.message.contains("no seeds"), "{err}");
        // service still serves
        let ok = service.submit(karate_request("ok", 2, vec![1])).unwrap();
        assert_eq!(ok.wait().unwrap().runs.len(), 1);
    }

    #[test]
    fn missing_shard_directory_fails_cleanly() {
        let service = BatchService::new(ServiceConfig::default());
        let t = service
            .submit(Request::new(
                "ghost",
                GraphHandle::Shards(PathBuf::from("/definitely/not/a/dir")),
                PartitionConfig::preset(Preset::CFast, 2),
                vec![1],
            ))
            .unwrap();
        let err = t.wait().unwrap_err();
        assert_eq!(err.id, "ghost");
        assert!(err.message.contains("shard"), "{err}");
    }

    #[test]
    fn submit_after_shutdown_refused() {
        let service = BatchService::new(ServiceConfig::default());
        let shared = service.shared.clone();
        service.shutdown();
        // the shared state is marked; a late producer holding a clone of
        // the front end would observe ShutDown (exercised through the
        // internal path since the public handle is consumed)
        assert!(lock(&shared.state).shutting_down);
    }
}
